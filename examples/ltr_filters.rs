//! The paper's §3 production case study: the Learning-to-Rank
//! search-filters pipeline (~60 chained transforms) served at the
//! production rate of 200 requests/second, comparing the MLeap-like
//! baseline against the compiled-graph service — the −61 % latency /
//! −58 % cost migration story.
//!
//! Requires `make artifacts`. Results recorded in EXPERIMENTS.md §C3/§C5.

use std::path::Path;

use kamae::serving::bench_serve;

fn main() -> kamae::error::Result<()> {
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("specs/ltr.json").exists() {
        eprintln!("run `make artifacts` first");
        return Ok(());
    }
    println!("=== LTR search-filters service (≈60-transform pipeline) ===\n");
    println!(
        "pipeline stages: {}",
        kamae::pipeline::catalog::ltr_stage_count()
    );

    println!("\n--- compiled graph (the paper's Keras/TF-Java replacement) @ 200 rps ---");
    let compiled = bench_serve(&artifacts, "ltr", 200, 10, "compiled")?;
    println!("{compiled}");

    println!("\n--- columnar interpreted (ablation) @ 200 rps ---");
    let interp = bench_serve(&artifacts, "ltr", 200, 10, "interpreted")?;
    println!("{interp}");

    println!("\n--- MLeap-like row interpreter @ 50 rps (cannot sustain 200) ---");
    let mleap = bench_serve(&artifacts, "ltr", 50, 10, "mleap")?;
    println!("{mleap}");

    println!("\n=== migration summary (paper: -61% latency, -58% cost) ===");
    println!(
        "latency p50:  mleap {:.2} ms -> compiled {:.2} ms  ({:+.0}%)",
        mleap.p50_ns / 1e6,
        compiled.p50_ns / 1e6,
        100.0 * (compiled.p50_ns / mleap.p50_ns - 1.0)
    );
    println!(
        "cost proxy :  mleap {:.3} -> compiled {:.3} cpu-s/1k req  ({:+.0}%)",
        mleap.cost_cpu_s_per_1k,
        compiled.cost_cpu_s_per_1k,
        100.0 * (compiled.cost_cpu_s_per_1k / mleap.cost_cpu_s_per_1k - 1.0)
    );
    Ok(())
}
