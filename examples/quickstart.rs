//! Quickstart: build → fit → transform → export → serve in ~60 lines.
//!
//! Run with: `cargo run --release --example quickstart`
//! (compiled-serving step needs `make artifacts` once).

use kamae::dataframe::{Column, DataFrame};
use kamae::engine::Dataset;
use kamae::pipeline::catalog;
use kamae::serving::load_backend;
use std::path::Path;

fn main() -> kamae::error::Result<()> {
    // 1. a small raw dataset: prices spanning decades + a categorical
    let df = DataFrame::new(vec![
        (
            "price".into(),
            Column::from_f64(vec![12.0, 95.0, 1_500.0, 7.5, 310.0, 42.0]),
        ),
        (
            "city".into(),
            Column::from_str(vec!["paris", "tokyo", "paris", "lima", "nyc", "tokyo"]),
        ),
    ])?;

    // 2. configure a pipeline (log1p -> standard scale; hash-index city)
    let pipeline = catalog::quickstart_pipeline();

    // 3. fit on a partitioned dataset (the "Spark" side)
    let model = pipeline.fit(&Dataset::from_dataframe(df.clone(), 2))?;

    // 4. offline transform
    let out = model.transform_df(df.clone())?;
    println!("offline transform:");
    for col in ["price_scaled", "city_indexed"] {
        println!("  {col}: {:?}", out.column(col)?);
    }

    // 5. export the GraphSpec (the `build_keras_model()` analogue)
    let spec = model.to_graph_spec(
        "quickstart_demo",
        catalog::quickstart_inputs(),
        &catalog::QUICKSTART_OUTPUTS,
    )?;
    println!(
        "\nexported spec: {} ingress ops, {} graph ops, {} graph inputs",
        spec.ingress.len(),
        spec.nodes.len(),
        spec.graph_inputs.len()
    );

    // 6. serve through the AOT-compiled artifact (built by `make artifacts`
    //    from the canonical quickstart spec)
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("specs/quickstart.json").exists() {
        let backend = load_backend(&artifacts, "quickstart", "compiled")?;
        let request = df.slice(0, 3);
        let tensors = backend.process(&request)?;
        println!("\ncompiled serving (PJRT, python-free):");
        for (name, t) in ["price_scaled", "city_indexed"].iter().zip(&tensors) {
            println!("  {name}: shape {:?} data {:?}", t.shape, t.data);
        }
    } else {
        println!("\n(skip compiled serving: run `make artifacts` first)");
    }
    Ok(())
}
