//! Streaming ingestion with backpressure: the offline engine as a
//! bounded-memory streaming orchestrator — micro-batches flow from a
//! generator through the fitted LTR pipeline on worker threads, with a
//! bounded queue capping in-flight batches regardless of consumer speed.

use kamae::engine::stream::{run_stream, StreamConfig};
use kamae::engine::Dataset;
use kamae::pipeline::catalog;
use kamae::synth;

fn main() -> kamae::error::Result<()> {
    println!("=== streaming ingest with backpressure ===\n");

    // fit once on a head sample (production: load a saved model)
    let head = synth::gen_ltr(&synth::LtrConfig { rows: 20_000, ..Default::default() });
    let model = catalog::ltr_pipeline().fit(&Dataset::from_dataframe(head, 4))?;
    println!("fitted {} pipeline stages", model.stages.len());

    let total_batches = 200usize;
    let batch_rows = 2_000usize;
    let mut produced = 0usize;
    let config = StreamConfig { workers: kamae::util::pool::default_threads(), queue_cap: 6 };
    println!(
        "streaming {total_batches} micro-batches x {batch_rows} rows \
         ({} workers, queue cap {})",
        config.workers, config.queue_cap
    );

    let t0 = std::time::Instant::now();
    let mut out_rows = 0usize;
    let stats = run_stream(
        &config,
        move || {
            if produced < total_batches {
                produced += 1;
                Some(synth::gen_ltr(&synth::LtrConfig {
                    rows: batch_rows,
                    seed: produced as u64,
                    ..Default::default()
                }))
            } else {
                None
            }
        },
        |batch| model.transform_df(batch),
        |_, df| {
            out_rows += df.num_columns() * 0 + df.num_rows();
            Ok(())
        },
    )?;
    let secs = t0.elapsed().as_secs_f64();

    println!("\nprocessed {} batches / {} rows in {secs:.2}s", stats.batches, stats.rows);
    println!(
        "throughput: {:.2} Mrows/s through the full ~60-transform pipeline",
        stats.rows as f64 / secs / 1e6
    );
    println!(
        "peak in-flight batches: {} (bound: {}) — memory stayed bounded",
        stats.peak_in_flight, config.queue_cap
    );
    assert!(stats.peak_in_flight <= config.queue_cap);
    Ok(())
}
