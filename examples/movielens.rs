//! **End-to-end driver** (the repository's full-system validation):
//! Listing 1's MovieLens pipeline on a real small workload, proving every
//! layer composes —
//!
//! 1. generate a 100k-row MovieLens-shaped dataset,
//! 2. fit the 5-stage pipeline distributed over worker threads (L3 engine),
//! 3. transform offline and report label statistics,
//! 4. export the GraphSpec and load the AOT-compiled HLO (L2 JAX / L1
//!    Pallas, built once by `make artifacts`),
//! 5. verify offline/online parity row-for-row on held-out requests
//!    (the paper's headline claim),
//! 6. serve batched requests through the PJRT backend and report
//!    latency/throughput.
//!
//! The run is recorded in EXPERIMENTS.md §L1.

use std::path::Path;

use kamae::baselines::mleap_like::column_to_tensor;
use kamae::engine::Dataset;
use kamae::pipeline::catalog;
use kamae::runtime::TensorData;
use kamae::serving::{bench_serve, load_backend, request_pool};
use kamae::synth;

fn main() -> kamae::error::Result<()> {
    let rows = 100_000;
    println!("=== MovieLens end-to-end (Listing 1) ===\n");

    // 1. data
    let t0 = std::time::Instant::now();
    let df = synth::gen_movielens(&synth::MovieLensConfig { rows, ..Default::default() });
    println!("[1] generated {rows} rows in {:?}", t0.elapsed());

    // 2. distributed fit
    let threads = kamae::util::pool::default_threads();
    let ds = Dataset::from_dataframe(df.clone(), threads * 2);
    let t0 = std::time::Instant::now();
    let model = catalog::movielens_pipeline().fit(&ds)?;
    println!(
        "[2] fitted {} stages on {} partitions ({} threads) in {:?}",
        model.stages.len(),
        ds.num_partitions(),
        threads,
        t0.elapsed()
    );

    // 3. offline transform
    let t0 = std::time::Instant::now();
    let out = model.transform(&ds)?.collect()?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "[3] offline transform: {rows} rows in {:.3}s ({:.2} Mrows/s)",
        secs,
        rows as f64 / secs / 1e6
    );
    let movie_idx = out.column("MovieID_indexed")?.as_i64()?;
    let max_idx = movie_idx.iter().max().unwrap();
    let genre = out.column("Genres_indexed")?.as_list_i64()?;
    println!(
        "    MovieID index space: 0..={max_idx}; Genres fixed width: {:?}",
        genre.fixed_width()
    );

    // 4. compiled artifact
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("specs/movielens.json").exists() {
        println!("\n(stopping early: run `make artifacts` for steps 4-6)");
        return Ok(());
    }
    let backend = load_backend(&artifacts, "movielens", "compiled")?;
    println!("[4] loaded compiled PJRT backend (buckets from artifacts)");

    // 5. parity on held-out requests (different seed: exercises OOV).
    //    Compare against the *deployed* model (the one the artifact was
    //    compiled from) — the freshly fitted model above has its own
    //    vocabulary ranks.
    let deployed =
        kamae::pipeline::PipelineModel::load(&artifacts.join("specs/movielens.model.json"))?;
    let requests = request_pool("movielens", 500)?;
    let engine_out = deployed.transform_df(requests.clone())?;
    let compiled_out = backend.process(&requests)?;
    let spec = kamae::export::GraphSpec::load(&artifacts.join("specs/movielens.json"))?;
    let mut checked = 0usize;
    for (i, out_name) in spec.outputs.iter().enumerate() {
        let col = out_name.strip_suffix("__out").unwrap_or(out_name);
        let engine_tensor = column_to_tensor(engine_out.column(col)?)?;
        match (&engine_tensor.data, &compiled_out[i].data) {
            (TensorData::I64(a), TensorData::I64(b)) => {
                assert_eq!(a, b, "parity violation in {col}");
                checked += a.len();
            }
            (TensorData::F32(a), TensorData::F32(b)) => {
                for (x, y) in a.iter().zip(b.iter()) {
                    assert!((x - y).abs() <= 1e-4 + y.abs() * 1e-4, "{col}: {x} vs {y}");
                }
                checked += a.len();
            }
            _ => panic!("dtype mismatch in {col}"),
        }
    }
    println!("[5] offline/online parity verified on {checked} values across 500 held-out rows");

    // 6. serving
    println!("[6] serving 200 req/s for 5s through the dynamic batcher:\n");
    let report = bench_serve(&artifacts, "movielens", 200, 5, "compiled")?;
    println!("{report}");
    Ok(())
}
