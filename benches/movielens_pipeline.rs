//! Experiment L1 — Listing 1 end-to-end: fit + transform cost of the
//! MovieLens pipeline, per stage and total, across partition counts.

use kamae::engine::Dataset;
use kamae::pipeline::catalog;
use kamae::synth;
use kamae::util::bench::{append_run, black_box, Bencher, Table};
use kamae::util::json::Json;

fn main() {
    let rows = 100_000;
    println!("L1: MovieLens pipeline (Listing 1) on {rows} synthetic rows\n");
    let df = synth::gen_movielens(&synth::MovieLensConfig { rows, ..Default::default() });

    // fit time vs partitions
    let mut table = Table::new(&["partitions", "fit ms", "transform Mrows/s"]);
    let mut records = Vec::new();
    for &parts in &[1usize, 2, 4, 8] {
        let ds = Dataset::from_dataframe(df.clone(), parts);
        let t0 = std::time::Instant::now();
        let model = catalog::movielens_pipeline().fit(&ds).unwrap();
        let fit_ms = t0.elapsed().as_millis();
        let st = Bencher::quick().run("transform", || {
            black_box(model.transform(&ds).unwrap());
        });
        table.row(&[
            parts.to_string(),
            fit_ms.to_string(),
            format!("{:.2}", st.throughput(rows as f64) / 1e6),
        ]);
        let mut rec = Json::object();
        rec.set("partitions", parts);
        rec.set("fit_ms", fit_ms as i64);
        rec.set("transform_mrows_s", st.throughput(rows as f64) / 1e6);
        records.push(rec);
    }
    table.print();

    // per-stage timing at 1 partition
    println!("\nper-stage transform cost:");
    let model = catalog::movielens_pipeline()
        .fit(&Dataset::from_dataframe(df.clone(), 1))
        .unwrap();
    let mut stage_table = Table::new(&["stage", "type", "ms/100k rows"]);
    let mut current = df.clone();
    for stage in &model.stages {
        let st = Bencher::quick().run(stage.layer_name(), || {
            let mut d = current.clone();
            stage.transform(&mut d).unwrap();
            black_box(d);
        });
        stage_table.row(&[
            stage.layer_name().to_string(),
            stage.type_name().to_string(),
            format!("{:.2}", st.mean_ns / 1e6),
        ]);
        let mut rec = st.to_json();
        rec.set("stage", stage.layer_name());
        rec.set("type", stage.type_name());
        records.push(rec);
        stage.transform(&mut current).unwrap();
    }
    stage_table.print();
    let path = append_run("movielens_pipeline", &[("rows", Json::Int(rows as i64))], records)
        .expect("bench trajectory");
    println!("\nappended run to {}", path.display());
}
