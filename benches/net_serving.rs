//! Network serving benchmark — the gate for the HTTP/1.1 front-end:
//! the listener + bounded admission window over the shared worker pool.
//!
//! No artifacts needed: the LTR pipeline is fitted in-process and merged
//! exactly like `benches/worker_pool.rs`, then served by a real
//! `NetServer` on an ephemeral loopback port and driven with CLOSED-loop
//! keep-alive HTTP clients (each client has one request in flight, the
//! wire analogue of the pool bench's bounded window) in three phases:
//!
//! * **pin**        — sampled requests over the wire must come back
//!   bit-identical to dedicated single-variant backends (the PR 4/5
//!   routing property re-asserted through JSON encode/decode);
//! * **saturation** — a wide admission window (`64`, nothing sheds):
//!   measures the front-end's saturated throughput `sat_rps`;
//! * **overload**   — a deliberately narrow window (`2`) under the same
//!   client fleet: most requests MUST shed. Sheds must be `429` with a
//!   `Retry-After` header, accepted responses stay bit-identical, and
//!   `/metrics` must report the exact shed count, the admission limit,
//!   and one per-client entry per driver thread.
//!
//! Every run appends machine-readable records to
//! `BENCH_net_serving.json` (both phases' serve reports + a summary).
//!
//! Flags (also settable via env for CI):
//!   --quick / KAMAE_BENCH_QUICK   reduced fit rows + request count
//!   --gate  / KAMAE_BENCH_GATE    exit non-zero unless the overload
//!                                 phase sheds, offered load reaches
//!                                 2x sat_rps, and shed p99 latency is
//!                                 at least 10x below accepted p99

use std::sync::Mutex;
use std::time::Instant;

use kamae::dataframe::{DataFrame, Value};
use kamae::engine::Dataset;
use kamae::export::GraphSpec;
use kamae::optim::{optimize, OptimizeLevel};
use kamae::pipeline::catalog;
use kamae::runtime::Tensor;
use kamae::serving::{
    request_pool, tensor_from_json, Backend, BatchConfig, InterpretedBackend, NetClient,
    NetConfig, NetResponse, NetServer,
};
use kamae::util::bench::{append_run, percentile, Table};
use kamae::util::json::Json;
use kamae::util::prop::tensors_bit_identical;
use kamae::util::rng::Rng;

const CLIENTS: usize = 8;
const ROWS_PER_REQUEST: usize = 8;
const SERVER_WORKERS: usize = 2;
/// Wide window for the saturation phase: with 8 closed-loop clients the
/// in-flight count can never reach it, so nothing sheds.
const SAT_ADMISSION: usize = 64;
/// Narrow window for the overload phase: 8 clients against 2 slots, so
/// most requests MUST shed.
const OVERLOAD_ADMISSION: usize = 2;
/// Wire requests replayed against dedicated backends before any timing.
const PIN_REQUESTS: usize = 64;
/// Accepted responses each overload client re-verifies against the
/// oracle (bounded so verification cost does not distort offered load).
const OVERLOAD_COMPARES: usize = 16;

/// One pre-built HTTP request: the JSON body that goes over the wire
/// plus the source frame + variant for oracle replay.
struct Req {
    body: String,
    df: DataFrame,
    variant: &'static str,
}

/// Fit LTR once and export the specs: merged (served) + dedicated
/// oracles for the differential pin.
fn build_specs(fit_rows: usize) -> (GraphSpec, GraphSpec, GraphSpec) {
    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig {
        rows: fit_rows,
        ..Default::default()
    });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let (full, _) = model
        .to_graph_spec_opt("ltr", catalog::ltr_inputs(), &catalog::LTR_OUTPUTS, OptimizeLevel::Full)
        .unwrap();
    let (lite, _) = model
        .to_graph_spec_opt(
            "ltr_lite",
            catalog::ltr_inputs(),
            &catalog::LTR_LITE_OUTPUTS,
            OptimizeLevel::Full,
        )
        .unwrap();
    let merged = GraphSpec::merge_variants("ltr+ltr_lite", &[&full, &lite]).unwrap();
    let (merged, _) = optimize(merged, OptimizeLevel::Full).unwrap();
    (full, lite, merged)
}

fn value_to_json(v: Value) -> Json {
    match v {
        Value::Null => Json::Null,
        Value::Bool(b) => Json::Bool(b),
        Value::I64(x) => Json::Int(x),
        Value::F64(x) => Json::Float(x),
        Value::Str(s) => Json::Str(s),
        Value::List(vs) => Json::Array(vs.into_iter().map(value_to_json).collect()),
    }
}

/// Encode a request frame as the listener's wire format:
/// `{"variant": ..., "rows": [{col: cell, ...}, ...]}`.
fn request_body(df: &DataFrame, variant: &str) -> String {
    let rows: Vec<Json> = (0..df.num_rows())
        .map(|i| {
            let mut row = Json::object();
            for (name, col) in df.iter() {
                row.set(name, value_to_json(col.value(i)));
            }
            row
        })
        .collect();
    let mut j = Json::object();
    j.set("variant", variant);
    j.set("rows", Json::Array(rows));
    j.to_string()
}

/// Pre-built request streams: one per client thread, round-robin variant
/// tags, built once up front (JSON encoding is not what this bench
/// measures).
fn build_streams(pool: &DataFrame, clients: usize, per_client: usize) -> Vec<Vec<Req>> {
    let mut rng = Rng::new(0xBEEF);
    (0..clients)
        .map(|_| {
            (0..per_client)
                .map(|i| {
                    let start =
                        rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
                    let variant = if i % 2 == 0 { "ltr" } else { "ltr_lite" };
                    let df = pool.slice(start, ROWS_PER_REQUEST);
                    let body = request_body(&df, variant);
                    Req { body, df, variant }
                })
                .collect()
        })
        .collect()
}

fn bind_server(merged: &GraphSpec, admission: usize) -> NetServer {
    let backend: std::sync::Arc<dyn Backend> =
        std::sync::Arc::new(InterpretedBackend::new(merged.clone()));
    NetServer::bind(
        backend,
        "127.0.0.1:0",
        NetConfig {
            batch: BatchConfig { workers: SERVER_WORKERS, ..BatchConfig::default() },
            admission,
            ..NetConfig::default()
        },
    )
    .unwrap()
}

fn decode_outputs(resp: &NetResponse) -> Vec<Tensor> {
    resp.json()
        .unwrap()
        .get("outputs")
        .and_then(Json::as_array)
        .expect("response has an 'outputs' array")
        .iter()
        .map(|o| tensor_from_json(o).unwrap())
        .collect()
}

fn fetch_metrics(addr: &str) -> Json {
    let mut client = NetClient::connect(addr).unwrap();
    let resp = client.request("GET", "/metrics", &[], "").unwrap();
    assert_eq!(resp.status, 200, "metrics: {}", resp.body);
    resp.json().unwrap()
}

struct PhaseOutcome {
    wall_secs: f64,
    accepted_ns: Vec<f64>,
    shed_ns: Vec<f64>,
}

/// Closed-loop HTTP driver: one keep-alive client per stream, one
/// request in flight per client. 200s land in `accepted_ns`, 429s (which
/// must carry `Retry-After`) in `shed_ns`; anything else panics. The
/// first `compare_per_client` accepted responses per client are replayed
/// against the dedicated oracle backends bit-for-bit.
fn drive_http(
    addr: &str,
    streams: &[Vec<Req>],
    full: &InterpretedBackend,
    lite: &InterpretedBackend,
    compare_per_client: usize,
) -> PhaseOutcome {
    let accepted = Mutex::new(Vec::new());
    let shed = Mutex::new(Vec::new());
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for (c, stream) in streams.iter().enumerate() {
            let accepted = &accepted;
            let shed = &shed;
            scope.spawn(move || {
                let client_id = format!("client-{c}");
                let mut client = NetClient::connect(addr).unwrap();
                let mut acc = Vec::new();
                let mut sh = Vec::new();
                let mut compared = 0usize;
                for req in stream {
                    let sent = Instant::now();
                    let resp = client
                        .request("POST", "/v1/infer", &[("x-kamae-client", &client_id)], &req.body)
                        .unwrap();
                    let ns = sent.elapsed().as_nanos() as f64;
                    match resp.status {
                        200 => {
                            acc.push(ns);
                            if compared < compare_per_client {
                                compared += 1;
                                let got = decode_outputs(&resp);
                                let want = if req.variant == "ltr" {
                                    full.process(&req.df).unwrap()
                                } else {
                                    lite.process(&req.df).unwrap()
                                };
                                if let Err(e) = tensors_bit_identical(&got, &want) {
                                    panic!("{} wire-vs-dedicated under load: {e}", req.variant);
                                }
                            }
                        }
                        429 => {
                            assert!(
                                resp.header("retry-after").is_some(),
                                "429 shed without a Retry-After header"
                            );
                            sh.push(ns);
                        }
                        other => panic!("unexpected status {other}: {}", resp.body),
                    }
                    if resp.closed {
                        client = NetClient::connect(addr).unwrap();
                    }
                }
                accepted.lock().unwrap().extend(acc);
                shed.lock().unwrap().extend(sh);
            });
        }
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    PhaseOutcome {
        wall_secs,
        accepted_ns: accepted.into_inner().unwrap(),
        shed_ns: shed.into_inner().unwrap(),
    }
}

/// p99 in milliseconds; 0.0 on an empty sample (the gates catch the
/// empty case separately, and `append_run` rejects non-finite values).
fn p99_ms(samples: &mut [f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(samples, 99.0) / 1e6
}

/// Env flag: set and not "0"/"false"/"" (so KAMAE_BENCH_GATE=0 disables).
fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
        .unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || env_flag("KAMAE_BENCH_QUICK");
    let gate = args.iter().any(|a| a == "--gate") || env_flag("KAMAE_BENCH_GATE");
    let (fit_rows, sat_per_client, overload_per_client) =
        if quick { (2_000, 250, 200) } else { (12_000, 1_200, 800) };
    if quick {
        println!("(quick mode: {fit_rows} fit rows, {sat_per_client} requests/client)\n");
    }

    let (full, lite, merged) = build_specs(fit_rows);
    println!(
        "merged ltr+ltr_lite: {} ingress + {} graph nodes, {} outputs",
        merged.ingress.len(),
        merged.nodes.len(),
        merged.outputs.len()
    );
    let pool_df = request_pool("ltr", 4096).unwrap();
    let sat_streams = build_streams(&pool_df, CLIENTS, sat_per_client);
    let overload_streams = build_streams(&pool_df, CLIENTS, overload_per_client);
    let full_backend = InterpretedBackend::new(full.clone());
    let lite_backend = InterpretedBackend::new(lite.clone());

    // ---- differential pin: routed inference over the wire must be
    // bit-identical to dedicated single-variant backends, BEFORE any
    // throughput measurement ------------------------------------------------
    {
        let server = bind_server(&merged, SAT_ADMISSION);
        let addr = server.addr().to_string();
        let mut client = NetClient::connect(&addr).unwrap();
        let health = client.request("GET", "/healthz", &[], "").unwrap();
        assert_eq!(health.status, 200, "healthz: {}", health.body);
        for req in sat_streams.iter().flatten().take(PIN_REQUESTS) {
            let resp = client
                .request("POST", "/v1/infer", &[("x-kamae-client", "pin")], &req.body)
                .unwrap();
            assert_eq!(resp.status, 200, "infer over the wire: {}", resp.body);
            let got = decode_outputs(&resp);
            let want = if req.variant == "ltr" {
                full_backend.process(&req.df).unwrap()
            } else {
                lite_backend.process(&req.df).unwrap()
            };
            if let Err(e) = tensors_bit_identical(&got, &want) {
                panic!("{} wire-vs-dedicated: {e}", req.variant);
            }
        }
        server.shutdown();
        println!(
            "differential pin: HTTP routed == dedicated backends, bit for bit \
             ({PIN_REQUESTS} requests)\n"
        );
    }

    let mut records = Vec::new();

    // ---- saturation: wide admission window, nothing sheds -----------------
    let (sat_rps, sat_p99_ms) = {
        let server = bind_server(&merged, SAT_ADMISSION);
        let addr = server.addr().to_string();
        let mut out = drive_http(&addr, &sat_streams, &full_backend, &lite_backend, 0);
        let metrics = fetch_metrics(&addr);
        server.shutdown();
        assert!(
            out.shed_ns.is_empty(),
            "saturation phase shed {} requests under a {SAT_ADMISSION}-wide window",
            out.shed_ns.len()
        );
        let total = CLIENTS * sat_per_client;
        assert_eq!(out.accepted_ns.len(), total, "saturation phase lost requests");
        let rps = total as f64 / out.wall_secs;
        let p99 = p99_ms(&mut out.accepted_ns);
        println!(
            "saturation: {total} requests, {rps:.0} req/s over {CLIENTS} clients, \
             accepted p99 {p99:.3} ms"
        );
        records.push(metrics.get("serve_report").cloned().expect("serve_report in metrics"));
        (rps, p99)
    };

    // ---- overload: narrow window, most requests must shed -----------------
    let server = bind_server(&merged, OVERLOAD_ADMISSION);
    let addr = server.addr().to_string();
    let mut out =
        drive_http(&addr, &overload_streams, &full_backend, &lite_backend, OVERLOAD_COMPARES);
    let metrics = fetch_metrics(&addr);
    server.shutdown();
    let accepted_count = out.accepted_ns.len();
    let shed_count = out.shed_ns.len();
    let total = CLIENTS * overload_per_client;
    assert_eq!(accepted_count + shed_count, total, "overload phase lost requests");
    let offered_rps = total as f64 / out.wall_secs;
    let accepted_p99_ms = p99_ms(&mut out.accepted_ns);
    let shed_p99_ms = p99_ms(&mut out.shed_ns);
    println!(
        "overload:   {total} offered at {offered_rps:.0} req/s -> {accepted_count} accepted, \
         {shed_count} shed (429 + Retry-After)"
    );

    // the listener's own accounting must agree with what the clients saw
    let report = metrics.get("serve_report").cloned().expect("serve_report in metrics");
    assert_eq!(
        report.get("shed_requests").and_then(Json::as_i64).unwrap_or(0),
        shed_count as i64,
        "/metrics shed_requests disagrees with observed 429 count"
    );
    assert_eq!(
        report.get("admission_limit").and_then(Json::as_i64).unwrap_or(0),
        OVERLOAD_ADMISSION as i64,
        "/metrics admission_limit"
    );
    let clients_seen = metrics
        .get("clients")
        .and_then(Json::as_object)
        .map(|c| c.len())
        .unwrap_or(0);
    assert_eq!(clients_seen, CLIENTS, "/metrics per-client counter entries");
    records.push(report);

    let mut table = Table::new(&["phase", "requests", "rate", "p99"]);
    table.row(&[
        "saturation".into(),
        (CLIENTS * sat_per_client).to_string(),
        format!("{sat_rps:.0} req/s"),
        format!("{sat_p99_ms:.3} ms"),
    ]);
    table.row(&[
        "overload accepted".into(),
        accepted_count.to_string(),
        format!("{offered_rps:.0} req/s offered"),
        format!("{accepted_p99_ms:.3} ms"),
    ]);
    table.row(&[
        "overload shed".into(),
        shed_count.to_string(),
        "-".into(),
        format!("{shed_p99_ms:.3} ms"),
    ]);
    table.print();

    // ---- trajectory + gate ------------------------------------------------
    let mut rec = Json::object();
    rec.set("spec", "ltr+ltr_lite");
    rec.set("mode", "net-closed-loop");
    rec.set("clients", CLIENTS);
    rec.set("rows_per_request", ROWS_PER_REQUEST);
    rec.set("server_workers", SERVER_WORKERS);
    rec.set("sat_admission", SAT_ADMISSION);
    rec.set("overload_admission", OVERLOAD_ADMISSION);
    rec.set("sat_rps", sat_rps);
    rec.set("sat_p99_ms", sat_p99_ms);
    rec.set("offered_rps", offered_rps);
    rec.set("overload_accepted", accepted_count);
    rec.set("overload_shed", shed_count);
    rec.set("accepted_p99_ms", accepted_p99_ms);
    rec.set("shed_p99_ms", shed_p99_ms);
    records.push(rec);
    let path = append_run("net_serving", &[("quick", Json::Bool(quick))], records)
        .expect("bench trajectory");
    println!("appended run to {}", path.display());

    let mut gate_failures = Vec::new();
    if shed_count == 0 {
        gate_failures.push(format!(
            "overload phase shed nothing: {CLIENTS} clients against a \
             {OVERLOAD_ADMISSION}-slot window should overrun it"
        ));
    }
    if offered_rps < 2.0 * sat_rps {
        gate_failures.push(format!(
            "offered load {offered_rps:.0} req/s under overload did not reach 2x the \
             saturated throughput {sat_rps:.0} req/s (shedding is not cheap enough)"
        ));
    }
    if shed_count > 0 && shed_p99_ms * 10.0 > accepted_p99_ms {
        gate_failures.push(format!(
            "shed p99 {shed_p99_ms:.3} ms is not an order of magnitude below \
             accepted p99 {accepted_p99_ms:.3} ms"
        ));
    }
    if gate {
        for f in &gate_failures {
            eprintln!("GATE FAILURE: {f}");
        }
        if !gate_failures.is_empty() {
            std::process::exit(1);
        }
    } else {
        for f in &gate_failures {
            eprintln!("warning (ungated): {f}");
        }
    }
}
