//! Experiment C5 — the production service point: ~200 requests/second
//! sustained, low tail latency, with the dynamic batcher amortising
//! graph executions. Also reports the cost proxy (backend CPU-seconds
//! per 1k requests) whose compiled-vs-mleap ratio is the analogue of the
//! paper's −58 % service-cost claim.
//!
//! Requires `make artifacts`. Rates and durations are kept modest so the
//! whole bench finishes in ~1 minute; `kamae serve-bench` runs longer
//! sweeps.

use std::path::Path;

use kamae::serving::bench_serve;
use kamae::util::bench::{append_run, fmt_ns, Table};
use kamae::util::json::Json;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("specs/ltr.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    println!("C5: open-loop Poisson serving at 200 req/s (LTR pipeline, 8-row requests)\n");
    let mut table = Table::new(&[
        "mode", "offered rps", "achieved rps", "p50", "p95", "p99", "cpu-s/1k req",
    ]);
    let mut costs = std::collections::HashMap::new();
    let mut records = Vec::new();
    for mode in ["compiled", "interpreted", "mleap"] {
        // mleap at 200rps would overload; offer what it can take
        let rps = if mode == "mleap" { 50 } else { 200 };
        let report = bench_serve(&dir, "ltr", rps, 5, mode).unwrap();
        costs.insert(mode, report.cost_cpu_s_per_1k);
        table.row(&[
            mode.into(),
            rps.to_string(),
            format!("{:.0}", report.throughput_rps),
            fmt_ns(report.p50_ns),
            fmt_ns(report.p95_ns),
            fmt_ns(report.p99_ns),
            format!("{:.3}", report.cost_cpu_s_per_1k),
        ]);
        let mut rec = report.to_json();
        rec.set("offered_rps", rps);
        records.push(rec);
    }
    table.print();
    if let (Some(c), Some(m)) = (costs.get("compiled"), costs.get("mleap")) {
        println!(
            "\ncost reduction compiled vs mleap-like: -{:.0}% (paper: -58%)",
            100.0 * (1.0 - c / m)
        );
    }
    let path = append_run(
        "serving_throughput",
        &[("spec", Json::Str("ltr".into()))],
        records,
    )
    .expect("bench trajectory");
    println!("appended run to {}", path.display());
    println!("shape check: compiled sustains 200 rps with p99 well under the");
    println!("mleap-like backend's p50.");
}
