//! Optimizer benchmark — interpreted-backend serving throughput and
//! per-request latency with passes on vs. off.
//!
//! No artifacts needed: pipelines are fitted in-process, exported at
//! `OptimizeLevel::None` and `OptimizeLevel::Full`, and probed directly
//! through `InterpretedBackend` (8-row requests, the LTR slate size).
//! Per-pass node counts and cost estimates are printed for each spec,
//! and every run appends a machine-readable record to
//! `BENCH_optimizer.json` for the perf trajectory.
//!
//! MovieLens is the paper's Listing-1 pipeline: with the round-2 fusion
//! passes its split/hash ingress chain fuses, so even the "no-win
//! floor" now carries a small win. LTR is where the big wins are: dead
//! offline-only features, prunable ingress hashing, scalar-affine
//! ladders, bucketize/compare ladders and select-over-compare branches.
//!
//! Flags (also settable via env for CI):
//!   --quick / KAMAE_BENCH_QUICK   reduced fit rows + request count
//!   --gate  / KAMAE_BENCH_GATE    exit non-zero if optimized throughput
//!                                 regresses below 90% of unoptimized

use std::time::{Duration, Instant};

use kamae::engine::Dataset;
use kamae::export::GraphSpec;
use kamae::optim::OptimizeLevel;
use kamae::pipeline::catalog;
use kamae::serving::{request_pool, Backend, InterpretedBackend, LatencyRecorder};
use kamae::util::bench::{append_run, fmt_ns, Table};
use kamae::util::json::Json;
use kamae::util::rng::Rng;

const ROWS_PER_REQUEST: usize = 8;
/// Gate threshold: optimized throughput below this fraction of the
/// unoptimized baseline fails a --gate run (0.9 absorbs CI noise while
/// still catching real pessimisation).
const GATE_RATIO: f64 = 0.9;

fn export_pair(name: &str, fit_rows: usize) -> (GraphSpec, GraphSpec, kamae::optim::OptReport) {
    let (pipeline, inputs, outputs, data): (_, fn() -> Vec<kamae::export::SpecInput>, Vec<&str>, _) =
        match name {
            "movielens" => (
                catalog::movielens_pipeline(),
                catalog::movielens_inputs as _,
                catalog::MOVIELENS_OUTPUTS.to_vec(),
                kamae::synth::gen_movielens(&kamae::synth::MovieLensConfig {
                    rows: fit_rows,
                    ..Default::default()
                }),
            ),
            _ => (
                catalog::ltr_pipeline(),
                catalog::ltr_inputs as _,
                catalog::LTR_OUTPUTS.to_vec(),
                kamae::synth::gen_ltr(&kamae::synth::LtrConfig {
                    rows: fit_rows,
                    ..Default::default()
                }),
            ),
        };
    let model = pipeline.fit(&Dataset::from_dataframe(data, 4)).unwrap();
    let (raw, _) = model.to_graph_spec_opt(name, inputs(), &outputs, OptimizeLevel::None).unwrap();
    let (opt, report) =
        model.to_graph_spec_opt(name, inputs(), &outputs, OptimizeLevel::Full).unwrap();
    (raw, opt, report)
}

fn drive(spec: GraphSpec, label: &str, spec_name: &str, requests: usize) -> kamae::serving::ServeReport {
    let backend = InterpretedBackend::new(spec);
    let pool = request_pool(spec_name, 4096).unwrap();
    let recorder = LatencyRecorder::new();
    let mut rng = Rng::new(0xC0FFEE);
    let mut busy = Duration::ZERO;
    let t0 = Instant::now();
    for _ in 0..requests {
        let start = rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
        let req = pool.slice(start, ROWS_PER_REQUEST);
        let sent = Instant::now();
        backend.process(&req).unwrap();
        let d = sent.elapsed();
        busy += d;
        recorder.record(d);
    }
    recorder.report(&format!("{spec_name}/{label}"), requests, t0.elapsed(), busy)
}

/// Env flag: set and not "0"/"false"/"" (so KAMAE_BENCH_GATE=0 disables).
fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
        .unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || env_flag("KAMAE_BENCH_QUICK");
    let gate = args.iter().any(|a| a == "--gate") || env_flag("KAMAE_BENCH_GATE");
    let (fit_rows, requests) = if quick { (2_000, 200) } else { (20_000, 2_000) };
    if quick {
        println!("(quick mode: {fit_rows} fit rows, {requests} requests)\n");
    }

    let mut records = Vec::new();
    let mut gate_failures = Vec::new();
    for spec_name in ["movielens", "ltr"] {
        println!("== {spec_name} ==\n");
        let (raw, opt, report) = export_pair(spec_name, fit_rows);
        println!("{report}\n");
        let mut table =
            Table::new(&["mode", "graph nodes", "ingress", "throughput", "p50", "p95", "p99"]);
        let mut rps = Vec::new();
        for (label, spec) in [("interpreted-O0", raw), ("interpreted-O2", opt)] {
            let (nodes, ingress) = (spec.nodes.len(), spec.ingress.len());
            let rep = drive(spec, label, spec_name, requests);
            table.row(&[
                label.into(),
                nodes.to_string(),
                ingress.to_string(),
                format!("{:.0} req/s", rep.throughput_rps),
                fmt_ns(rep.p50_ns),
                fmt_ns(rep.p95_ns),
                fmt_ns(rep.p99_ns),
            ]);
            rps.push(rep.throughput_rps);
            records.push(rep.to_json());
        }
        table.print();
        if let [before, after] = rps[..] {
            println!("\nthroughput with passes on: {:+.1}%\n", 100.0 * (after / before - 1.0));
            if gate && after < before * GATE_RATIO {
                gate_failures.push(format!(
                    "{spec_name}: optimized {after:.0} req/s < {:.0}% of unoptimized {before:.0} req/s",
                    GATE_RATIO * 100.0
                ));
            }
        }
        records.push(report.to_json());
    }

    // append this run to the perf trajectory
    let path = append_run(
        "optimizer",
        &[
            ("requests", Json::Int(requests as i64)),
            ("rows_per_request", Json::Int(ROWS_PER_REQUEST as i64)),
            ("quick", Json::Bool(quick)),
        ],
        records,
    );
    println!("appended run to {}", path.display());

    if !gate_failures.is_empty() {
        for f in &gate_failures {
            eprintln!("GATE FAILURE: {f}");
        }
        std::process::exit(1);
    }
}
