//! Optimizer benchmark — interpreted-backend serving throughput and
//! per-request latency with passes on vs. off.
//!
//! No artifacts needed: pipelines are fitted in-process, exported at
//! `OptimizeLevel::None` and `OptimizeLevel::Full`, and probed directly
//! through `InterpretedBackend` (8-row requests, the LTR slate size).
//! Per-pass node counts and cost estimates are printed for each spec,
//! and every run appends a machine-readable record to
//! `BENCH_optimizer.json` for the perf trajectory.
//!
//! MovieLens is the paper's Listing-1 pipeline: with the round-2 fusion
//! passes its split/hash ingress chain fuses, so even the "no-win
//! floor" now carries a small win. LTR is where the big wins are: dead
//! offline-only features, prunable ingress hashing, scalar-affine
//! ladders, bucketize/compare ladders and select-over-compare branches.
//!
//! Two additional sections cover the PR 3 multi-output passes:
//!
//! * **pass-set cost comparison** — the LTR spec optimized with the
//!   PR 2 pass list vs the full list (adds MultiLaneBucketize +
//!   CrossOutputDedup); the full set must land strictly below,
//! * **multi-variant dedup** — full + lite LTR variants merged into one
//!   spec; CrossOutputDedup must fire and the merged optimized cost
//!   must undercut the sum of the separately-optimized variants.
//!
//! Flags (also settable via env for CI):
//!   --quick / KAMAE_BENCH_QUICK   reduced fit rows + request count
//!   --gate  / KAMAE_BENCH_GATE    exit non-zero if optimized throughput
//!                                 regresses below 90% of unoptimized,
//!                                 if either new pass fails to fire on
//!                                 the LTR catalog, or if either cost
//!                                 comparison above fails

use std::time::{Duration, Instant};

use kamae::engine::Dataset;
use kamae::export::GraphSpec;
use kamae::optim::passes::{
    AffineFuse, BucketizeMerge, CommonSubexprElim, ConstFold, DeadNodeElim, IdentityElim,
    IngressFuse, SelectCmpFuse,
};
use kamae::optim::{optimize, spec_cost, OptReport, OptimizeLevel, Pass, PassManager};
use kamae::pipeline::catalog;
use kamae::serving::{request_pool, Backend, InterpretedBackend, LatencyRecorder};
use kamae::util::bench::{append_run, fmt_ns, Table};
use kamae::util::json::Json;
use kamae::util::rng::Rng;

const ROWS_PER_REQUEST: usize = 8;
/// Gate threshold: optimized throughput below this fraction of the
/// unoptimized baseline fails a --gate run (0.9 absorbs CI noise while
/// still catching real pessimisation).
const GATE_RATIO: f64 = 0.9;

fn export_pair(
    name: &str,
    fit_rows: usize,
) -> (kamae::pipeline::PipelineModel, GraphSpec, GraphSpec, OptReport) {
    let (pipeline, inputs, outputs, data): (_, fn() -> Vec<kamae::export::SpecInput>, Vec<&str>, _) =
        match name {
            "movielens" => (
                catalog::movielens_pipeline(),
                catalog::movielens_inputs as _,
                catalog::MOVIELENS_OUTPUTS.to_vec(),
                kamae::synth::gen_movielens(&kamae::synth::MovieLensConfig {
                    rows: fit_rows,
                    ..Default::default()
                }),
            ),
            _ => (
                catalog::ltr_pipeline(),
                catalog::ltr_inputs as _,
                catalog::LTR_OUTPUTS.to_vec(),
                kamae::synth::gen_ltr(&kamae::synth::LtrConfig {
                    rows: fit_rows,
                    ..Default::default()
                }),
            ),
        };
    let model = pipeline.fit(&Dataset::from_dataframe(data, 4)).unwrap();
    let (raw, _) = model.to_graph_spec_opt(name, inputs(), &outputs, OptimizeLevel::None).unwrap();
    let (opt, report) =
        model.to_graph_spec_opt(name, inputs(), &outputs, OptimizeLevel::Full).unwrap();
    (model, raw, opt, report)
}

fn drive(spec: GraphSpec, label: &str, spec_name: &str, requests: usize) -> kamae::serving::ServeReport {
    let backend = InterpretedBackend::new(spec);
    let pool = request_pool(spec_name, 4096).unwrap();
    let recorder = LatencyRecorder::new();
    let mut rng = Rng::new(0xC0FFEE);
    let mut busy = Duration::ZERO;
    let t0 = Instant::now();
    for _ in 0..requests {
        let start = rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
        let req = pool.slice(start, ROWS_PER_REQUEST);
        let sent = Instant::now();
        backend.process(&req).unwrap();
        let d = sent.elapsed();
        busy += d;
        recorder.record(d);
    }
    recorder.report(&format!("{spec_name}/{label}"), requests, t0.elapsed(), busy)
}

/// The PR 2 pass list (everything except the PR 3 multi-output passes),
/// for the cost-trajectory comparison on an identical catalog.
fn pr2_pass_manager() -> PassManager {
    let passes: Vec<Box<dyn Pass>> = vec![
        Box::new(DeadNodeElim),
        Box::new(IdentityElim),
        Box::new(ConstFold),
        Box::new(IdentityElim),
        Box::new(CommonSubexprElim),
        Box::new(AffineFuse),
        Box::new(IngressFuse),
        Box::new(BucketizeMerge),
        Box::new(SelectCmpFuse),
        Box::new(DeadNodeElim),
    ];
    PassManager::new(passes)
}

/// Multi-variant serving costs over the already-fitted LTR model:
/// export the full + lite variants, merge, optimize. Returns (full,
/// lite, merged-optimized) spec costs and the merged run's report.
fn variant_costs(model: &kamae::pipeline::PipelineModel) -> (u64, u64, u64, OptReport) {
    let (full, _) = model
        .to_graph_spec_opt("ltr", catalog::ltr_inputs(), &catalog::LTR_OUTPUTS, OptimizeLevel::Full)
        .unwrap();
    let (lite, _) = model
        .to_graph_spec_opt(
            "ltr_lite",
            catalog::ltr_inputs(),
            &catalog::LTR_LITE_OUTPUTS,
            OptimizeLevel::Full,
        )
        .unwrap();
    let merged = GraphSpec::merge_variants("ltr+ltr_lite", &[&full, &lite]).unwrap();
    let (merged_opt, report) = optimize(merged, OptimizeLevel::Full).unwrap();
    (spec_cost(&full), spec_cost(&lite), spec_cost(&merged_opt), report)
}

/// Env flag: set and not "0"/"false"/"" (so KAMAE_BENCH_GATE=0 disables).
fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
        .unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || env_flag("KAMAE_BENCH_QUICK");
    let gate = args.iter().any(|a| a == "--gate") || env_flag("KAMAE_BENCH_GATE");
    let (fit_rows, requests) = if quick { (2_000, 200) } else { (20_000, 2_000) };
    if quick {
        println!("(quick mode: {fit_rows} fit rows, {requests} requests)\n");
    }

    let mut records = Vec::new();
    let mut gate_failures = Vec::new();
    let mut ltr_model = None;
    for spec_name in ["movielens", "ltr"] {
        println!("== {spec_name} ==\n");
        let (model, raw, opt, report) = export_pair(spec_name, fit_rows);
        println!("{report}\n");
        if spec_name == "ltr" {
            // keep the fitted model: the multi-variant section below
            // re-exports it instead of paying a second fit
            ltr_model = Some(model);
            // the sibling lead_time fan-out must actually merge
            let multilane_fired = report
                .stats
                .iter()
                .any(|s| s.pass == "multilane-bucketize" && s.changed);
            // pass-set trajectory: PR 2 passes vs the full set, same spec
            let (pr2_spec, _) = pr2_pass_manager()
                .run(raw.clone(), OptimizeLevel::Full)
                .unwrap();
            let (pr2_cost, full_cost) = (spec_cost(&pr2_spec), spec_cost(&opt));
            println!(
                "ltr optimized est. cost: PR2 pass set {pr2_cost} -> full pass set {full_cost}\n"
            );
            let mut rec = Json::object();
            rec.set("spec", "ltr");
            rec.set("mode", "pass-set-cost");
            rec.set("cost_pr2_passes", pr2_cost as i64);
            rec.set("cost_full_passes", full_cost as i64);
            rec.set("multilane_fired", multilane_fired);
            records.push(rec);
            if gate {
                if !multilane_fired {
                    gate_failures
                        .push("ltr: multilane-bucketize did not fire on the catalog".into());
                }
                if full_cost >= pr2_cost {
                    gate_failures.push(format!(
                        "ltr: full pass set cost {full_cost} not below PR2 pass set {pr2_cost}"
                    ));
                }
            }
        }
        let mut table =
            Table::new(&["mode", "graph nodes", "ingress", "throughput", "p50", "p95", "p99"]);
        let mut rps = Vec::new();
        for (label, spec) in [("interpreted-O0", raw), ("interpreted-O2", opt)] {
            let (nodes, ingress) = (spec.nodes.len(), spec.ingress.len());
            let rep = drive(spec, label, spec_name, requests);
            table.row(&[
                label.into(),
                nodes.to_string(),
                ingress.to_string(),
                format!("{:.0} req/s", rep.throughput_rps),
                fmt_ns(rep.p50_ns),
                fmt_ns(rep.p95_ns),
                fmt_ns(rep.p99_ns),
            ]);
            rps.push(rep.throughput_rps);
            records.push(rep.to_json());
        }
        table.print();
        if let [before, after] = rps[..] {
            println!("\nthroughput with passes on: {:+.1}%\n", 100.0 * (after / before - 1.0));
            if gate && after < before * GATE_RATIO {
                gate_failures.push(format!(
                    "{spec_name}: optimized {after:.0} req/s < {:.0}% of unoptimized {before:.0} req/s",
                    GATE_RATIO * 100.0
                ));
            }
        }
        records.push(report.to_json());
    }

    // --- multi-variant serving: shared-prefix dedup ---------------------
    println!("== ltr multi-variant (full + lite) ==\n");
    let (full_cost, lite_cost, merged_cost, merged_report) =
        variant_costs(&ltr_model.expect("ltr fitted above"));
    println!("{merged_report}\n");
    let dedup_fired = merged_report
        .stats
        .iter()
        .any(|s| s.pass == "cross-output-dedup" && s.changed);
    println!(
        "est. cost: full {full_cost} + lite {lite_cost} = {} separate, {merged_cost} merged \
         ({:+.1}%)\n",
        full_cost + lite_cost,
        100.0 * (merged_cost as f64 / (full_cost + lite_cost) as f64 - 1.0)
    );
    let mut rec = Json::object();
    rec.set("spec", "ltr+ltr_lite");
    rec.set("mode", "variant-dedup-cost");
    rec.set("cost_full", full_cost as i64);
    rec.set("cost_lite", lite_cost as i64);
    rec.set("cost_merged_optimized", merged_cost as i64);
    rec.set("dedup_fired", dedup_fired);
    records.push(rec);
    records.push(merged_report.to_json());
    if gate {
        if !dedup_fired {
            gate_failures
                .push("ltr+ltr_lite: cross-output-dedup did not fire on the merged spec".into());
        }
        if merged_cost >= full_cost + lite_cost {
            gate_failures.push(format!(
                "ltr+ltr_lite: merged cost {merged_cost} not below separate {}",
                full_cost + lite_cost
            ));
        }
    }

    // append this run to the perf trajectory
    let path = append_run(
        "optimizer",
        &[
            ("requests", Json::Int(requests as i64)),
            ("rows_per_request", Json::Int(ROWS_PER_REQUEST as i64)),
            ("quick", Json::Bool(quick)),
        ],
        records,
    )
    .expect("bench trajectory");
    println!("appended run to {}", path.display());

    if !gate_failures.is_empty() {
        for f in &gate_failures {
            eprintln!("GATE FAILURE: {f}");
        }
        std::process::exit(1);
    }
}
