//! Optimizer benchmark — interpreted-backend serving throughput and
//! per-request latency with passes on vs. off.
//!
//! No artifacts needed: pipelines are fitted in-process, exported at
//! `OptimizeLevel::None` and `OptimizeLevel::Full`, and probed directly
//! through `InterpretedBackend` (8-row requests, the LTR slate size).
//! Per-pass node counts are printed for each spec, and every run
//! appends a machine-readable record to `BENCH_optimizer.json` for the
//! perf trajectory.
//!
//! MovieLens is the paper's Listing-1 pipeline: every exported node is
//! live, so it measures the optimizer's no-win floor (the two specs
//! should tie). LTR is where the wins are: dead offline-only features,
//! prunable ingress hashing and scalar-affine ladders.

use std::path::Path;
use std::time::{Duration, Instant};

use kamae::engine::Dataset;
use kamae::export::GraphSpec;
use kamae::optim::OptimizeLevel;
use kamae::pipeline::catalog;
use kamae::serving::{request_pool, Backend, InterpretedBackend, LatencyRecorder};
use kamae::util::bench::{fmt_ns, Table};
use kamae::util::json::Json;
use kamae::util::rng::Rng;

const FIT_ROWS: usize = 20_000;
const REQUESTS: usize = 2_000;
const ROWS_PER_REQUEST: usize = 8;

fn export_pair(name: &str) -> (GraphSpec, GraphSpec, kamae::optim::OptReport) {
    let (pipeline, inputs, outputs, data): (_, fn() -> Vec<kamae::export::SpecInput>, Vec<&str>, _) =
        match name {
            "movielens" => (
                catalog::movielens_pipeline(),
                catalog::movielens_inputs as _,
                catalog::MOVIELENS_OUTPUTS.to_vec(),
                kamae::synth::gen_movielens(&kamae::synth::MovieLensConfig {
                    rows: FIT_ROWS,
                    ..Default::default()
                }),
            ),
            _ => (
                catalog::ltr_pipeline(),
                catalog::ltr_inputs as _,
                catalog::LTR_OUTPUTS.to_vec(),
                kamae::synth::gen_ltr(&kamae::synth::LtrConfig {
                    rows: FIT_ROWS,
                    ..Default::default()
                }),
            ),
        };
    let model = pipeline.fit(&Dataset::from_dataframe(data, 4)).unwrap();
    let (raw, _) = model.to_graph_spec_opt(name, inputs(), &outputs, OptimizeLevel::None).unwrap();
    let (opt, report) =
        model.to_graph_spec_opt(name, inputs(), &outputs, OptimizeLevel::Full).unwrap();
    (raw, opt, report)
}

fn drive(spec: GraphSpec, label: &str, spec_name: &str) -> kamae::serving::ServeReport {
    let backend = InterpretedBackend::new(spec);
    let pool = request_pool(spec_name, 4096).unwrap();
    let recorder = LatencyRecorder::new();
    let mut rng = Rng::new(0xC0FFEE);
    let mut busy = Duration::ZERO;
    let t0 = Instant::now();
    for _ in 0..REQUESTS {
        let start = rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
        let req = pool.slice(start, ROWS_PER_REQUEST);
        let sent = Instant::now();
        backend.process(&req).unwrap();
        let d = sent.elapsed();
        busy += d;
        recorder.record(d);
    }
    recorder.report(&format!("{spec_name}/{label}"), REQUESTS, t0.elapsed(), busy)
}

fn main() {
    let mut records = Vec::new();
    for spec_name in ["movielens", "ltr"] {
        println!("== {spec_name} ==\n");
        let (raw, opt, report) = export_pair(spec_name);
        println!("{report}\n");
        let mut table =
            Table::new(&["mode", "graph nodes", "ingress", "throughput", "p50", "p95", "p99"]);
        let mut rps = Vec::new();
        for (label, spec) in [("interpreted-O0", raw), ("interpreted-O2", opt)] {
            let (nodes, ingress) = (spec.nodes.len(), spec.ingress.len());
            let rep = drive(spec, label, spec_name);
            table.row(&[
                label.into(),
                nodes.to_string(),
                ingress.to_string(),
                format!("{:.0} req/s", rep.throughput_rps),
                fmt_ns(rep.p50_ns),
                fmt_ns(rep.p95_ns),
                fmt_ns(rep.p99_ns),
            ]);
            rps.push(rep.throughput_rps);
            records.push(rep.to_json());
        }
        table.print();
        if let [before, after] = rps[..] {
            println!("\nthroughput with passes on: {:+.1}%\n", 100.0 * (after / before - 1.0));
        }
        records.push(report.to_json());
    }

    // append this run to the perf trajectory
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_optimizer.json");
    let mut runs = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| j.as_array().cloned())
        .unwrap_or_default();
    let mut run = Json::object();
    run.set("bench", "optimizer");
    run.set("requests", REQUESTS);
    run.set("rows_per_request", ROWS_PER_REQUEST);
    run.set("records", Json::Array(records));
    runs.push(run);
    std::fs::write(&path, Json::Array(runs).to_string_pretty()).unwrap();
    println!("appended run to {}", path.display());
}
