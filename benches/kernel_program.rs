//! Kernel-program benchmark — the gate for the compiled columnar hot
//! path in the spec interpreter.
//!
//! At backend load the `SpecInterpreter` compiles the optimized spec
//! once into a kernel program: a topologically ordered list of typed
//! kernels with pre-parsed attributes and slot-indexed flat buffers,
//! executed batch-at-a-time — no per-batch string matching, attr
//! lookups or `HashMap` env. The original per-node `eval_node`
//! interpreter is retained verbatim as the differential oracle
//! (`InterpretedBackend::new_oracle`); this bench pins the two paths
//! bit-identical and then gates the speedup.
//!
//! No artifacts needed: the LTR pipeline is fitted in-process, exported
//! as the full (`ltr`) and lite (`ltr_lite`) variants at
//! `OptimizeLevel::Full`, merged (`GraphSpec::merge_variants` +
//! `CrossOutputDedup`) and driven two ways over an IDENTICAL mixed
//! workload (8-row requests, half per variant, coalesced the way the
//! dynamic batcher does under bursts):
//!
//! * **routed** — `process_routed` on the merged backend: the serving
//!   hot path, per-cone sub-programs over the variant row groups;
//! * **process** — plain all-outputs `process` on the merged backend.
//!
//! Both shapes run on the kernel-program backend and on the oracle
//! backend; responses are asserted bit-identical before any timing runs
//! (the randomized differential property in `rust/tests/properties.rs`
//! pins the same contract per op and under random routing).
//!
//! Every run appends machine-readable records to
//! `BENCH_kernel_program.json` (gated metrics end in `_rps`; the
//! nightly `tools/bench_compare.py` comparator watches them).
//!
//! Flags (also settable via env for CI):
//!   --quick / KAMAE_BENCH_QUICK   reduced fit rows + measure time
//!   --gate  / KAMAE_BENCH_GATE    exit non-zero unless the kernel
//!                                 program serves routed mixed traffic
//!                                 at >= 2x the oracle's throughput

use kamae::dataframe::DataFrame;
use kamae::engine::Dataset;
use kamae::export::{GraphSpec, SpecInterpreter};
use kamae::optim::{optimize, OptimizeLevel};
use kamae::pipeline::catalog;
use kamae::runtime::Tensor;
use kamae::serving::{request_pool, Backend, InterpretedBackend, VariantGroup};
use kamae::util::bench::{append_run, fmt_ns, Bencher, Table};
use kamae::util::json::Json;
use kamae::util::rng::Rng;

const ROWS_PER_REQUEST: usize = 8;
/// Requests per mixed batch (half per variant) — matches
/// `benches/variant_routing.rs` so the routed numbers are comparable
/// across trajectory files.
const REQUESTS_PER_BATCH: usize = 2;

/// The gate: kernel-program routed throughput must be at least this
/// multiple of the `eval_node` oracle's.
const MIN_SPEEDUP: f64 = 2.0;

/// Fit LTR once and export the merged two-variant spec.
fn build_spec(fit_rows: usize) -> GraphSpec {
    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig {
        rows: fit_rows,
        ..Default::default()
    });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let (full, _) = model
        .to_graph_spec_opt("ltr", catalog::ltr_inputs(), &catalog::LTR_OUTPUTS, OptimizeLevel::Full)
        .unwrap();
    let (lite, _) = model
        .to_graph_spec_opt(
            "ltr_lite",
            catalog::ltr_inputs(),
            &catalog::LTR_LITE_OUTPUTS,
            OptimizeLevel::Full,
        )
        .unwrap();
    let merged = GraphSpec::merge_variants("ltr+ltr_lite", &[&full, &lite]).unwrap();
    let (merged, _) = optimize(merged, OptimizeLevel::Full).unwrap();
    merged
}

/// One pre-built mixed batch: the concatenated frame and its
/// per-variant row groups.
struct MixedBatch {
    merged_df: DataFrame,
    groups: Vec<VariantGroup>,
}

/// Pre-build the request batches outside the timed loops (request
/// construction is identical across paths and not what this bench
/// measures).
fn build_batches(pool: &DataFrame, count: usize) -> Vec<MixedBatch> {
    let mut rng = Rng::new(0xC0FFEE);
    let mut batches = Vec::with_capacity(count);
    for _ in 0..count {
        let mut reqs = Vec::with_capacity(REQUESTS_PER_BATCH);
        for _ in 0..REQUESTS_PER_BATCH {
            let start = rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
            reqs.push(pool.slice(start, ROWS_PER_REQUEST));
        }
        let refs: Vec<&DataFrame> = reqs.iter().collect();
        let merged_df = DataFrame::concat(&refs).unwrap();
        let split = reqs[0].num_rows();
        let groups = vec![
            VariantGroup { variant: Some("ltr".into()), rows: 0..split },
            VariantGroup { variant: Some("ltr_lite".into()), rows: split..merged_df.num_rows() },
        ];
        batches.push(MixedBatch { merged_df, groups });
    }
    batches
}

/// Bitwise tensor-list equality via the shared oracle
/// ([`kamae::util::prop::tensors_bit_identical`]), with a context
/// prefix.
fn assert_bit_identical_lists(got: &[Tensor], want: &[Tensor], what: &str) {
    if let Err(e) = kamae::util::prop::tensors_bit_identical(got, want) {
        panic!("{what}: {e}");
    }
}

/// Env flag: set and not "0"/"false"/"" (so KAMAE_BENCH_GATE=0 disables).
fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
        .unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || env_flag("KAMAE_BENCH_QUICK");
    let gate = args.iter().any(|a| a == "--gate") || env_flag("KAMAE_BENCH_GATE");
    let fit_rows = if quick { 2_000 } else { 20_000 };
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    if quick {
        println!("(quick mode: {fit_rows} fit rows)\n");
    }

    let merged = build_spec(fit_rows);
    println!(
        "merged ltr+ltr_lite: {} ingress + {} graph nodes, {} outputs",
        merged.ingress.len(),
        merged.nodes.len(),
        merged.outputs.len()
    );

    // the gate is meaningless if the kernel compiler silently fell back
    // to the oracle on this spec — fail loudly instead of measuring
    // oracle-vs-oracle
    assert!(
        SpecInterpreter::new(merged.clone()).is_compiled(),
        "LTR catalog spec did not compile to a kernel program"
    );
    println!("kernel program compiled for the merged LTR spec\n");

    let kernel_backend = InterpretedBackend::new(merged.clone());
    let oracle_backend = InterpretedBackend::new_oracle(merged.clone());

    let pool = request_pool("ltr", 4096).unwrap();
    let batches = build_batches(&pool, 64);

    // ---- differential pin: kernel == oracle, bit for bit --------------
    for batch in batches.iter().take(4) {
        let k = kernel_backend.process(&batch.merged_df).unwrap();
        let o = oracle_backend.process(&batch.merged_df).unwrap();
        assert_bit_identical_lists(&k, &o, "process kernel-vs-oracle");
        let kr = kernel_backend.process_routed(&batch.merged_df, &batch.groups).unwrap();
        let or = oracle_backend.process_routed(&batch.merged_df, &batch.groups).unwrap();
        assert_eq!(kr.len(), or.len(), "routed group count");
        for (gi, (kg, og)) in kr.iter().zip(or.iter()).enumerate() {
            assert_bit_identical_lists(kg, og, &format!("routed group {gi} kernel-vs-oracle"));
        }
    }
    println!("differential pin: kernel program == eval_node oracle, bit for bit\n");

    // ---- throughput: kernel program vs oracle, routed + plain ---------
    let mut idx = 0usize;
    let kernel_routed_stats = bencher.run("kernel routed", || {
        let b = &batches[idx % batches.len()];
        idx += 1;
        kamae::util::bench::black_box(
            kernel_backend.process_routed(&b.merged_df, &b.groups).unwrap(),
        );
    });
    let mut idx = 0usize;
    let oracle_routed_stats = bencher.run("oracle routed", || {
        let b = &batches[idx % batches.len()];
        idx += 1;
        kamae::util::bench::black_box(
            oracle_backend.process_routed(&b.merged_df, &b.groups).unwrap(),
        );
    });
    let mut idx = 0usize;
    let kernel_process_stats = bencher.run("kernel process", || {
        let b = &batches[idx % batches.len()];
        idx += 1;
        kamae::util::bench::black_box(kernel_backend.process(&b.merged_df).unwrap());
    });
    let mut idx = 0usize;
    let oracle_process_stats = bencher.run("oracle process", || {
        let b = &batches[idx % batches.len()];
        idx += 1;
        kamae::util::bench::black_box(oracle_backend.process(&b.merged_df).unwrap());
    });

    let rps = |st: &kamae::util::bench::Stats| st.throughput(REQUESTS_PER_BATCH as f64);
    let kernel_routed_rps = rps(&kernel_routed_stats);
    let oracle_routed_rps = rps(&oracle_routed_stats);
    let kernel_process_rps = rps(&kernel_process_stats);
    let oracle_process_rps = rps(&oracle_process_stats);

    let mut table = Table::new(&["path", "mean/batch", "p99/batch", "throughput"]);
    for (label, st, r) in [
        ("kernel routed", &kernel_routed_stats, kernel_routed_rps),
        ("oracle routed", &oracle_routed_stats, oracle_routed_rps),
        ("kernel process", &kernel_process_stats, kernel_process_rps),
        ("oracle process", &oracle_process_stats, oracle_process_rps),
    ] {
        table.row(&[
            label.into(),
            fmt_ns(st.mean_ns),
            fmt_ns(st.p99_ns),
            format!("{r:.0} req/s"),
        ]);
    }
    table.print();
    let routed_speedup = kernel_routed_rps / oracle_routed_rps;
    let process_speedup = kernel_process_rps / oracle_process_rps;
    println!(
        "\nkernel vs oracle: routed {routed_speedup:.2}x   process {process_speedup:.2}x\n"
    );

    // ---- trajectory + gate --------------------------------------------
    let mut rec = Json::object();
    rec.set("spec", "ltr+ltr_lite");
    rec.set("mode", "kernel-program-throughput");
    rec.set("requests_per_batch", REQUESTS_PER_BATCH);
    rec.set("rows_per_request", ROWS_PER_REQUEST);
    rec.set("kernel_routed_rps", kernel_routed_rps);
    rec.set("oracle_routed_rps", oracle_routed_rps);
    rec.set("kernel_process_rps", kernel_process_rps);
    rec.set("oracle_process_rps", oracle_process_rps);
    rec.set("routed_speedup", routed_speedup);
    rec.set("process_speedup", process_speedup);
    let path = append_run(
        "kernel_program",
        &[("quick", Json::Bool(quick))],
        vec![rec],
    )
    .expect("bench trajectory");
    println!("appended run to {}", path.display());

    let mut gate_failures = Vec::new();
    if routed_speedup < MIN_SPEEDUP {
        gate_failures.push(format!(
            "kernel routed {kernel_routed_rps:.0} req/s is only {routed_speedup:.2}x the \
             oracle's {oracle_routed_rps:.0} req/s (gate: >= {MIN_SPEEDUP}x)"
        ));
    }
    if gate {
        for f in &gate_failures {
            eprintln!("GATE FAILURE: {f}");
        }
        if !gate_failures.is_empty() {
            std::process::exit(1);
        }
    } else {
        for f in &gate_failures {
            eprintln!("warning (ungated): {f}");
        }
    }
}
