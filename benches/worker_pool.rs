//! Worker-pool serving benchmark — the gate for the multi-worker
//! batcher: N batcher threads draining one shared queue against ONE
//! shared merged backend.
//!
//! No artifacts needed: the LTR pipeline is fitted in-process, exported
//! as the full (`ltr`) and lite (`ltr_lite`) variants, merged and
//! optimized at `OptimizeLevel::Full` exactly like
//! `benches/variant_routing.rs`, then driven with CLOSED-loop mixed
//! routed traffic (M producer threads, bounded in-flight window — the
//! saturating load where pool parallelism must show) three ways:
//!
//! * **pool-1**  — the worker pool at `workers = 1`: the refactored
//!   queue (`Mutex` + `Condvar`, multi-consumer) with a single drainer;
//! * **pool-4**  — the same pool at `workers = 4`: concurrent batches
//!   against the one shared backend;
//! * **legacy**  — the PR 4 architecture reconstructed in-bench: one
//!   dedicated thread owning the backend behind a single-consumer
//!   `mpsc` channel. This is the pre-pool baseline the 1-worker pool
//!   must not regress against.
//!
//! Before any timing, the **differential pin** runs: concurrent
//! mixed-variant requests through the 4-worker pool must come back
//! bit-identical to dedicated single-variant backends — the PR 4
//! routing property re-asserted under real thread interleavings.
//!
//! Every run appends machine-readable records to
//! `BENCH_worker_pool.json` (pool reports carry `workers` +
//! `worker_utilization`).
//!
//! Flags (also settable via env for CI):
//!   --quick / KAMAE_BENCH_QUICK   reduced fit rows + request count
//!   --gate  / KAMAE_BENCH_GATE    exit non-zero unless 4-worker routed
//!                                 throughput strictly beats 1-worker,
//!                                 and 1-worker holds >= 90% of the
//!                                 legacy single-thread baseline

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use kamae::dataframe::DataFrame;
use kamae::engine::Dataset;
use kamae::export::GraphSpec;
use kamae::optim::{optimize, OptimizeLevel};
use kamae::pipeline::catalog;
use kamae::runtime::Tensor;
use kamae::serving::{
    request_pool, Backend, BatchConfig, InterpretedBackend, LatencyRecorder, Server, VariantGroup,
};
use kamae::util::bench::{append_run, Table};
use kamae::util::json::Json;
use kamae::util::prop::tensors_bit_identical;
use kamae::util::rng::Rng;

const ROWS_PER_REQUEST: usize = 8;
const PRODUCERS: usize = 4;
/// Per-producer in-flight window: deep enough to keep every worker fed
/// (PRODUCERS * WINDOW >> workers * requests-per-batch), bounded so the
/// queue cannot grow without limit.
const WINDOW: usize = 16;
const POOL_WORKERS: usize = 4;

type RespRx = mpsc::Receiver<kamae::error::Result<Vec<Tensor>>>;

/// Fit LTR once and export the specs: merged (served) + dedicated
/// oracles for the differential pin.
fn build_specs(fit_rows: usize) -> (GraphSpec, GraphSpec, GraphSpec) {
    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig {
        rows: fit_rows,
        ..Default::default()
    });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let (full, _) = model
        .to_graph_spec_opt("ltr", catalog::ltr_inputs(), &catalog::LTR_OUTPUTS, OptimizeLevel::Full)
        .unwrap();
    let (lite, _) = model
        .to_graph_spec_opt(
            "ltr_lite",
            catalog::ltr_inputs(),
            &catalog::LTR_LITE_OUTPUTS,
            OptimizeLevel::Full,
        )
        .unwrap();
    let merged = GraphSpec::merge_variants("ltr+ltr_lite", &[&full, &lite]).unwrap();
    let (merged, _) = optimize(merged, OptimizeLevel::Full).unwrap();
    (full, lite, merged)
}

/// Pre-built request streams: one per producer thread, round-robin
/// variant tags, identical across every mode (request construction is
/// not what this bench measures).
fn build_requests(
    pool: &DataFrame,
    producers: usize,
    per_producer: usize,
) -> Vec<Vec<(DataFrame, &'static str)>> {
    let mut rng = Rng::new(0xD00D);
    (0..producers)
        .map(|_| {
            (0..per_producer)
                .map(|i| {
                    let start =
                        rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
                    let variant = if i % 2 == 0 { "ltr" } else { "ltr_lite" };
                    (pool.slice(start, ROWS_PER_REQUEST), variant)
                })
                .collect()
        })
        .collect()
}

/// Closed-loop driver: each producer thread runs its own submit closure
/// (one per producer from `make_submit`) over its request stream with a
/// bounded in-flight window. Returns the wall time to complete EVERY
/// request; latencies land in `recorder`.
fn drive_closed_loop<F, S>(
    make_submit: F,
    streams: &[Vec<(DataFrame, &'static str)>],
    recorder: &LatencyRecorder,
) -> Duration
where
    F: Fn() -> S,
    S: FnMut(DataFrame, &'static str) -> RespRx + Send,
{
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for stream in streams {
            let mut submit = make_submit();
            scope.spawn(move || {
                let mut pending: VecDeque<(Instant, &'static str, RespRx)> = VecDeque::new();
                for (df, variant) in stream {
                    let sent = Instant::now();
                    let rx = submit(df.clone(), *variant);
                    pending.push_back((sent, *variant, rx));
                    while pending.len() >= WINDOW {
                        let (sent, variant, rx) = pending.pop_front().unwrap();
                        rx.recv().unwrap().unwrap();
                        recorder.record_variant(variant, sent.elapsed());
                    }
                }
                for (sent, variant, rx) in pending {
                    rx.recv().unwrap().unwrap();
                    recorder.record_variant(variant, sent.elapsed());
                }
            });
        }
    });
    t0.elapsed()
}

// ---------------------------------------------------------------------------
// legacy baseline: the PR 4 single-thread mpsc batcher, reconstructed

struct LegacyJob {
    df: DataFrame,
    variant: String,
    resp: mpsc::Sender<kamae::error::Result<Vec<Tensor>>>,
}

/// One dedicated thread owning the backend behind a single-consumer
/// channel — the exact pre-pool `Server` shape (drain greedily, wait
/// `max_wait` for stragglers, one routed backend call per batch).
/// `busy_ns` accumulates backend-execution time like the old
/// `batch_loop` did, so the baseline's cost proxy is real, not zero.
fn legacy_loop(
    backend: Box<dyn Backend>,
    rx: mpsc::Receiver<LegacyJob>,
    config: BatchConfig,
    busy_ns: std::sync::Arc<std::sync::atomic::AtomicU64>,
) {
    loop {
        let first = match rx.recv() {
            Ok(job) => job,
            Err(_) => return,
        };
        let mut rows = first.df.num_rows();
        let mut jobs = vec![first];
        while rows < config.max_batch_rows {
            match rx.try_recv() {
                Ok(job) => {
                    rows += job.df.num_rows();
                    jobs.push(job);
                }
                Err(_) => break,
            }
        }
        let deadline = Instant::now() + config.max_wait;
        while rows < config.max_batch_rows {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => {
                    rows += job.df.num_rows();
                    jobs.push(job);
                }
                Err(_) => break,
            }
        }
        // contiguous per-variant groups, arrival order within each
        let mut group_members: Vec<(String, Vec<usize>)> = Vec::new();
        for (i, job) in jobs.iter().enumerate() {
            match group_members.iter_mut().find(|(v, _)| *v == job.variant) {
                Some((_, m)) => m.push(i),
                None => group_members.push((job.variant.clone(), vec![i])),
            }
        }
        let order: Vec<usize> =
            group_members.iter().flat_map(|(_, m)| m.iter().copied()).collect();
        let frames: Vec<&DataFrame> = order.iter().map(|&i| &jobs[i].df).collect();
        let merged =
            if frames.len() == 1 { frames[0].clone() } else { DataFrame::concat(&frames).unwrap() };
        let mut groups = Vec::with_capacity(group_members.len());
        let mut start = 0usize;
        for (variant, members) in &group_members {
            let len: usize = members.iter().map(|&i| jobs[i].df.num_rows()).sum();
            groups.push(VariantGroup {
                variant: Some(variant.clone()),
                rows: start..start + len,
            });
            start += len;
        }
        let t0 = Instant::now();
        let result = backend.process_routed(&merged, &groups);
        busy_ns.fetch_add(
            t0.elapsed().as_nanos() as u64,
            std::sync::atomic::Ordering::Relaxed,
        );
        match result {
            Ok(per_group) => {
                for ((_, members), tensors) in group_members.iter().zip(per_group) {
                    if members.len() == 1 {
                        let _ = jobs[members[0]].resp.send(Ok(tensors));
                        continue;
                    }
                    let sizes: Vec<usize> =
                        members.iter().map(|&i| jobs[i].df.num_rows()).collect();
                    let mut split: Vec<Vec<Tensor>> =
                        members.iter().map(|_| Vec::new()).collect();
                    for out in &tensors {
                        for (slot, part) in
                            split.iter_mut().zip(out.split_batch(&sizes).unwrap())
                        {
                            slot.push(part);
                        }
                    }
                    for (&i, tensors) in members.iter().zip(split) {
                        let _ = jobs[i].resp.send(Ok(tensors));
                    }
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for job in jobs {
                    let _ = job
                        .resp
                        .send(Err(kamae::error::KamaeError::Serving(msg.clone())));
                }
            }
        }
    }
}

/// Env flag: set and not "0"/"false"/"" (so KAMAE_BENCH_GATE=0 disables).
fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
        .unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || env_flag("KAMAE_BENCH_QUICK");
    let gate = args.iter().any(|a| a == "--gate") || env_flag("KAMAE_BENCH_GATE");
    let (fit_rows, per_producer) = if quick { (2_000, 500) } else { (20_000, 2_500) };
    if quick {
        println!("(quick mode: {fit_rows} fit rows, {per_producer} requests/producer)\n");
    }
    let total_requests = PRODUCERS * per_producer;

    let (full, lite, merged) = build_specs(fit_rows);
    println!(
        "merged ltr+ltr_lite: {} ingress + {} graph nodes, {} outputs",
        merged.ingress.len(),
        merged.nodes.len(),
        merged.outputs.len()
    );
    let pool_df = request_pool("ltr", 4096).unwrap();
    let streams = build_requests(&pool_df, PRODUCERS, per_producer);

    // ---- differential pin: pooled concurrent routed serving must be
    // bit-identical to dedicated single-variant backends, BEFORE any
    // throughput comparison ------------------------------------------------
    {
        let full_backend = InterpretedBackend::new(full.clone());
        let lite_backend = InterpretedBackend::new(lite.clone());
        let server = Server::start(
            Box::new(InterpretedBackend::new(merged.clone())),
            BatchConfig { workers: POOL_WORKERS, ..BatchConfig::default() },
        )
        .unwrap();
        std::thread::scope(|scope| {
            for stream in streams.iter() {
                let server = &server;
                let full_backend = &full_backend;
                let lite_backend = &lite_backend;
                scope.spawn(move || {
                    // a slice of each stream is plenty: the pin is about
                    // interleaving, the property tests cover breadth
                    for (df, variant) in stream.iter().take(48) {
                        let got =
                            server.submit_variant(df.clone(), variant).recv().unwrap().unwrap();
                        let want = if *variant == "ltr" {
                            full_backend.process(df).unwrap()
                        } else {
                            lite_backend.process(df).unwrap()
                        };
                        if let Err(e) = tensors_bit_identical(&got, &want) {
                            panic!("{variant} pooled-vs-dedicated: {e}");
                        }
                    }
                });
            }
        });
        server.shutdown();
        println!("differential pin: 4-worker pooled routed == dedicated backends, bit for bit\n");
    }

    // ---- closed-loop throughput: legacy vs pool-1 vs pool-N ---------------
    let mut records = Vec::new();
    let mut rps = std::collections::BTreeMap::new();
    let mut utilizations = String::new();

    // legacy single-thread mpsc batcher (PR 4 architecture)
    {
        let backend: Box<dyn Backend> = Box::new(InterpretedBackend::new(merged.clone()));
        let (tx, rx) = mpsc::channel::<LegacyJob>();
        let config = BatchConfig::default();
        let busy_ns = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let worker = {
            let busy_ns = std::sync::Arc::clone(&busy_ns);
            std::thread::spawn(move || legacy_loop(backend, rx, config, busy_ns))
        };
        let recorder = LatencyRecorder::new();
        let wall = drive_closed_loop(
            || {
                let tx = tx.clone();
                move |df: DataFrame, variant: &'static str| {
                    let (rtx, rrx) = mpsc::channel();
                    tx.send(LegacyJob { df, variant: variant.to_string(), resp: rtx })
                        .unwrap();
                    rrx
                }
            },
            &streams,
            &recorder,
        );
        drop(tx); // close the channel so the worker exits
        worker.join().unwrap();
        let busy = Duration::from_nanos(busy_ns.load(std::sync::atomic::Ordering::Relaxed));
        let report = recorder.report("ltr+ltr_lite/legacy", total_requests, wall, busy);
        println!("{report}\n");
        rps.insert("legacy", report.throughput_rps);
        records.push(report.to_json());
    }

    // worker pool at 1 and POOL_WORKERS
    for workers in [1usize, POOL_WORKERS] {
        let server = Server::start(
            Box::new(InterpretedBackend::new(merged.clone())),
            BatchConfig { workers, ..BatchConfig::default() },
        )
        .unwrap();
        let recorder = LatencyRecorder::new();
        let sref = &server;
        let wall = drive_closed_loop(
            move || move |df: DataFrame, variant: &'static str| sref.submit_variant(df, variant),
            &streams,
            &recorder,
        );
        let worker_busy = server.worker_busy_times();
        let (batches, requests) = server.counts();
        server.shutdown();
        assert_eq!(requests as usize, total_requests, "pool-{workers} lost requests");
        let report = recorder.report_pool(
            &format!("ltr+ltr_lite/pool{workers}"),
            total_requests,
            wall,
            &worker_busy,
        );
        println!("{report}");
        println!(
            "batches {batches}  requests {requests}  ({:.1} req/batch)\n",
            requests as f64 / batches.max(1) as f64
        );
        let key: &'static str = if workers == 1 { "pool1" } else { "poolN" };
        rps.insert(key, report.throughput_rps);
        if workers > 1 {
            utilizations = report
                .worker_utilization
                .iter()
                .map(|u| format!("{:.0}%", 100.0 * u))
                .collect::<Vec<_>>()
                .join(" ");
        }
        records.push(report.to_json());
    }

    let (legacy_rps, pool1_rps, pooln_rps) = (rps["legacy"], rps["pool1"], rps["poolN"]);
    let mut table = Table::new(&["mode", "throughput", "vs pool-1"]);
    for (label, r) in [
        ("legacy (PR 4)", legacy_rps),
        ("pool-1", pool1_rps),
        ("pool-4", pooln_rps),
    ] {
        table.row(&[
            label.into(),
            format!("{r:.0} req/s"),
            format!("{:+.1}%", 100.0 * (r / pool1_rps - 1.0)),
        ]);
    }
    table.print();
    println!(
        "\npool-4 vs pool-1: {:+.1}%   pool-1 vs legacy: {:+.1}%   pool-4 utilization: {utilizations}\n",
        100.0 * (pooln_rps / pool1_rps - 1.0),
        100.0 * (pool1_rps / legacy_rps - 1.0)
    );

    // ---- trajectory + gate ------------------------------------------------
    let mut rec = Json::object();
    rec.set("spec", "ltr+ltr_lite");
    rec.set("mode", "pool-scaling");
    rec.set("producers", PRODUCERS);
    rec.set("window", WINDOW);
    rec.set("rows_per_request", ROWS_PER_REQUEST);
    rec.set("pool_workers", POOL_WORKERS);
    rec.set("legacy_rps", legacy_rps);
    rec.set("pool1_rps", pool1_rps);
    rec.set("pooln_rps", pooln_rps);
    rec.set("scaling_x", if pool1_rps > 0.0 { pooln_rps / pool1_rps } else { 0.0 });
    records.push(rec);
    let path = append_run("worker_pool", &[("quick", Json::Bool(quick))], records)
        .expect("bench trajectory");
    println!("appended run to {}", path.display());

    let mut gate_failures = Vec::new();
    if pooln_rps <= pool1_rps {
        gate_failures.push(format!(
            "{POOL_WORKERS}-worker routed throughput {pooln_rps:.0} req/s does not strictly \
             beat 1-worker {pool1_rps:.0} req/s"
        ));
    }
    if pool1_rps < 0.9 * legacy_rps {
        gate_failures.push(format!(
            "1-worker pool {pool1_rps:.0} req/s regressed below 90% of the PR 4 \
             single-thread baseline {legacy_rps:.0} req/s"
        ));
    }
    if gate {
        for f in &gate_failures {
            eprintln!("GATE FAILURE: {f}");
        }
        if !gate_failures.is_empty() {
            std::process::exit(1);
        }
    } else {
        for f in &gate_failures {
            eprintln!("warning (ungated): {f}");
        }
    }
}
