//! Experiment F1 — distributed fit scalability: estimator fitting uses
//! mergeable tree aggregation, so fit time should drop near-linearly
//! with worker threads until memory bandwidth saturates (the Spark-side
//! promise of the paper's "applied (or fitted) to the data in a
//! distributed manner").

use kamae::engine::Dataset;
use kamae::estimators::{StandardScaleEstimator, StringIndexEstimator};
use kamae::pipeline::Estimator;
use kamae::synth;
use kamae::util::bench::{append_run, Table};
use kamae::util::json::Json;

fn main() {
    let rows = 400_000;
    println!("F1: estimator fit scaling over worker threads ({rows} rows)\n");
    let df = synth::gen_ltr(&synth::LtrConfig { rows, ..Default::default() });
    let max_threads = kamae::util::pool::default_threads();

    let mut table = Table::new(&["threads", "string-index fit ms", "scale fit ms", "speedup"]);
    let mut records = Vec::new();
    let mut base: Option<f64> = None;
    let mut threads = 1usize;
    while threads <= max_threads.max(2) {
        let ds = Dataset::from_dataframe(df.clone(), threads * 2).with_threads(threads);

        let t0 = std::time::Instant::now();
        let _ = StringIndexEstimator::new("destination", "d_idx").fit(&ds).unwrap();
        let _ = StringIndexEstimator::new("amenities", "a_idx").fit(&ds).unwrap();
        let idx_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t0 = std::time::Instant::now();
        let _ = StandardScaleEstimator::new("price", "p_z").fit(&ds).unwrap();
        let _ = StandardScaleEstimator::new("review_score", "r_z").fit(&ds).unwrap();
        let scale_ms = t0.elapsed().as_secs_f64() * 1e3;

        let total = idx_ms + scale_ms;
        let speedup = base.map(|b| b / total).unwrap_or(1.0);
        if base.is_none() {
            base = Some(total);
        }
        table.row(&[
            threads.to_string(),
            format!("{idx_ms:.0}"),
            format!("{scale_ms:.0}"),
            format!("{speedup:.2}x"),
        ]);
        let mut rec = Json::object();
        rec.set("threads", threads);
        rec.set("string_index_fit_ms", idx_ms);
        rec.set("scale_fit_ms", scale_ms);
        rec.set("speedup", speedup);
        records.push(rec);
        threads *= 2;
    }
    table.print();
    let path = append_run("fit_scaling", &[("rows", Json::Int(rows as i64))], records)
        .expect("bench trajectory");
    println!("\nappended run to {}", path.display());
    println!("\nmachine parallelism: {max_threads} worker threads available");
    println!("shape check: speedup should grow with threads (sublinearly once");
    println!("the count-merge becomes the bottleneck).");
}
