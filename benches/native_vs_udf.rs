//! Experiment C2 — "implemented … using native transformations (rather
//! than user-defined functions) to guarantee high performance".
//!
//! Columnar (native) vs row-at-a-time (UDF/MLeap-model) execution of the
//! same fitted pipelines, across dataset sizes. The paper's claim is
//! directional: native wins by a large factor that grows with pipeline
//! depth.

use kamae::baselines::RowPipeline;
use kamae::engine::Dataset;
use kamae::pipeline::catalog;
use kamae::synth;
use kamae::util::bench::{append_run, black_box, fmt_ns, Bencher, Table};
use kamae::util::json::Json;

/// BENCH_native_vs_udf.json record for one (pipeline, rows) case.
fn record(pipeline: &str, rows: usize, native_per_row: f64, row_per_row: f64) -> Json {
    let mut j = Json::object();
    j.set("pipeline", pipeline);
    j.set("rows", rows);
    j.set("native_ns_per_row", native_per_row);
    j.set("rowwise_ns_per_row", row_per_row);
    j.set("speedup", row_per_row / native_per_row);
    j
}

fn main() {
    println!("C2: native columnar vs row-wise UDF execution\n");
    let mut table = Table::new(&["pipeline", "rows", "native", "row-wise", "speedup"]);
    let mut records = Vec::new();

    for &rows in &[1_000usize, 10_000, 100_000] {
        let df = synth::gen_movielens(&synth::MovieLensConfig { rows, ..Default::default() });
        let model = catalog::movielens_pipeline()
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let spec = model
            .to_graph_spec("m", catalog::movielens_inputs(), &catalog::MOVIELENS_OUTPUTS)
            .unwrap();
        let row_model = catalog::movielens_pipeline()
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let row_pipe = RowPipeline::from_spec(row_model, &spec);

        let bencher = if rows >= 100_000 { Bencher::quick() } else { Bencher::default() };
        let native = bencher.run("native", || {
            black_box(model.transform_df(df.clone()).unwrap());
        });
        // row-wise is orders slower: bound the measured rows
        let row_rows = rows.min(2_000);
        let row_df = df.slice(0, row_rows);
        let rowwise = Bencher::quick().run("rowwise", || {
            black_box(row_pipe.transform_rows(&row_df).unwrap());
        });
        let native_per_row = native.mean_ns / rows as f64;
        let row_per_row = rowwise.mean_ns / row_rows as f64;
        table.row(&[
            "movielens".into(),
            rows.to_string(),
            format!("{}/row", fmt_ns(native_per_row)),
            format!("{}/row", fmt_ns(row_per_row)),
            format!("{:.1}x", row_per_row / native_per_row),
        ]);
        records.push(record("movielens", rows, native_per_row, row_per_row));
    }

    // LTR pipeline (the ~60-transform chain)
    let rows = 20_000;
    let df = synth::gen_ltr(&synth::LtrConfig { rows, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(df.clone(), 1))
        .unwrap();
    let spec = model
        .to_graph_spec("ltr", catalog::ltr_inputs(), &catalog::LTR_OUTPUTS)
        .unwrap();
    let row_model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(df.clone(), 1))
        .unwrap();
    let row_pipe = RowPipeline::from_spec(row_model, &spec);
    let native = Bencher::quick().run("native", || {
        black_box(model.transform_df(df.clone()).unwrap());
    });
    let row_rows = 500;
    let row_df = df.slice(0, row_rows);
    let rowwise = Bencher::quick().run("rowwise", || {
        black_box(row_pipe.transform_rows(&row_df).unwrap());
    });
    let native_per_row = native.mean_ns / rows as f64;
    let row_per_row = rowwise.mean_ns / row_rows as f64;
    table.row(&[
        "ltr(60-op)".into(),
        rows.to_string(),
        format!("{}/row", fmt_ns(native_per_row)),
        format!("{}/row", fmt_ns(row_per_row)),
        format!("{:.1}x", row_per_row / native_per_row),
    ]);
    records.push(record("ltr", rows, native_per_row, row_per_row));

    table.print();
    let path = append_run("native_vs_udf", &[], records).expect("bench trajectory");
    println!("\nappended run to {}", path.display());
    println!("shape check: native should win by >=5x, growing with pipeline depth.");
}
