//! Experiment C3 — the paper's headline serving result: migrating from
//! MLeap (row-interpreted, JVM) to a compiled graph cut service latency
//! by 61 % and cost by 58 %.
//!
//! We measure single-call latency of the three backends (mleap-like
//! row-wise, columnar interpreted, AOT-compiled PJRT) on the LTR and
//! MovieLens pipelines at request sizes 1/8/32, and report the latency
//! reduction of compiled vs mleap-like — the analogue of the paper's
//! −61 %. Requires `make artifacts`.

use std::path::Path;

use kamae::serving::{load_backend, request_pool};
use kamae::util::bench::{append_run, black_box, fmt_ns, Bencher, Table};

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("specs/ltr.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    println!("C3: serving latency by backend (MLeap-like vs interpreted vs compiled)\n");
    let mut table = Table::new(&[
        "spec", "batch", "mleap-like", "interpreted", "compiled", "compiled vs mleap",
    ]);
    let mut reductions = Vec::new();
    let mut records = Vec::new();

    for spec in ["movielens", "ltr"] {
        let mleap = load_backend(&dir, spec, "mleap").unwrap();
        let interp = load_backend(&dir, spec, "interpreted").unwrap();
        let compiled = load_backend(&dir, spec, "compiled").unwrap();
        let pool = request_pool(spec, 512).unwrap();

        for &batch in &[1usize, 8, 32] {
            let df = pool.slice(17, batch);
            let b = Bencher::quick();
            let m = b.run(&format!("{spec}/b{batch}/mleap"), || {
                black_box(mleap.process(&df).unwrap());
            });
            let i = b.run(&format!("{spec}/b{batch}/interpreted"), || {
                black_box(interp.process(&df).unwrap());
            });
            let c = b.run(&format!("{spec}/b{batch}/compiled"), || {
                black_box(compiled.process(&df).unwrap());
            });
            let reduction = 100.0 * (1.0 - c.p50_ns / m.p50_ns);
            reductions.push(reduction);
            records.extend([m.to_json(), i.to_json(), c.to_json()]);
            table.row(&[
                spec.into(),
                batch.to_string(),
                fmt_ns(m.p50_ns),
                fmt_ns(i.p50_ns),
                fmt_ns(c.p50_ns),
                format!("{:+.0}%", -reduction),
            ]);
        }
    }
    table.print();
    let path = append_run("serving_latency", &[], records).expect("bench trajectory");
    println!("\nappended run to {}", path.display());
    let avg = reductions.iter().sum::<f64>() / reductions.len() as f64;
    println!("mean per-call latency delta compiled vs MLeap-like: {:+.0}%", -avg);
    println!("paper reports -61% on production traffic — i.e. *batched* service");
    println!("latency, reproduced by the C5 harness / ltr_filters example; at");
    println!("batch 1 the PJRT dispatch floor (~50-80µs) dominates, so compiled");
    println!("wins grow with batch size (crossover ~batch 8).");
}
