//! Ingress-validation benchmark — the gate for the data-quality gate's
//! "free when clean" claim: screening every batch at ingress must cost
//! < 5% throughput on clean traffic, and quarantining must be surgical.
//!
//! No artifacts needed: the quickstart pipeline is fitted in-process
//! and served by a 4-worker [`Server`] (single-tenant registry mode, so
//! the schema-derived [`ValidationSpec`] is built automatically at
//! deploy time). The same pre-built clean request streams are driven
//! CLOSED-loop two ways:
//!
//! * **baseline** — `submit_tenant`: the ungated path, no screening;
//! * **validated** — `submit_tenant_validated`: every batch is decoded
//!   through the verdict-mask evaluator before it reaches a worker.
//!
//! Before any timing, the **differential pin** runs: randomly corrupted
//! batches (nulled price / nulled city) go through the validated path
//! with a [`MemoryDeadLetter`] sink; surviving rows must come back
//! bit-identical to an oracle backend fed the same rows with the
//! corruption absent, every quarantined row must carry a structured
//! [`RowError`] naming its rule and column, and every one must land in
//! the sink.
//!
//! A third, ungated phase times dirty traffic (~25% corrupt rows) so
//! the trajectory records what quarantine + compaction actually cost.
//!
//! Every run appends machine-readable records to
//! `BENCH_ingress_validation.json`.
//!
//! Flags (also settable via env for CI):
//!   --quick / KAMAE_BENCH_QUICK   reduced fit rows + request count
//!   --gate  / KAMAE_BENCH_GATE    exit non-zero unless validated
//!                                 clean-traffic throughput holds
//!                                 >= 95% of the ungated baseline and
//!                                 the pin quarantined every corrupt
//!                                 row (and only those)

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use kamae::dataframe::{Column, DataFrame};
use kamae::engine::Dataset;
use kamae::export::GraphSpec;
use kamae::pipeline::catalog;
use kamae::runtime::Tensor;
use kamae::serving::{
    request_pool, Backend, BatchConfig, InterpretedBackend, LatencyRecorder, MemoryDeadLetter,
    Server, DEFAULT_TENANT,
};
use kamae::util::bench::{append_run, Table};
use kamae::util::json::Json;
use kamae::util::prop::tensors_bit_identical;
use kamae::util::rng::Rng;

const ROWS_PER_REQUEST: usize = 8;
const PRODUCERS: usize = 4;
/// Per-producer in-flight window (same shape as `worker_pool.rs`).
const WINDOW: usize = 16;
const POOL_WORKERS: usize = 4;
/// Clean-traffic throughput retention the validated path must hold.
const MIN_RETENTION: f64 = 0.95;
/// In the dirty phase, roughly this fraction of rows is corrupted.
const DIRTY_FRACTION: f64 = 0.25;

type RespRx = std::sync::mpsc::Receiver<kamae::error::Result<Vec<Tensor>>>;

/// Fit quickstart once and export the serving spec.
fn build_spec(fit_rows: usize) -> GraphSpec {
    let data = request_pool("quickstart", fit_rows).unwrap();
    let model = catalog::quickstart_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let outputs = catalog::QUICKSTART_OUTPUTS.to_vec();
    model
        .to_graph_spec("quickstart", catalog::quickstart_inputs(), &outputs)
        .unwrap()
}

/// A copy of `df` with price/city nulled out on ~`fraction` of rows.
/// Returns the corrupted frame and the expected verdict mask.
fn corrupt(df: &DataFrame, fraction: f64, rng: &mut Rng) -> (DataFrame, Vec<bool>) {
    let rows = df.num_rows();
    let mut price: Vec<Option<f64>> =
        df.column("price").unwrap().as_f64().unwrap().iter().copied().map(Some).collect();
    let mut city: Vec<Option<String>> =
        df.column("city").unwrap().as_str().unwrap().iter().cloned().map(Some).collect();
    let mut keep = vec![true; rows];
    let threshold = (fraction * 1000.0) as u64;
    for i in 0..rows {
        if rng.below(1000) < threshold {
            if rng.below(2) == 0 {
                price[i] = None;
            } else {
                city[i] = None;
            }
            keep[i] = false;
        }
    }
    let corrupted = DataFrame::new(vec![
        ("price".into(), Column::from_f64_opt(price)),
        ("city".into(), Column::from_str_opt(city)),
    ])
    .unwrap();
    (corrupted, keep)
}

/// Pre-built clean request streams, identical across phases.
fn build_requests(pool: &DataFrame, producers: usize, per_producer: usize) -> Vec<Vec<DataFrame>> {
    let mut rng = Rng::new(0xF00D);
    (0..producers)
        .map(|_| {
            (0..per_producer)
                .map(|_| {
                    let start = rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
                    pool.slice(start, ROWS_PER_REQUEST)
                })
                .collect()
        })
        .collect()
}

/// Closed-loop driver over the ungated path. Returns wall time.
fn drive_baseline(
    server: &Server,
    streams: &[Vec<DataFrame>],
    recorder: &LatencyRecorder,
) -> Duration {
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for stream in streams {
            scope.spawn(move || {
                let mut pending: VecDeque<(Instant, RespRx)> = VecDeque::new();
                for df in stream {
                    let sent = Instant::now();
                    let rx = server.submit_tenant(df.clone(), DEFAULT_TENANT, None);
                    pending.push_back((sent, rx));
                    while pending.len() >= WINDOW {
                        let (sent, rx) = pending.pop_front().unwrap();
                        rx.recv().unwrap().unwrap();
                        recorder.record(sent.elapsed());
                    }
                }
                for (sent, rx) in pending {
                    rx.recv().unwrap().unwrap();
                    recorder.record(sent.elapsed());
                }
            });
        }
    });
    t0.elapsed()
}

/// Closed-loop driver over the validated path. Returns wall time and
/// the total number of quarantined rows observed.
fn drive_validated(
    server: &Server,
    streams: &[Vec<DataFrame>],
    recorder: &LatencyRecorder,
) -> (Duration, u64) {
    use std::sync::atomic::{AtomicU64, Ordering};
    let quarantined = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for stream in streams {
            let quarantined = &quarantined;
            scope.spawn(move || {
                let mut pending: VecDeque<(Instant, RespRx)> = VecDeque::new();
                for df in stream {
                    let sent = Instant::now();
                    let (rx, report) =
                        server.submit_tenant_validated(df.clone(), DEFAULT_TENANT, None, None, None);
                    quarantined.fetch_add(report.num_quarantined() as u64, Ordering::Relaxed);
                    pending.push_back((sent, rx));
                    while pending.len() >= WINDOW {
                        let (sent, rx) = pending.pop_front().unwrap();
                        rx.recv().unwrap().unwrap();
                        recorder.record(sent.elapsed());
                    }
                }
                for (sent, rx) in pending {
                    rx.recv().unwrap().unwrap();
                    recorder.record(sent.elapsed());
                }
            });
        }
    });
    (t0.elapsed(), quarantined.load(std::sync::atomic::Ordering::Relaxed))
}

fn start_server(spec: &GraphSpec) -> Server {
    Server::start(
        Box::new(InterpretedBackend::new(spec.clone())),
        BatchConfig { workers: POOL_WORKERS, ..BatchConfig::default() },
    )
    .unwrap()
}

/// Env flag: set and not "0"/"false"/"" (so KAMAE_BENCH_GATE=0 disables).
fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
        .unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || env_flag("KAMAE_BENCH_QUICK");
    let gate = args.iter().any(|a| a == "--gate") || env_flag("KAMAE_BENCH_GATE");
    let (fit_rows, per_producer) = if quick { (2_000, 400) } else { (20_000, 2_000) };
    if quick {
        println!("(quick mode: {fit_rows} fit rows, {per_producer} requests/producer)\n");
    }
    let total_requests = PRODUCERS * per_producer;

    let spec = build_spec(fit_rows);
    println!(
        "quickstart: {} ingress columns, {} graph nodes, {} outputs",
        spec.ingress.len(),
        spec.nodes.len(),
        spec.outputs.len()
    );
    let pool = request_pool("quickstart", 4096).unwrap();
    let streams = build_requests(&pool, PRODUCERS, per_producer);
    let oracle = InterpretedBackend::new(spec.clone());

    // ---- differential pin: quarantine is surgical -------------------------
    {
        let server = start_server(&spec);
        let sink = MemoryDeadLetter::new(8192);
        let mut rng = Rng::new(0xBADF00D);
        let mut corrupted_total = 0usize;
        let cases = if quick { 32 } else { 128 };
        for case in 0..cases {
            let rows = 2 + rng.below(14) as usize;
            let start = rng.below((pool.num_rows() - rows) as u64) as usize;
            let clean = pool.slice(start, rows);
            let (corrupted, keep) = corrupt(&clean, 0.3, &mut rng);
            let (rx, report) =
                server.submit_tenant_validated(corrupted, DEFAULT_TENANT, None, None, Some(&sink));
            let got = rx.recv().unwrap().unwrap();
            let n_bad = keep.iter().filter(|k| !**k).count();
            corrupted_total += n_bad;
            assert_eq!(report.keep, keep, "pin case {case}: verdict mask");
            for i in report.quarantined() {
                assert!(
                    !report.errors[i].is_empty(),
                    "pin case {case} row {i}: quarantined without a RowError"
                );
                for e in &report.errors[i] {
                    assert_eq!(e.rule, "not_null", "pin case {case} row {i}: rule");
                    assert!(
                        e.column == "price" || e.column == "city",
                        "pin case {case} row {i}: error names column {:?}",
                        e.column
                    );
                }
            }
            if report.num_valid() == 0 {
                assert!(got.is_empty(), "pin case {case}: all-quarantined batch returned tensors");
                continue;
            }
            let want = oracle.process(&clean.filter_rows(&keep).unwrap()).unwrap();
            if let Err(e) = tensors_bit_identical(&got, &want) {
                panic!("pin case {case}: valid rows vs uncorrupted oracle: {e}");
            }
        }
        server.shutdown();
        assert!(corrupted_total > 0, "pin never corrupted a row");
        assert_eq!(sink.len(), corrupted_total, "pin: every quarantined row dead-lettered");
        println!(
            "differential pin: {cases} corrupted batches, {corrupted_total} rows quarantined \
             with rule+column, survivors bit-identical to uncorrupted oracle\n"
        );
    }

    // ---- baseline: clean traffic, ungated path ----------------------------
    let baseline_report = {
        let server = start_server(&spec);
        let recorder = LatencyRecorder::new();
        let wall = drive_baseline(&server, &streams, &recorder);
        let worker_busy = server.worker_busy_times();
        let (_, requests) = server.counts();
        server.shutdown();
        assert_eq!(requests as usize, total_requests, "baseline lost requests");
        let report =
            recorder.report_pool("quickstart/ingress-baseline", total_requests, wall, &worker_busy);
        println!("{report}\n");
        report
    };

    // ---- validated: the SAME clean traffic through the gate ---------------
    let validated_report = {
        let server = start_server(&spec);
        let recorder = LatencyRecorder::new();
        let (wall, quarantined) = drive_validated(&server, &streams, &recorder);
        let worker_busy = server.worker_busy_times();
        let (_, requests) = server.counts();
        server.shutdown();
        assert_eq!(requests as usize, total_requests, "validated phase lost requests");
        assert_eq!(quarantined, 0, "clean traffic must not quarantine anything");
        let report = recorder.report_pool(
            "quickstart/ingress-validated",
            total_requests,
            wall,
            &worker_busy,
        );
        println!("{report}\n");
        report
    };

    // ---- dirty traffic: what quarantine + compaction cost (ungated) -------
    let (dirty_report, dirty_quarantined, dirty_rows) = {
        let mut rng = Rng::new(0xDEAD);
        let mut expected_bad = 0u64;
        let dirty_streams: Vec<Vec<DataFrame>> = streams
            .iter()
            .map(|stream| {
                stream
                    .iter()
                    .map(|df| {
                        let (corrupted, keep) = corrupt(df, DIRTY_FRACTION, &mut rng);
                        expected_bad += keep.iter().filter(|k| !**k).count() as u64;
                        corrupted
                    })
                    .collect()
            })
            .collect();
        let server = start_server(&spec);
        let recorder = LatencyRecorder::new();
        let (wall, quarantined) = drive_validated(&server, &dirty_streams, &recorder);
        let worker_busy = server.worker_busy_times();
        server.shutdown();
        assert_eq!(quarantined, expected_bad, "dirty phase quarantine count");
        let report =
            recorder.report_pool("quickstart/ingress-dirty", total_requests, wall, &worker_busy);
        println!("{report}\n");
        (report, quarantined, (total_requests * ROWS_PER_REQUEST) as u64)
    };

    let baseline_rps = baseline_report.throughput_rps;
    let validated_rps = validated_report.throughput_rps;
    let dirty_rps = dirty_report.throughput_rps;
    let retention = if baseline_rps > 0.0 { validated_rps / baseline_rps } else { 0.0 };
    let mut table = Table::new(&["mode", "throughput", "vs baseline"]);
    for (label, r) in [
        ("baseline (no gate)", baseline_rps),
        ("validated, clean", validated_rps),
        ("validated, ~25% dirty", dirty_rps),
    ] {
        table.row(&[
            label.into(),
            format!("{r:.0} req/s"),
            format!("{:+.1}%", 100.0 * (r / baseline_rps - 1.0)),
        ]);
    }
    table.print();
    println!(
        "\nclean-traffic retention through the gate: {:.1}%  (gate: >= {:.0}%)",
        100.0 * retention,
        100.0 * MIN_RETENTION
    );
    println!(
        "dirty phase: {dirty_quarantined}/{dirty_rows} rows quarantined\n"
    );

    // ---- trajectory + gate ------------------------------------------------
    let mut records =
        vec![baseline_report.to_json(), validated_report.to_json(), dirty_report.to_json()];
    let mut rec = Json::object();
    rec.set("spec", "quickstart");
    rec.set("mode", "ingress-validation");
    rec.set("producers", PRODUCERS);
    rec.set("window", WINDOW);
    rec.set("rows_per_request", ROWS_PER_REQUEST);
    rec.set("pool_workers", POOL_WORKERS);
    rec.set("baseline_rps", baseline_rps);
    rec.set("validated_rps", validated_rps);
    rec.set("dirty_rps", dirty_rps);
    rec.set("retention", retention);
    rec.set("dirty_quarantined", dirty_quarantined as i64);
    rec.set("dirty_rows", dirty_rows as i64);
    records.push(rec);
    let path = append_run("ingress_validation", &[("quick", Json::Bool(quick))], records)
        .expect("bench trajectory");
    println!("appended run to {}", path.display());

    let mut gate_failures = Vec::new();
    if validated_rps < MIN_RETENTION * baseline_rps {
        gate_failures.push(format!(
            "validated clean-traffic throughput {validated_rps:.0} req/s fell below \
             {:.0}% of the ungated baseline {baseline_rps:.0} req/s ({:.1}% retention)",
            100.0 * MIN_RETENTION,
            100.0 * retention
        ));
    }
    if gate {
        for f in &gate_failures {
            eprintln!("GATE FAILURE: {f}");
        }
        if !gate_failures.is_empty() {
            std::process::exit(1);
        }
    } else {
        for f in &gate_failures {
            eprintln!("warning (ungated): {f}");
        }
    }
}
