//! Hot-swap serving benchmark — the gate for the spec registry's
//! zero-downtime claim: continuous deploys must not meaningfully dent
//! throughput, drop requests, or change a single bit of any response.
//!
//! No artifacts needed: the LTR pipeline is fitted in-process and
//! exported as the merged `ltr+ltr_lite` spec exactly like
//! `benches/worker_pool.rs`. The merged backend is deployed as tenant
//! `ltr` in a [`SpecRegistry`] behind a 4-worker [`Server`], then driven
//! with CLOSED-loop mixed routed traffic two ways:
//!
//! * **steady** — no deploys: the no-swap baseline throughput;
//! * **swap storm** — the same traffic while a deployer thread swaps
//!   the tenant's active version every few milliseconds (pre-built
//!   backends, O(1) Arc swaps) and periodically rebuilds from raw specs
//!   (`deploy_specs`: merge → optimize → compile, all off the swap
//!   path).
//!
//! Before any timing, the **differential pin** runs: concurrent routed
//! requests during a live swap storm must come back bit-identical to
//! dedicated single-variant oracle backends — whichever version serves
//! a request, the answer is the same, and no request errors or is
//! dropped mid-swap.
//!
//! Every run appends machine-readable records to `BENCH_hot_swap.json`.
//!
//! Flags (also settable via env for CI):
//!   --quick / KAMAE_BENCH_QUICK   reduced fit rows + request count
//!   --gate  / KAMAE_BENCH_GATE    exit non-zero unless swap-storm
//!                                 throughput holds >= 90% of steady,
//!                                 every request is accounted to exactly
//!                                 one version, and the slowest swap
//!                                 stays under the visibility bound

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kamae::dataframe::DataFrame;
use kamae::engine::Dataset;
use kamae::export::GraphSpec;
use kamae::optim::{optimize, OptimizeLevel};
use kamae::pipeline::catalog;
use kamae::runtime::Tensor;
use kamae::serving::{
    request_pool, Backend, BatchConfig, InterpretedBackend, LatencyRecorder, Server, SpecRegistry,
};
use kamae::util::bench::{append_run, Table};
use kamae::util::json::Json;
use kamae::util::prop::tensors_bit_identical;
use kamae::util::rng::Rng;

const ROWS_PER_REQUEST: usize = 8;
const PRODUCERS: usize = 4;
/// Per-producer in-flight window (same shape as `worker_pool.rs`).
const WINDOW: usize = 16;
const POOL_WORKERS: usize = 4;
const TENANT: &str = "ltr";
/// Pause between storm swaps: short enough that every closed-loop run
/// sees many swaps, long enough that the deployer doesn't monopolise
/// the tenant's write lock.
const SWAP_PAUSE: Duration = Duration::from_millis(3);
/// Every Nth storm swap is a full rebuild from raw specs instead of a
/// pre-built Arc swap — the expensive path must also stay off-path.
const REBUILD_EVERY: u64 = 16;
/// Swap visibility bound: time from "new version built" to "active".
const MAX_SWAP: Duration = Duration::from_millis(100);

type RespRx = std::sync::mpsc::Receiver<kamae::error::Result<Vec<Tensor>>>;

/// Fit LTR once: dedicated oracles + the merged spec the tenant serves.
fn build_specs(fit_rows: usize) -> (GraphSpec, GraphSpec, GraphSpec) {
    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig {
        rows: fit_rows,
        ..Default::default()
    });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let (full, _) = model
        .to_graph_spec_opt("ltr", catalog::ltr_inputs(), &catalog::LTR_OUTPUTS, OptimizeLevel::Full)
        .unwrap();
    let (lite, _) = model
        .to_graph_spec_opt(
            "ltr_lite",
            catalog::ltr_inputs(),
            &catalog::LTR_LITE_OUTPUTS,
            OptimizeLevel::Full,
        )
        .unwrap();
    let merged = GraphSpec::merge_variants("ltr+ltr_lite", &[&full, &lite]).unwrap();
    let (merged, _) = optimize(merged, OptimizeLevel::Full).unwrap();
    (full, lite, merged)
}

/// Pre-built request streams, identical across phases.
fn build_requests(
    pool: &DataFrame,
    producers: usize,
    per_producer: usize,
) -> Vec<Vec<(DataFrame, &'static str)>> {
    let mut rng = Rng::new(0xD00D);
    (0..producers)
        .map(|_| {
            (0..per_producer)
                .map(|i| {
                    let start =
                        rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
                    let variant = if i % 2 == 0 { "ltr" } else { "ltr_lite" };
                    (pool.slice(start, ROWS_PER_REQUEST), variant)
                })
                .collect()
        })
        .collect()
}

/// Closed-loop driver against the registry-backed server: every request
/// is addressed to the tenant and MUST succeed (a dropped or errored
/// response during a swap fails the bench by panic). Returns wall time.
fn drive_closed_loop(
    server: &Server,
    streams: &[Vec<(DataFrame, &'static str)>],
    recorder: &LatencyRecorder,
) -> Duration {
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for stream in streams {
            scope.spawn(move || {
                let mut pending: VecDeque<(Instant, &'static str, RespRx)> = VecDeque::new();
                for (df, variant) in stream {
                    let sent = Instant::now();
                    let rx = server.submit_tenant(df.clone(), TENANT, Some(*variant));
                    pending.push_back((sent, *variant, rx));
                    while pending.len() >= WINDOW {
                        let (sent, variant, rx) = pending.pop_front().unwrap();
                        rx.recv().unwrap().unwrap();
                        recorder.record_variant(variant, sent.elapsed());
                    }
                }
                for (sent, variant, rx) in pending {
                    rx.recv().unwrap().unwrap();
                    recorder.record_variant(variant, sent.elapsed());
                }
            });
        }
    });
    t0.elapsed()
}

/// Deployer thread body: alternate pre-built backends with O(1) swaps,
/// rebuilding from raw specs every `REBUILD_EVERY`th deploy. Returns
/// (swaps, rebuilds, max swap ns, total swap ns).
fn swap_storm(
    registry: &SpecRegistry,
    prebuilt: &[Arc<dyn Backend>],
    raw_specs: &[GraphSpec],
    stop: &AtomicBool,
) -> (u64, u64, u64, u64) {
    let mut swaps = 0u64;
    let mut rebuilds = 0u64;
    let mut max_swap_ns = 0u64;
    let mut total_swap_ns = 0u64;
    while !stop.load(Ordering::SeqCst) {
        let summary = if swaps % REBUILD_EVERY == REBUILD_EVERY - 1 {
            rebuilds += 1;
            registry
                .deploy_specs(TENANT, raw_specs, None, Some(OptimizeLevel::Full))
                .unwrap()
        } else {
            let backend = Arc::clone(&prebuilt[(swaps % prebuilt.len() as u64) as usize]);
            registry.deploy_backend(TENANT, backend, None).unwrap()
        };
        let ns = summary.swap.as_nanos() as u64;
        max_swap_ns = max_swap_ns.max(ns);
        total_swap_ns += ns;
        swaps += 1;
        std::thread::sleep(SWAP_PAUSE);
    }
    (swaps, rebuilds, max_swap_ns, total_swap_ns)
}

/// Env flag: set and not "0"/"false"/"" (so KAMAE_BENCH_GATE=0 disables).
fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
        .unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || env_flag("KAMAE_BENCH_QUICK");
    let gate = args.iter().any(|a| a == "--gate") || env_flag("KAMAE_BENCH_GATE");
    let (fit_rows, per_producer) = if quick { (2_000, 400) } else { (20_000, 2_000) };
    if quick {
        println!("(quick mode: {fit_rows} fit rows, {per_producer} requests/producer)\n");
    }
    let total_requests = PRODUCERS * per_producer;

    let (full, lite, merged) = build_specs(fit_rows);
    println!(
        "merged ltr+ltr_lite: {} ingress + {} graph nodes, {} outputs",
        merged.ingress.len(),
        merged.nodes.len(),
        merged.outputs.len()
    );
    let pool_df = request_pool("ltr", 4096).unwrap();
    let streams = build_requests(&pool_df, PRODUCERS, per_producer);
    let raw_specs = vec![full.clone(), lite.clone()];
    // the storm alternates between two independently-built instances of
    // the same optimized spec: bit-identical by construction, so the
    // oracle pin below holds whichever version answers
    let prebuilt: Vec<Arc<dyn Backend>> = (0..2)
        .map(|_| Arc::new(InterpretedBackend::new(merged.clone())) as Arc<dyn Backend>)
        .collect();

    // ---- differential pin: responses during a live swap storm are
    // bit-identical to dedicated oracles, zero requests lost ---------------
    {
        let registry = Arc::new(SpecRegistry::with_level(OptimizeLevel::Full));
        registry
            .deploy_backend(TENANT, Arc::clone(&prebuilt[0]), None)
            .unwrap();
        let server = Server::start_registry(
            Arc::clone(&registry),
            BatchConfig { workers: POOL_WORKERS, ..BatchConfig::default() },
        )
        .unwrap();
        let full_backend = InterpretedBackend::new(full.clone());
        let lite_backend = InterpretedBackend::new(lite.clone());
        let stop = AtomicBool::new(false);
        let pinned = AtomicU64::new(0);
        let (swaps, ..) = std::thread::scope(|scope| {
            let deployer = scope.spawn(|| swap_storm(&registry, &prebuilt, &raw_specs, &stop));
            for stream in streams.iter() {
                let (server, stop, pinned) = (&server, &stop, &pinned);
                let full_backend = &full_backend;
                let lite_backend = &lite_backend;
                scope.spawn(move || {
                    for (df, variant) in stream.iter().take(48) {
                        let got = server
                            .submit_tenant(df.clone(), TENANT, Some(*variant))
                            .recv()
                            .unwrap()
                            .unwrap();
                        let want = if *variant == "ltr" {
                            full_backend.process(df).unwrap()
                        } else {
                            lite_backend.process(df).unwrap()
                        };
                        if let Err(e) = tensors_bit_identical(&got, &want) {
                            panic!("{variant} under swap storm vs dedicated oracle: {e}");
                        }
                        pinned.fetch_add(1, Ordering::Relaxed);
                    }
                    stop.store(true, Ordering::SeqCst);
                });
            }
            deployer.join().unwrap()
        });
        let (_, requests) = server.counts();
        server.shutdown();
        assert_eq!(requests, pinned.load(Ordering::Relaxed), "pin lost requests");
        assert!(swaps > 0, "the pin never saw a swap");
        println!(
            "differential pin: {} routed requests bit-identical to oracles across {swaps} live swaps\n",
            pinned.load(Ordering::Relaxed)
        );
    }

    // ---- steady baseline: no deploys --------------------------------------
    let steady_report = {
        let registry = Arc::new(SpecRegistry::with_level(OptimizeLevel::Full));
        registry
            .deploy_backend(TENANT, Arc::clone(&prebuilt[0]), None)
            .unwrap();
        let server = Server::start_registry(
            Arc::clone(&registry),
            BatchConfig { workers: POOL_WORKERS, ..BatchConfig::default() },
        )
        .unwrap();
        let recorder = LatencyRecorder::new();
        let wall = drive_closed_loop(&server, &streams, &recorder);
        let worker_busy = server.worker_busy_times();
        let (_, requests) = server.counts();
        server.shutdown();
        assert_eq!(requests as usize, total_requests, "steady phase lost requests");
        let report = recorder.report_pool(
            "ltr+ltr_lite/hot-swap-steady",
            total_requests,
            wall,
            &worker_busy,
        );
        println!("{report}\n");
        report
    };

    // ---- swap storm: same traffic under continuous deploys ----------------
    let (storm_report, swaps, rebuilds, max_swap_ns, mean_swap_ns, versions_serving) = {
        let registry = Arc::new(SpecRegistry::with_level(OptimizeLevel::Full));
        registry
            .deploy_backend(TENANT, Arc::clone(&prebuilt[0]), None)
            .unwrap();
        let server = Server::start_registry(
            Arc::clone(&registry),
            BatchConfig { workers: POOL_WORKERS, ..BatchConfig::default() },
        )
        .unwrap();
        let recorder = LatencyRecorder::new();
        let stop = AtomicBool::new(false);
        let (wall, storm) = std::thread::scope(|scope| {
            let deployer = scope.spawn(|| swap_storm(&registry, &prebuilt, &raw_specs, &stop));
            let wall = drive_closed_loop(&server, &streams, &recorder);
            stop.store(true, Ordering::SeqCst);
            (wall, deployer.join().unwrap())
        });
        let (swaps, rebuilds, max_swap_ns, total_swap_ns) = storm;
        let worker_busy = server.worker_busy_times();
        let (_, requests) = server.counts();
        server.shutdown();
        assert_eq!(requests as usize, total_requests, "swap storm lost requests");
        // every request is accounted to exactly ONE version
        let snapshot = registry.snapshot();
        let tenant = snapshot.iter().find(|s| s.tenant == TENANT).unwrap();
        let per_version_total: u64 = tenant.versions.iter().map(|v| v.requests).sum();
        assert_eq!(
            per_version_total, total_requests as u64,
            "per-version request counters do not conserve the total"
        );
        let versions_serving =
            tenant.versions.iter().filter(|v| v.requests > 0).count();
        assert!(
            versions_serving >= 2,
            "traffic never spanned a swap ({versions_serving} version(s) served)"
        );
        let report = recorder.report_pool(
            "ltr+ltr_lite/hot-swap-storm",
            total_requests,
            wall,
            &worker_busy,
        );
        println!("{report}");
        println!(
            "swaps {swaps} ({rebuilds} full rebuilds)  versions serving {versions_serving}  \
             swap max {:.1}µs  mean {:.1}µs\n",
            max_swap_ns as f64 / 1e3,
            total_swap_ns as f64 / swaps.max(1) as f64 / 1e3
        );
        (
            report,
            swaps,
            rebuilds,
            max_swap_ns,
            total_swap_ns as f64 / swaps.max(1) as f64,
            versions_serving,
        )
    };

    let steady_rps = steady_report.throughput_rps;
    let storm_rps = storm_report.throughput_rps;
    let retention = if steady_rps > 0.0 { storm_rps / steady_rps } else { 0.0 };
    let mut table = Table::new(&["mode", "throughput", "vs steady"]);
    for (label, r) in [("steady (no swaps)", steady_rps), ("swap storm", storm_rps)] {
        table.row(&[
            label.into(),
            format!("{r:.0} req/s"),
            format!("{:+.1}%", 100.0 * (r / steady_rps - 1.0)),
        ]);
    }
    table.print();
    println!(
        "\nthroughput retention under {swaps} swaps: {:.1}%  (gate: >= 90%)\n",
        100.0 * retention
    );

    // ---- trajectory + gate ------------------------------------------------
    let mut records = vec![steady_report.to_json(), storm_report.to_json()];
    let mut rec = Json::object();
    rec.set("spec", "ltr+ltr_lite");
    rec.set("mode", "hot-swap");
    rec.set("producers", PRODUCERS);
    rec.set("window", WINDOW);
    rec.set("rows_per_request", ROWS_PER_REQUEST);
    rec.set("pool_workers", POOL_WORKERS);
    rec.set("steady_rps", steady_rps);
    rec.set("swap_storm_rps", storm_rps);
    rec.set("retention", retention);
    rec.set("swaps", swaps as i64);
    rec.set("rebuilds", rebuilds as i64);
    rec.set("versions_serving", versions_serving);
    rec.set("max_swap_ns", max_swap_ns as f64);
    rec.set("mean_swap_ns", mean_swap_ns);
    records.push(rec);
    let path = append_run("hot_swap", &[("quick", Json::Bool(quick))], records)
        .expect("bench trajectory");
    println!("appended run to {}", path.display());

    let mut gate_failures = Vec::new();
    if storm_rps < 0.9 * steady_rps {
        gate_failures.push(format!(
            "swap-storm throughput {storm_rps:.0} req/s fell below 90% of the no-swap \
             baseline {steady_rps:.0} req/s ({:.1}% retention)",
            100.0 * retention
        ));
    }
    if swaps < 10 {
        gate_failures.push(format!(
            "only {swaps} swaps landed during the storm — the storm did not storm"
        ));
    }
    if max_swap_ns > MAX_SWAP.as_nanos() as u64 {
        gate_failures.push(format!(
            "slowest swap took {:.1}ms, visibility bound is {:?}",
            max_swap_ns as f64 / 1e6,
            MAX_SWAP
        ));
    }
    if gate {
        for f in &gate_failures {
            eprintln!("GATE FAILURE: {f}");
        }
        if !gate_failures.is_empty() {
            std::process::exit(1);
        }
    } else {
        for f in &gate_failures {
            eprintln!("warning (ungated): {f}");
        }
    }
}
