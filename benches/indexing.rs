//! Experiment C4 — indexing-strategy trade-offs (Serrà & Karatzoglou's
//! bloom embeddings): full-vocabulary string indexing vs hash indexing
//! vs bloom encoding on a high-cardinality categorical.
//!
//! Reported per strategy: fit time, export size (the memory the serving
//! model carries), transform throughput, and collision rate (fraction of
//! distinct tokens whose encoding collides with another token's) — the
//! memory-for-accuracy trade the paper's bloom option buys.

use std::collections::HashMap;

use kamae::dataframe::{Column, DataFrame};
use kamae::engine::Dataset;
use kamae::estimators::StringIndexEstimator;
use kamae::pipeline::{Estimator, Transformer};
use kamae::transformers::{BloomEncodeTransformer, HashIndexTransformer};
use kamae::util::bench::{append_run, black_box, Bencher, Table};
use kamae::util::json::Json;
use kamae::util::rng::{Rng, Zipf};

/// BENCH_indexing.json record for one strategy row.
fn record(strategy: &str, fit_ms: f64, export_kib: f64, mrows_s: f64, collisions: f64) -> Json {
    let mut j = Json::object();
    j.set("strategy", strategy);
    j.set("fit_ms", fit_ms);
    j.set("export_kib", export_kib);
    j.set("transform_mrows_s", mrows_s);
    j.set("collision_rate", collisions);
    j
}

fn token_data(rows: usize, cardinality: usize) -> DataFrame {
    let mut rng = Rng::new(11);
    let pop = Zipf::new(cardinality, 1.05);
    let tokens: Vec<String> = (0..rows)
        .map(|_| format!("token_{}", pop.sample(&mut rng)))
        .collect();
    DataFrame::new(vec![("t".into(), Column::from_str(tokens))]).unwrap()
}

/// Collision rate over distinct tokens: two tokens collide if their full
/// encodings are identical.
fn collision_rate(df: &DataFrame, col: &str) -> f64 {
    let tokens = df.column("t").unwrap().as_str().unwrap();
    let mut enc_of: HashMap<&str, Vec<i64>> = HashMap::new();
    let encoded = df.column(col).unwrap();
    for (i, tok) in tokens.iter().enumerate() {
        let enc = match encoded {
            Column::I64(v, _) => vec![v[i]],
            Column::ListI64(l) => l.row(i).to_vec(),
            _ => unreachable!(),
        };
        enc_of.entry(tok).or_insert(enc);
    }
    let mut seen: HashMap<&[i64], usize> = HashMap::new();
    for enc in enc_of.values() {
        *seen.entry(enc.as_slice()).or_insert(0) += 1;
    }
    let collided: usize = seen.values().filter(|&&c| c > 1).map(|&c| c).sum();
    collided as f64 / enc_of.len() as f64
}

fn main() {
    let rows = 200_000;
    let cardinality = 100_000;
    println!("C4: indexing strategies on a {cardinality}-cardinality categorical ({rows} rows)\n");
    let df = token_data(rows, cardinality);
    let ds = Dataset::from_dataframe(df.clone(), kamae::util::pool::default_threads());
    let mut table = Table::new(&[
        "strategy", "fit ms", "export KiB", "transform Mrows/s", "collision rate",
    ]);
    let mut records = Vec::new();

    // --- full vocabulary ---------------------------------------------------
    let t0 = std::time::Instant::now();
    let vocab_model = StringIndexEstimator::new("t", "idx").fit(&ds).unwrap();
    let fit_ms = t0.elapsed().as_millis();
    let export_kib = vocab_model.save().to_string().len() as f64 / 1024.0;
    let st = Bencher::quick().run("vocab", || {
        let mut d = df.clone();
        vocab_model.transform(&mut d).unwrap();
        black_box(d);
    });
    let mut out = df.clone();
    vocab_model.transform(&mut out).unwrap();
    table.row(&[
        "full vocab".into(),
        fit_ms.to_string(),
        format!("{export_kib:.0}"),
        format!("{:.2}", st.throughput(rows as f64) / 1e6),
        format!("{:.5}", collision_rate(&out, "idx")),
    ]);
    records.push(record(
        "full vocab",
        fit_ms as f64,
        export_kib,
        st.throughput(rows as f64) / 1e6,
        collision_rate(&out, "idx"),
    ));

    // --- hash indexing at several bin counts ---------------------------------
    for &bins in &[1 << 14, 1 << 17, 1 << 20] {
        let t = HashIndexTransformer::new("t", "idx_h", bins);
        let export_kib = t.save().to_string().len() as f64 / 1024.0;
        let st = Bencher::quick().run("hash", || {
            let mut d = df.clone();
            t.transform(&mut d).unwrap();
            black_box(d);
        });
        let mut out = df.clone();
        t.transform(&mut out).unwrap();
        table.row(&[
            format!("hash {}k bins", bins / 1024),
            "0".into(),
            format!("{export_kib:.1}"),
            format!("{:.2}", st.throughput(rows as f64) / 1e6),
            format!("{:.5}", collision_rate(&out, "idx_h")),
        ]);
        records.push(record(
            &format!("hash {}k bins", bins / 1024),
            0.0,
            export_kib,
            st.throughput(rows as f64) / 1e6,
            collision_rate(&out, "idx_h"),
        ));
    }

    // --- bloom encoding: k probes, smaller bin spaces -------------------------
    for &(k, bins) in &[(2usize, 1 << 13), (3, 1 << 12), (4, 1 << 11)] {
        let t = BloomEncodeTransformer::new("t", "idx_b", k, bins);
        let export_kib = t.save().to_string().len() as f64 / 1024.0;
        let st = Bencher::quick().run("bloom", || {
            let mut d = df.clone();
            t.transform(&mut d).unwrap();
            black_box(d);
        });
        let mut out = df.clone();
        t.transform(&mut out).unwrap();
        table.row(&[
            format!("bloom k={k} {}k bins", bins / 1024),
            "0".into(),
            format!("{export_kib:.1}"),
            format!("{:.2}", st.throughput(rows as f64) / 1e6),
            format!("{:.5}", collision_rate(&out, "idx_b")),
        ]);
        records.push(record(
            &format!("bloom k={k} {}k bins", bins / 1024),
            0.0,
            export_kib,
            st.throughput(rows as f64) / 1e6,
            collision_rate(&out, "idx_b"),
        ));
    }

    table.print();
    let path = append_run("indexing", &[("rows", Json::Int(rows as i64))], records)
        .expect("bench trajectory");
    println!("\nappended run to {}", path.display());
    println!("\nshape check: bloom with k*bins << cardinality should reach");
    println!("near-vocab collision rates at a fraction of the embedding rows");
    println!("(k=3 x 4k bins addresses 12k embedding rows vs 100k vocab).");
}
