//! Variant-routed serving benchmark — the gate for per-variant request
//! targeting over one merged multi-variant backend.
//!
//! No artifacts needed: the LTR pipeline is fitted in-process, exported
//! as the full (`ltr`, 30 outputs) and lite (`ltr_lite`, 10 outputs)
//! variants at `OptimizeLevel::Full`, merged
//! (`GraphSpec::merge_variants` + `CrossOutputDedup`) and probed three
//! ways over an IDENTICAL mixed workload (8-row requests, half per
//! variant, coalesced into one mixed batch the way the dynamic batcher
//! does under bursts):
//!
//! * **routed**   — the merged backend's `process_routed`: shared
//!   prefix once over the whole mixed batch, variant-exclusive nodes
//!   only on their variant's rows, each request answered with its
//!   variant's outputs only;
//! * **all-outputs** — the merged backend's plain `process`: every
//!   request pays for (and receives) every variant's outputs — the
//!   PR 3 baseline routing replaces;
//! * **separate** — two dedicated single-variant interpreted backends,
//!   each processing its own variant's sub-batch — the
//!   one-deployment-per-variant baseline.
//!
//! All three run single-threaded through the backends directly, so the
//! comparison measures evaluation work, not thread scheduling. Routed
//! responses are asserted bit-identical to the dedicated backends
//! before any timing runs (the differential harness in
//! `rust/tests/properties.rs` pins the same contract across optimize
//! levels and random interleavings).
//!
//! A second section drives the real `Server` batcher with mixed
//! CLOSED-loop traffic (a bounded in-flight window, routed vs
//! route-off) so the per-variant request/latency split lands in the
//! trajectory records. Closed-loop latencies self-throttle under load —
//! compare them with each other, not with the open-loop Poisson
//! numbers `serving::bench_serve_variants` reports under the same
//! `<spec>/routed` naming.
//!
//! Every run appends machine-readable records to
//! `BENCH_variant_routing.json`.
//!
//! Flags (also settable via env for CI):
//!   --quick / KAMAE_BENCH_QUICK   reduced fit rows + measure time
//!   --gate  / KAMAE_BENCH_GATE    exit non-zero unless routed
//!                                 throughput strictly beats BOTH the
//!                                 all-outputs and the separate-backend
//!                                 baselines

use std::time::Instant;

use kamae::dataframe::DataFrame;
use kamae::engine::Dataset;
use kamae::export::GraphSpec;
use kamae::optim::{optimize, variant_costs, OptimizeLevel};
use kamae::pipeline::catalog;
use kamae::runtime::Tensor;
use kamae::serving::{
    request_pool, Backend, BatchConfig, InterpretedBackend, LatencyRecorder, Server, VariantGroup,
};
use kamae::util::bench::{append_run, fmt_ns, Bencher, Table};
use kamae::util::json::Json;
use kamae::util::rng::Rng;

const ROWS_PER_REQUEST: usize = 8;
/// Requests per mixed batch (half per variant) — the minimal mixed
/// burst the batcher produces when one slate request per variant lands
/// inside a flush window. Small batches are where routing's
/// one-backend-call shape matters most: per-call fixed work (vocab
/// attr parsing, env setup, per-node dispatch) is paid once instead of
/// once per variant backend.
const REQUESTS_PER_BATCH: usize = 2;

/// Fit LTR once and export the three specs the bench compares.
fn build_specs(fit_rows: usize) -> (GraphSpec, GraphSpec, GraphSpec) {
    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig {
        rows: fit_rows,
        ..Default::default()
    });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let (full, _) = model
        .to_graph_spec_opt("ltr", catalog::ltr_inputs(), &catalog::LTR_OUTPUTS, OptimizeLevel::Full)
        .unwrap();
    let (lite, _) = model
        .to_graph_spec_opt(
            "ltr_lite",
            catalog::ltr_inputs(),
            &catalog::LTR_LITE_OUTPUTS,
            OptimizeLevel::Full,
        )
        .unwrap();
    let merged = GraphSpec::merge_variants("ltr+ltr_lite", &[&full, &lite]).unwrap();
    let (merged, _) = optimize(merged, OptimizeLevel::Full).unwrap();
    (full, lite, merged)
}

/// One pre-built mixed batch: the concatenated frame, its per-variant
/// groups, and the per-variant sub-frames the separate baseline serves.
struct MixedBatch {
    merged_df: DataFrame,
    groups: Vec<VariantGroup>,
    full_df: DataFrame,
    lite_df: DataFrame,
}

/// Pre-build the request batches outside the timed loops (request
/// construction is identical across modes and not what this bench
/// measures).
fn build_batches(pool: &DataFrame, count: usize) -> Vec<MixedBatch> {
    let mut rng = Rng::new(0xC0FFEE);
    let mut batches = Vec::with_capacity(count);
    let per_variant = REQUESTS_PER_BATCH / 2;
    for _ in 0..count {
        let mut reqs = Vec::with_capacity(REQUESTS_PER_BATCH);
        for _ in 0..REQUESTS_PER_BATCH {
            let start = rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
            reqs.push(pool.slice(start, ROWS_PER_REQUEST));
        }
        let (full_reqs, lite_reqs) = reqs.split_at(per_variant);
        let full_df = DataFrame::concat(&full_reqs.iter().collect::<Vec<_>>()).unwrap();
        let lite_df = DataFrame::concat(&lite_reqs.iter().collect::<Vec<_>>()).unwrap();
        let merged_df = DataFrame::concat(&[&full_df, &lite_df]).unwrap();
        let split = full_df.num_rows();
        let groups = vec![
            VariantGroup { variant: Some("ltr".into()), rows: 0..split },
            VariantGroup { variant: Some("ltr_lite".into()), rows: split..merged_df.num_rows() },
        ];
        batches.push(MixedBatch { merged_df, groups, full_df, lite_df });
    }
    batches
}

/// Bitwise tensor-list equality via the shared oracle
/// ([`kamae::util::prop::tensors_bit_identical`]), with a context
/// prefix.
fn assert_bit_identical_lists(got: &[Tensor], want: &[Tensor], what: &str) {
    if let Err(e) = kamae::util::prop::tensors_bit_identical(got, want) {
        panic!("{what}: {e}");
    }
}

/// Env flag: set and not "0"/"false"/"" (so KAMAE_BENCH_GATE=0 disables).
fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
        .unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || env_flag("KAMAE_BENCH_QUICK");
    let gate = args.iter().any(|a| a == "--gate") || env_flag("KAMAE_BENCH_GATE");
    let (fit_rows, server_requests) = if quick { (2_000, 400) } else { (20_000, 2_000) };
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    if quick {
        println!("(quick mode: {fit_rows} fit rows)\n");
    }

    let (full, lite, merged) = build_specs(fit_rows);
    println!(
        "merged ltr+ltr_lite: {} ingress + {} graph nodes, {} outputs",
        merged.ingress.len(),
        merged.nodes.len(),
        merged.outputs.len()
    );
    let attribution = variant_costs(&merged);
    for c in &attribution {
        println!(
            "  {:<10} {:>2} outputs  exclusive {:>5}  shared share {:>5}",
            c.variant, c.outputs, c.exclusive, c.shared
        );
    }
    println!();

    let routed_backend = InterpretedBackend::new(merged.clone());
    let all_backend = InterpretedBackend::new(merged.clone());
    let full_backend = InterpretedBackend::new(full.clone());
    let lite_backend = InterpretedBackend::new(lite.clone());

    let pool = request_pool("ltr", 4096).unwrap();
    let batches = build_batches(&pool, 64);

    // ---- differential pin: routed == dedicated, bit for bit -----------
    for batch in batches.iter().take(4) {
        let routed = routed_backend.process_routed(&batch.merged_df, &batch.groups).unwrap();
        let full_out = full_backend.process(&batch.full_df).unwrap();
        let lite_out = lite_backend.process(&batch.lite_df).unwrap();
        assert_bit_identical_lists(&routed[0], &full_out, "ltr routed-vs-dedicated");
        assert_bit_identical_lists(&routed[1], &lite_out, "ltr_lite routed-vs-dedicated");
    }
    println!("differential pin: routed == dedicated backends, bit for bit\n");

    // ---- single-threaded throughput: routed vs both baselines ---------
    let mut idx = 0usize;
    let routed_stats = bencher.run("routed", || {
        let b = &batches[idx % batches.len()];
        idx += 1;
        kamae::util::bench::black_box(
            routed_backend.process_routed(&b.merged_df, &b.groups).unwrap(),
        );
    });
    let mut idx = 0usize;
    let all_stats = bencher.run("all-outputs", || {
        let b = &batches[idx % batches.len()];
        idx += 1;
        // the un-routed baseline serves every output; the per-request
        // split is the client's problem, so process() alone is charged
        kamae::util::bench::black_box(all_backend.process(&b.merged_df).unwrap());
    });
    let mut idx = 0usize;
    let separate_stats = bencher.run("separate", || {
        let b = &batches[idx % batches.len()];
        idx += 1;
        kamae::util::bench::black_box(full_backend.process(&b.full_df).unwrap());
        kamae::util::bench::black_box(lite_backend.process(&b.lite_df).unwrap());
    });

    let rps = |st: &kamae::util::bench::Stats| st.throughput(REQUESTS_PER_BATCH as f64);
    let (routed_rps, all_rps, separate_rps) =
        (rps(&routed_stats), rps(&all_stats), rps(&separate_stats));
    let mut table = Table::new(&["mode", "mean/batch", "p99/batch", "throughput"]);
    for (label, st, r) in [
        ("routed", &routed_stats, routed_rps),
        ("all-outputs", &all_stats, all_rps),
        ("separate", &separate_stats, separate_rps),
    ] {
        table.row(&[
            label.into(),
            fmt_ns(st.mean_ns),
            fmt_ns(st.p99_ns),
            format!("{r:.0} req/s"),
        ]);
    }
    table.print();
    println!(
        "\nrouted vs all-outputs: {:+.1}%   routed vs separate: {:+.1}%\n",
        100.0 * (routed_rps / all_rps - 1.0),
        100.0 * (routed_rps / separate_rps - 1.0)
    );

    // ---- server-driven mixed traffic (batcher + per-variant split) ----
    let mut records = Vec::new();
    for (label, route) in [("routed", true), ("merged-all", false)] {
        let backend = Box::new(InterpretedBackend::new(merged.clone()));
        let server = Server::start(backend, BatchConfig::default()).unwrap();
        let recorder = LatencyRecorder::new();
        let mut rng = Rng::new(0xBEEF);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        // closed loop with a bounded in-flight window: keeps the
        // batcher fed (mixed batches form) without unbounded queueing
        for i in 0..server_requests {
            let start = rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
            let req = pool.slice(start, ROWS_PER_REQUEST);
            let variant = if i % 2 == 0 { "ltr" } else { "ltr_lite" };
            let sent = Instant::now();
            let rx = if route { server.submit_variant(req, variant) } else { server.submit(req) };
            pending.push((sent, variant, rx));
            while pending.len() >= 32 {
                let (sent, variant, rx) = pending.remove(0);
                rx.recv().unwrap().unwrap();
                recorder.record_variant(variant, sent.elapsed());
            }
        }
        for (sent, variant, rx) in pending {
            rx.recv().unwrap().unwrap();
            recorder.record_variant(variant, sent.elapsed());
        }
        let wall = t0.elapsed();
        let busy = server.busy_time();
        let (batches_n, requests_n) = server.counts();
        server.shutdown();
        let report = recorder.report(
            &format!("ltr+ltr_lite/{label}"),
            server_requests,
            wall,
            busy,
        );
        println!("{report}");
        println!(
            "batches {batches_n}  requests {requests_n}  ({:.1} req/batch)\n",
            requests_n as f64 / batches_n.max(1) as f64
        );
        records.push(report.to_json());
    }

    // ---- trajectory + gate ---------------------------------------------
    let mut rec = Json::object();
    rec.set("spec", "ltr+ltr_lite");
    rec.set("mode", "routing-throughput");
    rec.set("requests_per_batch", REQUESTS_PER_BATCH);
    rec.set("rows_per_request", ROWS_PER_REQUEST);
    rec.set("routed_rps", routed_rps);
    rec.set("all_outputs_rps", all_rps);
    rec.set("separate_rps", separate_rps);
    rec.set(
        "variants",
        Json::Array(
            attribution
                .iter()
                .map(|c| {
                    let mut v = Json::object();
                    v.set("variant", c.variant.clone());
                    v.set("outputs", c.outputs);
                    v.set("exclusive_cost", c.exclusive as i64);
                    v.set("shared_cost", c.shared as i64);
                    v
                })
                .collect(),
        ),
    );
    records.push(rec);
    let path = append_run(
        "variant_routing",
        &[("quick", Json::Bool(quick))],
        records,
    )
    .expect("bench trajectory");
    println!("appended run to {}", path.display());

    let mut gate_failures = Vec::new();
    if routed_rps <= all_rps {
        gate_failures.push(format!(
            "routed {routed_rps:.0} req/s does not beat all-outputs {all_rps:.0} req/s"
        ));
    }
    if routed_rps <= separate_rps {
        gate_failures.push(format!(
            "routed {routed_rps:.0} req/s does not beat separate backends {separate_rps:.0} req/s"
        ));
    }
    if gate {
        for f in &gate_failures {
            eprintln!("GATE FAILURE: {f}");
        }
        if !gate_failures.is_empty() {
            std::process::exit(1);
        }
    } else {
        for f in &gate_failures {
            eprintln!("warning (ungated): {f}");
        }
    }
}
