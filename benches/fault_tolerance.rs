//! Fault-tolerance benchmark — the gate for the serving stack's
//! containment claims: a panicking backend call never costs pool
//! capacity or an innocent request, a poison row is isolated by
//! bisection (survivors bit-identical, the row dead-lettered with a
//! `poison` verdict), and a request past its deadline gets a fast typed
//! answer instead of hanging behind a stalled worker.
//!
//! The quickstart pipeline is fitted in-process and served through a
//! [`ChaosBackend`] whose [`FaultPlan`] is **deterministic** — faults
//! key off the backend-call counter or row content, never randomness —
//! so the differential pins reproduce exactly and CI failures replay
//! locally.
//!
//! Phases:
//!
//! 1. **differential pins** (sequential, fully deterministic):
//!    content-keyed poison rows are condemned with exact indices and
//!    dead-lettered, survivors resubmit bit-identical to an un-faulted
//!    oracle; counter-keyed transient panics are forgiven by the
//!    re-probe and served bit-identical; a failing dead-letter sink
//!    costs counter increments, never an answer.
//! 2. **baseline** — clean closed-loop traffic through the chaos
//!    wrapper with an empty plan (the wrapper itself is free).
//! 3. **fault storm** — the same traffic with injected panics, poison
//!    rows and slow batches; throughput must hold >= 90% of baseline
//!    and every request must be answered (counter conservation, zero
//!    lost), with pool capacity intact afterwards.
//! 4. **deadline storm** — every batch stalls longer than the
//!    configured request deadline; expired requests must be answered
//!    promptly by the reaper (expired p99 far below served p99).
//!
//! Every run appends machine-readable records to
//! `BENCH_fault_tolerance.json`.
//!
//! Flags (also settable via env for CI):
//!   --quick / KAMAE_BENCH_QUICK   reduced fit rows + request count
//!   --gate  / KAMAE_BENCH_GATE    exit non-zero on any gate failure

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use kamae::dataframe::{Column, DataFrame};
use kamae::engine::Dataset;
use kamae::error::KamaeError;
use kamae::export::GraphSpec;
use kamae::pipeline::catalog;
use kamae::runtime::Tensor;
use kamae::serving::{
    request_pool, Backend, BatchConfig, ChaosBackend, DeadLetterSink, FailingDeadLetter,
    FaultPlan, InterpretedBackend, LatencyRecorder, MemoryDeadLetter, Server, SpecRegistry,
    DEFAULT_TENANT,
};
use kamae::util::bench::{append_run, Table};
use kamae::util::json::Json;
use kamae::util::prop::tensors_bit_identical;
use kamae::util::rng::Rng;

const ROWS_PER_REQUEST: usize = 8;
const PRODUCERS: usize = 4;
/// Per-producer in-flight window (same shape as `worker_pool.rs`).
const WINDOW: usize = 16;
const POOL_WORKERS: usize = 4;
/// Storm throughput retention the isolated pool must hold.
const MIN_RETENTION: f64 = 0.90;
/// Sentinel price that the poison predicate condemns — far outside
/// anything `request_pool` generates, so clean rows never match.
const POISON_PRICE: f64 = 1.0e18;
/// A response still pending after this long counts as HUNG — the
/// containment contract says that must never happen.
const LOST_AFTER: Duration = Duration::from_secs(30);

type RespRx = std::sync::mpsc::Receiver<kamae::error::Result<Vec<Tensor>>>;

/// Fit quickstart once and export the serving spec.
fn build_spec(fit_rows: usize) -> GraphSpec {
    let data = request_pool("quickstart", fit_rows).unwrap();
    let model = catalog::quickstart_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let outputs = catalog::QUICKSTART_OUTPUTS.to_vec();
    model
        .to_graph_spec("quickstart", catalog::quickstart_inputs(), &outputs)
        .unwrap()
}

/// Content-keyed poison: condemn rows whose price is the sentinel.
fn poison_plan() -> FaultPlan {
    FaultPlan::poison_rows(|df, i| {
        df.column("price")
            .ok()
            .and_then(|c| c.as_f64().ok())
            .is_some_and(|v| v[i] == POISON_PRICE)
    })
}

/// A copy of `df` with the sentinel price written into `idxs`.
fn poison_frame(df: &DataFrame, idxs: &[usize]) -> DataFrame {
    let mut price: Vec<f64> = df.column("price").unwrap().as_f64().unwrap().to_vec();
    let city: Vec<String> = df.column("city").unwrap().as_str().unwrap().to_vec();
    for &i in idxs {
        price[i] = POISON_PRICE;
    }
    DataFrame::new(vec![
        ("price".into(), Column::from_f64(price)),
        ("city".into(), Column::from_str(city)),
    ])
    .unwrap()
}

/// Pool over the quickstart backend wrapped in [`ChaosBackend`].
fn start_chaos(
    spec: &GraphSpec,
    plan: FaultPlan,
    deadline: Option<Duration>,
    sink: Option<Arc<dyn DeadLetterSink>>,
) -> Server {
    let inner: Arc<dyn Backend> = Arc::new(InterpretedBackend::new(spec.clone()));
    let chaos: Arc<dyn Backend> = Arc::new(ChaosBackend::new(inner, plan));
    let registry = SpecRegistry::single(DEFAULT_TENANT, chaos).unwrap();
    Server::start_registry_sink(
        registry,
        BatchConfig { workers: POOL_WORKERS, request_deadline: deadline, ..BatchConfig::default() },
        sink,
    )
    .unwrap()
}

/// Pre-built clean request streams, identical across phases.
fn build_requests(pool: &DataFrame, producers: usize, per_producer: usize) -> Vec<Vec<DataFrame>> {
    let mut rng = Rng::new(0xF00D);
    (0..producers)
        .map(|_| {
            (0..per_producer)
                .map(|_| {
                    let start = rng.below((pool.num_rows() - ROWS_PER_REQUEST) as u64) as usize;
                    pool.slice(start, ROWS_PER_REQUEST)
                })
                .collect()
        })
        .collect()
}

/// What a chaos-phase driver observed. Conservation gate: `ok + poison
/// + expired + other == offered` and `lost == 0`.
#[derive(Default)]
struct Outcome {
    ok: AtomicU64,
    poison: AtomicU64,
    expired: AtomicU64,
    other: AtomicU64,
    lost: AtomicU64,
}

impl Outcome {
    fn answered(&self) -> u64 {
        self.ok.load(Ordering::Relaxed)
            + self.poison.load(Ordering::Relaxed)
            + self.expired.load(Ordering::Relaxed)
            + self.other.load(Ordering::Relaxed)
    }

    fn count(&self, result: &kamae::error::Result<Vec<Tensor>>) {
        let slot = match result {
            Ok(_) => &self.ok,
            Err(KamaeError::PoisonRows(_)) => &self.poison,
            Err(KamaeError::DeadlineExceeded(_)) => &self.expired,
            Err(_) => &self.other,
        };
        slot.fetch_add(1, Ordering::Relaxed);
    }
}

/// Closed-loop driver that tolerates (and tallies) typed fault
/// responses instead of unwrapping. A receiver that stays silent past
/// [`LOST_AFTER`] counts as lost — the gate treats any of those as a
/// containment failure.
fn drive_chaos(
    server: &Server,
    streams: &[Vec<DataFrame>],
    recorder: &LatencyRecorder,
    outcome: &Outcome,
) -> Duration {
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for stream in streams {
            scope.spawn(move || {
                let mut pending: VecDeque<(Instant, RespRx)> = VecDeque::new();
                let mut settle = |pending: &mut VecDeque<(Instant, RespRx)>| {
                    let (sent, rx) = pending.pop_front().unwrap();
                    match rx.recv_timeout(LOST_AFTER) {
                        Ok(result) => {
                            outcome.count(&result);
                            recorder.record(sent.elapsed());
                        }
                        Err(_) => {
                            outcome.lost.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                };
                for df in stream {
                    let rx = server.submit_tenant(df.clone(), DEFAULT_TENANT, None);
                    pending.push_back((Instant::now(), rx));
                    while pending.len() >= WINDOW {
                        settle(&mut pending);
                    }
                }
                while !pending.is_empty() {
                    settle(&mut pending);
                }
            });
        }
    });
    t0.elapsed()
}

/// p-th percentile of an UNSORTED latency sample (sorts a copy).
fn percentile(samples: &[Duration], p: f64) -> Duration {
    if samples.is_empty() {
        return Duration::ZERO;
    }
    let mut sorted = samples.to_vec();
    sorted.sort();
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Env flag: set and not "0"/"false"/"" (so KAMAE_BENCH_GATE=0 disables).
fn env_flag(name: &str) -> bool {
    std::env::var(name)
        .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
        .unwrap_or(false)
}

/// Phase 1a: poison rows are condemned with EXACT indices,
/// dead-lettered with a `poison` verdict, and the resubmitted survivors
/// come back bit-identical to the un-faulted oracle.
fn pin_poison_isolation(spec: &GraphSpec, pool: &DataFrame, oracle: &InterpretedBackend, quick: bool) {
    let sink = Arc::new(MemoryDeadLetter::new(8192));
    let server = start_chaos(
        spec,
        poison_plan(),
        None,
        Some(Arc::clone(&sink) as Arc<dyn DeadLetterSink>),
    );
    let mut rng = Rng::new(0xBADF00D);
    let cases = if quick { 24 } else { 96 };
    let mut poison_total = 0u64;
    for case in 0..cases {
        let rows = 2 + rng.below(11) as usize;
        let start = rng.below((pool.num_rows() - rows) as u64) as usize;
        let clean = pool.slice(start, rows);
        // 1..=rows/2 poison rows at distinct positions, at least one
        let mut keep = vec![true; rows];
        for _ in 0..(1 + rng.below(rows as u64 / 2)) {
            keep[rng.below(rows as u64) as usize] = false;
        }
        let expected: Vec<usize> = (0..rows).filter(|&i| !keep[i]).collect();
        poison_total += expected.len() as u64;
        let bad = poison_frame(&clean, &expected);

        // one request in flight => one job per batch => deterministic
        match server.submit(bad.clone()).recv().unwrap() {
            Err(KamaeError::PoisonRows(mut idx)) => {
                idx.sort_unstable();
                assert_eq!(idx, expected, "pin case {case}: condemned indices");
            }
            other => panic!("pin case {case}: expected PoisonRows, got {other:?}"),
        }
        // the net layer resubmits survivors automatically; do the same
        // by hand and demand bit-identical outputs vs the oracle
        let survivors = bad.filter_rows(&keep).unwrap();
        if survivors.num_rows() > 0 {
            let got = server.submit(survivors).recv().unwrap().unwrap();
            let want = oracle.process(&clean.filter_rows(&keep).unwrap()).unwrap();
            if let Err(e) = tensors_bit_identical(&got, &want) {
                panic!("pin case {case}: survivors vs oracle: {e}");
            }
        }
    }
    assert_eq!(server.poison_rows(), poison_total, "pin: poison_rows counter");
    assert_eq!(sink.len() as u64, poison_total, "pin: every poison row dead-lettered");
    for entry in sink.entries() {
        let rule = entry
            .get("errors")
            .and_then(Json::as_array)
            .and_then(|es| es.first())
            .and_then(|e| e.get("rule"))
            .and_then(Json::as_str)
            .unwrap_or("");
        assert_eq!(rule, "poison", "pin: dead-letter verdict rule");
    }
    server.shutdown();
    println!(
        "pin: {cases} poisoned batches, {poison_total} rows condemned with exact indices + \
         `poison` verdicts, survivors bit-identical to oracle"
    );
}

/// Phase 1b: counter-keyed panics are transient — the bisection
/// re-probe forgives them, every request serves bit-identical, and no
/// row is condemned.
fn pin_transient_forgiveness(spec: &GraphSpec, pool: &DataFrame, oracle: &InterpretedBackend) {
    let server = start_chaos(
        spec,
        FaultPlan { panic_every: 3, ..FaultPlan::default() },
        None,
        None,
    );
    for case in 0..12usize {
        let df = pool.slice(case * ROWS_PER_REQUEST, ROWS_PER_REQUEST);
        let got = server.submit(df.clone()).recv().unwrap().unwrap_or_else(|e| {
            panic!("transient pin case {case}: request not forgiven: {e}")
        });
        let want = oracle.process(&df).unwrap();
        if let Err(e) = tensors_bit_identical(&got, &want) {
            panic!("transient pin case {case}: {e}");
        }
    }
    assert!(server.worker_panics() >= 2, "transient pin: no panics were injected");
    assert_eq!(server.poison_rows(), 0, "transient pin: a transient fault condemned a row");
    let panics = server.worker_panics();
    server.shutdown();
    println!("pin: {panics} injected transient panics all forgiven, zero rows condemned");
}

/// Phase 1c: a dead-letter sink that drops records never fails a
/// request — drops cost exactly one counter increment each.
fn pin_sink_failure_containment(spec: &GraphSpec, pool: &DataFrame) {
    let ring = Arc::new(MemoryDeadLetter::new(64));
    let failing = Arc::new(FailingDeadLetter::new(
        Arc::clone(&ring) as Arc<dyn DeadLetterSink>,
        2,
    ));
    let server = start_chaos(
        spec,
        poison_plan(),
        None,
        Some(Arc::clone(&failing) as Arc<dyn DeadLetterSink>),
    );
    for case in 0..8usize {
        let df = poison_frame(&pool.slice(case * 4, 4), &[1]);
        match server.submit(df).recv().unwrap() {
            Err(KamaeError::PoisonRows(idx)) => assert_eq!(idx, vec![1], "sink pin case {case}"),
            other => panic!("sink pin case {case}: expected PoisonRows, got {other:?}"),
        }
    }
    assert_eq!(failing.dropped(), 4, "sink pin: every 2nd record dropped");
    assert_eq!(failing.errors(), 4, "sink pin: drops surfaced via errors()");
    assert_eq!(ring.len(), 4, "sink pin: surviving records passed through");
    server.shutdown();
    println!("pin: failing dead-letter sink dropped 4/8 records; all 8 requests still answered\n");
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick") || env_flag("KAMAE_BENCH_QUICK");
    let gate = args.iter().any(|a| a == "--gate") || env_flag("KAMAE_BENCH_GATE");
    let (fit_rows, per_producer) = if quick { (2_000, 400) } else { (20_000, 2_000) };
    if quick {
        println!("(quick mode: {fit_rows} fit rows, {per_producer} requests/producer)\n");
    }
    let total_requests = PRODUCERS * per_producer;

    let spec = build_spec(fit_rows);
    println!(
        "quickstart: {} ingress columns, {} graph nodes, {} outputs",
        spec.ingress.len(),
        spec.nodes.len(),
        spec.outputs.len()
    );
    let pool = request_pool("quickstart", 4096).unwrap();
    let streams = build_requests(&pool, PRODUCERS, per_producer);
    let oracle = InterpretedBackend::new(spec.clone());

    // ---- differential pins ------------------------------------------------
    pin_poison_isolation(&spec, &pool, &oracle, quick);
    pin_transient_forgiveness(&spec, &pool, &oracle);
    pin_sink_failure_containment(&spec, &pool);

    // ---- baseline: clean traffic through an empty fault plan --------------
    let (baseline_report, baseline_outcome) = {
        let server = start_chaos(&spec, FaultPlan::default(), None, None);
        let recorder = LatencyRecorder::new();
        let outcome = Outcome::default();
        let wall = drive_chaos(&server, &streams, &recorder, &outcome);
        let worker_busy = server.worker_busy_times();
        server.shutdown();
        assert_eq!(outcome.answered() as usize, total_requests, "baseline lost requests");
        let report =
            recorder.report_pool("quickstart/fault-baseline", total_requests, wall, &worker_busy);
        println!("{report}\n");
        (report, outcome)
    };
    assert_eq!(baseline_outcome.ok.load(Ordering::Relaxed) as usize, total_requests);

    // ---- fault storm: panics + poison rows + slow batches -----------------
    // deterministic positions: 2 poisoned requests per producer, one
    // sentinel row each
    let mut storm_streams = streams.clone();
    let mut poisoned_requests = 0u64;
    for stream in &mut storm_streams {
        for &at in &[per_producer / 3, (2 * per_producer) / 3] {
            stream[at] = poison_frame(&stream[at], &[3]);
            poisoned_requests += 1;
        }
    }
    let storm_plan = FaultPlan {
        panic_every: 50,
        slow_every: Some((100, Duration::from_micros(200))),
        ..poison_plan()
    };
    let (storm_report, storm_outcome, storm_panics, storm_poison_rows) = {
        let sink = Arc::new(MemoryDeadLetter::new(8192));
        let server = start_chaos(
            &spec,
            storm_plan,
            None,
            Some(Arc::clone(&sink) as Arc<dyn DeadLetterSink>),
        );
        let recorder = LatencyRecorder::new();
        let outcome = Outcome::default();
        let wall = drive_chaos(&server, &storm_streams, &recorder, &outcome);
        let worker_busy = server.worker_busy_times();
        // capacity intact: every supervised worker still drains after
        // the storm — a clean request round-trips
        assert_eq!(server.workers(), POOL_WORKERS, "storm: pool capacity decayed");
        let live = server.submit(pool.slice(0, ROWS_PER_REQUEST)).recv().unwrap();
        assert!(live.is_ok(), "storm: pool not live after faults: {live:?}");
        let (panics, poison_rows) = (server.worker_panics(), server.poison_rows());
        server.shutdown();
        assert_eq!(sink.len() as u64, poison_rows, "storm: poison rows dead-lettered");
        let report =
            recorder.report_pool("quickstart/fault-storm", total_requests, wall, &worker_busy);
        println!("{report}\n");
        (report, outcome, panics, poison_rows)
    };

    // ---- deadline storm: every batch stalls past the deadline -------------
    let deadline = Duration::from_millis(4);
    let stall = Duration::from_millis(15);
    let deadline_per_producer = if quick { 60 } else { 150 };
    let (served_lat, expired_lat, deadline_expired_count) = {
        let plan = FaultPlan { slow_every: Some((1, stall)), ..FaultPlan::default() };
        let server = start_chaos(&spec, plan, Some(deadline), None);
        let served: Mutex<Vec<Duration>> = Mutex::new(Vec::new());
        let expired: Mutex<Vec<Duration>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for p in 0..PRODUCERS {
                let (server, pool) = (&server, &pool);
                let (served, expired) = (&served, &expired);
                scope.spawn(move || {
                    let mut pending: VecDeque<(Instant, RespRx)> = VecDeque::new();
                    let mut settle = |pending: &mut VecDeque<(Instant, RespRx)>| {
                        let (sent, rx) = pending.pop_front().unwrap();
                        match rx.recv_timeout(LOST_AFTER) {
                            Ok(Ok(_)) => served.lock().unwrap().push(sent.elapsed()),
                            Ok(Err(KamaeError::DeadlineExceeded(_))) => {
                                expired.lock().unwrap().push(sent.elapsed())
                            }
                            Ok(Err(e)) => panic!("deadline storm: unexpected error {e}"),
                            Err(_) => panic!("deadline storm: request hung"),
                        }
                    };
                    for i in 0..deadline_per_producer {
                        let start = ((p * deadline_per_producer + i) * ROWS_PER_REQUEST)
                            % (pool.num_rows() - ROWS_PER_REQUEST);
                        let rx = server.submit(pool.slice(start, ROWS_PER_REQUEST));
                        pending.push_back((Instant::now(), rx));
                        while pending.len() >= WINDOW {
                            settle(&mut pending);
                        }
                    }
                    while !pending.is_empty() {
                        settle(&mut pending);
                    }
                });
            }
        });
        let count = server.deadline_expired();
        server.shutdown();
        (served.into_inner().unwrap(), expired.into_inner().unwrap(), count)
    };
    let served_p99 = percentile(&served_lat, 0.99);
    let expired_p99 = percentile(&expired_lat, 0.99);
    println!(
        "deadline storm ({}ms deadline vs {}ms batches): {} served (p99 {:.1}ms), {} expired \
         (p99 {:.1}ms, typed 504)\n",
        deadline.as_millis(),
        stall.as_millis(),
        served_lat.len(),
        ms(served_p99),
        expired_lat.len(),
        ms(expired_p99),
    );

    // ---- summary ----------------------------------------------------------
    let baseline_rps = baseline_report.throughput_rps;
    let storm_rps = storm_report.throughput_rps;
    let retention = if baseline_rps > 0.0 { storm_rps / baseline_rps } else { 0.0 };
    let mut table = Table::new(&["mode", "throughput", "vs baseline"]);
    for (label, r) in [("baseline (no faults)", baseline_rps), ("fault storm", storm_rps)] {
        table.row(&[
            label.into(),
            format!("{r:.0} req/s"),
            format!("{:+.1}%", 100.0 * (r / baseline_rps - 1.0)),
        ]);
    }
    table.print();
    println!(
        "\nstorm retention: {:.1}% (gate: >= {:.0}%); {} panics caught, {} poison rows \
         condemned, {} poisoned requests answered, {} other errors, {} lost",
        100.0 * retention,
        100.0 * MIN_RETENTION,
        storm_panics,
        storm_poison_rows,
        storm_outcome.poison.load(Ordering::Relaxed),
        storm_outcome.other.load(Ordering::Relaxed),
        storm_outcome.lost.load(Ordering::Relaxed),
    );

    // ---- trajectory + gate ------------------------------------------------
    let mut records = vec![baseline_report.to_json(), storm_report.to_json()];
    let mut rec = Json::object();
    rec.set("spec", "quickstart");
    rec.set("mode", "fault-tolerance");
    rec.set("producers", PRODUCERS);
    rec.set("window", WINDOW);
    rec.set("rows_per_request", ROWS_PER_REQUEST);
    rec.set("pool_workers", POOL_WORKERS);
    rec.set("baseline_rps", baseline_rps);
    rec.set("storm_rps", storm_rps);
    rec.set("retention", retention);
    rec.set("storm_panics", storm_panics as i64);
    rec.set("storm_poison_rows", storm_poison_rows as i64);
    rec.set("storm_lost", storm_outcome.lost.load(Ordering::Relaxed) as i64);
    rec.set("deadline_served", served_lat.len() as i64);
    rec.set("deadline_expired", deadline_expired_count as i64);
    rec.set("served_p99_ms", ms(served_p99));
    rec.set("expired_p99_ms", ms(expired_p99));
    records.push(rec);
    let path = append_run("fault_tolerance", &[("quick", Json::Bool(quick))], records)
        .expect("bench trajectory");
    println!("appended run to {}", path.display());

    let mut gate_failures = Vec::new();
    if storm_rps < MIN_RETENTION * baseline_rps {
        gate_failures.push(format!(
            "storm throughput {storm_rps:.0} req/s fell below {:.0}% of the clean baseline \
             {baseline_rps:.0} req/s ({:.1}% retention)",
            100.0 * MIN_RETENTION,
            100.0 * retention
        ));
    }
    let lost = storm_outcome.lost.load(Ordering::Relaxed);
    if lost > 0 {
        gate_failures.push(format!("{lost} storm request(s) hung past {LOST_AFTER:?}"));
    }
    if storm_outcome.answered() as usize != total_requests {
        gate_failures.push(format!(
            "storm conservation: {} answered of {total_requests} offered",
            storm_outcome.answered()
        ));
    }
    if storm_panics == 0 {
        gate_failures.push("storm injected no panics (plan mis-wired?)".into());
    }
    if storm_outcome.poison.load(Ordering::Relaxed) != poisoned_requests {
        gate_failures.push(format!(
            "storm: {} poisoned requests offered but {} PoisonRows answers",
            poisoned_requests,
            storm_outcome.poison.load(Ordering::Relaxed)
        ));
    }
    if served_lat.is_empty() || expired_lat.is_empty() || deadline_expired_count == 0 {
        gate_failures.push(format!(
            "deadline storm did not produce both outcomes ({} served, {} expired)",
            served_lat.len(),
            expired_lat.len()
        ));
    } else if ms(expired_p99) * 2.0 >= ms(served_p99) {
        gate_failures.push(format!(
            "expired p99 {:.1}ms is not far below served p99 {:.1}ms — the reaper is not \
             answering aged-out requests promptly",
            ms(expired_p99),
            ms(served_p99)
        ));
    }
    if gate {
        for f in &gate_failures {
            eprintln!("GATE FAILURE: {f}");
        }
        if !gate_failures.is_empty() {
            std::process::exit(1);
        }
    } else {
        for f in &gate_failures {
            eprintln!("warning (ungated): {f}");
        }
    }
}
