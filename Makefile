# Kamae-RS build/verify entry points.
#
# `make verify` is the tier-1 gate (ROADMAP.md): release build, tests,
# formatting. `make artifacts` produces the spec JSONs + AOT-compiled
# HLO the serving benchmarks and parity tests consume.
#
# NOTE: the seed tree ships without a Cargo.toml — the build image
# provides the manifest wiring the in-tree `xla` (PJRT) dependency.
# When adding one: lib path rust/src/lib.rs, bin path rust/src/main.rs,
# and `harness = false` [[bench]]/[[example]] entries for everything
# under benches/ and examples/ (each defines its own `fn main`).

.PHONY: verify build test fmt bench-optimizer bench-variant-routing bench-worker-pool bench-net-serving bench-kernel-program bench-hot-swap bench-ingress-validation bench-fault-tolerance bench-smoke bench-all artifacts clean

verify:
	cargo build --release
	cargo test -q
	cargo fmt --check
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt

# Optimizer node counts + interpreted-backend throughput, passes on vs
# off; appends a record to BENCH_optimizer.json.
bench-optimizer:
	cargo bench --bench optimizer

# Variant-routed serving over the merged ltr+ltr_lite backend: routed
# mixed-variant throughput vs the all-outputs-per-request and
# separate-backend baselines; appends to BENCH_variant_routing.json.
bench-variant-routing:
	cargo bench --bench variant_routing

# Worker-pool serving: 4 batcher threads over one shared merged backend
# vs 1 worker vs the legacy single-thread mpsc batcher, pooled responses
# pinned bit-for-bit against dedicated backends first; appends to
# BENCH_worker_pool.json.
bench-worker-pool:
	cargo bench --bench worker_pool

# HTTP front-end serving: a real listener on an ephemeral port driven by
# closed-loop keep-alive clients — wire responses pinned bit-for-bit
# against dedicated backends, saturation throughput, then a deliberate
# overload phase where sheds must be 429 + Retry-After with p99 an order
# of magnitude below accepted p99; appends to BENCH_net_serving.json.
bench-net-serving:
	cargo bench --bench net_serving

# Kernel-program serving: the compiled columnar hot path vs the
# eval_node oracle over the merged LTR backend, pinned bit-for-bit
# first, gated at >= 2x routed throughput; appends to
# BENCH_kernel_program.json.
bench-kernel-program:
	cargo bench --bench kernel_program

# Registry hot-swap serving: closed-loop routed traffic against a
# registry-resolved tenant while a deployer thread swaps the active
# version every few ms (plus periodic full rebuilds), responses pinned
# bit-for-bit against dedicated oracles DURING the storm, gated at
# >= 90% of the no-swap baseline throughput with zero lost requests and
# bounded swap visibility; appends to BENCH_hot_swap.json.
bench-hot-swap:
	cargo bench --bench hot_swap

# Ingress data-quality gate: randomly corrupted batches through the
# validated submit path first (surviving rows pinned bit-for-bit
# against an uncorrupted oracle, every quarantined row dead-lettered
# with rule + column), then identical clean traffic driven closed-loop
# through the ungated vs validated paths, gated at >= 95% throughput
# retention; appends to BENCH_ingress_validation.json.
bench-ingress-validation:
	cargo bench --bench ingress_validation

# Fault containment: deterministic poison/transient/sink-failure pins
# first (exact condemned indices, survivors bit-identical to an
# un-faulted oracle, forgiven transients, a dropping sink never failing
# a request), then a fault storm (injected panics + poison rows + slow
# batches) gated at >= 90% of clean throughput with every request
# answered and pool capacity intact, and a deadline storm gated on
# expired-504 p99 far below served p99; appends to
# BENCH_fault_tolerance.json.
bench-fault-tolerance:
	cargo bench --bench fault_tolerance

# CI smoke flavour of the gated benches: reduced rows/requests, exits
# non-zero if optimized throughput regresses below the unoptimized
# baseline, if multilane-bucketize / cross-output-dedup fail to fire on
# the LTR catalog, if the full pass set does not beat the PR 2 pass
# set's cost estimate, if variant-routed serving fails to strictly
# beat the all-outputs and separate-backend baselines, if the
# 4-worker pool fails to strictly beat 1 worker / 1 worker regresses
# against the single-thread baseline, if the HTTP listener fails to
# shed under overload / sheds too slowly, or if the kernel program
# fails to compile for / outpace the eval_node oracle on the LTR
# catalog, or if hot-swapping the registry's active version under load
# costs more than 10% throughput, loses a request, or stalls a swap
# past its visibility bound, or if screening every batch through the
# ingress data-quality gate costs clean traffic more than 5% throughput
# (the gates the bench-smoke CI job enforces), or if the fault storm
# drops throughput below 90% of clean baseline / loses a request /
# leaves a deadline answer slow.
bench-smoke:
	KAMAE_BENCH_QUICK=1 KAMAE_BENCH_GATE=1 cargo bench --bench optimizer
	KAMAE_BENCH_QUICK=1 KAMAE_BENCH_GATE=1 cargo bench --bench variant_routing
	KAMAE_BENCH_QUICK=1 KAMAE_BENCH_GATE=1 cargo bench --bench worker_pool
	KAMAE_BENCH_QUICK=1 KAMAE_BENCH_GATE=1 cargo bench --bench net_serving
	KAMAE_BENCH_QUICK=1 KAMAE_BENCH_GATE=1 cargo bench --bench kernel_program
	KAMAE_BENCH_QUICK=1 KAMAE_BENCH_GATE=1 cargo bench --bench hot_swap
	KAMAE_BENCH_QUICK=1 KAMAE_BENCH_GATE=1 cargo bench --bench ingress_validation
	KAMAE_BENCH_QUICK=1 KAMAE_BENCH_GATE=1 cargo bench --bench fault_tolerance

# Every bench, each appending a record to its BENCH_<name>.json
# trajectory file (serving benches skip themselves without artifacts).
bench-all: bench-optimizer bench-variant-routing bench-worker-pool bench-net-serving bench-kernel-program bench-hot-swap bench-ingress-validation bench-fault-tolerance
	cargo bench --bench movielens_pipeline
	cargo bench --bench native_vs_udf
	cargo bench --bench indexing
	cargo bench --bench fit_scaling
	cargo bench --bench serving_latency
	cargo bench --bench serving_throughput

# Fit the example pipelines, export (optimized) GraphSpec JSONs, then
# AOT-lower them to HLO text via the python L2 compiler.
artifacts:
	cargo run --release -- export-examples --out-dir artifacts/specs
	cd python && python -m compile.aot --specs ../artifacts/specs --out ../artifacts

clean:
	cargo clean
	rm -rf artifacts
