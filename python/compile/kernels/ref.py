"""Pure-jnp / pure-python oracles for the Pallas kernels.

These are the CORE correctness pins: `ref_bucket_py` is a transliteration
of ``rust/src/ops/hash.rs::bucket`` using python big-int arithmetic (no
numpy wrapping subtleties), so a kernel↔ref match here plus the Rust
parity test closes the loop Rust ⇄ JAX bit-exactly.
"""

import jax.numpy as jnp

from .preprocess import MIX

_U64 = (1 << 64) - 1


def fnv1a64(s: str) -> int:
    """FNV-1a 64 over UTF-8 bytes, top bit cleared — mirrors
    rust/src/ops/hash.rs::fnv1a64 (test utility: ingress hashing is
    Rust-side in production)."""
    h = 0xCBF29CE484222325
    for b in s.encode("utf-8"):
        h ^= b
        h = (h * 0x100000001B3) & _U64
    return h & 0x7FFFFFFFFFFFFFFF


def ref_bucket_py(h: int, k: int, bins: int) -> int:
    """Python big-int transliteration of hash.rs::bucket."""
    h &= _U64
    mixed = ((h * MIX[2]) & _U64) ^ (h >> 33)
    mixed = ((mixed * MIX[k % len(MIX)]) & _U64) >> 33
    return mixed % bins


def ref_hash_bucket(h, bins: int, k: int = 0):
    """Vectorised jnp reference (uint64 arithmetic)."""
    hu = h.astype(jnp.uint64)
    mixed = (hu * jnp.uint64(MIX[2])) ^ (hu >> jnp.uint64(33))
    mixed = (mixed * jnp.uint64(MIX[k % len(MIX)])) >> jnp.uint64(33)
    return (mixed % jnp.uint64(bins)).astype(jnp.int64)


def ref_bloom_probes(h, num_hashes: int, bins: int):
    cols = [jnp.int64(j * bins) + ref_hash_bucket(h, bins, j) for j in range(num_hashes)]
    return jnp.stack(cols, axis=-1)


def ref_affine_scale(x, scale, shift):
    x2 = x.astype(jnp.float32)
    if x2.ndim == 1:
        return x2 * scale.astype(jnp.float32)[0] + shift.astype(jnp.float32)[0]
    return x2 * scale.astype(jnp.float32)[None, :] + shift.astype(jnp.float32)[None, :]
