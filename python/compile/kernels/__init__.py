"""L1 Pallas kernels for the preprocessing hot-spots.

`preprocess` holds the Pallas implementations (hash bucketing, bloom
probes, fused affine scaling); `ref` holds the pure-jnp oracles used by
pytest to pin the kernels down. Kernels run with ``interpret=True`` —
the CPU PJRT plugin cannot execute Mosaic custom-calls; on a real TPU
the same `pallas_call`s lower natively (structure notes in each
docstring, perf estimates in DESIGN.md §Perf).
"""

from . import preprocess, ref  # noqa: F401
