"""Pallas kernels for the preprocessing graph's compute hot-spots.

Three kernels cover the profiled hot ops of exported Kamae pipelines:

* ``hash_bucket``  — multiply-shift mixing of 64-bit token hashes into
  ``[0, bins)`` (HashIndexTransformer, OOV bucketing).
* ``bloom_probes`` — k independent mixes per token, probe j offset into
  ``[j*bins, (j+1)*bins)`` (BloomEncodeTransformer).
* ``affine_scale`` — fused ``x*scale + shift`` with per-position
  constants (StandardScale / MinMaxScale; the paper's assemble→scale→
  disassemble chain collapses into this one kernel).

Bit-exactness contract: the integer mixing here must match
``rust/src/ops/hash.rs::bucket`` exactly (same constants, wrapping u64
multiplies, *logical* right shifts). The pytest suite checks the kernels
against ``ref.py``; the Rust parity test then checks the whole compiled
graph against the engine.

TPU-structure notes (§Hardware-Adaptation): kernels are written over
flat (N,)/(N,W) blocks sized to VMEM. On CPU they run in interpret
mode; on TPU, `hash_bucket` at block 8×128 i64 uses ~8 KiB VMEM in +
8 KiB out, `affine_scale` streams (8,128) f32 tiles with the (1,W)
constant rows resident — both far under the ~16 MiB/core budget, so the
grid is purely bandwidth-bound (estimates in DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Odd 64-bit mixing constants — MUST match rust/src/ops/hash.rs::MIX.
MIX = (
    0xFF51AFD7ED558CCD,
    0xC4CEB9FE1A85EC53,
    0x9E3779B97F4A7C15,
    0xBF58476D1CE4E5B9,
    0x94D049BB133111EB,
    0x2545F4914F6CDD1D,
    0xD6E8FEB86659FD93,
    0xA5CB9243F0AEF993,
)


def _mix_bucket(h_u64, k: int, bins: int):
    """The shared mixing body: ((h*MIX2 ^ h>>33) * MIX[k]) >>33 mod bins.

    Operates on uint64 so multiplies wrap and shifts are logical,
    matching Rust's `wrapping_mul` / `>>` on u64 exactly.
    """
    mixed = (h_u64 * jnp.uint64(MIX[2])) ^ (h_u64 >> jnp.uint64(33))
    mixed = (mixed * jnp.uint64(MIX[k % len(MIX)])) >> jnp.uint64(33)
    return (mixed % jnp.uint64(bins)).astype(jnp.int64)


# ---------------------------------------------------------------------------
# hash_bucket


def _hash_bucket_kernel(h_ref, o_ref, *, k: int, bins: int):
    h = h_ref[...].astype(jnp.uint64)
    o_ref[...] = _mix_bucket(h, k, bins)


def hash_bucket(h, bins: int, k: int = 0):
    """Token hashes (any shape, int64) -> bin indices in [0, bins)."""
    kernel = functools.partial(_hash_bucket_kernel, k=k, bins=bins)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(h.shape, jnp.int64),
        interpret=True,
    )(h)


# ---------------------------------------------------------------------------
# bloom_probes


def _bloom_kernel(h_ref, o_ref, *, num_hashes: int, bins: int):
    h = h_ref[...].astype(jnp.uint64)  # (N,)
    # vectorise probes across a new trailing axis: each probe j is an
    # independent mix, offset into its own bin space. On TPU the probe
    # axis maps onto lanes; no loop-carried state.
    cols = []
    for j in range(num_hashes):
        cols.append(jnp.int64(j * bins) + _mix_bucket(h, j, bins))
    o_ref[...] = jnp.stack(cols, axis=-1)


def bloom_probes(h, num_hashes: int, bins: int):
    """Token hashes (N,) int64 -> (N, num_hashes) probe indices."""
    kernel = functools.partial(_bloom_kernel, num_hashes=num_hashes, bins=bins)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((*h.shape, num_hashes), jnp.int64),
        interpret=True,
    )(h)


# ---------------------------------------------------------------------------
# affine_scale


def _affine_kernel(x_ref, scale_ref, shift_ref, o_ref):
    o_ref[...] = x_ref[...] * scale_ref[...] + shift_ref[...]


def affine_scale(x, scale, shift):
    """Fused x*scale + shift.

    x: (N,) or (N, W) float32; scale/shift: (W,) float32 broadcast over
    rows (W = 1 for scalar features).
    """
    x2 = x if x.ndim == 2 else x[:, None]
    s2 = jnp.broadcast_to(scale.astype(jnp.float32), (1, x2.shape[1]))
    t2 = jnp.broadcast_to(shift.astype(jnp.float32), (1, x2.shape[1]))
    out = pl.pallas_call(
        _affine_kernel,
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        interpret=True,
    )(x2.astype(jnp.float32), s2, t2)
    return out if x.ndim == 2 else out[:, 0]
