"""Build-time compiler: GraphSpec JSON -> JAX -> HLO text artifacts.

This package is the L2/L1 half of the reproduction. It never runs at
serving time: `make artifacts` invokes `aot.py` once, and the Rust
binary loads the resulting `artifacts/*.hlo.txt` through PJRT.
"""

import jax

# The whole stack computes token hashes and date math on int64; x64 must
# be enabled before anything traces.
jax.config.update("jax_enable_x64", True)
