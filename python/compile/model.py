"""L2: compile a GraphSpec (exported by the Rust engine) into a JAX
function — the analogue of Kamae's `build_keras_model()`.

The compiled function takes the spec's `graph_inputs` as positional
arrays (float32 / int64; scalar features (B,), sequence features (B,W))
and returns the spec's `outputs` as a tuple. String handling never
reaches this layer: the Rust ingress already hashed/split/parsed
everything (DESIGN.md §Substitutions).

Each op here mirrors `rust/src/export/interp.rs::eval_node` — that
interpreter plus the parity tests are the ground truth for semantics.
The hot ops (hash_bucket, bloom_encode, scale_vec) call the L1 Pallas
kernels.
"""

import json
import math

import jax.numpy as jnp

from .kernels import preprocess as K

# ---------------------------------------------------------------------------
# date math (mirrors rust/src/ops/date.rs, all int64 floor-division)


def _civil_from_days(z):
    z = z + 719_468
    era = z // 146_097
    doe = z - era * 146_097
    yoe = (doe - doe // 1460 + doe // 36524 - doe // 146_096) // 365
    y = yoe + era * 400
    doy = doe - (365 * yoe + yoe // 4 - yoe // 100)
    mp = (5 * doy + 2) // 153
    d = doy - (153 * mp + 2) // 5 + 1
    m = jnp.where(mp < 10, mp + 3, mp - 9)
    y = jnp.where(m <= 2, y + 1, y)
    return y, m, d


def _days_from_civil(y, m, d):
    y = jnp.where(m <= 2, y - 1, y)
    era = y // 400
    yoe = y - era * 400
    mp = jnp.where(m > 2, m - 3, m + 9)
    doy = (153 * mp + 2) // 5 + d - 1
    doe = yoe * 365 + yoe // 4 - yoe // 100 + doy
    return era * 146_097 + doe - 719_468


def _date_part(z, part: str):
    if part == "year":
        return _civil_from_days(z)[0]
    if part == "month":
        return _civil_from_days(z)[1]
    if part == "day":
        return _civil_from_days(z)[2]
    if part == "weekday":
        return (z + 3) % 7 + 1
    if part == "day_of_year":
        y, _, _ = _civil_from_days(z)
        return z - _days_from_civil(y, jnp.int64(1), jnp.int64(1)) + 1
    raise ValueError(f"unknown date part: {part}")


# ---------------------------------------------------------------------------
# op table

_F = jnp.float32
_I = jnp.int64


def _f(x):
    return x.astype(_F)


def _bcast(x, y):
    """Row-broadcast for list∘scalar mixes: (B,W)∘(B,) -> (B,W)."""
    if x.ndim == 2 and y.ndim == 1:
        return x, y[:, None]
    if x.ndim == 1 and y.ndim == 2:
        return x[:, None], y
    return x, y


def _unary(fn):
    return lambda args, a: fn(_f(args[0]), a)


_UNARY = {
    "log": lambda x, a: jnp.log(x) if a.get("base") is None else jnp.log(x) / _F(math.log(a["base"])),
    "log1p": lambda x, a: jnp.log1p(x),
    "exp": lambda x, a: jnp.exp(x),
    "sqrt": lambda x, a: jnp.sqrt(x),
    "abs": lambda x, a: jnp.abs(x),
    "neg": lambda x, a: -x,
    "reciprocal": lambda x, a: 1.0 / x,
    "round": lambda x, a: jnp.round(x),  # half-to-even, like the engine
    "floor": lambda x, a: jnp.floor(x),
    "ceil": lambda x, a: jnp.ceil(x),
    "sin": lambda x, a: jnp.sin(x),
    "cos": lambda x, a: jnp.cos(x),
    "tanh": lambda x, a: jnp.tanh(x),
    "sigmoid": lambda x, a: 1.0 / (1.0 + jnp.exp(-x)),
    "clip": lambda x, a: jnp.clip(
        x,
        _F(a["min"]) if a.get("min") is not None else None,
        _F(a["max"]) if a.get("max") is not None else None,
    ),
    "pow_scalar": lambda x, a: jnp.power(x, _F(a["p"])),
    "add_scalar": lambda x, a: x + _F(a["c"]),
    "sub_scalar": lambda x, a: x - _F(a["c"]),
    "mul_scalar": lambda x, a: x * _F(a["c"]),
    "div_scalar": lambda x, a: x / _F(a["c"]),
    "scale_shift": lambda x, a: x * _F(a["scale"]) + _F(a["shift"]),
}

_BINARY = {
    "add": lambda x, y: x + y,
    "sub": lambda x, y: x - y,
    "mul": lambda x, y: x * y,
    "div": lambda x, y: x / y,
    "pow": jnp.power,
    "min": jnp.minimum,
    "max": jnp.maximum,
    "mod": jnp.mod,  # python-style sign, matching the engine
}

_CMP = {
    "eq": lambda x, y: x == y,
    "ne": lambda x, y: x != y,
    "lt": lambda x, y: x < y,
    "le": lambda x, y: x <= y,
    "gt": lambda x, y: x > y,
    "ge": lambda x, y: x >= y,
}


def _bsearch(table, x, side: str):
    """Unrolled branchless binary search (jnp.searchsorted replacement).

    jnp.searchsorted lowers to a scan/while whose HLO miscompiles on the
    xla_extension 0.5.1 CPU runtime for large constant tables (found-mask
    silently all-false); ceil(log2 n)+1 unrolled where-steps are immune,
    fully vectorised, and map cleanly onto TPU vector units.
    """
    n = table.shape[0]
    iters = max(1, (n).bit_length() + 1)
    lo = jnp.zeros(x.shape, dtype=_I)
    hi = jnp.full(x.shape, n, dtype=_I)
    for _ in range(iters):
        mid = (lo + hi) >> 1
        probe = table[jnp.minimum(mid, n - 1)]
        go_right = (probe <= x) if side == "right" else (probe < x)
        cond = lo < hi
        lo = jnp.where(cond & go_right, mid + 1, lo)
        hi = jnp.where(cond & (~go_right), mid, hi)
    return lo


def _vocab_found(hashes, x):
    """Sorted-table membership: (found_mask, rank_at_position)."""
    table = jnp.asarray(hashes, dtype=_I)
    idx = _bsearch(table, x, side="left")
    idx_c = jnp.clip(idx, 0, len(hashes) - 1)
    found = table[idx_c] == x
    return found, idx_c


def _op_vocab_lookup(args, a):
    x = args[0]
    hashes, ranks = a["vocab_hashes"], a["vocab_ranks"]
    num_oov, base = int(a["num_oov"]), int(a["base"])
    rank_table = jnp.asarray(ranks, dtype=_I)
    if len(hashes) > 0:
        found, pos = _vocab_found(hashes, x)
        in_vocab = base + num_oov + rank_table[pos]
    else:
        found = jnp.zeros(x.shape, dtype=bool)
        in_vocab = jnp.zeros(x.shape, dtype=_I)
    oov = base + K.hash_bucket(x, num_oov)
    out = jnp.where(found, in_vocab, oov)
    if a.get("mask_hash") is not None:
        out = jnp.where(x == jnp.int64(a["mask_hash"]), jnp.int64(0), out)
    return out


def _op_one_hot(args, a):
    x = args[0]
    hashes, ranks = a["vocab_hashes"], a["vocab_ranks"]
    num_oov = int(a["num_oov"])
    drop = bool(a.get("drop_unseen", False))
    depth = len(hashes) if drop else num_oov + len(hashes)
    rank_table = jnp.asarray(ranks, dtype=_I)
    found, pos = _vocab_found(hashes, x)
    rank = rank_table[pos]
    hot_vocab = rank if drop else num_oov + rank
    if drop:
        hot = jnp.where(found, hot_vocab, -1)  # -1 -> all-zero row
    else:
        hot = jnp.where(found, hot_vocab, K.hash_bucket(x, num_oov))
    eye = jnp.arange(depth, dtype=_I)
    return (hot[..., None] == eye).astype(_F)


def _op_affine(args, a):
    """Fused scalar-affine chain (rust `optim::passes::AffineFuse`).

    `steps` records the original op/constant sequence; replaying it in
    f32 reproduces the unfused nodes bit-for-bit. The canonical
    standard-scaling shape — a multiply followed by an add/sub — lowers
    onto the fused-scaling Pallas kernel instead (one kernel, same
    semantics as `scale_vec`; the kernel's FMA contraction may differ
    from the two-op form in the last ulp, exactly like `scale_vec`
    already does, and well inside the C1 parity tolerance).
    """
    x = _f(args[0])
    steps = a["steps"]
    ops = [s["op"] for s in steps]
    if ops in (["mul_scalar", "add_scalar"], ["mul_scalar", "sub_scalar"]):
        scale = jnp.asarray([steps[0]["c"]], dtype=_F)
        sign = 1.0 if ops[1] == "add_scalar" else -1.0
        shift = jnp.asarray([sign * steps[1]["c"]], dtype=_F)
        return K.affine_scale(x, scale, shift)
    for s in steps:
        x = _UNARY[s["op"]](x, s)
    return x


def _op_impute(args, a):
    x = _f(args[0])
    missing = jnp.isnan(x)
    if a.get("mask_value") is not None:
        missing = missing | (x == _F(a["mask_value"]))
    return jnp.where(missing, _F(a["fill"]), x)


def _eval_lanes(node, args, a):
    """Multi-output node -> [(lane_name, value)].

    Mirrors ``rust/src/export/interp.rs::eval_multi``: the only
    multi-output op is the multi-lane ``multi_bucketize`` produced by the
    rust ``MultiLaneBucketize`` pass. ONE branchless ``_bsearch`` over
    the merged splits table feeds every lane:

    * ``bucket`` lanes gather their original bucket index through the
      lane's ``remap`` table (composing ``bucketize``'s lowering exactly),
    * ``compare`` lanes replay ``compare_scalar``'s f32 compare on the
      raw input (they share the node, not the search),
    * ``bucket_compare`` lanes compose the remap gather with
      ``multi_bucketize``'s threshold compare, op for op.
    """
    if node["op"] != "multi_bucketize":
        raise ValueError(f"multi-output graph op: {node['op']}")
    x = _f(args[0])
    m = _bsearch(jnp.asarray(a["splits"], dtype=_F), x, side="right")
    out = []
    for lane in node["lanes"]:
        la = lane["attrs"]
        kind = la["kind"]
        if kind == "bucket":
            val = jnp.asarray(la["remap"], dtype=_I)[m]
        elif kind == "compare":
            val = _CMP[la["op"]](x, _F(la["value"])).astype(_I)
        elif kind == "bucket_compare":
            bucket = jnp.asarray(la["remap"], dtype=_I)[m]
            val = _CMP[la["op"]](_f(bucket), _F(la["value"])).astype(_I)
        else:
            raise ValueError(f"multi_bucketize lane kind: {kind}")
        out.append((lane["name"], val))
    return out


_OPS = {
    "identity": lambda args, a: args[0],
    "to_f32": lambda args, a: _f(args[0]),
    "to_i64": lambda args, a: args[0].astype(_I),  # trunc toward zero
    "bucketize": lambda args, a: _bsearch(
        jnp.asarray(a["splits"], dtype=_F), _f(args[0]), side="right"
    ),
    # fused compare_scalar(bucketize(x)) — rust optim::passes::BucketizeMerge.
    # One branchless _bsearch over the sorted splits feeding the threshold
    # compare directly; composes the two ops' lowerings exactly, so parity
    # with the unfused ladder is op-for-op.
    "multi_bucketize": lambda args, a: _CMP[a["op"]](
        _f(_bsearch(jnp.asarray(a["splits"], dtype=_F), _f(args[0]), side="right")),
        _F(a["value"]),
    ).astype(_I),
    "columns_agg": lambda args, a: _columns_agg(args, a),
    "date_part": lambda args, a: _date_part(args[0], a["part"]),
    "sub_i64": lambda args, a: args[0] - args[1],
    "add_scalar_i64": lambda args, a: args[0] + jnp.int64(a["c"]),
    "floordiv_scalar_i64": lambda args, a: args[0] // jnp.int64(a["c"]),
    "compare": lambda args, a: _CMP[a["op"]](*_bcast(_f(args[0]), _f(args[1]))).astype(_I),
    "compare_scalar": lambda args, a: _CMP[a["op"]](_f(args[0]), _F(a["value"])).astype(_I),
    "eq_hash": lambda args, a: (args[0] == jnp.int64(a["value_hash"])).astype(_I),
    "bool_op": lambda args, a: _bool_op(args, a),
    "not": lambda args, a: (args[0] == 0).astype(_I),
    "select": lambda args, a: jnp.where(args[0] != 0, _f(args[1]), _f(args[2])),
    # fused select(compare_scalar(x), a, b) — rust optim::passes::SelectCmpFuse.
    # The predicate is evaluated inside the where: branchless, and the i64
    # mask column of the unfused pair never exists.
    "select_cmp": lambda args, a: jnp.where(
        _CMP[a["op"]](_f(args[0]), _F(a["value"])), _f(args[1]), _f(args[2])
    ),
    "is_nan": lambda args, a: jnp.isnan(_f(args[0])).astype(_I),
    "assemble": lambda args, a: jnp.stack([_f(x) for x in args], axis=-1),
    "vector_at": lambda args, a: args[0][:, int(a["index"])],
    "list_sum": lambda args, a: jnp.sum(_f(args[0]), axis=-1),
    "list_mean": lambda args, a: jnp.mean(_f(args[0]), axis=-1),
    "list_min": lambda args, a: jnp.min(_f(args[0]), axis=-1),
    "list_max": lambda args, a: jnp.max(_f(args[0]), axis=-1),
    "list_len": lambda args, a: jnp.full(
        args[0].shape[:1], args[0].shape[-1] if args[0].ndim > 1 else 1, dtype=_I
    ),
    "element_at": lambda args, a: _element_at(args[0], int(a["index"])),
    "slice_list": lambda args, a: _slice_list(args[0], a),
    "hash_bucket": lambda args, a: K.hash_bucket(args[0], int(a["num_bins"])),
    "bloom_encode": lambda args, a: K.bloom_probes(
        args[0], int(a["num_hashes"]), int(a["num_bins"])
    ),
    "affine": _op_affine,
    "vocab_lookup": _op_vocab_lookup,
    "one_hot": _op_one_hot,
    "scale_vec": lambda args, a: K.affine_scale(
        _f(args[0]),
        jnp.asarray(a["scale"], dtype=_F),
        jnp.asarray(a["shift"], dtype=_F),
    ),
    "impute": _op_impute,
    "haversine": lambda args, a: _haversine(args),
    "cosine_similarity": lambda args, a: _cosine(args),
}


def _cosine(args):
    x, y = _f(args[0]), _f(args[1])
    dot = jnp.sum(x * y, axis=-1)
    nx = jnp.sqrt(jnp.sum(x * x, axis=-1))
    ny = jnp.sqrt(jnp.sum(y * y, axis=-1))
    denom = nx * ny
    return jnp.where(denom == 0, _F(0.0), dot / denom)


def _columns_agg(args, a):
    stacked = jnp.stack([_f(x) for x in args], axis=0)
    agg = a["agg"]
    if agg == "sum":
        return jnp.sum(stacked, axis=0)
    if agg == "mean":
        return jnp.mean(stacked, axis=0)
    if agg == "min":
        return jnp.min(stacked, axis=0)
    return jnp.max(stacked, axis=0)


def _bool_op(args, a):
    x, y = args[0] != 0, args[1] != 0
    op = a["op"]
    if op == "and":
        return (x & y).astype(_I)
    if op == "or":
        return (x | y).astype(_I)
    return (x ^ y).astype(_I)


def _element_at(x, idx: int):
    w = x.shape[-1]
    j = w + idx if idx < 0 else idx
    return x[:, j]


def _slice_list(x, a):
    w = x.shape[-1]
    s = min(int(a["start"]), w)
    e = min(int(a["start"]) + int(a["len"]), w)
    return x[:, s:e]


def _haversine(args):
    lat1, lon1, lat2, lon2 = (_f(x) for x in args)
    radius = _F(6371.0088)
    p1, p2 = jnp.radians(lat1), jnp.radians(lat2)
    dp = jnp.radians(lat2 - lat1)
    dl = jnp.radians(lon2 - lon1)
    h = jnp.sin(dp / 2) ** 2 + jnp.cos(p1) * jnp.cos(p2) * jnp.sin(dl / 2) ** 2
    return 2 * radius * jnp.arcsin(jnp.minimum(jnp.sqrt(h), 1.0))


# ---------------------------------------------------------------------------
# spec compiler


def _binary_with_bcast(op, args):
    x, y = _bcast(_f(args[0]), _f(args[1]))
    return _BINARY[op](x, y)


def load_spec(path):
    with open(path) as f:
        return json.load(f)


def input_meta(spec):
    """Positional (name, dtype, width) for the compiled function's args."""
    ingress = {n["id"]: n for n in spec["ingress"]}
    raw = {i["name"]: i for i in spec["inputs"]}
    out = []
    for name in spec["graph_inputs"]:
        if name in ingress:
            node = ingress[name]
            out.append((name, node["dtype"], node.get("width")))
        else:
            inp = raw[name]
            dt = inp["dtype"]
            if dt.startswith("array<"):
                dt = dt[len("array<"):-1]
            spec_dt = "int64" if dt in ("int32", "int64", "bool", "string") else "float32"
            out.append((name, spec_dt, inp.get("width")))
    return out


def build_fn(spec):
    """GraphSpec dict -> python callable over positional jnp arrays."""
    nodes = spec["nodes"]
    graph_inputs = list(spec["graph_inputs"])
    outputs = list(spec["outputs"])

    def fn(*args):
        env = dict(zip(graph_inputs, args))
        for node in nodes:
            ins = [env[i] for i in node["inputs"]]
            op = node["op"]
            attrs = node.get("attrs", {})
            if node.get("lanes"):
                # multi-output node: lanes bind under both the qualified
                # "id.lane" reference and the bare lane name (the latter
                # is how spec outputs resolve) — mirroring the rust
                # interpreter's env contract
                for lane_name, val in _eval_lanes(node, ins, attrs):
                    env[f"{node['id']}.{lane_name}"] = val
                    env[lane_name] = val
                continue
            if op in _UNARY:
                val = _UNARY[op](_f(ins[0]), attrs)
            elif op in _BINARY:
                val = _binary_with_bcast(op, ins)
            elif op in _OPS:
                val = _OPS[op](ins, attrs)
            else:
                raise ValueError(f"unknown graph op: {op}")
            env[node["id"]] = val
        return tuple(env[o] for o in outputs)

    return fn


def example_args(spec, batch: int):
    """ShapeDtypeStructs for lowering at a given batch size."""
    import jax

    metas = input_meta(spec)
    out = []
    for _, dtype, width in metas:
        shape = (batch,) if width is None else (batch, int(width))
        out.append(jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)))
    return out
