"""AOT driver: GraphSpec JSON -> HLO text artifacts for the Rust runtime.

For every ``artifacts/specs/*.json`` (exported by ``kamae fit`` /
``kamae export``), lower the compiled JAX function at each batch-bucket
size and write ``artifacts/<name>@b<batch>.hlo.txt``.

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the Rust `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. Lowered with return_tuple=True —
the Rust side unwraps with `to_tuple()`.

Usage:
    python -m compile.aot [--specs DIR] [--out DIR] [--batches 1,8,32,128]
"""

import argparse
import pathlib
import sys

import jax

from . import model  # noqa: E402  (triggers x64 via package __init__)

DEFAULT_BATCHES = (1, 8, 32, 128)


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides literals over ~64
    # elements as `constant({...})`, which the HLO text parser then reads
    # as garbage — vocab tables silently break without this flag.
    return comp.as_hlo_text(print_large_constants=True)


def cost_analysis(lowered) -> str:
    """L2 profile: XLA cost analysis of the lowered module (flops/bytes),
    recorded per artifact in EXPERIMENTS.md §Perf."""
    try:
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        flops = cost.get("flops", float("nan"))
        bytes_ = cost.get("bytes accessed", float("nan"))
        return f"flops={flops:.0f} bytes={bytes_:.0f}"
    except Exception as e:  # cost analysis is best-effort
        return f"cost-analysis unavailable ({e})"


def compile_spec(spec_path: pathlib.Path, out_dir: pathlib.Path, batches) -> list:
    spec = model.load_spec(spec_path)
    fn = model.build_fn(spec)
    name = spec.get("name") or spec_path.stem
    written = []
    for batch in batches:
        args = model.example_args(spec, batch)
        # keep_unused: the positional input contract with the Rust runtime
        # is exactly spec["graph_inputs"] — jit must not prune params the
        # graph body happens not to use.
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        out = out_dir / f"{name}@b{batch}.hlo.txt"
        out.write_text(text)
        written.append(out)
        if batch == batches[-1]:
            print(f"  {name}@b{batch}: {cost_analysis(lowered)}")
    return written


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--specs", default="../artifacts/specs", help="directory of GraphSpec JSON files")
    p.add_argument("--out", default="../artifacts", help="artifact output directory")
    p.add_argument(
        "--batches",
        default=",".join(str(b) for b in DEFAULT_BATCHES),
        help="comma-separated batch-bucket sizes",
    )
    args = p.parse_args(argv)

    specs_dir = pathlib.Path(args.specs)
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    batches = [int(b) for b in args.batches.split(",") if b]

    # *.model.json are fitted PipelineModel payloads, not GraphSpecs
    spec_files = sorted(
        p for p in specs_dir.glob("*.json") if not p.name.endswith(".model.json")
    )
    if not spec_files:
        print(f"no specs found in {specs_dir}", file=sys.stderr)
        return 1
    total = 0
    for sp in spec_files:
        written = compile_spec(sp, out_dir, batches)
        total += len(written)
        print(f"{sp.name}: wrote {len(written)} artifacts "
              f"({', '.join(w.name for w in written)})")
    print(f"done: {total} artifacts in {out_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
