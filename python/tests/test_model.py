"""L2 spec-compiler correctness: op semantics, date math, binary search,
and an end-to-end handcrafted spec compiled + executed."""

import datetime

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

# ---------------------------------------------------------------------------
# date math vs python's datetime (the oracle the Rust side also matches)


@settings(max_examples=200, deadline=None)
@given(days=st.integers(min_value=-150_000, max_value=150_000))
def test_civil_from_days_matches_datetime(days):
    d = datetime.date(1970, 1, 1) + datetime.timedelta(days=days)
    z = jnp.int64(days)
    y, m, dd = model._civil_from_days(z)
    assert (int(y), int(m), int(dd)) == (d.year, d.month, d.day)
    assert int(model._days_from_civil(y, m, dd)) == days
    # ISO weekday 1..7
    assert int(model._date_part(z, "weekday")) == d.isoweekday()
    assert int(model._date_part(z, "day_of_year")) == d.timetuple().tm_yday


# ---------------------------------------------------------------------------
# the searchsorted replacement


@settings(max_examples=100, deadline=None)
@given(
    table=st.lists(st.integers(min_value=-1 << 62, max_value=1 << 62), min_size=1, max_size=200, unique=True),
    xs=st.lists(st.integers(min_value=-1 << 62, max_value=1 << 62), min_size=1, max_size=50),
    side=st.sampled_from(["left", "right"]),
)
def test_bsearch_matches_numpy(table, xs, side):
    table = sorted(table)
    t = jnp.array(table, dtype=jnp.int64)
    x = jnp.array(xs, dtype=jnp.int64)
    got = model._bsearch(t, x, side)
    expected = np.searchsorted(np.array(table), np.array(xs), side=side)
    np.testing.assert_array_equal(got, expected)


# ---------------------------------------------------------------------------
# op semantics (mirroring rust/src/export/interp.rs)


def test_vocab_lookup_semantics():
    labels = ["drama", "comedy", "action"]  # rank = position
    pairs = sorted((ref.fnv1a64(s), r) for r, s in enumerate(labels))
    attrs = {
        "vocab_hashes": [h for h, _ in pairs],
        "vocab_ranks": [r for _, r in pairs],
        "num_oov": 2,
        "base": 1,
        "mask_hash": ref.fnv1a64("PAD"),
    }
    x = jnp.array(
        [ref.fnv1a64("comedy"), ref.fnv1a64("PAD"), ref.fnv1a64("zzz_unseen")],
        dtype=jnp.int64,
    )
    out = np.asarray(model._op_vocab_lookup([x], attrs))
    assert out[0] == 1 + 2 + 1  # base + num_oov + rank(comedy)
    assert out[1] == 0  # mask
    assert 1 <= out[2] <= 2  # oov bucket


def test_one_hot_semantics():
    labels = ["a", "b"]
    pairs = sorted((ref.fnv1a64(s), r) for r, s in enumerate(labels))
    attrs = {
        "vocab_hashes": [h for h, _ in pairs],
        "vocab_ranks": [r for _, r in pairs],
        "num_oov": 1,
        "drop_unseen": False,
    }
    x = jnp.array([ref.fnv1a64("a"), ref.fnv1a64("nope")], dtype=jnp.int64)
    out = np.asarray(model._op_one_hot([x], attrs))
    assert out.shape == (2, 3)
    np.testing.assert_array_equal(out[0], [0, 1, 0])  # oov slot 0, a -> 1
    np.testing.assert_array_equal(out[1], [1, 0, 0])  # unseen -> oov
    attrs["drop_unseen"] = True
    out = np.asarray(model._op_one_hot([x], attrs))
    assert out.shape == (2, 2)
    np.testing.assert_array_equal(out[1], [0, 0])  # dropped


def test_impute_and_select():
    x = jnp.array([1.0, jnp.nan, -1.0], dtype=jnp.float32)
    out = np.asarray(model._op_impute([x], {"fill": 7.0, "mask_value": -1.0}))
    np.testing.assert_array_equal(out, [1.0, 7.0, 7.0])
    cond = jnp.array([1, 0], dtype=jnp.int64)
    a = jnp.array([10.0, 10.0], dtype=jnp.float32)
    b = jnp.array([20.0, 20.0], dtype=jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(model._OPS["select"]([cond, a, b], {})), [10.0, 20.0]
    )


def test_binary_broadcast_list_scalar():
    x = jnp.array([[1.0, 2.0], [3.0, 4.0]], dtype=jnp.float32)  # (2,2)
    y = jnp.array([10.0, 100.0], dtype=jnp.float32)  # (2,)
    out = np.asarray(model._binary_with_bcast("mul", [x, y]))
    np.testing.assert_array_equal(out, [[10.0, 20.0], [300.0, 400.0]])


def test_mod_python_semantics():
    x = jnp.array([-7.0, 7.0], dtype=jnp.float32)
    y = jnp.array([3.0, -3.0], dtype=jnp.float32)
    out = np.asarray(model._binary_with_bcast("mod", [x, y]))
    np.testing.assert_allclose(out, [2.0, -2.0])


def test_round_half_even():
    x = jnp.array([0.5, 1.5, 2.5, -0.5], dtype=jnp.float32)
    out = np.asarray(model._UNARY["round"](x, {}))
    np.testing.assert_array_equal(out, [0.0, 2.0, 2.0, -0.0])


def test_haversine_london_paris():
    args = [jnp.array([v], dtype=jnp.float32) for v in (51.5074, -0.1278, 48.8566, 2.3522)]
    d = float(model._OPS["haversine"](args, {})[0])
    assert abs(d - 344.0) < 5.0


# ---------------------------------------------------------------------------
# end-to-end: handcrafted spec -> compiled fn -> expected values


def _mini_spec():
    labels = ["nyc", "lon"]
    pairs = sorted((ref.fnv1a64(s), r) for r, s in enumerate(labels))
    return {
        "name": "mini",
        "inputs": [
            {"name": "price", "dtype": "float64", "width": None},
            {"name": "city", "dtype": "string", "width": None},
        ],
        "ingress": [
            {"id": "city__hash", "op": "hash64", "inputs": ["city"], "attrs": {},
             "dtype": "int64", "width": None},
        ],
        "graph_inputs": ["price", "city__hash"],
        "nodes": [
            {"id": "price_log", "op": "log1p", "inputs": ["price"], "attrs": {},
             "dtype": "float32", "width": None},
            {"id": "city_idx", "op": "vocab_lookup", "inputs": ["city__hash"],
             "attrs": {"vocab_hashes": [h for h, _ in pairs],
                       "vocab_ranks": [r for _, r in pairs],
                       "num_oov": 1, "base": 0, "mask_hash": None},
             "dtype": "int64", "width": None},
            {"id": "city_bin", "op": "hash_bucket", "inputs": ["city__hash"],
             "attrs": {"num_bins": 32}, "dtype": "int64", "width": None},
        ],
        "outputs": ["price_log", "city_idx", "city_bin"],
    }


def test_spec_compiles_and_runs():
    spec = _mini_spec()
    fn = model.build_fn(spec)
    metas = model.input_meta(spec)
    assert [m[0] for m in metas] == ["price", "city__hash"]
    assert metas[0][1] == "float32" and metas[1][1] == "int64"

    price = jnp.array([0.0, np.e - 1.0], dtype=jnp.float32)
    city = jnp.array([ref.fnv1a64("lon"), ref.fnv1a64("tokyo")], dtype=jnp.int64)
    out = fn(price, city)
    np.testing.assert_allclose(np.asarray(out[0]), [0.0, 1.0], rtol=1e-6)
    assert int(out[1][0]) == 1 + 1  # num_oov + rank(lon)
    assert int(out[1][1]) == 0  # oov
    assert int(out[2][0]) == ref.ref_bucket_py(ref.fnv1a64("lon"), 0, 32)

    # lowering must keep both params and stay jit-compatible
    lowered = jax.jit(fn, keep_unused=True).lower(*model.example_args(spec, 4))
    text = lowered.as_text()
    assert "tensor<4xf32>" in text and "tensor<4xi64>" in text


def test_example_args_shapes():
    spec = _mini_spec()
    spec["ingress"][0]["width"] = 3
    spec["inputs"][1]["width"] = 3
    args = model.example_args(spec, 8)
    assert args[0].shape == (8,)
    assert args[1].shape == (8, 3)


def test_cosine_similarity_op():
    x = jnp.array([[1.0, 0.0], [3.0, 4.0], [0.0, 0.0]], dtype=jnp.float32)
    y = jnp.array([[0.0, 2.0], [3.0, 4.0], [1.0, 1.0]], dtype=jnp.float32)
    out = np.asarray(model._OPS["cosine_similarity"]([x, y], {}))
    np.testing.assert_allclose(out, [0.0, 1.0, 0.0], atol=1e-6)


def test_affine_replays_steps_bit_exactly():
    # the fused node must reproduce the unfused chain's f32 arithmetic
    x = jnp.asarray(np.random.RandomState(7).randn(128).astype(np.float32) * 1e3)
    steps = [{"op": "add_scalar", "c": -1.0}, {"op": "mul_scalar", "c": 0.5235987755982988}]
    fused = model._OPS["affine"]([x], {"steps": steps, "scale": 0.5235987755982988, "shift": -0.5235987755982988})
    sep = model._UNARY["mul_scalar"](model._UNARY["add_scalar"](x, {"c": -1.0}), {"c": 0.5235987755982988})
    np.testing.assert_array_equal(np.asarray(fused).view(np.uint32), np.asarray(sep).view(np.uint32))


def test_affine_kernel_path_matches_chain():
    # mul-then-add lowers onto the fused-scaling Pallas kernel; like
    # scale_vec, FMA contraction may differ in the last ulp
    x = jnp.asarray(np.random.RandomState(8).randn(16, 4).astype(np.float32))
    steps = [{"op": "mul_scalar", "c": 2.5}, {"op": "sub_scalar", "c": 3.25}]
    fused = model._OPS["affine"]([x], {"steps": steps, "scale": 2.5, "shift": -3.25})
    sep = model._UNARY["sub_scalar"](model._UNARY["mul_scalar"](x, {"c": 2.5}), {"c": 3.25})
    assert fused.shape == x.shape
    np.testing.assert_allclose(np.asarray(fused), np.asarray(sep), rtol=1e-6)


def test_multi_bucketize_matches_unfused_ladder():
    # the fused ladder must compose bucketize + compare_scalar op-for-op
    x = jnp.asarray(np.random.RandomState(11).randn(256).astype(np.float32) * 2.0)
    splits = [-1.0, 0.0, 1.0]
    bucket = model._OPS["bucketize"]([x], {"splits": splits})
    for op, value in [("le", 1.0), ("ge", 2.0), ("lt", 3.0), ("eq", 0.0)]:
        sep = model._OPS["compare_scalar"]([bucket], {"op": op, "value": value})
        fused = model._OPS["multi_bucketize"]([x], {"splits": splits, "op": op, "value": value})
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(sep))


def test_select_cmp_matches_unfused_pair():
    x = jnp.asarray(np.random.RandomState(13).randn(256).astype(np.float32))
    a = jnp.asarray(np.random.RandomState(17).randn(256).astype(np.float32))
    b = jnp.asarray(np.random.RandomState(19).randn(256).astype(np.float32))
    for op, value in [("gt", 0.0), ("ge", 0.5), ("lt", -0.25)]:
        mask = model._OPS["compare_scalar"]([x], {"op": op, "value": value})
        sep = model._OPS["select"]([mask, a, b], {})
        fused = model._OPS["select_cmp"]([x, a, b], {"op": op, "value": value})
        np.testing.assert_array_equal(
            np.asarray(fused).view(np.uint32), np.asarray(sep).view(np.uint32)
        )


# ---------------------------------------------------------------------------
# multi-output lanes (MultiLaneBucketize on the rust side)


def _lanes_node():
    # merged splits = sorted union of [0.0, 1.0], [0.5] and the ladder's
    # [-1.0, 1.0] -> [-1.0, 0.0, 0.5, 1.0]
    return {
        "id": "x__lanes",
        "op": "multi_bucketize",
        "inputs": ["x"],
        "attrs": {"splits": [-1.0, 0.0, 0.5, 1.0]},
        "dtype": "int64",
        "width": None,
        "lanes": [
            {"name": "b1", "attrs": {"kind": "bucket", "remap": [0, 0, 1, 1, 2]},
             "dtype": "int64", "width": None},
            {"name": "b2", "attrs": {"kind": "bucket", "remap": [0, 0, 0, 1, 1]},
             "dtype": "int64", "width": None},
            {"name": "c1", "attrs": {"kind": "compare", "op": "gt", "value": 0.0},
             "dtype": "int64", "width": None},
            {"name": "f", "attrs": {"kind": "bucket_compare",
                                    "remap": [0, 1, 1, 1, 2], "op": "ge", "value": 2.0},
             "dtype": "int64", "width": None},
        ],
    }


def test_multilane_bucketize_matches_sibling_nodes():
    # one merged search must reproduce the sibling nodes exactly
    x = jnp.asarray(np.random.RandomState(23).randn(512).astype(np.float32) * 2.0)
    node = _lanes_node()
    lanes = dict(model._eval_lanes(node, [x], node["attrs"]))
    b1 = model._OPS["bucketize"]([x], {"splits": [0.0, 1.0]})
    b2 = model._OPS["bucketize"]([x], {"splits": [0.5]})
    c1 = model._OPS["compare_scalar"]([x], {"op": "gt", "value": 0.0})
    f = model._OPS["multi_bucketize"]([x], {"splits": [-1.0, 1.0], "op": "ge", "value": 2.0})
    np.testing.assert_array_equal(np.asarray(lanes["b1"]), np.asarray(b1))
    np.testing.assert_array_equal(np.asarray(lanes["b2"]), np.asarray(b2))
    np.testing.assert_array_equal(np.asarray(lanes["c1"]), np.asarray(c1))
    np.testing.assert_array_equal(np.asarray(lanes["f"]), np.asarray(f))


def test_multilane_spec_binds_qualified_and_bare_names():
    # consumers may address a lane as "<id>.<lane>" or by its bare name
    # (spec outputs use the latter); the compiled fn must bind both
    spec = {
        "name": "lanes",
        "inputs": [{"name": "x", "dtype": "float64", "width": None}],
        "ingress": [],
        "graph_inputs": ["x"],
        "nodes": [
            _lanes_node(),
            {"id": "n", "op": "not", "inputs": ["x__lanes.c1"], "attrs": {},
             "dtype": "int64", "width": None},
        ],
        "outputs": ["b1", "f", "n"],
    }
    fn = model.build_fn(spec)
    x = jnp.array([-2.0, -0.5, 0.25, 0.75, 3.0], dtype=jnp.float32)
    b1, f, n = fn(x)
    np.testing.assert_array_equal(np.asarray(b1), [0, 0, 1, 1, 2])
    np.testing.assert_array_equal(np.asarray(f), [0, 0, 0, 0, 1])
    np.testing.assert_array_equal(np.asarray(n), [1, 1, 0, 0, 0])
    # and it still lowers under jit with the positional input contract
    lowered = jax.jit(fn, keep_unused=True).lower(*model.example_args(spec, 4))
    assert "tensor<4x" in lowered.as_text()
