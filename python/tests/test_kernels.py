"""L1 kernel correctness: Pallas kernels vs pure-jnp/pure-python oracles.

Hypothesis sweeps shapes, dtypes-edge values (full 63-bit hash range) and
kernel parameters; assert_allclose against ref.py pins the kernels, and
ref_bucket_py (big-int transliteration of the Rust mixing) closes the
Rust⇄JAX loop from this side.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import preprocess as K
from compile.kernels import ref

HASHES = st.integers(min_value=0, max_value=(1 << 63) - 1)


@settings(max_examples=50, deadline=None)
@given(
    hs=st.lists(HASHES, min_size=1, max_size=64),
    bins=st.integers(min_value=1, max_value=1 << 20),
    k=st.integers(min_value=0, max_value=7),
)
def test_hash_bucket_matches_refs(hs, bins, k):
    h = jnp.array(hs, dtype=jnp.int64)
    out = K.hash_bucket(h, bins, k)
    np.testing.assert_array_equal(out, ref.ref_hash_bucket(h, bins, k))
    # big-int python transliteration of the Rust kernel
    expected = [ref.ref_bucket_py(x, k, bins) for x in hs]
    np.testing.assert_array_equal(np.asarray(out), expected)
    assert int(jnp.min(out)) >= 0 and int(jnp.max(out)) < bins


@settings(max_examples=30, deadline=None)
@given(
    hs=st.lists(HASHES, min_size=1, max_size=32),
    num_hashes=st.integers(min_value=1, max_value=8),
    bins=st.integers(min_value=1, max_value=1 << 16),
)
def test_bloom_probes_match_ref(hs, num_hashes, bins):
    h = jnp.array(hs, dtype=jnp.int64)
    out = K.bloom_probes(h, num_hashes, bins)
    np.testing.assert_array_equal(out, ref.ref_bloom_probes(h, num_hashes, bins))
    assert out.shape == (len(hs), num_hashes)
    # probe j confined to its own bin space
    for j in range(num_hashes):
        col = np.asarray(out[:, j])
        assert col.min() >= j * bins and col.max() < (j + 1) * bins


def test_hash_bucket_2d_shapes():
    h = jnp.array([[1, 2, 3], [4, 5, 6]], dtype=jnp.int64)
    out = K.hash_bucket(h, 100)
    assert out.shape == (2, 3)
    np.testing.assert_array_equal(out, ref.ref_hash_bucket(h, 100))


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=32),
    width=st.integers(min_value=1, max_value=16),
    data=st.data(),
)
def test_affine_scale_matches_ref(rows, width, data):
    floats = st.floats(
        min_value=-1e6, max_value=1e6, allow_nan=False, allow_infinity=False, width=32
    )
    x = np.array(
        data.draw(st.lists(floats, min_size=rows * width, max_size=rows * width)),
        dtype=np.float32,
    ).reshape(rows, width)
    scale = np.array(data.draw(st.lists(floats, min_size=width, max_size=width)), dtype=np.float32)
    shift = np.array(data.draw(st.lists(floats, min_size=width, max_size=width)), dtype=np.float32)
    out = K.affine_scale(jnp.asarray(x), jnp.asarray(scale), jnp.asarray(shift))
    np.testing.assert_allclose(
        out, ref.ref_affine_scale(jnp.asarray(x), jnp.asarray(scale), jnp.asarray(shift)),
        rtol=1e-6,
    )


def test_affine_scale_1d():
    x = jnp.array([1.0, 2.0, 3.0], dtype=jnp.float32)
    out = K.affine_scale(x, jnp.array([2.0]), jnp.array([-1.0]))
    assert out.shape == (3,)
    np.testing.assert_allclose(out, [1.0, 3.0, 5.0])


def test_fnv_known_vectors():
    # FNV-1a 64 reference: hash of "" is the offset basis (top bit clear)
    assert ref.fnv1a64("") == 0xCBF29CE484222325 & 0x7FFFFFFFFFFFFFFF
    assert ref.fnv1a64("hotel") != ref.fnv1a64("hostel")
    assert 0 <= ref.fnv1a64("日本語") < 1 << 63


@pytest.mark.parametrize("bins", [1, 2, 10_000])
def test_bucket_determinism_and_mask(bins):
    h = jnp.array([ref.fnv1a64(f"t{i}") for i in range(100)], dtype=jnp.int64)
    a = K.hash_bucket(h, bins)
    b = K.hash_bucket(h, bins)
    np.testing.assert_array_equal(a, b)
    if bins == 1:
        assert int(jnp.max(a)) == 0


def test_bucket_spread():
    h = jnp.array([ref.fnv1a64(f"token{i}") for i in range(5000)], dtype=jnp.int64)
    out = np.asarray(K.hash_bucket(h, 1000))
    assert len(np.unique(out)) > 950
