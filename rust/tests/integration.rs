//! Cross-module integration tests: full pipelines over the partitioned
//! engine, streaming, serving through the dynamic batcher, persistence
//! round trips through real files, and failure injection.

use std::sync::Mutex;
use std::time::Duration;

use kamae::dataframe::{read_jsonl, write_jsonl, Column, DataFrame};
use kamae::engine::stream::{run_stream, StreamConfig};
use kamae::engine::Dataset;
use kamae::error::KamaeError;
use kamae::pipeline::catalog;
use kamae::pipeline::{Pipeline, PipelineModel, Stage};
use kamae::serving::{BatchConfig, Server};
use kamae::synth;
use kamae::transformers::*;

#[test]
fn ltr_pipeline_partition_invariance() {
    // transform result must be identical no matter the partitioning
    let df = synth::gen_ltr(&synth::LtrConfig { rows: 3_000, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(df.clone(), 4))
        .unwrap();
    let whole = model.transform_df(df.clone()).unwrap();
    for parts in [1usize, 3, 7] {
        let ds = Dataset::from_dataframe(df.clone(), parts);
        let out = model.transform(&ds).unwrap().collect().unwrap();
        for col in catalog::LTR_OUTPUTS {
            assert_eq!(
                format!("{:?}", out.column(col).unwrap()),
                format!("{:?}", whole.column(col).unwrap()),
                "{col} differs at {parts} partitions"
            );
        }
    }
}

#[test]
fn fit_is_partition_invariant() {
    // vocabularies and moments must not depend on partitioning
    let df = synth::gen_movielens(&synth::MovieLensConfig { rows: 20_000, ..Default::default() });
    let spec_of = |parts: usize| {
        let model = catalog::movielens_pipeline()
            .fit(&Dataset::from_dataframe(df.clone(), parts))
            .unwrap();
        model
            .to_graph_spec("m", catalog::movielens_inputs(), &catalog::MOVIELENS_OUTPUTS)
            .unwrap()
            .to_json()
            .to_string()
    };
    let one = spec_of(1);
    assert_eq!(one, spec_of(4));
    assert_eq!(one, spec_of(13));
}

#[test]
fn model_file_roundtrip_on_disk() {
    let df = synth::gen_ltr(&synth::LtrConfig { rows: 2_000, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(df.clone(), 2))
        .unwrap();
    let tmp = std::env::temp_dir().join("kamae_it_model.json");
    model.save(&tmp).unwrap();
    let loaded = PipelineModel::load(&tmp).unwrap();
    let a = model.transform_df(df.clone()).unwrap();
    let b = loaded.transform_df(df).unwrap();
    for col in catalog::LTR_OUTPUTS {
        assert_eq!(
            format!("{:?}", a.column(col).unwrap()),
            format!("{:?}", b.column(col).unwrap()),
        );
    }
    std::fs::remove_file(tmp).ok();
}

#[test]
fn jsonl_dataset_roundtrip_through_pipeline() {
    let df = synth::gen_movielens(&synth::MovieLensConfig { rows: 500, ..Default::default() });
    let tmp = std::env::temp_dir().join("kamae_it_data.jsonl");
    write_jsonl(&df, &tmp).unwrap();
    let back = read_jsonl(&tmp, &df.schema()).unwrap();
    assert_eq!(back, df);
    let model = catalog::movielens_pipeline()
        .fit(&Dataset::from_dataframe(back.clone(), 2))
        .unwrap();
    let out = model.transform_df(back).unwrap();
    assert!(out.has_column("Genres_indexed"));
    std::fs::remove_file(tmp).ok();
}

#[test]
fn streaming_applies_fitted_pipeline() {
    let head = synth::gen_ltr(&synth::LtrConfig { rows: 2_000, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(head, 2))
        .unwrap();
    let mut produced = 0;
    let rows_seen = Mutex::new(0usize);
    let stats = run_stream(
        &StreamConfig { workers: 2, queue_cap: 3 },
        move || {
            if produced < 10 {
                produced += 1;
                Some(synth::gen_ltr(&synth::LtrConfig {
                    rows: 200,
                    seed: produced,
                    ..Default::default()
                }))
            } else {
                None
            }
        },
        |batch| model.transform_df(batch),
        |_, df| {
            assert!(df.has_column("price_z"));
            *rows_seen.lock().unwrap() += df.num_rows();
            Ok(())
        },
    )
    .unwrap();
    assert_eq!(stats.batches, 10);
    assert_eq!(*rows_seen.lock().unwrap(), 2_000);
    assert!(stats.peak_in_flight <= 3);
}

/// Deterministic backend for batcher integration below.
struct EchoBackend;

impl kamae::serving::Backend for EchoBackend {
    fn name(&self) -> &str {
        "echo"
    }

    fn process(
        &self,
        df: &DataFrame,
    ) -> kamae::error::Result<Vec<kamae::runtime::Tensor>> {
        let v = df.column("x")?.as_i64()?;
        Ok(vec![kamae::runtime::Tensor::i64(v.to_vec(), vec![v.len()])?])
    }
}

#[test]
fn server_under_concurrent_submitters() {
    let server = std::sync::Arc::new(
        Server::start(
            Box::new(EchoBackend),
            BatchConfig {
                max_batch_rows: 64,
                max_wait: Duration::from_millis(1),
                ..BatchConfig::default()
            },
        )
        .unwrap(),
    );
    std::thread::scope(|scope| {
        for t in 0..4i64 {
            let server = std::sync::Arc::clone(&server);
            scope.spawn(move || {
                for i in 0..50i64 {
                    let v = t * 1000 + i;
                    let df = DataFrame::new(vec![("x".into(), Column::from_i64(vec![v, v + 1]))])
                        .unwrap();
                    let rx = server.submit(df);
                    let out = rx.recv().unwrap().unwrap();
                    assert_eq!(out[0].as_i64().unwrap(), &[v, v + 1]);
                }
            });
        }
    });
    let (_batches, requests) = server.counts();
    assert_eq!(requests, 200);
}

#[test]
fn pipeline_errors_surface_cleanly() {
    // missing column
    let df = DataFrame::new(vec![("a".into(), Column::from_f64(vec![1.0]))]).unwrap();
    let t = LogTransformer::new("missing", "out");
    let mut d = df.clone();
    let err = kamae::pipeline::Transformer::transform(&t, &mut d).unwrap_err();
    assert!(matches!(err, KamaeError::ColumnNotFound(_)), "{err}");

    // wrong dtype for a string op
    let t = TrimTransformer::new("a", "out");
    let mut d = df.clone();
    let err = kamae::pipeline::Transformer::transform(&t, &mut d).unwrap_err();
    assert!(matches!(err, KamaeError::TypeMismatch { .. }), "{err}");

    // estimator on empty data
    let empty = DataFrame::new(vec![("a".into(), Column::from_f64(vec![]))]).unwrap();
    let est = kamae::estimators::StandardScaleEstimator::new("a", "z");
    let err = kamae::pipeline::Estimator::fit(&est, &Dataset::from_dataframe(empty, 1));
    assert!(err.is_err());
}

#[test]
fn export_rejects_invalid_flows() {
    use kamae::dataframe::DType;
    use kamae::export::SpecInput;
    // string op after a numeric graph op cannot export
    let df = DataFrame::new(vec![("x".into(), Column::from_f64(vec![1.0, 2.0]))]).unwrap();
    let pipeline = Pipeline::new(vec![
        Stage::transformer(LogTransformer::new("x", "x_log")),
        Stage::transformer(CastTransformer::new("x_log", "x_str", DType::Str)),
        Stage::transformer(TrimTransformer::new("x_str", "x_trim")),
    ]);
    let model = pipeline.fit(&Dataset::from_dataframe(df, 1)).unwrap();
    let res = model.to_graph_spec(
        "bad",
        vec![SpecInput { name: "x".into(), dtype: DType::F64, width: None }],
        &["x_trim"],
    );
    assert!(res.is_err(), "string-after-graph must be rejected at export");
}

/// Satellite coverage for the `kamae optimize` CLI: export an
/// unoptimized MovieLens spec into a tempdir, run the real binary with
/// `--report-json`, and check the trajectory parses with node counts
/// and cost estimates monotonically non-increasing pass over pass.
#[test]
fn optimize_cli_report_json_trajectory() {
    use kamae::export::GraphSpec;
    use kamae::optim::OptimizeLevel;
    use kamae::util::json::Json;

    // resolved at compile time for integration tests of the package that
    // defines the binary; guarded so a renamed bin target skips loudly
    // instead of breaking the suite
    let Some(bin) = option_env!("CARGO_BIN_EXE_kamae") else {
        eprintln!("SKIP: kamae binary path not provided by cargo");
        return;
    };

    let dir = std::env::temp_dir().join(format!("kamae_cli_opt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let df = synth::gen_movielens(&synth::MovieLensConfig { rows: 2_000, ..Default::default() });
    let model = catalog::movielens_pipeline()
        .fit(&Dataset::from_dataframe(df, 2))
        .unwrap();
    let (spec, _) = model
        .to_graph_spec_opt(
            "movielens",
            catalog::movielens_inputs(),
            &catalog::MOVIELENS_OUTPUTS,
            OptimizeLevel::None,
        )
        .unwrap();
    let spec_path = dir.join("movielens.json");
    spec.save(&spec_path).unwrap();
    let out_path = dir.join("movielens.opt.json");
    let report_path = dir.join("report.json");

    let status = std::process::Command::new(bin)
        .args([
            "optimize",
            "--spec",
            spec_path.to_str().unwrap(),
            "--out",
            out_path.to_str().unwrap(),
            "--level",
            "full",
            "--report-json",
            report_path.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(status.success(), "kamae optimize failed: {status}");

    let report = Json::parse(&std::fs::read_to_string(&report_path).unwrap()).unwrap();
    let passes = report.req_array("passes").unwrap();
    assert!(!passes.is_empty());
    let mut prev_nodes = i64::MAX;
    let mut prev_cost = i64::MAX;
    for p in passes {
        let (nb, na) = (
            p.req_i64("graph_nodes_before").unwrap(),
            p.req_i64("graph_nodes_after").unwrap(),
        );
        let (cb, ca) = (p.req_i64("cost_before").unwrap(), p.req_i64("cost_after").unwrap());
        let pass = p.req_str("pass").unwrap();
        assert!(na <= nb, "pass {pass} grew the graph: {nb} -> {na}");
        assert!(ca <= cb, "pass {pass} raised the cost estimate: {cb} -> {ca}");
        assert!(nb <= prev_nodes, "trajectory not monotone at {pass}");
        assert!(cb <= prev_cost, "cost trajectory not monotone at {pass}");
        prev_nodes = na;
        prev_cost = ca;
    }
    assert!(report.req_i64("cost_after").unwrap() < report.req_i64("cost_before").unwrap());

    // the rewritten spec loads and actually carries a fused ingress chain
    let opt = GraphSpec::load(&out_path).unwrap();
    assert_eq!(opt.outputs.len(), catalog::MOVIELENS_OUTPUTS.len());
    assert!(opt.ingress.iter().any(|n| n.op == "fused_ingress"));

    std::fs::remove_dir_all(&dir).ok();
}

/// Multi-variant serving end to end: export full + lite LTR specs into
/// an artifacts layout, load them as ONE merged interpreted backend,
/// and check the response is the two variants' outputs concatenated and
/// identical to serving each variant separately.
#[test]
fn variant_backend_serves_merged_outputs() {
    use kamae::optim::OptimizeLevel;

    let dir = std::env::temp_dir().join(format!("kamae_it_variants_{}", std::process::id()));
    std::fs::create_dir_all(dir.join("specs")).unwrap();
    let df = synth::gen_ltr(&synth::LtrConfig { rows: 2_000, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(df, 2))
        .unwrap();
    for (name, outputs) in [
        ("ltr", catalog::LTR_OUTPUTS.as_slice()),
        ("ltr_lite", catalog::LTR_LITE_OUTPUTS.as_slice()),
    ] {
        let spec = model
            .to_graph_spec(name, catalog::ltr_inputs(), outputs)
            .unwrap();
        spec.save(&dir.join("specs").join(format!("{name}.json"))).unwrap();
    }

    let backend =
        kamae::serving::load_variant_backend(&dir, &["ltr", "ltr_lite"], OptimizeLevel::default())
            .unwrap();
    let req = kamae::serving::request_pool("ltr", 32).unwrap();
    let merged_out = backend.process(&req).unwrap();
    assert_eq!(
        merged_out.len(),
        catalog::LTR_OUTPUTS.len() + catalog::LTR_LITE_OUTPUTS.len()
    );
    // each variant served alone must agree with its slice of the merged
    // response
    for (name, range) in [
        ("ltr", 0..catalog::LTR_OUTPUTS.len()),
        ("ltr_lite", catalog::LTR_OUTPUTS.len()..merged_out.len()),
    ] {
        let single = kamae::serving::load_backend(&dir, name, "interpreted").unwrap();
        let single_out = single.process(&req).unwrap();
        assert_eq!(single_out.len(), range.len());
        for (a, b) in merged_out[range].iter().zip(single_out.iter()) {
            // debug render: bitwise-identical tensors print identically
            // (NaN-tolerant, unlike PartialEq on f32)
            assert_eq!(
                format!("{a:?}"),
                format!("{b:?}"),
                "{name}: merged backend diverged from single-variant"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Variant-ROUTED serving end to end through the public API: the same
/// artifacts layout, driven by `bench_serve_variants` with routing on —
/// mixed ltr/ltr_lite traffic through the real batcher, each response
/// carrying only its variant's outputs, and the per-variant split
/// landing in the report.
#[test]
fn routed_variant_serving_end_to_end() {
    use kamae::optim::OptimizeLevel;

    let dir = std::env::temp_dir().join(format!("kamae_it_routed_{}", std::process::id()));
    std::fs::create_dir_all(dir.join("specs")).unwrap();
    let df = synth::gen_ltr(&synth::LtrConfig { rows: 2_000, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(df, 2))
        .unwrap();
    for (name, outputs) in [
        ("ltr", catalog::LTR_OUTPUTS.as_slice()),
        ("ltr_lite", catalog::LTR_LITE_OUTPUTS.as_slice()),
    ] {
        let spec = model
            .to_graph_spec(name, catalog::ltr_inputs(), outputs)
            .unwrap();
        spec.save(&dir.join("specs").join(format!("{name}.json"))).unwrap();
    }

    // direct submit path: a targeted request gets ONLY its variant's
    // outputs, in the variant's own order
    let backend = kamae::serving::load_variant_backend(
        &dir,
        &["ltr", "ltr_lite"],
        OptimizeLevel::default(),
    )
    .unwrap();
    assert_eq!(backend.variants(), &["ltr".to_string(), "ltr_lite".to_string()]);
    let server = Server::start(backend, BatchConfig::default()).unwrap();
    let req = kamae::serving::request_pool("ltr", 16).unwrap();
    let lite_out = server
        .submit_variant(req.slice(0, 8), "ltr_lite")
        .recv()
        .unwrap()
        .unwrap();
    assert_eq!(lite_out.len(), catalog::LTR_LITE_OUTPUTS.len());
    let full_out = server.submit_variant(req.slice(8, 8), "ltr").recv().unwrap().unwrap();
    assert_eq!(full_out.len(), catalog::LTR_OUTPUTS.len());
    let counts = server.variant_counts();
    assert_eq!(counts.get("ltr"), Some(&1));
    assert_eq!(counts.get("ltr_lite"), Some(&1));
    server.shutdown();

    // the mixed open-loop driver: report carries the per-variant split
    let report = kamae::serving::bench_serve_variants(
        &dir,
        &["ltr", "ltr_lite"],
        100,
        1,
        OptimizeLevel::default(),
        true,
    )
    .unwrap();
    assert_eq!(report.requests, 100);
    assert_eq!(report.variants.len(), 2);
    assert_eq!(report.variants[0].variant, "ltr");
    assert_eq!(report.variants[1].variant, "ltr_lite");
    assert_eq!(report.variants.iter().map(|v| v.requests).sum::<usize>(), 100);
    assert!(report.to_json().get("variants").is_some());
    std::fs::remove_dir_all(&dir).ok();
}

/// `kamae optimize --variants a.json,b.json` merges, optimizes, and
/// writes a multi-variant spec whose outputs carry variant prefixes.
#[test]
fn optimize_cli_merges_variants() {
    use kamae::export::GraphSpec;
    use kamae::optim::OptimizeLevel;
    use kamae::util::json::Json;

    let Some(bin) = option_env!("CARGO_BIN_EXE_kamae") else {
        eprintln!("SKIP: kamae binary path not provided by cargo");
        return;
    };
    let dir = std::env::temp_dir().join(format!("kamae_cli_variants_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let df = synth::gen_movielens(&synth::MovieLensConfig { rows: 2_000, ..Default::default() });
    let model = catalog::movielens_pipeline()
        .fit(&Dataset::from_dataframe(df, 2))
        .unwrap();
    for (name, outputs) in [
        ("ml_a", catalog::MOVIELENS_OUTPUTS.as_slice()),
        ("ml_b", &catalog::MOVIELENS_OUTPUTS[..2]),
    ] {
        let (spec, _) = model
            .to_graph_spec_opt(name, catalog::movielens_inputs(), outputs, OptimizeLevel::None)
            .unwrap();
        spec.save(&dir.join(format!("{name}.json"))).unwrap();
    }
    let out_path = dir.join("merged.json");
    let report_path = dir.join("report.json");
    let status = std::process::Command::new(bin)
        .args([
            "optimize",
            "--variants",
            &format!("{},{}", dir.join("ml_a.json").display(), dir.join("ml_b.json").display()),
            "--out",
            out_path.to_str().unwrap(),
            "--report-json",
            report_path.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(status.success(), "kamae optimize --variants failed: {status}");

    let merged = GraphSpec::load(&out_path).unwrap();
    assert_eq!(merged.outputs.len(), 6);
    assert!(merged.outputs.iter().take(4).all(|o| o.starts_with("ml_a::")));
    assert!(merged.outputs.iter().skip(4).all(|o| o.starts_with("ml_b::")));
    // the overlap must have deduped: fewer nodes than the two variants
    // concatenated, and the dedup pass shows up in the report
    let report = Json::parse(&std::fs::read_to_string(&report_path).unwrap()).unwrap();
    let deduped = report.req_array("passes").unwrap().iter().any(|p| {
        p.req_str("pass").unwrap() == "cross-output-dedup"
            && p.get("changed").and_then(|c| c.as_bool()) == Some(true)
    });
    assert!(deduped, "cross-output-dedup did not fire via the CLI");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unseen_category_rate_is_handled() {
    // fit on seed A, serve data from seed B: OOV tokens must land in the
    // reserved buckets, never panic, never alias into the vocab range
    let train = synth::gen_movielens(&synth::MovieLensConfig {
        rows: 5_000,
        num_movies: 500,
        ..Default::default()
    });
    let model = catalog::movielens_pipeline()
        .fit(&Dataset::from_dataframe(train, 2))
        .unwrap();
    let serve = synth::gen_movielens(&synth::MovieLensConfig {
        rows: 1_000,
        num_movies: 4_000, // most ids unseen
        seed: 777,
        ..Default::default()
    });
    let out = model.transform_df(serve).unwrap();
    let idx = out.column("MovieID_indexed").unwrap().as_i64().unwrap();
    let oov = idx.iter().filter(|&&i| i == 0).count();
    assert!(oov > 100, "expected many OOV hits, got {oov}");
    assert!(idx.iter().all(|&i| i >= 0));
}

/// `kamae optimize --calibrate` (cost-model calibration harness): the
/// real binary fits the quickstart catalog, times per-op interpreter
/// evaluation on a synthetic batch, and appends finite per-op drift
/// records to the BENCH_op_costs.json trajectory at the repo root.
#[test]
fn optimize_cli_calibrate_appends_op_cost_records() {
    use kamae::util::json::Json;

    let Some(bin) = option_env!("CARGO_BIN_EXE_kamae") else {
        eprintln!("SKIP: kamae binary path not provided by cargo");
        return;
    };
    // write the trajectory into a temp dir (KAMAE_BENCH_DIR) — a tiny
    // 2-repeat test run must never pollute the real BENCH_op_costs.json
    // the cost-model refit will be fitted from
    let dir = std::env::temp_dir().join(format!("kamae_cli_calibrate_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let status = std::process::Command::new(bin)
        .env("KAMAE_BENCH_DIR", &dir)
        .args([
            "optimize",
            "--calibrate",
            "quickstart",
            "--fit-rows",
            "400",
            "--rows",
            "128",
            "--repeats",
            "2",
        ])
        .status()
        .unwrap();
    assert!(status.success(), "kamae optimize --calibrate failed: {status}");

    let path = dir.join("BENCH_op_costs.json");
    let runs = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let runs = runs.as_array().unwrap();
    let last = runs.last().unwrap();
    assert_eq!(last.req_str("bench").unwrap(), "op_costs");
    assert_eq!(last.req_str("spec").unwrap(), "quickstart");
    assert!(last.req_f64("scale_ns_per_unit").unwrap().is_finite());
    let records = last.req_array("records").unwrap();
    assert!(!records.is_empty(), "calibration produced no per-op records");
    for r in records {
        let op = r.req_str("op").unwrap();
        assert!(!op.is_empty());
        assert!(r.req_f64("drift_pct").unwrap().is_finite(), "{op}");
        assert!(r.req_f64("measured_ns_per_row").unwrap() >= 0.0, "{op}");
        assert!(r.req_i64("estimated_units").unwrap() > 0, "{op}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// `kamae serve --listen` end to end through the real binary: export a
/// quickstart spec, spawn the server on an ephemeral port, hit
/// `/healthz` and `/v1/infer` over the wire, then `/admin/shutdown` and
/// assert the process drains to a clean exit.
#[test]
fn serve_cli_listens_answers_and_drains() {
    use kamae::optim::OptimizeLevel;
    use kamae::serving::NetClient;
    use kamae::util::json::Json;
    use std::io::BufRead;

    let Some(bin) = option_env!("CARGO_BIN_EXE_kamae") else {
        eprintln!("SKIP: kamae binary path not provided by cargo");
        return;
    };
    let dir = std::env::temp_dir().join(format!("kamae_cli_serve_{}", std::process::id()));
    std::fs::create_dir_all(dir.join("specs")).unwrap();
    let df = kamae::serving::request_pool("quickstart", 2_000).unwrap();
    let model = catalog::quickstart_pipeline()
        .fit(&Dataset::from_dataframe(df, 2))
        .unwrap();
    let (spec, _) = model
        .to_graph_spec_opt(
            "quickstart",
            catalog::quickstart_inputs(),
            &catalog::QUICKSTART_OUTPUTS,
            OptimizeLevel::Full,
        )
        .unwrap();
    spec.save(&dir.join("specs").join("quickstart.json")).unwrap();

    let mut child = std::process::Command::new(bin)
        .args([
            "serve",
            "--artifacts",
            dir.to_str().unwrap(),
            "--variants",
            "quickstart",
            "--listen",
            "127.0.0.1:0",
            "--workers",
            "2",
            "--admission",
            "8",
        ])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();

    // the binary prints its bound address once the listener is up
    let stdout = child.stdout.take().unwrap();
    let mut lines = std::io::BufReader::new(stdout).lines();
    let addr = loop {
        let line = lines
            .next()
            .expect("server exited before announcing its address")
            .unwrap();
        if let Some(rest) = line.split("listening on http://").nth(1) {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };

    let mut client = NetClient::connect(&addr).unwrap();
    let health = client.request("GET", "/healthz", &[], "").unwrap();
    assert_eq!(health.status, 200, "{}", health.body);
    let j = health.json().unwrap();
    assert_eq!(j.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(j.get("workers").and_then(Json::as_i64), Some(2));
    assert_eq!(j.get("admission_limit").and_then(Json::as_i64), Some(8));

    let body = r#"{"variant":"quickstart","rows":[{"city":"city_3","price":12.5},{"city":"city_7","price":99.0}]}"#;
    let resp = client.request("POST", "/v1/infer", &[], body).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = resp.json().unwrap();
    assert_eq!(j.get("rows").and_then(Json::as_i64), Some(2));
    let outs = j.get("outputs").and_then(Json::as_array).unwrap();
    assert_eq!(outs.len(), catalog::QUICKSTART_OUTPUTS.len());
    // a single spec still goes through the variant merge, so the served
    // output names carry the variant prefix
    for (o, want) in outs.iter().zip(catalog::QUICKSTART_OUTPUTS) {
        assert_eq!(
            o.get("name").and_then(Json::as_str),
            Some(format!("quickstart::{want}").as_str())
        );
    }

    let resp = client.request("POST", "/admin/shutdown", &[], "").unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);

    // the drain must finish on its own: poll for a clean exit
    let deadline = std::time::Instant::now() + Duration::from_secs(15);
    let status = loop {
        if let Some(status) = child.try_wait().unwrap() {
            break status;
        }
        if std::time::Instant::now() > deadline {
            child.kill().ok();
            panic!("kamae serve did not drain within 15s of /admin/shutdown");
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.success(), "kamae serve exited uncleanly: {status}");
    std::fs::remove_dir_all(&dir).ok();
}

/// `kamae dead-letter replay` end to end through the real binary: a
/// validating listener quarantines a row into a JSONL sink, then the
/// replay verb re-submits the file — the still-broken row stays
/// quarantined (with its rule quoted), a since-fixed row recovers, and
/// `--dry-run` touches nothing.
#[test]
fn dead_letter_replay_cli_resubmits_quarantined_rows() {
    use kamae::optim::OptimizeLevel;
    use kamae::serving::NetClient;
    use kamae::util::json::Json;
    use std::io::{BufRead, Write};

    let Some(bin) = option_env!("CARGO_BIN_EXE_kamae") else {
        eprintln!("SKIP: kamae binary path not provided by cargo");
        return;
    };
    let dir = std::env::temp_dir().join(format!("kamae_cli_replay_{}", std::process::id()));
    std::fs::create_dir_all(dir.join("specs")).unwrap();
    let df = kamae::serving::request_pool("quickstart", 2_000).unwrap();
    let model = catalog::quickstart_pipeline()
        .fit(&Dataset::from_dataframe(df, 2))
        .unwrap();
    let (spec, _) = model
        .to_graph_spec_opt(
            "quickstart",
            catalog::quickstart_inputs(),
            &catalog::QUICKSTART_OUTPUTS,
            OptimizeLevel::Full,
        )
        .unwrap();
    spec.save(&dir.join("specs").join("quickstart.json")).unwrap();
    let sink_path = dir.join("dead.jsonl");

    let mut child = std::process::Command::new(bin)
        .args([
            "serve",
            "--artifacts",
            dir.to_str().unwrap(),
            "--variants",
            "quickstart",
            "--listen",
            "127.0.0.1:0",
            "--workers",
            "2",
            "--validate",
            "--dead-letter",
            sink_path.to_str().unwrap(),
        ])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut lines = std::io::BufReader::new(stdout).lines();
    let addr = loop {
        let line = lines
            .next()
            .expect("server exited before announcing its address")
            .unwrap();
        if let Some(rest) = line.split("listening on http://").nth(1) {
            break rest.split_whitespace().next().unwrap().to_string();
        }
    };

    // quarantine one row (null price) into the sink
    let mut client = NetClient::connect(&addr).unwrap();
    let body = r#"{"variant":"quickstart","rows":[{"city":"city_3","price":12.5},{"city":"city_7","price":null}]}"#;
    let resp = client.request("POST", "/v1/infer", &[], body).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = resp.json().unwrap();
    assert_eq!(j.get("valid_rows").and_then(Json::as_i64), Some(1));

    // append a since-fixed entry by hand, as if a later deploy relaxed
    // the rules for this row: clean content, so replay must recover it
    {
        let mut f = std::fs::OpenOptions::new().append(true).open(&sink_path).unwrap();
        writeln!(
            f,
            r#"{{"tenant":"default","row":{{"city":"city_1","price":5.0}},"errors":[{{"rule":"stale","column":"price","message":"fixed since"}}]}}"#
        )
        .unwrap();
        // and one for another tenant, which this replay must skip
        writeln!(
            f,
            r#"{{"tenant":"other","row":{{"city":"city_2","price":7.0}},"errors":[]}}"#
        )
        .unwrap();
    }

    // --dry-run lists without submitting
    let out = std::process::Command::new(bin)
        .args([
            "dead-letter",
            "replay",
            sink_path.to_str().unwrap(),
            "--tenant",
            "default",
            "--dry-run",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("would replay 2 row(s) for tenant 'default'"), "{text}");

    // the real replay: the null-price row stays quarantined with its
    // rule quoted, the clean row recovers, the other tenant is skipped
    let out = std::process::Command::new(bin)
        .args([
            "dead-letter",
            "replay",
            sink_path.to_str().unwrap(),
            "--tenant",
            "default",
            "--addr",
            &addr,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("still quarantined — not_null"), "{text}");
    assert!(text.contains("recovered"), "{text}");
    assert!(
        text.contains("replayed 2 row(s) for tenant 'default': 1 recovered, 1 still quarantined, 0 rejected"),
        "{text}"
    );

    // an unknown verb fails fast with usage, not a stack trace
    let out = std::process::Command::new(bin)
        .args(["dead-letter", "purge", sink_path.to_str().unwrap(), "--tenant", "default"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown dead-letter verb"));

    let resp = client.request("POST", "/admin/shutdown", &[], "").unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let deadline = std::time::Instant::now() + Duration::from_secs(15);
    loop {
        if let Some(status) = child.try_wait().unwrap() {
            assert!(status.success(), "kamae serve exited uncleanly: {status}");
            break;
        }
        if std::time::Instant::now() > deadline {
            child.kill().ok();
            panic!("kamae serve did not drain within 15s of /admin/shutdown");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The routed-rejection bugfix pinned on a REAL spec-less-routing
/// backend: MLeap cannot restrict evaluation to one variant, and its
/// refusal must name the backend, its kind, and the offending variant.
#[test]
fn mleap_backend_rejection_names_backend_kind_and_variant() {
    use kamae::serving::{MleapBackend, VariantGroup};

    let df = kamae::serving::request_pool("quickstart", 1_000).unwrap();
    let model = catalog::quickstart_pipeline()
        .fit(&Dataset::from_dataframe(df.clone(), 2))
        .unwrap();
    let spec = model
        .to_graph_spec("quickstart", catalog::quickstart_inputs(), &catalog::QUICKSTART_OUTPUTS)
        .unwrap();
    let backend = MleapBackend::new(model, &spec);
    let err = kamae::serving::Backend::process_routed(
        &backend,
        &df.slice(0, 8),
        &[VariantGroup { variant: Some("quickstart".into()), rows: 0..8 }],
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("quickstart-mleap"), "{err}");
    assert!(err.contains("(mleap backend)"), "{err}");
    assert!(err.contains("variant 'quickstart'"), "{err}");
}
