//! Golden GraphSpec fixtures — serialization drift is caught by DIFF,
//! not by construction-in-test.
//!
//! `rust/tests/fixtures/` holds committed spec JSON in the exact
//! canonical form `GraphSpec::save` writes (`Json::to_string_pretty`:
//! sorted keys, 2-space indent, integral floats as `x.0`):
//!
//! * `prelane.json`          — the pre-lane (PR ≤ 2) node shape, no
//!                             `lanes` key anywhere: the back-compat
//!                             contract for old artifact specs,
//! * `lanes.json`            — a multi-output `multi_bucketize` node
//!                             with bucket + compare lanes and a
//!                             qualified `id.lane` consumer,
//! * `merged_variants.json`  — a naive merged two-variant spec (the
//!                             `GraphSpec::merge_variants` shape before
//!                             optimization: `::`-prefixed ids, shared
//!                             raw inputs, duplicate cross-variant
//!                             subgraphs for `CrossOutputDedup`).
//!
//! Each fixture must load, re-serialise to the exact committed bytes,
//! and keep behaving (interpretation, variant routing, optimization).
//! If an intentional format change breaks the byte comparison,
//! regenerate the fixture and review the diff — that diff IS the
//! serialization change review.

use std::path::PathBuf;

use kamae::dataframe::{Column, DataFrame};
use kamae::export::{GraphSpec, RouteGroup, SpecInterpreter};
use kamae::optim::{optimize, OptimizeLevel};
use kamae::util::json::Json;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(format!("{name}.json"))
}

fn load_fixture(name: &str) -> (GraphSpec, String) {
    let path = fixture_path(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", path.display()));
    let spec = GraphSpec::load(&path)
        .unwrap_or_else(|e| panic!("fixture {} does not load: {e}", path.display()));
    (spec, text)
}

/// load → to_json → pretty must reproduce the committed bytes exactly
/// (modulo a trailing newline, which `GraphSpec::save` never writes).
fn assert_canonical_roundtrip(name: &str) -> GraphSpec {
    let (spec, text) = load_fixture(name);
    let serialized = spec.to_json().to_string_pretty();
    assert_eq!(
        serialized,
        text.trim_end(),
        "fixture {name}.json is not byte-canonical: the serializer changed \
         (or the fixture was edited by hand) — regenerate it and review the diff"
    );
    // and a full parse → construct → parse cycle is lossless
    let back = GraphSpec::from_json(&Json::parse(&serialized).unwrap()).unwrap();
    assert_eq!(back, spec, "fixture {name}.json round-trip lost information");
    spec
}

#[test]
fn prelane_fixture_is_canonical_and_stays_lane_free() {
    let spec = assert_canonical_roundtrip("prelane");
    // the pre-lane shape must survive: no lanes key materialises on
    // re-serialisation (old readers keep loading what we write)
    assert!(spec.ingress.iter().chain(spec.nodes.iter()).all(|n| n.lanes.is_empty()));
    let text = spec.to_json().to_string_pretty();
    assert!(!text.contains("\"lanes\""), "lanes key leaked into pre-lane JSON");
    // and it still runs
    let df = DataFrame::new(vec![
        ("price".into(), Column::from_f64(vec![1.0, 100.0])),
        ("city".into(), Column::from_str(vec!["NYC", "LON"])),
    ])
    .unwrap();
    let out = SpecInterpreter::new(spec).run(&df).unwrap();
    assert_eq!(out.len(), 2);
    // mirror the interpreter's arithmetic exactly: f64 ln_1p, f32 round
    assert_eq!(out[0].as_f32().unwrap()[0], 1.0f64.ln_1p() as f32);
}

#[test]
fn lanes_fixture_is_canonical_and_lane_refs_resolve() {
    let spec = assert_canonical_roundtrip("lanes");
    let mlb = &spec.nodes[0];
    assert_eq!(mlb.lanes.len(), 2);
    // lane meta resolves through the bare name AND the qualified ref
    assert!(spec.node_meta("price_bucket").is_some());
    assert!(spec.node_meta("price__lanes.is_pricey").is_some());
    // behavior: bucket lane + negated compare lane
    let df = DataFrame::new(vec![(
        "price".into(),
        Column::from_f64(vec![-1.0, 0.5, 2.0]),
    )])
    .unwrap();
    let out = SpecInterpreter::new(spec).run(&df).unwrap();
    assert_eq!(out[0].as_i64().unwrap(), &[0, 1, 2]);
    assert_eq!(out[1].as_i64().unwrap(), &[1, 1, 0]); // not(price >= 1.0)
}

#[test]
fn merged_variants_fixture_routes_and_dedupes() {
    let spec = assert_canonical_roundtrip("merged_variants");
    assert_eq!(spec.variants(), vec!["a", "b"]);
    assert_eq!(spec.variant_outputs("a"), vec![0, 1]);
    assert_eq!(spec.variant_outputs("b"), vec![2, 3]);

    let df = DataFrame::new(vec![
        ("price".into(), Column::from_f64(vec![1.0, 50.0, 150.0, 200.0, 3.0])),
        ("city".into(), Column::from_str(vec!["NYC", "LON", "PAR", "BER", "RIO"])),
    ])
    .unwrap();

    // routed evaluation over a mixed batch equals the full run's slices
    let interp = SpecInterpreter::new(spec.clone());
    let full = interp.run(&df).unwrap();
    let groups = vec![
        RouteGroup { outputs: spec.variant_outputs("a"), rows: 0..2 },
        RouteGroup { outputs: spec.variant_outputs("b"), rows: 2..5 },
    ];
    let routed = interp.run_routed(&df, &groups).unwrap();
    for (g, got) in groups.iter().zip(routed.iter()) {
        for (t, &oi) in got.iter().zip(g.outputs.iter()) {
            let expect = full[oi]
                .split_batch(&[g.rows.start, g.rows.len(), df.num_rows() - g.rows.end])
                .unwrap()
                .swap_remove(1);
            assert_eq!(t, &expect, "{} rows {:?}", spec.outputs[oi], g.rows);
        }
    }

    // the naive merged shape is exactly what CrossOutputDedup exists
    // for: optimizing must fire it (b::price_log duplicates
    // a::price_log) and preserve outputs + values bit-for-bit
    let (opt, report) = optimize(spec.clone(), OptimizeLevel::Full).unwrap();
    assert!(
        report.stats.iter().any(|s| s.pass == "cross-output-dedup" && s.changed),
        "cross-output-dedup did not fire on the merged fixture\n{report}"
    );
    assert_eq!(opt.outputs, spec.outputs);
    let opt_out = SpecInterpreter::new(opt).run(&df).unwrap();
    assert_eq!(opt_out, full, "optimizing the merged fixture changed its outputs");
}

#[test]
fn fixtures_match_their_generated_counterparts() {
    // prelane.json must be exactly what the current exporter writes for
    // the same spec built in code — pinning the WRITER, not just the
    // reader (a one-sided reader test would let the written format
    // drift until old readers break)
    use kamae::dataframe::DType;
    use kamae::export::{SpecDType, SpecInput, SpecNode};

    let node = |id: &str, op: &str, inputs: &[&str], attrs: &str, dtype: SpecDType| SpecNode {
        id: id.into(),
        op: op.into(),
        inputs: inputs.iter().map(|s| s.to_string()).collect(),
        attrs: Json::parse(attrs).unwrap(),
        dtype,
        width: None,
        lanes: vec![],
    };
    let spec = GraphSpec {
        name: "prelane".into(),
        inputs: vec![
            SpecInput { name: "price".into(), dtype: DType::F64, width: None },
            SpecInput { name: "city".into(), dtype: DType::Str, width: None },
        ],
        ingress: vec![node("city__hash", "hash64", &["city"], "{}", SpecDType::I64)],
        graph_inputs: vec!["city__hash".into(), "price".into()],
        nodes: vec![
            node("price_log", "log1p", &["price"], "{}", SpecDType::F32),
            node(
                "city_idx",
                "hash_bucket",
                &["city__hash"],
                r#"{"num_bins": 64}"#,
                SpecDType::I64,
            ),
        ],
        outputs: vec!["price_log".into(), "city_idx".into()],
    };
    let (_, text) = load_fixture("prelane");
    assert_eq!(
        spec.to_json().to_string_pretty(),
        text.trim_end(),
        "the exporter no longer writes the committed pre-lane shape"
    );
}
