//! Golden wire-protocol tests for the HTTP/1.1 serving front-end.
//!
//! `rust/tests/fixtures/net/*.json` hold committed request/response
//! fixtures — each one a list of requests (method, path, JSON body or a
//! deliberately broken `raw_body`) with the expected status, error code
//! + message fragment, or output names/dtypes. The driver replays every
//! fixture against a REAL listener (`NetServer::bind` on an ephemeral
//! loopback port, serving the `merged_variants.json` spec on the
//! interpreted backend) over one keep-alive `NetClient` connection, and
//! re-verifies every accepted response bit-for-bit against an in-process
//! oracle: decode the fixture's rows with the same schema, run the
//! backend directly, compare tensors.
//!
//! * `net_single_variant.json` — targeted requests, one variant each;
//! * `net_mixed_variant.json`  — different variants + an untargeted
//!                               request over ONE connection;
//! * `net_malformed.json`      — every typed 4xx the parser can emit;
//! * `net_oversized.json`      — the `max_request_rows` 413 boundary
//!                               (5 rows rejected, 4 accepted).
//!
//! Beyond the fixtures: admission-window shedding (429 + `Retry-After`
//! + `/metrics` accounting) against a deliberately slow backend, the
//! `/healthz` shape, a clean in-process shutdown drain, and the
//! registry admin surface (deploy a second tenant over the wire, infer
//! against it, CAS-protected redeploy, rollback, snapshot).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use kamae::dataframe::{dataframe_from_json_rows, DataFrame, Field, Schema};
use kamae::export::GraphSpec;
use kamae::runtime::Tensor;
use kamae::serving::{
    tensor_from_json, Backend, BatchConfig, InterpretedBackend, NetClient, NetConfig, NetServer,
    VariantGroup,
};
use kamae::util::json::Json;
use kamae::util::prop::tensors_bit_identical;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(format!("{name}.json"))
}

/// Request/response fixtures live in a `net/` subdirectory so the spec
/// fixtures directory keeps holding only GraphSpec JSON (the python AOT
/// probe compiles every top-level `fixtures/*.json` as a spec).
fn fixture(name: &str) -> Json {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/net")
        .join(format!("{name}.json"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", path.display()));
    Json::parse(&text).unwrap_or_else(|e| panic!("fixture {} is not JSON: {e}", path.display()))
}

fn merged_spec() -> GraphSpec {
    GraphSpec::load(&fixture_path("merged_variants")).unwrap()
}

/// The listener config every fixture runs under: 2 pool workers and the
/// 4-row cap the oversized fixture probes (all other fixture requests
/// stay at or under 4 rows).
fn test_config() -> NetConfig {
    NetConfig {
        batch: BatchConfig { workers: 2, ..BatchConfig::default() },
        max_request_rows: 4,
        ..NetConfig::default()
    }
}

fn bind(config: NetConfig) -> (NetServer, String, GraphSpec) {
    let spec = merged_spec();
    let backend: Arc<dyn Backend> = Arc::new(InterpretedBackend::new(spec.clone()));
    let server = NetServer::bind(backend, "127.0.0.1:0", config).unwrap();
    let addr = server.addr().to_string();
    (server, addr, spec)
}

fn request_schema(spec: &GraphSpec) -> Schema {
    Schema {
        fields: spec
            .inputs
            .iter()
            .map(|i| Field { name: i.name.clone(), dtype: i.dtype.clone() })
            .collect(),
    }
}

/// Replay one fixture file against a fresh listener.
fn run_fixture(name: &str) {
    let doc = fixture(name);
    let (server, addr, spec) = bind(test_config());
    let schema = request_schema(&spec);
    let oracle = InterpretedBackend::new(spec.clone());
    let mut client = NetClient::connect(&addr).unwrap();
    let requests = doc
        .get("requests")
        .and_then(Json::as_array)
        .unwrap_or_else(|| panic!("{name}: fixture has no 'requests' array"));
    for req in requests {
        let case = req.get("name").and_then(Json::as_str).expect("request has a name");
        let method = req.get("method").and_then(Json::as_str).expect("method");
        let path = req.get("path").and_then(Json::as_str).expect("path");
        let body = match req.get("raw_body") {
            Some(Json::Str(s)) => s.clone(),
            _ => req.get("body").expect("request has body or raw_body").to_string(),
        };
        let resp = client.request(method, path, &[], &body).unwrap();
        let expect = req.get("expect").expect("request has expectations");
        let want_status = expect.get("status").and_then(Json::as_i64).expect("status") as u16;
        assert_eq!(resp.status, want_status, "{name}/{case}: {}", resp.body);
        if want_status == 200 {
            assert_success(name, case, req, expect, &resp, &spec, &schema, &oracle);
        } else {
            let j = resp.json().unwrap();
            let err = j.get("error").unwrap_or_else(|| panic!("{name}/{case}: no error object"));
            assert_eq!(
                err.get("code").and_then(Json::as_str),
                expect.get("code").and_then(Json::as_str),
                "{name}/{case}: error code"
            );
            assert_eq!(
                err.get("status").and_then(Json::as_i64),
                Some(want_status as i64),
                "{name}/{case}: status echoed in the error body"
            );
            let msg = err.get("message").and_then(Json::as_str).unwrap_or_default();
            let frag = expect
                .get("message_contains")
                .and_then(Json::as_str)
                .expect("error expectation has message_contains");
            assert!(
                msg.contains(frag),
                "{name}/{case}: message {msg:?} does not contain {frag:?}"
            );
        }
        if resp.closed {
            client = NetClient::connect(&addr).unwrap();
        }
    }
    server.shutdown();
}

/// A 200 must echo the row count + variant, carry the expected output
/// names/dtypes, and decode bit-identical to the in-process oracle.
#[allow(clippy::too_many_arguments)]
fn assert_success(
    name: &str,
    case: &str,
    req: &Json,
    expect: &Json,
    resp: &kamae::serving::NetResponse,
    spec: &GraphSpec,
    schema: &Schema,
    oracle: &InterpretedBackend,
) {
    let j = resp.json().unwrap();
    assert_eq!(
        j.get("rows").and_then(Json::as_i64),
        expect.get("rows").and_then(Json::as_i64),
        "{name}/{case}: row count echo"
    );
    let variant = expect.get("variant").and_then(Json::as_str);
    assert_eq!(
        j.get("variant").and_then(Json::as_str),
        variant,
        "{name}/{case}: variant echo"
    );
    let outs = j.get("outputs").and_then(Json::as_array).expect("outputs array");
    let want_outs = expect.get("outputs").and_then(Json::as_array).expect("expected outputs");
    assert_eq!(outs.len(), want_outs.len(), "{name}/{case}: output count");
    for (o, w) in outs.iter().zip(want_outs) {
        assert_eq!(o.get("name"), w.get("name"), "{name}/{case}: output name");
        assert_eq!(o.get("dtype"), w.get("dtype"), "{name}/{case}: output dtype");
    }
    // oracle replay: same rows, same schema, straight through the backend
    let rows = req
        .get("body")
        .and_then(|b| b.get("rows"))
        .and_then(Json::as_array)
        .expect("success case has body rows");
    let df = dataframe_from_json_rows(rows, schema).unwrap();
    let full = oracle.process(&df).unwrap();
    let idx: Vec<usize> = match variant {
        Some(v) => spec.variant_outputs(v),
        None => (0..spec.outputs.len()).collect(),
    };
    let got: Vec<Tensor> = outs.iter().map(|o| tensor_from_json(o).unwrap()).collect();
    let want: Vec<Tensor> = idx.iter().map(|&i| full[i].clone()).collect();
    if let Err(e) = tensors_bit_identical(&got, &want) {
        panic!("{name}/{case}: wire-vs-oracle: {e}");
    }
}

#[test]
fn single_variant_fixture_round_trips() {
    run_fixture("net_single_variant");
}

#[test]
fn mixed_variant_fixture_round_trips_on_one_connection() {
    run_fixture("net_mixed_variant");
}

#[test]
fn malformed_fixture_gets_typed_4xx_errors() {
    run_fixture("net_malformed");
}

#[test]
fn oversized_fixture_hits_the_batch_cap() {
    run_fixture("net_oversized");
}

/// An interpreted backend slowed down enough that a 1-slot admission
/// window must shed concurrent clients.
struct SlowBackend {
    inner: InterpretedBackend,
    delay: Duration,
}

impl Backend for SlowBackend {
    fn name(&self) -> &str {
        "slow"
    }
    fn kind(&self) -> &'static str {
        "interpreted"
    }
    fn spec(&self) -> Option<&GraphSpec> {
        self.inner.spec()
    }
    fn variants(&self) -> &[String] {
        self.inner.variants()
    }
    fn process(&self, df: &DataFrame) -> kamae::error::Result<Vec<Tensor>> {
        std::thread::sleep(self.delay);
        self.inner.process(df)
    }
    fn process_routed(
        &self,
        df: &DataFrame,
        groups: &[VariantGroup],
    ) -> kamae::error::Result<Vec<Vec<Tensor>>> {
        std::thread::sleep(self.delay);
        self.inner.process_routed(df, groups)
    }
}

#[test]
fn overload_sheds_429_with_retry_after_and_metrics_account_for_it() {
    let spec = merged_spec();
    let backend: Arc<dyn Backend> = Arc::new(SlowBackend {
        inner: InterpretedBackend::new(spec.clone()),
        delay: Duration::from_millis(50),
    });
    let server =
        NetServer::bind(backend, "127.0.0.1:0", NetConfig { admission: 1, ..NetConfig::default() })
            .unwrap();
    let addr = server.addr().to_string();
    let body = r#"{"variant":"a","rows":[{"city":"NYC","price":1.0}]}"#;
    let accepted = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let (addr, accepted, shed) = (&addr, &accepted, &shed);
            scope.spawn(move || {
                let mut client = NetClient::connect(addr).unwrap();
                for _ in 0..6 {
                    let resp = client
                        .request("POST", "/v1/infer", &[("x-kamae-client", "shed-test")], body)
                        .unwrap();
                    match resp.status {
                        200 => {
                            accepted.fetch_add(1, Ordering::SeqCst);
                        }
                        429 => {
                            // the hint is load-derived (queue depth /
                            // drain rate), so pin the contract, not a
                            // constant: integral seconds within
                            // [floor, cap]
                            let hint: u64 = resp
                                .header("retry-after")
                                .expect("shed without the Retry-After hint")
                                .parse()
                                .expect("Retry-After must be integral seconds");
                            assert!(
                                (1..=60).contains(&hint),
                                "Retry-After {hint} outside [floor, cap]"
                            );
                            let j = resp.json().unwrap();
                            assert_eq!(
                                j.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
                                Some("overloaded")
                            );
                            shed.fetch_add(1, Ordering::SeqCst);
                        }
                        other => panic!("unexpected status {other}: {}", resp.body),
                    }
                    if resp.closed {
                        client = NetClient::connect(addr).unwrap();
                    }
                }
            });
        }
    });
    let accepted = accepted.load(Ordering::SeqCst);
    let shed = shed.load(Ordering::SeqCst);
    assert!(accepted >= 1, "nothing was accepted");
    assert!(shed >= 1, "4 concurrent clients against a 1-slot window never shed");

    let mut client = NetClient::connect(&addr).unwrap();
    let m = client.request("GET", "/metrics", &[], "").unwrap();
    assert_eq!(m.status, 200, "{}", m.body);
    let j = m.json().unwrap();
    let report = j.get("serve_report").expect("metrics carries serve_report");
    assert_eq!(report.get("admission_limit").and_then(Json::as_i64), Some(1));
    assert_eq!(
        report.get("shed_requests").and_then(Json::as_i64),
        Some(shed as i64),
        "/metrics shed_requests disagrees with the 429s the clients saw"
    );
    let clients = j.get("clients").and_then(Json::as_object).expect("per-client counters");
    let c = clients.get("shed-test").expect("the X-Kamae-Client id is tracked");
    assert_eq!(c.get("requests").and_then(Json::as_i64), Some(accepted as i64));
    assert_eq!(c.get("shed").and_then(Json::as_i64), Some(shed as i64));
    server.shutdown();
}

#[test]
fn shed_hint_is_the_floor_until_a_drain_rate_exists() {
    let spec = merged_spec();
    let backend: Arc<dyn Backend> = Arc::new(SlowBackend {
        inner: InterpretedBackend::new(spec.clone()),
        delay: Duration::from_millis(500),
    });
    let server = NetServer::bind(
        backend,
        "127.0.0.1:0",
        NetConfig { admission: 1, retry_after_secs: 7, ..NetConfig::default() },
    )
    .unwrap();
    let addr = server.addr().to_string();
    let body = r#"{"variant":"a","rows":[{"city":"NYC","price":1.0}]}"#;
    let slow = std::thread::spawn({
        let addr = addr.clone();
        move || {
            let mut c = NetClient::connect(&addr).unwrap();
            c.request("POST", "/v1/infer", &[], body).unwrap()
        }
    });
    // let the slow request claim the only admission slot
    std::thread::sleep(Duration::from_millis(100));
    let mut c = NetClient::connect(&addr).unwrap();
    let resp = c.request("POST", "/v1/infer", &[], body).unwrap();
    assert_eq!(resp.status, 429, "{}", resp.body);
    // zero requests have completed: no drain-rate signal exists yet, so
    // the hint is exactly the configured floor
    assert_eq!(resp.header("retry-after"), Some("7"));
    assert_eq!(slow.join().unwrap().status, 200);
    server.shutdown();
}

#[test]
fn validation_mode_quarantines_dead_letters_and_serves_clean_rows() {
    let dl_path = std::env::temp_dir().join(format!(
        "kamae_net_dead_letter_{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&dl_path);
    let config = NetConfig {
        validate: true,
        dead_letter: Some(dl_path.clone()),
        ..test_config()
    };
    let (server, addr, spec) = bind(config);
    let schema = request_schema(&spec);
    let oracle = InterpretedBackend::new(spec.clone());
    let mut client = NetClient::connect(&addr).unwrap();

    // rows 1 and 3 are bad: a null price, then a wrong-typed price
    let body = r#"{"variant":"a","rows":[
        {"city":"NYC","price":1.0},
        {"city":"LA","price":null},
        {"city":"SF","price":3.5},
        {"city":"CHI","price":"oops"}]}"#;
    let resp = client
        .request("POST", "/v1/infer", &[("x-kamae-client", "vtest")], body)
        .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = resp.json().unwrap();
    assert_eq!(j.get("rows").and_then(Json::as_i64), Some(4));
    assert_eq!(j.get("valid_rows").and_then(Json::as_i64), Some(2));
    let verdicts = j.get("verdicts").and_then(Json::as_array).expect("verdicts array");
    assert_eq!(verdicts.len(), 4, "one verdict per submitted row");
    let statuses: Vec<&str> = verdicts
        .iter()
        .filter_map(|v| v.get("status").and_then(Json::as_str))
        .collect();
    assert_eq!(statuses, vec!["ok", "quarantined", "ok", "quarantined"]);
    // ok rows map to their positions in the compacted outputs
    assert_eq!(verdicts[0].get("output_row").and_then(Json::as_i64), Some(0));
    assert_eq!(verdicts[2].get("output_row").and_then(Json::as_i64), Some(1));
    // every quarantined row carries structured errors naming rule + column
    for &i in &[1usize, 3] {
        let errors = verdicts[i].get("errors").and_then(Json::as_array).expect("errors array");
        assert!(!errors.is_empty(), "row {i} quarantined without errors");
        for e in errors {
            assert!(
                e.get("rule").and_then(Json::as_str).is_some_and(|r| !r.is_empty()),
                "row {i}: error without a rule name"
            );
            assert_eq!(e.get("column").and_then(Json::as_str), Some("price"), "row {i}");
        }
    }
    // outputs cover exactly the valid rows, bit-identical to serving
    // them without the corrupted neighbours
    let good = Json::parse(r#"[{"city":"NYC","price":1.0},{"city":"SF","price":3.5}]"#).unwrap();
    let df = dataframe_from_json_rows(good.as_array().unwrap(), &schema).unwrap();
    let full = oracle.process(&df).unwrap();
    let want: Vec<Tensor> = spec.variant_outputs("a").iter().map(|&i| full[i].clone()).collect();
    let got: Vec<Tensor> = j
        .get("outputs")
        .and_then(Json::as_array)
        .expect("outputs array")
        .iter()
        .map(|o| tensor_from_json(o).unwrap())
        .collect();
    if let Err(e) = tensors_bit_identical(&got, &want) {
        panic!("validated wire vs clean oracle: {e}");
    }

    // a batch whose rows are ALL quarantined still answers with full
    // verdicts and empty outputs — and is still billed as a request
    let all_bad = r#"{"rows":[{"city":"X","price":null},{"city":"Y","price":"nope"}]}"#;
    let resp = client
        .request("POST", "/v1/infer", &[("x-kamae-client", "vtest")], all_bad)
        .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = resp.json().unwrap();
    assert_eq!(j.get("rows").and_then(Json::as_i64), Some(2));
    assert_eq!(j.get("valid_rows").and_then(Json::as_i64), Some(0));
    assert_eq!(j.get("outputs").and_then(Json::as_array).map(Vec::len), Some(0));
    let verdicts = j.get("verdicts").and_then(Json::as_array).expect("verdicts array");
    assert_eq!(verdicts.len(), 2);
    assert!(verdicts
        .iter()
        .all(|v| v.get("status").and_then(Json::as_str) == Some("quarantined")));

    // dead-letter file: one JSONL entry per quarantined row, holding the
    // ORIGINAL wire row and its errors
    let dl = std::fs::read_to_string(&dl_path).unwrap();
    let entries: Vec<Json> = dl.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(entries.len(), 4, "2 + 2 quarantined rows dead-lettered");
    for e in &entries {
        assert_eq!(e.get("tenant").and_then(Json::as_str), Some("default"));
        assert!(e.get("row").and_then(Json::as_object).is_some(), "original row preserved");
        assert!(!e.get("errors").and_then(Json::as_array).unwrap().is_empty());
    }
    // the wrong-typed row survives verbatim — not the decoder's nulled shadow
    assert_eq!(
        entries[1].get("row").and_then(|r| r.get("price")).and_then(Json::as_str),
        Some("oops")
    );

    // /metrics: per-rule violation counters + the quarantine gauge, and
    // both requests (including the all-quarantined one) billed
    let m = client.request("GET", "/metrics", &[], "").unwrap();
    let j = m.json().unwrap();
    let report = j.get("serve_report").expect("serve_report");
    assert_eq!(report.get("quarantined_rows").and_then(Json::as_i64), Some(4));
    let violations = report.get("violations").expect("violations object");
    assert_eq!(violations.get("not_null").and_then(Json::as_i64), Some(4));
    assert_eq!(violations.get("dtype").and_then(Json::as_i64), Some(2));
    let clients = j.get("clients").and_then(Json::as_object).expect("clients");
    assert_eq!(
        clients.get("vtest").and_then(|c| c.get("requests")).and_then(Json::as_i64),
        Some(2),
        "the all-quarantined request must still be billed"
    );
    server.shutdown();
    let _ = std::fs::remove_file(&dl_path);
}

#[test]
fn deploy_attaches_validation_rules_that_quarantine_by_rule() {
    let config = NetConfig { validate: true, ..test_config() };
    let (server, addr, spec) = bind(config);
    let mut client = NetClient::connect(&addr).unwrap();

    // a rule set naming an unknown column is refused as a 400 — the
    // registry never swaps in a half-built version
    let mut body = Json::object();
    body.set("tenant", "shop");
    body.set("spec", spec.to_json());
    body.set(
        "validation",
        Json::parse(r#"[{"rule":"range","column":"ghost","min":0.0}]"#).unwrap(),
    );
    let resp = client.request("POST", "/admin/deploy", &[], &body.to_string()).unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body);
    assert!(resp.body.contains("unknown column"), "{}", resp.body);

    // deploy with a real range rule: price must be non-negative
    body.set(
        "validation",
        Json::parse(r#"[{"rule":"range","column":"price","min":0.0}]"#).unwrap(),
    );
    let resp = client.request("POST", "/admin/deploy", &[], &body.to_string()).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);

    let infer = r#"{"rows":[{"city":"NYC","price":2.0},{"city":"LA","price":-5.0}]}"#;
    let resp = client.request("POST", "/v1/infer/shop", &[], infer).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = resp.json().unwrap();
    assert_eq!(j.get("valid_rows").and_then(Json::as_i64), Some(1));
    let verdicts = j.get("verdicts").and_then(Json::as_array).unwrap();
    assert_eq!(verdicts[0].get("status").and_then(Json::as_str), Some("ok"));
    let errors = verdicts[1].get("errors").and_then(Json::as_array).expect("errors");
    assert_eq!(errors.len(), 1);
    assert_eq!(errors[0].get("rule").and_then(Json::as_str), Some("range"));
    assert_eq!(errors[0].get("column").and_then(Json::as_str), Some("price"));
    assert!(errors[0]
        .get("message")
        .and_then(Json::as_str)
        .is_some_and(|m| m.contains("below minimum")));
    server.shutdown();
}

#[test]
fn healthz_reports_the_listener_shape() {
    let (server, addr, _spec) = bind(test_config());
    let mut client = NetClient::connect(&addr).unwrap();
    let resp = client.request("GET", "/healthz", &[], "").unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = resp.json().unwrap();
    assert_eq!(j.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(j.get("backend").and_then(Json::as_str), Some("a+b"));
    assert_eq!(j.get("kind").and_then(Json::as_str), Some("interpreted"));
    assert_eq!(j.get("workers").and_then(Json::as_i64), Some(2));
    assert_eq!(j.get("admission_limit").and_then(Json::as_i64), Some(64));
    let variants: Vec<&str> = j
        .get("variants")
        .and_then(Json::as_array)
        .expect("variants array")
        .iter()
        .filter_map(Json::as_str)
        .collect();
    assert_eq!(variants, vec!["a", "b"]);
    server.shutdown();
}

#[test]
fn registry_admin_deploy_infer_rollback_over_the_wire() {
    let (server, addr, spec) = bind(test_config());
    let mut client = NetClient::connect(&addr).unwrap();

    // deploy a second tenant carrying the same spec
    let mut body = Json::object();
    body.set("tenant", "shop");
    body.set("spec", spec.to_json());
    let resp = client.request("POST", "/admin/deploy", &[], &body.to_string()).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = resp.json().unwrap();
    assert_eq!(j.get("status").and_then(Json::as_str), Some("deployed"));
    assert_eq!(j.get("version").and_then(Json::as_i64), Some(1));

    // infer against the new tenant; the optimizer is semantics-
    // preserving, so outputs match the default tenant bit-for-bit
    let infer = r#"{"variant":"a","rows":[{"city":"NYC","price":1.0}]}"#;
    let shop = client.request("POST", "/v1/infer/shop", &[], infer).unwrap();
    assert_eq!(shop.status, 200, "{}", shop.body);
    let base = client.request("POST", "/v1/infer", &[], infer).unwrap();
    assert_eq!(base.status, 200, "{}", base.body);
    let shop_out: Vec<Tensor> = shop
        .json()
        .unwrap()
        .get("outputs")
        .and_then(Json::as_array)
        .unwrap()
        .iter()
        .map(|o| tensor_from_json(o).unwrap())
        .collect();
    let base_out: Vec<Tensor> = base
        .json()
        .unwrap()
        .get("outputs")
        .and_then(Json::as_array)
        .unwrap()
        .iter()
        .map(|o| tensor_from_json(o).unwrap())
        .collect();
    if let Err(e) = tensors_bit_identical(&shop_out, &base_out) {
        panic!("tenant 'shop' vs default tenant: {e}");
    }

    // no version before v1: rollback is a typed 409
    let rb = r#"{"tenant":"shop"}"#;
    let resp = client.request("POST", "/admin/rollback", &[], rb).unwrap();
    assert_eq!(resp.status, 409, "{}", resp.body);
    assert_eq!(
        resp.json().unwrap().get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("version_conflict")
    );

    // CAS: deploying against the wrong expected version loses with 409
    body.set("expect_version", 7);
    let resp = client.request("POST", "/admin/deploy", &[], &body.to_string()).unwrap();
    assert_eq!(resp.status, 409, "{}", resp.body);
    // the right expectation lands v2
    body.set("expect_version", 1);
    let resp = client.request("POST", "/admin/deploy", &[], &body.to_string()).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(resp.json().unwrap().get("version").and_then(Json::as_i64), Some(2));

    // rollback re-activates v1 without a rebuild
    let resp = client.request("POST", "/admin/rollback", &[], rb).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(resp.json().unwrap().get("version").and_then(Json::as_i64), Some(1));

    // snapshot: both tenants, shop with two versions and v1 active
    let resp = client.request("GET", "/admin/tenants", &[], "").unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = resp.json().unwrap();
    let tenants = j.get("tenants").and_then(Json::as_array).expect("tenants array");
    let shop = tenants
        .iter()
        .find(|t| t.get("tenant").and_then(Json::as_str) == Some("shop"))
        .expect("shop tenant listed");
    assert_eq!(shop.get("active_version").and_then(Json::as_i64), Some(1));
    assert_eq!(shop.get("versions").and_then(Json::as_array).unwrap().len(), 2);
    assert!(tenants
        .iter()
        .any(|t| t.get("tenant").and_then(Json::as_str) == Some("default")));

    // healthz lists the tenant names
    let resp = client.request("GET", "/healthz", &[], "").unwrap();
    let names: Vec<String> = resp
        .json()
        .unwrap()
        .get("tenants")
        .and_then(Json::as_array)
        .expect("healthz tenants array")
        .iter()
        .filter_map(Json::as_str)
        .map(str::to_string)
        .collect();
    assert_eq!(names, vec!["default", "shop"]);
    server.shutdown();
}

#[test]
fn deadline_ms_expires_queued_requests_with_typed_504() {
    // one worker, pinned down by a slow batch: a queued request with a
    // small deadline_ms must come back as a fast typed 504 from the
    // reaper instead of waiting the worker out
    let spec = merged_spec();
    let backend: Arc<dyn Backend> = Arc::new(SlowBackend {
        inner: InterpretedBackend::new(spec.clone()),
        delay: Duration::from_millis(80),
    });
    let config = NetConfig {
        batch: BatchConfig { workers: 1, ..BatchConfig::default() },
        ..NetConfig::default()
    };
    let server = NetServer::bind(backend, "127.0.0.1:0", config).unwrap();
    let addr = server.addr().to_string();
    let body = r#"{"rows":[{"city":"NYC","price":1.0}]}"#;

    // malformed deadlines are refused before anything queues
    let mut client = NetClient::connect(&addr).unwrap();
    for bad in [r#"{"deadline_ms":0,"rows":[{"city":"NYC","price":1.0}]}"#,
                r#"{"deadline_ms":"soon","rows":[{"city":"NYC","price":1.0}]}"#] {
        let resp = client.request("POST", "/v1/infer", &[], bad).unwrap();
        assert_eq!(resp.status, 400, "{}", resp.body);
        let j = resp.json().unwrap();
        let msg = j
            .get("error")
            .and_then(|e| e.get("message"))
            .and_then(Json::as_str)
            .unwrap_or_default();
        assert!(msg.contains("'deadline_ms' must be a positive integer"), "{msg}");
    }

    let slow = std::thread::spawn({
        let addr = addr.clone();
        move || {
            let mut c = NetClient::connect(&addr).unwrap();
            c.request("POST", "/v1/infer", &[], body).unwrap()
        }
    });
    // wait until the slow request is in flight (and thus holds the only
    // worker) before queueing the deadlined one behind it
    for _ in 0..200 {
        let h = client.request("GET", "/healthz", &[], "").unwrap();
        if h.json().unwrap().get("in_flight").and_then(Json::as_i64).unwrap_or(0) >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    std::thread::sleep(Duration::from_millis(10));
    let deadlined = r#"{"deadline_ms":5,"rows":[{"city":"NYC","price":1.0}]}"#;
    let resp = client.request("POST", "/v1/infer", &[], deadlined).unwrap();
    assert_eq!(resp.status, 504, "{}", resp.body);
    let j = resp.json().unwrap();
    let err = j.get("error").expect("504 carries the typed error object");
    assert_eq!(err.get("code").and_then(Json::as_str), Some("deadline_exceeded"));
    assert_eq!(err.get("status").and_then(Json::as_i64), Some(504));
    assert!(err
        .get("message")
        .and_then(Json::as_str)
        .is_some_and(|m| m.contains("deadline")));
    assert_eq!(slow.join().unwrap().status, 200, "the slow request still completes");
    if resp.closed {
        client = NetClient::connect(&addr).unwrap();
    }

    let m = client.request("GET", "/metrics", &[], "").unwrap();
    let report = m.json().unwrap();
    let report = report.get("serve_report").expect("serve_report").clone();
    assert_eq!(
        report.get("deadline_expired").and_then(Json::as_i64),
        Some(1),
        "expiry must be visible in /metrics"
    );
    server.shutdown();
}

#[test]
fn quarantine_alert_flips_healthz_to_degraded_and_recovers() {
    let config = NetConfig {
        validate: true,
        quarantine_alert: Some(0.5),
        ..test_config()
    };
    let (server, addr, _spec) = bind(config);
    let mut client = NetClient::connect(&addr).unwrap();

    // an all-quarantined request pushes default's rolling rate to 1.0
    let bad = r#"{"rows":[{"city":"NYC","price":null},{"city":"LA","price":null}]}"#;
    let resp = client.request("POST", "/v1/infer", &[], bad).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);

    let h = client.request("GET", "/healthz", &[], "").unwrap();
    assert_eq!(h.status, 200, "degraded is an ALERT, not an outage: still 200");
    let j = h.json().unwrap();
    assert_eq!(j.get("status").and_then(Json::as_str), Some("degraded"));
    let alert = j.get("alert").expect("degraded healthz names its cause");
    assert_eq!(alert.get("reason").and_then(Json::as_str), Some("quarantine_rate"));
    assert_eq!(alert.get("tenant").and_then(Json::as_str), Some("default"));
    assert_eq!(alert.get("threshold").and_then(|t| t.as_f64()), Some(0.5));
    assert!(alert
        .get("quarantine_rate")
        .and_then(|r| r.as_f64())
        .is_some_and(|r| r >= 0.5));

    // healthy traffic decays the rolling window below the threshold
    let clean = r#"{"rows":[
        {"city":"NYC","price":1.0},{"city":"LA","price":2.0},
        {"city":"SF","price":3.0},{"city":"CHI","price":4.0}]}"#;
    for _ in 0..6 {
        let resp = client.request("POST", "/v1/infer", &[], clean).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
    }
    let h = client.request("GET", "/healthz", &[], "").unwrap();
    let j = h.json().unwrap();
    assert_eq!(j.get("status").and_then(Json::as_str), Some("ok"));
    assert!(j.get("alert").is_none(), "recovered healthz must drop the alert");
    server.shutdown();
}

#[test]
fn poison_rows_get_verdicts_and_survivors_serve_over_the_wire() {
    use kamae::serving::{ChaosBackend, FaultPlan};

    // content-keyed poison: any row with price == 666.0 panics the
    // backend; bisection must blame exactly that row on the wire
    let spec = merged_spec();
    let inner: Arc<dyn Backend> = Arc::new(InterpretedBackend::new(spec.clone()));
    let chaos: Arc<dyn Backend> = Arc::new(ChaosBackend::new(
        inner,
        FaultPlan::poison_rows(|df, i| {
            df.column("price")
                .ok()
                .and_then(|c| c.as_f64().ok())
                .is_some_and(|v| v[i] == 666.0)
        }),
    ));
    let server = NetServer::bind(chaos, "127.0.0.1:0", test_config()).unwrap();
    let addr = server.addr().to_string();
    let schema = request_schema(&spec);
    let oracle = InterpretedBackend::new(spec.clone());
    let mut client = NetClient::connect(&addr).unwrap();

    let body = r#"{"rows":[
        {"city":"NYC","price":1.0},
        {"city":"LA","price":666.0},
        {"city":"SF","price":3.5}]}"#;
    let resp = client.request("POST", "/v1/infer", &[], body).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = resp.json().unwrap();
    assert_eq!(j.get("rows").and_then(Json::as_i64), Some(3));
    assert_eq!(j.get("valid_rows").and_then(Json::as_i64), Some(2));
    let verdicts = j.get("verdicts").and_then(Json::as_array).expect("verdicts");
    let statuses: Vec<&str> = verdicts
        .iter()
        .filter_map(|v| v.get("status").and_then(Json::as_str))
        .collect();
    assert_eq!(statuses, vec!["ok", "quarantined", "ok"]);
    assert_eq!(verdicts[0].get("output_row").and_then(Json::as_i64), Some(0));
    assert_eq!(verdicts[2].get("output_row").and_then(Json::as_i64), Some(1));
    let errors = verdicts[1].get("errors").and_then(Json::as_array).expect("errors");
    assert_eq!(errors[0].get("rule").and_then(Json::as_str), Some("poison"));
    assert!(errors[0]
        .get("message")
        .and_then(Json::as_str)
        .is_some_and(|m| m.contains("bisection")));

    // survivors are served bit-identical to a backend never fed poison
    let good = Json::parse(r#"[{"city":"NYC","price":1.0},{"city":"SF","price":3.5}]"#).unwrap();
    let df = dataframe_from_json_rows(good.as_array().unwrap(), &schema).unwrap();
    let want = oracle.process(&df).unwrap();
    let got: Vec<Tensor> = j
        .get("outputs")
        .and_then(Json::as_array)
        .expect("outputs")
        .iter()
        .map(|o| tensor_from_json(o).unwrap())
        .collect();
    if let Err(e) = tensors_bit_identical(&got, &want) {
        panic!("poison survivors vs clean oracle: {e}");
    }

    let m = client.request("GET", "/metrics", &[], "").unwrap();
    let j = m.json().unwrap();
    let report = j.get("serve_report").expect("serve_report");
    assert_eq!(report.get("poison_rows").and_then(Json::as_i64), Some(1));
    assert!(
        report.get("worker_panics").and_then(Json::as_i64).is_some_and(|p| p >= 1),
        "isolation panics must be visible in /metrics"
    );
    server.shutdown();
}

#[test]
fn erroring_dead_letter_sink_never_fails_serving() {
    // /dev/full accepts the open but fails every write — the "disk
    // filled up mid-run" shape, end to end over the wire
    if !std::path::Path::new("/dev/full").exists() {
        eprintln!("SKIP: /dev/full not available on this platform");
        return;
    }
    let config = NetConfig {
        validate: true,
        dead_letter: Some(PathBuf::from("/dev/full")),
        ..test_config()
    };
    let (server, addr, _spec) = bind(config);
    let mut client = NetClient::connect(&addr).unwrap();
    let bad = r#"{"rows":[{"city":"NYC","price":null},{"city":"LA","price":2.0}]}"#;
    let resp = client.request("POST", "/v1/infer", &[], bad).unwrap();
    assert_eq!(resp.status, 200, "a dead sink must never fail the request: {}", resp.body);
    let j = resp.json().unwrap();
    assert_eq!(j.get("valid_rows").and_then(Json::as_i64), Some(1));

    let m = client.request("GET", "/metrics", &[], "").unwrap();
    let report = m.json().unwrap();
    let report = report.get("serve_report").expect("serve_report").clone();
    assert_eq!(
        report.get("dead_letter_errors").and_then(Json::as_i64),
        Some(1),
        "the swallowed write failure must be visible in /metrics"
    );
    server.shutdown();
}

#[test]
fn admin_shutdown_drains_and_closes() {
    let (server, addr, _spec) = bind(test_config());
    let mut client = NetClient::connect(&addr).unwrap();
    let resp = client.request("POST", "/admin/shutdown", &[], "").unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(resp.json().unwrap().get("status").and_then(Json::as_str), Some("draining"));
    assert!(resp.closed, "drain response should ask the client to hang up");
    // the stop flag is set, so wait() completes the drain promptly
    server.wait();
    // the listener is gone: a fresh request cannot complete
    assert!(
        NetClient::connect(&addr).and_then(|mut c| c.request("GET", "/healthz", &[], "")).is_err(),
        "listener still answering after drain"
    );
}
