//! Experiment C1 — offline/online parity, the paper's headline claim:
//! "Extensive unit tests ensure parity between Spark and Keras
//! implementations."
//!
//! Here the three implementations that must agree are:
//!   1. the Rust engine (offline fit/transform — the "Spark" side),
//!   2. the GraphSpec interpreter (serving fallback / oracle),
//!   3. the AOT-compiled HLO executed via PJRT (the "Keras" side).
//!
//! Integer outputs (indices, hashes, date parts, flags) must match
//! **bit-for-bit**; float outputs to f32 rounding (the engine computes
//! f64, the graph f32).
//!
//! Requires `make artifacts` to have run; tests skip (with a loud
//! message) if artifacts are missing so plain `cargo test` still works.

use std::path::{Path, PathBuf};

use kamae::baselines::mleap_like::column_to_tensor;
use kamae::engine::Dataset;
use kamae::export::{GraphSpec, SpecInterpreter};
use kamae::pipeline::catalog;
use kamae::runtime::{Tensor, TensorData};
use kamae::serving::{load_backend, request_pool};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("specs").join("movielens.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn assert_tensors_close(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shape");
    match (&a.data, &b.data) {
        (TensorData::I64(x), TensorData::I64(y)) => {
            assert_eq!(x, y, "{what}: i64 values must match bit-for-bit");
        }
        (TensorData::F32(x), TensorData::F32(y)) => {
            for (i, (p, q)) in x.iter().zip(y.iter()).enumerate() {
                let diff = (p - q).abs();
                let tol = 1e-4_f32.max(q.abs() * 1e-4);
                assert!(
                    diff <= tol || (p.is_nan() && q.is_nan()),
                    "{what}[{i}]: {p} vs {q} (diff {diff})"
                );
            }
        }
        other => panic!("{what}: dtype mismatch {other:?}"),
    }
}

/// Engine → interp → compiled three-way parity over a spec + fresh data
/// (seed differs from the fit seed, so OOV paths are exercised).
fn three_way_parity(spec_name: &str) {
    let Some(dir) = artifacts_dir() else { return };
    let spec = GraphSpec::load(&dir.join("specs").join(format!("{spec_name}.json"))).unwrap();
    let model = kamae::pipeline::PipelineModel::load(
        &dir.join("specs").join(format!("{spec_name}.model.json")),
    )
    .unwrap();

    // request rows incl. tokens unseen at fit time
    let df = request_pool(spec_name, 256).unwrap();

    // 1. engine transform (offline path)
    let engine_out = model.transform_df(df.clone()).unwrap();

    // 2. interpreter
    let interp = SpecInterpreter::new(spec.clone());
    let interp_out = interp.run(&df).unwrap();

    // 3. compiled graph via PJRT (exercises bucket padding: 256 rows
    //    through max bucket 128 forces chunking; also try odd sizes)
    let compiled = load_backend(&dir, spec_name, "compiled").unwrap();
    let compiled_out = compiled.process(&df).unwrap();

    assert_eq!(interp_out.len(), spec.outputs.len());
    assert_eq!(compiled_out.len(), spec.outputs.len());

    for (i, out_name) in spec.outputs.iter().enumerate() {
        // engine column name = spec output without the pass-through suffix
        let col_name = out_name.strip_suffix("__out").unwrap_or(out_name);
        let engine_tensor = column_to_tensor(engine_out.column(col_name).unwrap()).unwrap();
        assert_tensors_close(&interp_out[i], &engine_tensor, &format!("{spec_name}/{col_name} interp-vs-engine"));
        assert_tensors_close(&compiled_out[i], &interp_out[i], &format!("{spec_name}/{col_name} compiled-vs-interp"));
    }
}

#[test]
fn quickstart_parity() {
    three_way_parity("quickstart");
}

#[test]
fn movielens_parity() {
    three_way_parity("movielens");
}

#[test]
fn ltr_parity() {
    three_way_parity("ltr");
}

#[test]
fn compiled_handles_every_batch_size() {
    let Some(dir) = artifacts_dir() else { return };
    let backend = load_backend(&dir, "movielens", "compiled").unwrap();
    let interp = SpecInterpreter::new(
        GraphSpec::load(&dir.join("specs").join("movielens.json")).unwrap(),
    );
    let pool = request_pool("movielens", 300).unwrap();
    // exact bucket, sub-bucket (padding), over-max (chunking)
    for batch in [1usize, 3, 8, 17, 32, 100, 128, 131, 256, 300] {
        let df = pool.slice(0, batch);
        let a = backend.process(&df).unwrap();
        let b = interp.run(&df).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_tensors_close(x, y, &format!("batch {batch}"));
        }
    }
}

#[test]
fn mleap_backend_agrees_on_movielens() {
    let Some(dir) = artifacts_dir() else { return };
    let mleap = load_backend(&dir, "movielens", "mleap").unwrap();
    let interp_backend = load_backend(&dir, "movielens", "interpreted").unwrap();
    let df = request_pool("movielens", 64).unwrap();
    let a = mleap.process(&df).unwrap();
    let b = interp_backend.process(&df).unwrap();
    for (x, y) in a.iter().zip(b.iter()) {
        assert_tensors_close(x, y, "mleap-vs-interp");
    }
}

#[test]
fn fitted_pipelines_round_trip_through_json() {
    let Some(dir) = artifacts_dir() else { return };
    for name in ["quickstart", "movielens", "ltr"] {
        let path = dir.join("specs").join(format!("{name}.model.json"));
        let model = kamae::pipeline::PipelineModel::load(&path).unwrap();
        let df = request_pool(name, 32).unwrap();
        let out = model.transform_df(df).unwrap();
        assert!(out.num_columns() > 4, "{name} transformed nothing");
        // save → load → identical re-serialisation (canonical JSON)
        let json1 = model.to_json().to_string();
        let model2 = kamae::pipeline::PipelineModel::from_json(
            &kamae::util::json::Json::parse(&json1).unwrap(),
        )
        .unwrap();
        assert_eq!(json1, model2.to_json().to_string(), "{name} save/load not canonical");
    }
}

// ---------------------------------------------------------------------------
// optimizer parity — no artifacts needed: pipelines are fitted in-test.
// The optimizer's contract is stronger than the C1 float tolerance:
// optimized and unoptimized specs must agree BIT-FOR-BIT under the
// interpreter, i64 and f32 alike.

fn assert_tensors_bit_identical(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shape");
    match (&a.data, &b.data) {
        (TensorData::I64(x), TensorData::I64(y)) => {
            assert_eq!(x, y, "{what}: i64 values must match bit-for-bit");
        }
        (TensorData::F32(x), TensorData::F32(y)) => {
            for (i, (p, q)) in x.iter().zip(y.iter()).enumerate() {
                assert!(
                    p.to_bits() == q.to_bits() || (p.is_nan() && q.is_nan()),
                    "{what}[{i}]: {p:?} vs {q:?} (bits {:#010x} vs {:#010x})",
                    p.to_bits(),
                    q.to_bits()
                );
            }
        }
        other => panic!("{what}: dtype mismatch {other:?}"),
    }
}

/// Fit a catalog pipeline, export it unoptimized and fully optimized,
/// and require bit-identical interpreter outputs on fresh request data
/// (seed 999 — unseen at fit time, so OOV paths are exercised too).
/// `expect_fused` names fused ops that MUST appear in the optimized
/// spec — the fusion passes have to actually fire on the example
/// pipelines, not just exist. `expect_lanes` additionally requires a
/// multi-output node (MultiLaneBucketize's product).
fn optimizer_parity(spec_name: &str, expect_fused: &[&str], expect_lanes: bool) {
    use kamae::optim::OptimizeLevel;

    let (pipeline, inputs, outputs, data): (_, fn() -> Vec<kamae::export::SpecInput>, Vec<&str>, _) =
        match spec_name {
            "movielens" => (
                catalog::movielens_pipeline(),
                catalog::movielens_inputs as _,
                catalog::MOVIELENS_OUTPUTS.to_vec(),
                kamae::synth::gen_movielens(&kamae::synth::MovieLensConfig {
                    rows: 4_000,
                    ..Default::default()
                }),
            ),
            "ltr" => (
                catalog::ltr_pipeline(),
                catalog::ltr_inputs as _,
                catalog::LTR_OUTPUTS.to_vec(),
                kamae::synth::gen_ltr(&kamae::synth::LtrConfig {
                    rows: 4_000,
                    ..Default::default()
                }),
            ),
            other => panic!("no optimizer-parity fixture for {other}"),
        };
    let model = pipeline.fit(&Dataset::from_dataframe(data, 4)).unwrap();
    let (raw, _) = model
        .to_graph_spec_opt(spec_name, inputs(), &outputs, OptimizeLevel::None)
        .unwrap();
    let (opt, _report) = model
        .to_graph_spec_opt(spec_name, inputs(), &outputs, OptimizeLevel::Full)
        .unwrap();
    assert!(
        opt.nodes.len() <= raw.nodes.len(),
        "{spec_name}: optimizer grew the graph ({} -> {})",
        raw.nodes.len(),
        opt.nodes.len()
    );
    assert_eq!(opt.outputs, raw.outputs, "{spec_name}: output contract changed");
    for op in expect_fused {
        assert!(
            opt.nodes.iter().any(|n| n.op == *op) || opt.ingress.iter().any(|n| n.op == *op),
            "{spec_name}: expected fused op '{op}' in the optimized spec"
        );
    }
    if expect_lanes {
        assert!(
            opt.nodes.iter().any(|n| !n.lanes.is_empty()),
            "{spec_name}: expected a multi-output (lanes) node in the optimized spec"
        );
    }

    // serving loads specs from JSON — round-trip the optimized one
    let opt = GraphSpec::from_json(
        &kamae::util::json::Json::parse(&opt.to_json().to_string()).unwrap(),
    )
    .unwrap();

    let out_names = opt.outputs.clone();
    let df = request_pool(spec_name, 256).unwrap();
    let a = SpecInterpreter::new(raw).run(&df).unwrap();
    let b = SpecInterpreter::new(opt).run(&df).unwrap();
    assert_eq!(a.len(), b.len());
    for (out_name, (x, y)) in out_names.iter().zip(a.iter().zip(b.iter())) {
        assert_tensors_bit_identical(y, x, &format!("{spec_name}/{out_name} optimized-vs-raw"));
    }
}

#[test]
fn optimizer_parity_movielens() {
    // the Genres split_pad -> hash64 chain must fuse
    optimizer_parity("movielens", &["fused_ingress"], false);
}

#[test]
fn optimizer_parity_ltr() {
    // all three round-2 fusions plus the round-1 affine fusion must fire:
    // amenities split_pad->hash64 (ingress chain), the price-decile
    // bucketize->compare ladder, the seasonal select-over-compare, and
    // the cyclic month affine ladders — and the round-3 multi-lane merge
    // of the lead_time sibling fan-out (lead_bucket / lead_bucket_fine /
    // is_last_minute) must produce a multi-output node
    optimizer_parity("ltr", &["fused_ingress", "affine", "multi_bucketize", "select_cmp"], true);
}

/// Multi-variant serving parity: the merged, deduped full+lite LTR spec
/// must reproduce each variant's raw (unoptimized) outputs bit-for-bit,
/// the CrossOutputDedup pass must actually fire on the merged spec, and
/// sharing must make the merged graph strictly cheaper than serving the
/// two variants separately.
#[test]
fn cross_variant_dedup_parity_ltr() {
    use kamae::optim::{spec_cost, OptimizeLevel};

    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig { rows: 4_000, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let export = |name: &str, outputs: &[&str], level| {
        model
            .to_graph_spec_opt(name, catalog::ltr_inputs(), outputs, level)
            .unwrap()
            .0
    };
    let full_raw = export("ltr", &catalog::LTR_OUTPUTS, OptimizeLevel::None);
    let lite_raw = export("ltr_lite", &catalog::LTR_LITE_OUTPUTS, OptimizeLevel::None);
    let full_opt = export("ltr", &catalog::LTR_OUTPUTS, OptimizeLevel::Full);
    let lite_opt = export("ltr_lite", &catalog::LTR_LITE_OUTPUTS, OptimizeLevel::Full);

    let merged = GraphSpec::merge_variants("ltr+ltr_lite", &[&full_opt, &lite_opt]).unwrap();
    let (merged_opt, report) =
        kamae::optim::optimize(merged, OptimizeLevel::Full).unwrap();
    assert!(
        report.stats.iter().any(|s| s.pass == "cross-output-dedup" && s.changed),
        "cross-output-dedup did not fire on the merged spec\n{report}"
    );
    assert!(
        spec_cost(&merged_opt) < spec_cost(&full_opt) + spec_cost(&lite_opt),
        "merged cost {} not below separate {} + {}\n{report}",
        spec_cost(&merged_opt),
        spec_cost(&full_opt),
        spec_cost(&lite_opt)
    );

    // serving loads merged specs from JSON — round-trip first (this also
    // exercises lane serialization on a real optimized spec)
    let merged_opt = GraphSpec::from_json(
        &kamae::util::json::Json::parse(&merged_opt.to_json().to_string()).unwrap(),
    )
    .unwrap();

    let df = request_pool("ltr", 256).unwrap();
    let merged_out = SpecInterpreter::new(merged_opt.clone()).run(&df).unwrap();
    let full_out = SpecInterpreter::new(full_raw.clone()).run(&df).unwrap();
    let lite_out = SpecInterpreter::new(lite_raw.clone()).run(&df).unwrap();
    assert_eq!(merged_out.len(), full_out.len() + lite_out.len());
    for (i, (name, raw_t)) in full_raw
        .outputs
        .iter()
        .zip(full_out.iter())
        .chain(lite_raw.outputs.iter().zip(lite_out.iter()))
        .enumerate()
    {
        assert_tensors_bit_identical(
            &merged_out[i],
            raw_t,
            &format!("merged[{i}] ({name}) vs separate raw"),
        );
    }
}

#[test]
fn regex_ingress_precompile_parity() {
    // Regex step specialisation (ROADMAP): the interpreter precompiles
    // every ingress regex once per backend load — standalone
    // `regex_replace` / `regex_extract` nodes AND steps inside
    // IngressFuse's `fused_ingress` chains. Precompilation must not
    // change a single bit: engine transform, unoptimized
    // interpretation, and the fully optimized spec (where the
    // regex→hash chain fuses and replays through the cache) must agree
    // exactly — including across repeated requests over one backend
    // (the cache is reused, not rebuilt).
    use kamae::dataframe::{Column, DataFrame, DType};
    use kamae::export::SpecInput;
    use kamae::optim::OptimizeLevel;
    use kamae::pipeline::{Pipeline, Stage};
    use kamae::transformers::{HashIndexTransformer, RegexExtractTransformer, RegexReplaceTransformer};

    let df = DataFrame::new(vec![(
        "s".into(),
        Column::from_str(vec!["item-12 x", "no digits", "éé-7 ab", "", "42"]),
    )])
    .unwrap();
    let pipeline = Pipeline::new(vec![
        Stage::transformer(
            RegexReplaceTransformer::new("s", "s_clean", "[0-9]+", "#").unwrap(),
        ),
        Stage::transformer(HashIndexTransformer::new("s_clean", "s_clean_idx", 257)),
        Stage::transformer(
            RegexExtractTransformer::new("s", "s_word", "([a-z]+)", 1).unwrap(),
        ),
        Stage::transformer(HashIndexTransformer::new("s_word", "s_word_idx", 509)),
    ]);
    let model = pipeline.fit(&Dataset::from_dataframe(df.clone(), 2)).unwrap();

    let inputs = || vec![SpecInput { name: "s".into(), dtype: DType::Str, width: None }];
    let outputs = ["s_clean_idx", "s_word_idx"];
    let (raw, _) = model
        .to_graph_spec_opt("re", inputs(), &outputs, OptimizeLevel::None)
        .unwrap();
    let (opt, _) = model
        .to_graph_spec_opt("re", inputs(), &outputs, OptimizeLevel::Full)
        .unwrap();
    // the regex→hash chains must actually fuse, so the cached replay
    // path (not just standalone nodes) is what this test pins
    assert!(
        opt.ingress.iter().any(|n| n.op == "fused_ingress"),
        "regex ingress chain did not fuse"
    );

    let raw_interp = SpecInterpreter::new(raw);
    let opt_interp = SpecInterpreter::new(opt);
    // two requests through the same interpreters: the second reuses the
    // warm regex cache and must not drift
    for request in [df.clone(), df.slice(1, 3)] {
        let engine_req = model.transform_df(request.clone()).unwrap();
        let a = raw_interp.run(&request).unwrap();
        let b = opt_interp.run(&request).unwrap();
        for (i, out_name) in outputs.iter().enumerate() {
            let engine_col = engine_req.column(out_name).unwrap().as_i64().unwrap();
            assert_eq!(a[i].as_i64().unwrap(), engine_col, "{out_name} raw-vs-engine");
            assert_eq!(b[i].as_i64().unwrap(), engine_col, "{out_name} optimized-vs-engine");
        }
    }
}

#[test]
fn optimizer_shrinks_the_ltr_graph() {
    use kamae::optim::OptimizeLevel;
    // LTR carries offline-only features (price_decile, stay_norm,
    // property hashing) and scalar-affine ladders (cyclic month
    // encodings) — the optimizer must find real wins, not just tie.
    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig { rows: 2_000, ..Default::default() });
    let model = catalog::ltr_pipeline().fit(&Dataset::from_dataframe(data, 4)).unwrap();
    let (raw, _) = model
        .to_graph_spec_opt("ltr", catalog::ltr_inputs(), &catalog::LTR_OUTPUTS, OptimizeLevel::None)
        .unwrap();
    let (opt, report) = model
        .to_graph_spec_opt("ltr", catalog::ltr_inputs(), &catalog::LTR_OUTPUTS, OptimizeLevel::Full)
        .unwrap();
    assert!(
        opt.nodes.len() < raw.nodes.len(),
        "expected a strict node reduction, got {} -> {}\n{report}",
        raw.nodes.len(),
        opt.nodes.len()
    );
    // dead property hashing must also drop its ingress node + graph input
    assert!(opt.ingress.len() < raw.ingress.len(), "ingress not pruned\n{report}");
    assert!(opt.graph_inputs.len() < raw.graph_inputs.len(), "graph inputs not pruned");
    // at least one affine chain (the cyclic month encodings) fused
    assert!(
        opt.nodes.iter().any(|n| n.op == "affine"),
        "no affine fusion happened\n{report}"
    );
}

#[test]
fn spec_exports_are_stable() {
    // re-fitting on the same seed must export an identical spec (the
    // artifact cache in `make` depends on this determinism)
    let df = kamae::synth::gen_movielens(&kamae::synth::MovieLensConfig {
        rows: 5_000,
        ..Default::default()
    });
    let fit = |df: &kamae::dataframe::DataFrame| {
        let model = catalog::movielens_pipeline()
            .fit(&Dataset::from_dataframe(df.clone(), 4))
            .unwrap();
        model
            .to_graph_spec("movielens", catalog::movielens_inputs(), &catalog::MOVIELENS_OUTPUTS)
            .unwrap()
            .to_json()
            .to_string()
    };
    assert_eq!(fit(&df), fit(&df));
}
