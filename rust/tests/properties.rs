//! Property-based tests on coordinator invariants: routing/batching
//! (tensor split/concat round trips), pipeline state (save/load/re-save
//! canonicalisation), spec-builder invariants, ingress determinism, and
//! the kernel-program differential (compiled columnar hot path ==
//! `eval_node` oracle, bit for bit, per registry op / lane kind / null
//! mask / routed cone).

use kamae::dataframe::{Column, DataFrame, DType};
use kamae::engine::Dataset;
use kamae::export::SpecInput;
use kamae::pipeline::{Pipeline, Stage};
use kamae::runtime::Tensor;
use kamae::transformers::*;
use kamae::util::prop::{check, check_res, gen};
use kamae::util::rng::Rng;

/// Random DataFrame with a string and a float column.
fn random_df(rng: &mut Rng, max_rows: usize) -> DataFrame {
    let rows = 1 + rng.below(max_rows as u64) as usize;
    let strings: Vec<String> = (0..rows).map(|_| gen::string(rng, 12)).collect();
    let floats: Vec<f64> = (0..rows).map(|_| gen::f64_mixed(rng)).collect();
    DataFrame::new(vec![
        ("s".into(), Column::from_str(strings)),
        ("x".into(), Column::from_f64(floats)),
    ])
    .unwrap()
}

#[test]
fn tensor_concat_split_roundtrip() {
    check_res(
        "concat(split(t)) == t for random splits",
        60,
        |rng| {
            let total = 1 + rng.below(50) as usize;
            let width = 1 + rng.below(5) as usize;
            let data: Vec<i64> = (0..total * width).map(|_| rng.next_u64() as i64).collect();
            // random partition of `total`
            let mut sizes = Vec::new();
            let mut left = total;
            while left > 0 {
                let take = 1 + rng.below(left as u64) as usize;
                sizes.push(take);
                left -= take;
            }
            (data, width, total, sizes)
        },
        |(data, width, total, sizes)| {
            let t = Tensor::i64(data.clone(), vec![*total, *width]).map_err(|e| e.to_string())?;
            let parts = t.split_batch(sizes).map_err(|e| e.to_string())?;
            if parts.len() != sizes.len() {
                return Err("wrong part count".into());
            }
            let refs: Vec<&Tensor> = parts.iter().collect();
            let back = Tensor::concat_batch(&refs).map_err(|e| e.to_string())?;
            if back != t {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn tensor_pad_preserves_prefix() {
    check(
        "pad_batch keeps original rows intact",
        40,
        |rng| {
            let rows = 1 + rng.below(20) as usize;
            let data: Vec<f32> = (0..rows).map(|_| rng.f64() as f32).collect();
            let target = rows + rng.below(30) as usize;
            (data, rows, target)
        },
        |(data, rows, target)| {
            let t = Tensor::f32(data.clone(), vec![*rows]).unwrap();
            let p = t.pad_batch(*target);
            p.batch() == (*target).max(*rows) && p.as_f32().unwrap()[..*rows] == data[..]
        },
    );
}

#[test]
fn partitioning_never_loses_rows() {
    check(
        "Dataset::from_dataframe covers all rows in order",
        40,
        |rng| {
            let df = random_df(rng, 200);
            let parts = 1 + rng.below(16) as usize;
            (df, parts)
        },
        |(df, parts)| {
            let ds = Dataset::from_dataframe(df.clone(), *parts);
            ds.num_rows() == df.num_rows() && ds.collect().unwrap() == *df
        },
    );
}

#[test]
fn hash_ingress_deterministic_across_partitioning() {
    check(
        "hash64 of a column is independent of partitioning",
        30,
        |rng| (random_df(rng, 120), 1 + rng.below(8) as usize),
        |(df, parts)| {
            let whole = kamae::ops::hash::hash64_column(df.column("s").unwrap()).unwrap();
            let ds = Dataset::from_dataframe(df.clone(), *parts);
            let mapped = ds
                .map(|p| {
                    let mut p = p.clone();
                    let h = kamae::ops::hash::hash64_column(p.column("s")?)?;
                    p.set_column("h", h)?;
                    Ok(p)
                })
                .unwrap()
                .collect()
                .unwrap();
            mapped.column("h").unwrap() == &whole
        },
    );
}

#[test]
fn pipeline_save_load_transform_identical() {
    check_res(
        "fitted pipeline: load(save(m)) transforms identically",
        15,
        |rng| random_df(rng, 80),
        |df| {
            let pipeline = Pipeline::new(vec![
                Stage::transformer(LogTransformer::new("x", "x_log").log1p()),
                Stage::transformer(ClipTransformer::new("x_log", "x_clip", Some(-10.0), Some(10.0))),
                Stage::transformer(HashIndexTransformer::new("s", "s_idx", 97)),
                Stage::estimator(kamae::estimators::StringIndexEstimator::new("s", "s_vocab")),
            ]);
            let ds = Dataset::from_dataframe(df.clone(), 2);
            let model = pipeline.fit(&ds).map_err(|e| e.to_string())?;
            let json = model.to_json();
            let loaded =
                kamae::pipeline::PipelineModel::from_json(&json).map_err(|e| e.to_string())?;
            let a = model.transform_df(df.clone()).map_err(|e| e.to_string())?;
            let b = loaded.transform_df(df.clone()).map_err(|e| e.to_string())?;
            // NaN-tolerant comparison via debug render of output columns
            for col in ["x_log", "x_clip", "s_idx", "s_vocab"] {
                let ca = format!("{:?}", a.column(col).unwrap());
                let cb = format!("{:?}", b.column(col).unwrap());
                if ca != cb {
                    return Err(format!("{col} differs after save/load"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn interp_engine_parity_random_strings() {
    // the C1 invariant under adversarial string inputs (unicode,
    // separators, empties)
    check_res(
        "engine == interpreter on random data",
        15,
        |rng| random_df(rng, 60),
        |df| {
            let pipeline = Pipeline::new(vec![
                Stage::transformer(HashIndexTransformer::new("s", "s_idx", 1009)),
                Stage::transformer(LogTransformer::new("x", "x_log").log1p()),
                Stage::estimator(
                    kamae::estimators::StringIndexEstimator::new("s", "s_vocab").num_oov(2),
                ),
            ]);
            let ds = Dataset::from_dataframe(df.clone(), 2);
            let model = pipeline.fit(&ds).map_err(|e| e.to_string())?;
            let spec = model
                .to_graph_spec(
                    "prop",
                    vec![
                        SpecInput { name: "s".into(), dtype: DType::Str, width: None },
                        SpecInput { name: "x".into(), dtype: DType::F64, width: None },
                    ],
                    &["s_idx", "s_vocab", "x_log"],
                )
                .map_err(|e| e.to_string())?;
            let interp = kamae::export::SpecInterpreter::new(spec);
            let out = interp.run(df).map_err(|e| e.to_string())?;
            let engine = model.transform_df(df.clone()).map_err(|e| e.to_string())?;
            if out[0].as_i64().unwrap() != engine.column("s_idx").unwrap().as_i64().unwrap() {
                return Err("s_idx mismatch".into());
            }
            if out[1].as_i64().unwrap() != engine.column("s_vocab").unwrap().as_i64().unwrap() {
                return Err("s_vocab mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn optimizer_preserves_interpreter_outputs_bitwise() {
    // the optim contract under adversarial float inputs (NaN-producing
    // logs, huge magnitudes, negatives) and adversarial strings:
    // optimized and unoptimized specs must agree bit-for-bit, not just
    // within tolerance. The pipeline is built so every pass fires: a
    // dead branch (DCE), a duplicated subexpression (CSE), a
    // multiply-by-one on a rounded producer (const fold), a
    // scalar-affine ladder (AffineFuse), a trim→case→hash64 string
    // chain (IngressFuse), a bucketize→compare ladder (BucketizeMerge)
    // and a select over a dead compare mask (SelectCmpFuse).
    use kamae::optim::OptimizeLevel;

    check_res(
        "optimized == unoptimized interpreter outputs (bitwise)",
        12,
        |rng| random_df(rng, 60),
        |df| {
            let pipeline = Pipeline::new(vec![
                Stage::transformer(HashIndexTransformer::new("s", "s_idx", 1009)),
                Stage::transformer(LogTransformer::new("x", "x_log").log1p()),
                // affine ladder: fused into one node at OptimizeLevel::Full
                Stage::transformer(AddConstantTransformer::new("x_log", "t1", -1.5)),
                Stage::transformer(MultiplyConstantTransformer::new("t1", "t2", 0.25)),
                // no-op on an f32-rounded producer: const-folded
                Stage::transformer(MultiplyConstantTransformer::new("t2", "t2_noop", 1.0)),
                // duplicate subexpression: CSE'd into x_log
                Stage::transformer(LogTransformer::new("x", "x_log_dup").log1p()),
                Stage::transformer(MultiplyConstantTransformer::new("x_log_dup", "t3", 2.0)),
                // dead branch: dropped by DCE
                Stage::transformer(SqrtTransformer::new("x", "x_dead")),
                // ingress chain: trim -> case -> hash64, fused by IngressFuse
                Stage::transformer(TrimTransformer::new("s", "s_trim")),
                Stage::transformer(StringCaseTransformer::new("s_trim", "s_up", CaseMode::Upper)),
                Stage::transformer(HashIndexTransformer::new("s_up", "s_up_idx", 257)),
                // bucketize -> compare ladder, fused by BucketizeMerge
                Stage::transformer(BucketizeTransformer::new("x", "x_bucket", vec![-1.0, 0.0, 1.0])),
                Stage::transformer(CompareConstantTransformer::new("x_bucket", "x_high", CmpOp::Ge, 2.0)),
                // sibling fan-out over x: two more bucketizes + a flag.
                // MultiLaneBucketize merges them (with the fused ladder
                // above riding along as a bucket_compare lane) into one
                // multi-output node — all three lane kinds exercised
                Stage::transformer(BucketizeTransformer::new("x", "x_coarse", vec![0.0])),
                Stage::transformer(BucketizeTransformer::new("x", "x_fine", vec![-2.0, -0.5, 0.0, 0.5, 2.0])),
                Stage::transformer(CompareConstantTransformer::new("x", "x_big", CmpOp::Ge, 1.0)),
                // select over a single-use compare mask, fused by SelectCmpFuse
                Stage::transformer(CompareConstantTransformer::new("x_log", "x_pos", CmpOp::Gt, 0.0)),
                Stage::transformer(IfThenElseTransformer::new("x_pos", "t3", "x_log", "sel")),
                Stage::estimator(
                    kamae::estimators::StringIndexEstimator::new("s", "s_vocab").num_oov(2),
                ),
            ]);
            let ds = Dataset::from_dataframe(df.clone(), 2);
            let model = pipeline.fit(&ds).map_err(|e| e.to_string())?;
            let inputs = || {
                vec![
                    SpecInput { name: "s".into(), dtype: DType::Str, width: None },
                    SpecInput { name: "x".into(), dtype: DType::F64, width: None },
                ]
            };
            let outputs = [
                "s_idx", "s_vocab", "t2_noop", "t3", "x_log", "s_up_idx", "x_high",
                "x_coarse", "x_fine", "x_big", "sel",
            ];
            let (raw, _) = model
                .to_graph_spec_opt("prop", inputs(), &outputs, OptimizeLevel::None)
                .map_err(|e| e.to_string())?;
            let (opt, _) = model
                .to_graph_spec_opt("prop", inputs(), &outputs, OptimizeLevel::Full)
                .map_err(|e| e.to_string())?;
            if opt.nodes.len() >= raw.nodes.len() {
                return Err(format!(
                    "optimizer found nothing: {} -> {} nodes",
                    raw.nodes.len(),
                    opt.nodes.len()
                ));
            }
            for fused_op in ["fused_ingress", "multi_bucketize", "select_cmp", "affine"] {
                let present = opt.nodes.iter().any(|n| n.op == fused_op)
                    || opt.ingress.iter().any(|n| n.op == fused_op);
                if !present {
                    return Err(format!("fusion '{fused_op}' did not fire"));
                }
            }
            // the x fan-out must have merged into a multi-output node
            // carrying all three lane kinds
            let Some(mlb) = opt.nodes.iter().find(|n| !n.lanes.is_empty()) else {
                return Err("multilane-bucketize did not fire".into());
            };
            for kind in ["bucket", "compare", "bucket_compare"] {
                if !mlb.lanes.iter().any(|l| l.attrs.opt_str("kind") == Some(kind)) {
                    return Err(format!("no '{kind}' lane in the merged node"));
                }
            }
            let a = kamae::export::SpecInterpreter::new(raw).run(df).map_err(|e| e.to_string())?;
            let b = kamae::export::SpecInterpreter::new(opt).run(df).map_err(|e| e.to_string())?;
            kamae::util::prop::tensors_bit_identical(&a, &b)?;
            Ok(())
        },
    );
}

#[test]
fn routed_merged_backend_matches_dedicated_variants_bitwise() {
    // The variant-routing differential: interleaved ltr / ltr_lite
    // requests through the ROUTED merged backend must be bit-identical
    // to dedicated single-variant interpreted backends — across
    // optimize levels (None / Basic / Full merged specs all against the
    // raw dedicated oracle) and across random request interleavings,
    // sizes, and variant mixes (including same-variant-only batches).
    use kamae::optim::OptimizeLevel;
    use kamae::pipeline::catalog;
    use kamae::serving::{request_pool, Backend, InterpretedBackend, VariantGroup};

    // fit once (outside the property loop — the property randomises the
    // traffic, not the model)
    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig { rows: 2_000, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let export = |name: &str, outputs: &[&str], level| {
        model
            .to_graph_spec_opt(name, catalog::ltr_inputs(), outputs, level)
            .unwrap()
            .0
    };
    // raw dedicated oracles
    let full_oracle = kamae::export::SpecInterpreter::new(export(
        "ltr",
        &catalog::LTR_OUTPUTS,
        OptimizeLevel::None,
    ));
    let lite_oracle = kamae::export::SpecInterpreter::new(export(
        "ltr_lite",
        &catalog::LTR_LITE_OUTPUTS,
        OptimizeLevel::None,
    ));
    // routed merged backends, one per optimize level (variants exported
    // at the same level, like the artifact flow)
    let routed: Vec<(OptimizeLevel, InterpretedBackend)> =
        [OptimizeLevel::None, OptimizeLevel::Basic, OptimizeLevel::Full]
            .into_iter()
            .map(|level| {
                let full = export("ltr", &catalog::LTR_OUTPUTS, level);
                let lite = export("ltr_lite", &catalog::LTR_LITE_OUTPUTS, level);
                let merged =
                    kamae::export::GraphSpec::merge_variants("ltr+ltr_lite", &[&full, &lite])
                        .unwrap();
                let (merged, _) = kamae::optim::optimize(merged, level).unwrap();
                (level, InterpretedBackend::new(merged))
            })
            .collect();
    let pool = request_pool("ltr", 512).unwrap();

    check_res(
        "routed merged backend == dedicated variant backends (bitwise)",
        10,
        |rng| {
            // 1..=5 requests of 1..=12 rows each, random variant tags
            let n = 1 + rng.below(5) as usize;
            (0..n)
                .map(|_| {
                    let rows = 1 + rng.below(12) as usize;
                    let start = rng.below((pool.num_rows() - rows) as u64) as usize;
                    let lite = rng.below(2) == 0;
                    (start, rows, lite)
                })
                .collect::<Vec<_>>()
        },
        |requests| {
            // batcher shape: contiguous per-variant groups, arrival
            // order preserved within each group
            let mut order: Vec<&(usize, usize, bool)> = Vec::new();
            for lite in [false, true] {
                order.extend(requests.iter().filter(|r| r.2 == lite));
            }
            let frames: Vec<kamae::dataframe::DataFrame> =
                order.iter().map(|&&(start, rows, _)| pool.slice(start, rows)).collect();
            let refs: Vec<&kamae::dataframe::DataFrame> = frames.iter().collect();
            let merged_df =
                kamae::dataframe::DataFrame::concat(&refs).map_err(|e| e.to_string())?;
            let mut groups = Vec::new();
            let mut row = 0usize;
            for lite in [false, true] {
                let len: usize =
                    requests.iter().filter(|r| r.2 == lite).map(|r| r.1).sum();
                if len > 0 {
                    groups.push(VariantGroup {
                        variant: Some(if lite { "ltr_lite" } else { "ltr" }.to_string()),
                        rows: row..row + len,
                    });
                    row += len;
                }
            }
            for (level, backend) in &routed {
                let per_group = backend
                    .process_routed(&merged_df, &groups)
                    .map_err(|e| format!("{level:?}: {e}"))?;
                // each group's tensors must equal the dedicated raw
                // oracle on the group's own rows
                for (g, got) in groups.iter().zip(per_group.iter()) {
                    let gdf = merged_df.slice(g.rows.start, g.rows.len());
                    let want = if g.variant.as_deref() == Some("ltr_lite") {
                        lite_oracle.run(&gdf).map_err(|e| e.to_string())?
                    } else {
                        full_oracle.run(&gdf).map_err(|e| e.to_string())?
                    };
                    kamae::util::prop::tensors_bit_identical(got, &want)
                        .map_err(|e| format!("{level:?}/{:?}: {e}", g.variant))?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shard_rebalance_preserves_content() {
    check(
        "rebalance/coalesce keep rows and order",
        25,
        |rng| {
            let df = random_df(rng, 150);
            let parts = 1 + rng.below(10) as usize;
            // target 0 included on purpose: both helpers clamp to 1
            let target = rng.below(7) as usize;
            (df, parts, target)
        },
        |(df, parts, target)| {
            let ds = Dataset::from_dataframe(df.clone(), *parts);
            let re = kamae::engine::shard::rebalance(&ds, *target).unwrap();
            let co = kamae::engine::shard::coalesce(&ds, *target).unwrap();
            re.collect().unwrap() == *df && co.collect().unwrap() == *df
        },
    );
}

#[test]
fn pooled_server_matches_dedicated_variants_bitwise() {
    // The PR 4 routing differential re-run against the WORKER POOL:
    // concurrent producers submit interleaved ltr / ltr_lite requests
    // to a 4-worker server over the merged backend, and every response
    // must be bit-identical to the raw dedicated single-variant oracle
    // on that request's own rows — whatever worker drained it, whatever
    // mixed batch it was coalesced into, under real thread
    // interleavings.
    use kamae::optim::OptimizeLevel;
    use kamae::pipeline::catalog;
    use kamae::serving::{request_pool, BatchConfig, InterpretedBackend, Server};

    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig { rows: 2_000, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let export = |name: &str, outputs: &[&str], level| {
        model
            .to_graph_spec_opt(name, catalog::ltr_inputs(), outputs, level)
            .unwrap()
            .0
    };
    // raw dedicated oracles (same contract as the process_routed
    // differential above)
    let full_oracle = kamae::export::SpecInterpreter::new(export(
        "ltr",
        &catalog::LTR_OUTPUTS,
        OptimizeLevel::None,
    ));
    let lite_oracle = kamae::export::SpecInterpreter::new(export(
        "ltr_lite",
        &catalog::LTR_LITE_OUTPUTS,
        OptimizeLevel::None,
    ));
    let full = export("ltr", &catalog::LTR_OUTPUTS, OptimizeLevel::Full);
    let lite = export("ltr_lite", &catalog::LTR_LITE_OUTPUTS, OptimizeLevel::Full);
    let merged =
        kamae::export::GraphSpec::merge_variants("ltr+ltr_lite", &[&full, &lite]).unwrap();
    let (merged, _) = kamae::optim::optimize(merged, OptimizeLevel::Full).unwrap();

    let server = Server::start(
        Box::new(InterpretedBackend::new(merged)),
        BatchConfig {
            workers: 4,
            // short flush + small batches force plenty of distinct
            // mixed batches across the workers
            max_batch_rows: 64,
            max_wait: std::time::Duration::from_micros(200),
            ..BatchConfig::default()
        },
    )
    .unwrap();
    let pool = request_pool("ltr", 512).unwrap();

    std::thread::scope(|scope| {
        for t in 0..3u64 {
            let server = &server;
            let pool = &pool;
            let full_oracle = &full_oracle;
            let lite_oracle = &lite_oracle;
            scope.spawn(move || {
                let mut rng = Rng::new(0xA11CE + t);
                for i in 0..30 {
                    let rows = 1 + rng.below(12) as usize;
                    let start = rng.below((pool.num_rows() - rows) as u64) as usize;
                    let frame = pool.slice(start, rows);
                    let lite = rng.below(2) == 0;
                    let variant = if lite { "ltr_lite" } else { "ltr" };
                    let got = server
                        .submit_variant(frame.clone(), variant)
                        .recv()
                        .unwrap()
                        .unwrap();
                    let want = if lite {
                        lite_oracle.run(&frame).unwrap()
                    } else {
                        full_oracle.run(&frame).unwrap()
                    };
                    if let Err(e) = kamae::util::prop::tensors_bit_identical(&got, &want) {
                        panic!("producer {t} request {i} ({variant}): {e}");
                    }
                }
            });
        }
    });
    // the pool served every request across its workers
    let (_, requests) = server.counts();
    assert_eq!(requests, 90);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// kernel-program differential: the compiled columnar hot path must be
// bit-identical to the `eval_node` oracle — per registry op, per
// multi_bucketize lane kind, under null masks, and over routed cone
// sub-programs. `SpecInterpreter::new` compiles the program (asserted
// via `is_compiled`, so a silent fallback can't turn these into
// oracle-vs-oracle no-ops); `new_oracle` never compiles.

/// Run `spec` through both interpreter paths and compare bitwise.
/// Divergent success/failure — or divergent error text — is a failure
/// too: the kernel program must preserve request-time error behaviour
/// exactly.
fn kernel_vs_oracle_run(
    spec: &kamae::export::GraphSpec,
    df: &DataFrame,
    what: &str,
) -> Result<(), String> {
    use kamae::export::SpecInterpreter;
    let kernel = SpecInterpreter::new(spec.clone());
    if !kernel.is_compiled() {
        return Err(format!("{what}: spec did not compile to a kernel program"));
    }
    let oracle = SpecInterpreter::new_oracle(spec.clone());
    match (kernel.run(df), oracle.run(df)) {
        (Ok(k), Ok(o)) => kamae::util::prop::tensors_bit_identical(&k, &o)
            .map_err(|e| format!("{what}: {e}")),
        (Err(k), Err(o)) if k.to_string() == o.to_string() => Ok(()),
        (k, o) => Err(format!(
            "{what}: paths diverge: kernel={:?} oracle={:?}",
            k.map(|_| "ok"),
            o.map(|_| "ok")
        )),
    }
}

/// Random batch covering every column the registry coverage templates
/// read — adversarial floats (NaN, huge magnitudes) plus occasional
/// null masks on the scalar columns (masks ride through both paths and
/// must not perturb the output bits).
fn random_kernel_df(rng: &mut Rng) -> DataFrame {
    let rows = 1 + rng.below(9) as usize;
    let f_col = |rng: &mut Rng| -> Column {
        if rng.below(4) == 0 {
            Column::from_f64_opt(
                (0..rows)
                    .map(|_| {
                        if rng.below(5) == 0 { None } else { Some(gen::f64_mixed(rng)) }
                    })
                    .collect(),
            )
        } else {
            Column::from_f64((0..rows).map(|_| gen::f64_mixed(rng)).collect())
        }
    };
    let i_vals = |rng: &mut Rng| -> Vec<i64> {
        // modest range: date_part arithmetic on arbitrary i64 days
        // would overflow (identically in both paths, but panicking
        // under debug), so stay in a sane day window
        (0..rows).map(|_| rng.below(40_000) as i64 - 20_000).collect()
    };
    let xi = if rng.below(4) == 0 {
        let nulls: Vec<bool> = (0..rows).map(|_| rng.below(5) == 0).collect();
        let mask = if nulls.iter().any(|&n| n) { Some(nulls) } else { None };
        Column::I64(i_vals(rng), mask)
    } else {
        Column::from_i64(i_vals(rng))
    };
    let strings: Vec<String> = (0..rows)
        .map(|_| {
            if rng.below(2) == 0 {
                // embedded separator so split_pad / concat do real work
                format!("{}-{}", gen::string(rng, 5), gen::string(rng, 5))
            } else {
                gen::string(rng, 8)
            }
        })
        .collect();
    let s = if rng.below(4) == 0 {
        Column::from_str_opt(
            strings
                .iter()
                .map(|v| if rng.below(6) == 0 { None } else { Some(v.clone()) })
                .collect(),
        )
    } else {
        Column::from_str(strings)
    };
    DataFrame::new(vec![
        ("s".into(), s),
        (
            "ls".into(),
            Column::from_str_rows(
                (0..rows)
                    .map(|_| vec![gen::string(rng, 4), gen::string(rng, 4)])
                    .collect(),
            ),
        ),
        ("xf".into(), f_col(rng)),
        ("yf".into(), f_col(rng)),
        ("xi".into(), xi),
        (
            "vf".into(),
            Column::from_f64_rows(
                (0..rows).map(|_| vec![gen::f64_mixed(rng), gen::f64_mixed(rng)]).collect(),
            ),
        ),
        (
            "vi".into(),
            Column::from_i64_rows(
                (0..rows)
                    .map(|_| vec![rng.below(100) as i64 - 50, rng.below(100) as i64 - 50])
                    .collect(),
            ),
        ),
        (
            "d".into(),
            Column::from_str(
                (0..rows)
                    .map(|_| {
                        format!(
                            "20{:02}-{:02}-{:02}",
                            rng.below(30),
                            1 + rng.below(12),
                            1 + rng.below(28)
                        )
                    })
                    .collect::<Vec<String>>(),
            ),
        ),
        (
            "ts".into(),
            Column::from_str(
                (0..rows)
                    .map(|_| {
                        format!(
                            "20{:02}-{:02}-{:02} {:02}:{:02}:{:02}",
                            rng.below(30),
                            1 + rng.below(12),
                            1 + rng.below(28),
                            rng.below(24),
                            rng.below(60),
                            rng.below(60)
                        )
                    })
                    .collect::<Vec<String>>(),
            ),
        ),
    ])
    .unwrap()
}

#[test]
fn kernel_program_matches_oracle_on_every_graph_op() {
    // every graph-section registry op, instantiated from its coverage
    // template, over randomized batches (NaN, null masks, tiny rows)
    use kamae::export::{GraphSpec, SpecNode};
    use kamae::optim::registry::{coverage, OPS};
    use kamae::util::json::Json;

    check_res(
        "kernel program == eval_node oracle per graph op (bitwise)",
        10,
        random_kernel_df,
        |df| {
            for info in OPS.iter().filter(|o| o.section.allows_graph()) {
                let (inputs, attrs, dtype, width) = coverage::graph_template(info.name);
                let spec = GraphSpec {
                    name: format!("op_{}", info.name),
                    inputs: coverage::sample_inputs(),
                    ingress: vec![],
                    graph_inputs: inputs.iter().map(|s| s.to_string()).collect(),
                    nodes: vec![SpecNode {
                        id: "out".into(),
                        op: info.name.into(),
                        inputs: inputs.iter().map(|s| s.to_string()).collect(),
                        attrs: Json::parse(attrs).unwrap(),
                        dtype,
                        width,
                        lanes: vec![],
                    }],
                    outputs: vec!["out".into()],
                };
                kernel_vs_oracle_run(&spec, df, info.name)?;
            }
            Ok(())
        },
    );
}

#[test]
fn kernel_program_matches_oracle_on_every_ingress_op() {
    // every ingress-section registry op through `run_ingress` (the
    // pre-parsed ingress kernels vs the per-node oracle walk). String
    // outputs can't cross into the graph section, so each template op
    // is chained into a hash64 node whose i64 output is the observable
    // graph input — same trick the engine uses for string features.
    use kamae::export::{GraphSpec, SpecDType, SpecInterpreter, SpecNode};
    use kamae::optim::registry::{coverage, OPS};
    use kamae::util::json::Json;

    check_res(
        "kernel ingress == oracle ingress per op (bitwise)",
        10,
        random_kernel_df,
        |df| {
            for info in OPS.iter().filter(|o| o.section.allows_ingress()) {
                let (input, attrs, out_dtype, width) = coverage::ingress_template(info.name);
                let out_width = match &out_dtype {
                    DType::List(_) => width,
                    _ => None,
                };
                let node = |id: &str, op: &str, input: &str, attrs: &str, dtype, width| SpecNode {
                    id: id.into(),
                    op: op.into(),
                    inputs: vec![input.into()],
                    attrs: Json::parse(attrs).unwrap(),
                    dtype,
                    width,
                    lanes: vec![],
                };
                let spec = GraphSpec {
                    name: format!("ing_{}", info.name),
                    inputs: vec![
                        SpecInput { name: "s".into(), dtype: DType::Str, width: None },
                        SpecInput {
                            name: "ls".into(),
                            dtype: DType::List(Box::new(DType::Str)),
                            width: Some(2),
                        },
                        SpecInput { name: "d".into(), dtype: DType::Str, width: None },
                        SpecInput { name: "ts".into(), dtype: DType::Str, width: None },
                    ],
                    ingress: vec![
                        node(
                            "mid",
                            info.name,
                            input,
                            attrs,
                            SpecDType::for_engine(&out_dtype),
                            width,
                        ),
                        // hash64 accepts every template output shape:
                        // Str and List(Str) hash directly, numeric /
                        // bool scalars hash via their string render
                        node("out_h", "hash64", "mid", "{}", SpecDType::I64, out_width),
                    ],
                    graph_inputs: vec!["out_h".into()],
                    nodes: vec![],
                    outputs: vec![],
                };
                let what = info.name;
                let kernel = SpecInterpreter::new(spec.clone());
                if !kernel.is_compiled() {
                    return Err(format!("{what}: spec did not compile to a kernel program"));
                }
                let oracle = SpecInterpreter::new_oracle(spec);
                match (kernel.run_ingress(df), oracle.run_ingress(df)) {
                    (Ok(k), Ok(o)) => kamae::util::prop::tensors_bit_identical(&k, &o)
                        .map_err(|e| format!("{what}: {e}"))?,
                    (Err(k), Err(o)) if k.to_string() == o.to_string() => {}
                    (k, o) => {
                        return Err(format!(
                            "{what}: paths diverge: kernel={:?} oracle={:?}",
                            k.map(|_| "ok"),
                            o.map(|_| "ok")
                        ))
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn kernel_program_matches_oracle_on_multilane_bucketize() {
    // the multi-output node: one shared split search feeding all three
    // lane kinds (bucket remap, f32-rounded compare, remapped
    // bucket_compare), with randomized splits / remap tables / compare
    // ops, and probe values planted exactly ON split boundaries
    use kamae::export::{GraphSpec, SpecDType, SpecInput, SpecLane, SpecNode};
    use kamae::util::json::Json;

    check_res(
        "kernel program == oracle on multi_bucketize lanes (bitwise)",
        25,
        |rng| {
            let n_splits = 1 + rng.below(4) as usize;
            let mut splits = Vec::with_capacity(n_splits);
            let mut s = -2.0 + rng.f64();
            for _ in 0..n_splits {
                splits.push(s);
                s += 0.1 + rng.f64();
            }
            let remap =
                |rng: &mut Rng| -> Vec<i64> { (0..=n_splits).map(|_| rng.below(10) as i64).collect() };
            let (r1, r2) = (remap(rng), remap(rng));
            let cmps = ["lt", "le", "gt", "ge", "eq", "ne"];
            let op1 = cmps[rng.below(6) as usize];
            let op2 = cmps[rng.below(6) as usize];
            // half the thresholds sit exactly on a split / remap value
            // to probe the boundary semantics of the rounded compares
            let value = |rng: &mut Rng| -> f64 {
                if rng.below(2) == 0 {
                    splits[rng.below(n_splits as u64) as usize]
                } else {
                    -3.0 + 6.0 * rng.f64()
                }
            };
            let (v1, v2) = (value(rng), value(rng));
            let rows = 1 + rng.below(16) as usize;
            let xs: Vec<f64> = (0..rows)
                .map(|_| {
                    if rng.below(3) == 0 {
                        // exact boundary hit: partition_point's `<=` edge
                        splits[rng.below(n_splits as u64) as usize]
                    } else {
                        gen::f64_mixed(rng)
                    }
                })
                .collect();
            (splits, r1, r2, op1, op2, v1, v2, xs)
        },
        |(splits, r1, r2, op1, op2, v1, v2, xs)| {
            let arr = |vals: &[f64]| {
                vals.iter().map(|v| format!("{v:?}")).collect::<Vec<_>>().join(", ")
            };
            let iarr = |vals: &[i64]| {
                vals.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(", ")
            };
            let lane = |name: &str, attrs: String| SpecLane {
                name: name.into(),
                attrs: Json::parse(&attrs).unwrap(),
                dtype: SpecDType::I64,
                width: None,
            };
            let spec = GraphSpec {
                name: "mlb_prop".into(),
                inputs: vec![SpecInput { name: "x".into(), dtype: DType::F64, width: None }],
                ingress: vec![],
                graph_inputs: vec!["x".into()],
                nodes: vec![SpecNode {
                    id: "mx".into(),
                    op: "multi_bucketize".into(),
                    inputs: vec!["x".into()],
                    attrs: Json::parse(&format!(r#"{{"splits": [{}]}}"#, arr(splits))).unwrap(),
                    dtype: SpecDType::I64,
                    width: None,
                    lanes: vec![
                        lane("lb", format!(r#"{{"kind": "bucket", "remap": [{}]}}"#, iarr(r1))),
                        lane(
                            "lc",
                            format!(r#"{{"kind": "compare", "op": "{op1}", "value": {v1:?}}}"#),
                        ),
                        lane(
                            "lbc",
                            format!(
                                r#"{{"kind": "bucket_compare", "remap": [{}], "op": "{op2}", "value": {v2:?}}}"#,
                                iarr(r2)
                            ),
                        ),
                    ],
                }],
                outputs: vec!["lb".into(), "lc".into(), "lbc".into()],
            };
            let df = DataFrame::new(vec![("x".into(), Column::from_f64(xs.clone()))])
                .map_err(|e| e.to_string())?;
            kernel_vs_oracle_run(&spec, &df, "multi_bucketize lanes")
        },
    );
}

#[test]
fn kernel_program_routed_cones_match_oracle_bitwise() {
    // routed serving: per-group cone SUB-programs on the merged LTR
    // catalog vs the oracle's env-walking `run_routed`, over random
    // request interleavings / sizes / variant mixes — plus the plain
    // all-outputs `process` path on the same mixed frames
    use kamae::optim::OptimizeLevel;
    use kamae::pipeline::catalog;
    use kamae::serving::{request_pool, Backend, InterpretedBackend, VariantGroup};

    let data = kamae::synth::gen_ltr(&kamae::synth::LtrConfig { rows: 2_000, ..Default::default() });
    let model = catalog::ltr_pipeline()
        .fit(&Dataset::from_dataframe(data, 4))
        .unwrap();
    let export = |name: &str, outputs: &[&str]| {
        model
            .to_graph_spec_opt(name, catalog::ltr_inputs(), outputs, OptimizeLevel::Full)
            .unwrap()
            .0
    };
    let full = export("ltr", &catalog::LTR_OUTPUTS);
    let lite = export("ltr_lite", &catalog::LTR_LITE_OUTPUTS);
    let merged =
        kamae::export::GraphSpec::merge_variants("ltr+ltr_lite", &[&full, &lite]).unwrap();
    let (merged, _) = kamae::optim::optimize(merged, OptimizeLevel::Full).unwrap();
    // the differential is vacuous if the kernel compiler fell back
    assert!(
        kamae::export::SpecInterpreter::new(merged.clone()).is_compiled(),
        "merged LTR catalog spec did not compile to a kernel program"
    );
    let kernel = InterpretedBackend::new(merged.clone());
    let oracle = InterpretedBackend::new_oracle(merged);
    let pool = request_pool("ltr", 512).unwrap();

    check_res(
        "kernel routed cones == oracle routed (bitwise)",
        10,
        |rng| {
            let n = 1 + rng.below(5) as usize;
            (0..n)
                .map(|_| {
                    let rows = 1 + rng.below(12) as usize;
                    let start = rng.below((pool.num_rows() - rows) as u64) as usize;
                    let lite = rng.below(2) == 0;
                    (start, rows, lite)
                })
                .collect::<Vec<_>>()
        },
        |requests| {
            // batcher shape: contiguous per-variant groups
            let mut order: Vec<&(usize, usize, bool)> = Vec::new();
            for lite in [false, true] {
                order.extend(requests.iter().filter(|r| r.2 == lite));
            }
            let frames: Vec<DataFrame> =
                order.iter().map(|&&(start, rows, _)| pool.slice(start, rows)).collect();
            let refs: Vec<&DataFrame> = frames.iter().collect();
            let merged_df = DataFrame::concat(&refs).map_err(|e| e.to_string())?;
            let mut groups = Vec::new();
            let mut row = 0usize;
            for lite in [false, true] {
                let len: usize = requests.iter().filter(|r| r.2 == lite).map(|r| r.1).sum();
                if len > 0 {
                    groups.push(VariantGroup {
                        variant: Some(if lite { "ltr_lite" } else { "ltr" }.to_string()),
                        rows: row..row + len,
                    });
                    row += len;
                }
            }
            let k = kernel.process_routed(&merged_df, &groups).map_err(|e| e.to_string())?;
            let o = oracle.process_routed(&merged_df, &groups).map_err(|e| e.to_string())?;
            if k.len() != o.len() {
                return Err(format!("group count: kernel {} vs oracle {}", k.len(), o.len()));
            }
            for (g, (kg, og)) in groups.iter().zip(k.iter().zip(o.iter())) {
                kamae::util::prop::tensors_bit_identical(kg, og)
                    .map_err(|e| format!("routed {:?}: {e}", g.variant))?;
            }
            let kp = kernel.process(&merged_df).map_err(|e| e.to_string())?;
            let op = oracle.process(&merged_df).map_err(|e| e.to_string())?;
            kamae::util::prop::tensors_bit_identical(&kp, &op)
                .map_err(|e| format!("process: {e}"))
        },
    );
}

// ---------------------------------------------------------------------------
// ingress validation gate: quarantining must be surgical — the rows that
// survive the gate must be served EXACTLY as if the corruption had never
// been in the batch.

#[test]
fn validated_serving_matches_uncorrupted_oracle_bitwise() {
    // Corrupt a random subset of a clean batch's rows (null price /
    // null city), serve it through the validated submit path, and the
    // surviving rows' outputs must be bit-identical to running the same
    // rows straight through the backend with the corruption absent.
    // Every quarantined row must carry a structured error naming its
    // rule and column, and every one must land in the dead-letter sink.
    use kamae::pipeline::catalog;
    use kamae::serving::{
        request_pool, Backend, BatchConfig, InterpretedBackend, MemoryDeadLetter, Server,
        DEFAULT_TENANT,
    };

    let fit = request_pool("quickstart", 4_000).unwrap();
    let model = catalog::quickstart_pipeline()
        .fit(&Dataset::from_dataframe(fit, 4))
        .unwrap();
    let outputs = catalog::QUICKSTART_OUTPUTS.to_vec();
    let spec = model
        .to_graph_spec("quickstart", catalog::quickstart_inputs(), &outputs)
        .unwrap();
    let oracle = InterpretedBackend::new(spec.clone());
    let server =
        Server::start(Box::new(InterpretedBackend::new(spec)), BatchConfig::default()).unwrap();
    let pool = request_pool("quickstart", 512).unwrap();
    let sink = MemoryDeadLetter::new(1024);

    let mut rng = Rng::new(0xC0FFEE);
    let mut corrupted_total = 0usize;
    for case in 0..40 {
        let rows = 2 + rng.below(14) as usize;
        let start = rng.below((pool.num_rows() - rows) as u64) as usize;
        let clean = pool.slice(start, rows);
        let mut price: Vec<Option<f64>> = clean
            .column("price")
            .unwrap()
            .as_f64()
            .unwrap()
            .iter()
            .copied()
            .map(Some)
            .collect();
        let mut city: Vec<Option<String>> = clean
            .column("city")
            .unwrap()
            .as_str()
            .unwrap()
            .iter()
            .cloned()
            .map(Some)
            .collect();
        let mut keep = vec![true; rows];
        for i in 0..rows {
            match rng.below(4) {
                0 => {
                    price[i] = None;
                    keep[i] = false;
                }
                1 => {
                    city[i] = None;
                    keep[i] = false;
                }
                _ => {}
            }
        }
        let corrupted = DataFrame::new(vec![
            ("price".into(), Column::from_f64_opt(price)),
            ("city".into(), Column::from_str_opt(city)),
        ])
        .unwrap();

        let (rx, report) =
            server.submit_tenant_validated(corrupted, DEFAULT_TENANT, None, None, Some(&sink));
        let got = rx.recv().unwrap().unwrap();
        let n_bad = keep.iter().filter(|k| !**k).count();
        corrupted_total += n_bad;
        assert_eq!(report.num_quarantined(), n_bad, "case {case}: quarantine count");
        assert_eq!(report.keep, keep, "case {case}: verdict mask");
        for i in report.quarantined() {
            assert!(!report.errors[i].is_empty(), "case {case} row {i}: no errors");
            for e in &report.errors[i] {
                assert_eq!(e.rule, "not_null", "case {case} row {i}");
                assert!(
                    e.column == "price" || e.column == "city",
                    "case {case} row {i}: error names column {:?}",
                    e.column
                );
                assert!(!e.message.is_empty());
            }
        }
        if report.num_valid() == 0 {
            assert!(got.is_empty(), "case {case}: all-quarantined batch returned tensors");
            continue;
        }
        let want = oracle.process(&clean.filter_rows(&keep).unwrap()).unwrap();
        if let Err(e) = kamae::util::prop::tensors_bit_identical(&got, &want) {
            panic!("case {case}: valid rows vs uncorrupted oracle: {e}");
        }
    }
    assert!(corrupted_total > 0, "40 random cases never corrupted a row");
    assert_eq!(sink.len(), corrupted_total.min(1024), "every quarantined row dead-lettered");
    server.shutdown();
}
