//! # Kamae-RS
//!
//! A Rust + JAX + Pallas reproduction of *"Kamae: Bridging Spark and Keras
//! for Seamless ML Preprocessing"* (RecSys 2025).
//!
//! The library mirrors the paper's architecture in three layers:
//!
//! * **L3 (this crate)** — a Spark-like partitioned columnar engine with a
//!   `Pipeline`/`PipelineModel` API, a library of configurable transformers
//!   and estimators, a GraphSpec exporter, and a serving stack (router +
//!   dynamic batcher) that executes AOT-compiled preprocessing graphs via
//!   PJRT on the request path.
//! * **Optimizer ([`optim`])** — a pass-based rewriter sitting between
//!   "fitted pipeline" and "executable graph": exported specs are
//!   dead-code-eliminated, deduplicated and fused (scalar-affine chains
//!   collapse onto the fused-scaling kernel path) before they are
//!   compiled or interpreted. The lifecycle is
//!   `fit → export → optimize → compile/interpret → serve`; optimization
//!   is on by default with `OptimizeLevel::None` as the escape hatch,
//!   and preserves interpreter outputs bit-for-bit.
//! * **L2 (python/compile/model.py)** — compiles an exported GraphSpec into
//!   a JAX function, lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots (fused scaling, hash/bloom indexing, vocabulary lookup).
//!
//! Python never runs on the request path: the serving binary loads
//! `artifacts/*.hlo.txt` and executes them through the PJRT CPU client.
//!
//! ## Quickstart
//!
//! See `examples/quickstart.rs` for a fit → transform → export → serve
//! round trip on a small dataset.

pub mod baselines;
pub mod dataframe;
pub mod engine;
pub mod error;
pub mod estimators;
pub mod export;
pub mod ops;
pub mod optim;
pub mod pipeline;
pub mod runtime;
pub mod serving;
pub mod synth;
pub mod transformers;
pub mod util;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::dataframe::{Column, DataFrame, DType, Value};
    pub use crate::engine::Dataset;
    pub use crate::error::{KamaeError, Result};
    pub use crate::estimators::*;
    pub use crate::export::{GraphSpec, SpecInterpreter};
    pub use crate::optim::{optimize, OptimizeLevel};
    pub use crate::transformers::*;
}
