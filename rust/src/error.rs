//! Crate-wide error type.
//!
//! A single lightweight error enum keeps the hot paths allocation-free on
//! success while still carrying enough context for debugging pipeline
//! configuration mistakes (the dominant error class in preprocessing code).

use std::fmt;

/// Errors produced by the Kamae engine, pipeline API, exporter and runtime.
#[derive(Debug)]
pub enum KamaeError {
    /// A referenced column does not exist in the DataFrame.
    ColumnNotFound(String),
    /// A column had a different dtype than the operation requires.
    TypeMismatch { expected: String, found: String, context: String },
    /// Columns participating in one operation disagree on length.
    LengthMismatch { left: usize, right: usize, context: String },
    /// Invalid transformer / estimator configuration.
    InvalidConfig(String),
    /// Errors from (de)serialising pipelines or specs.
    Serde(String),
    /// I/O errors (dataset files, artifacts).
    Io(std::io::Error),
    /// Errors surfaced by the XLA / PJRT runtime.
    Xla(String),
    /// The GraphSpec interpreter / compiler hit an unsupported construct.
    Unsupported(String),
    /// Serving-layer errors (queue closed, deadline exceeded, ...).
    Serving(String),
    /// A request (or admin verb) addressed a tenant the spec registry
    /// does not know. Kept separate from [`KamaeError::Serving`] so the
    /// network layer can map it to a typed `404 unknown_tenant` instead
    /// of a generic 500.
    UnknownTenant(String),
    /// A deploy/rollback named an expected version that no longer
    /// matches the tenant's active version (compare-and-swap lost the
    /// race, or a rollback has nowhere to go). Maps to `409
    /// version_conflict` on the wire.
    VersionConflict(String),
    /// The serving pool is draining: the request was rejected at submit
    /// time because the queue is closed. Typed (rather than a generic
    /// [`KamaeError::Serving`] string) so the network layer maps it to
    /// `503 shutting_down` — the same answer the listener gives before
    /// a request ever reaches the pool.
    ShuttingDown,
    /// The request aged past its deadline while queued and was answered
    /// without occupying a batch. Maps to `504 deadline_exceeded` on
    /// the wire. The message reports the configured deadline and the
    /// time actually spent in the queue.
    DeadlineExceeded(String),
    /// Batch execution failed and bisection isolated the failure to
    /// these specific rows of THIS request's frame (0-based row
    /// indices). The rows were dead-lettered with a `poison` verdict;
    /// the caller may resubmit the surviving rows — the network layer
    /// does exactly that and folds the poison rows into the response's
    /// per-row verdicts.
    PoisonRows(Vec<usize>),
}

impl fmt::Display for KamaeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KamaeError::ColumnNotFound(c) => write!(f, "column not found: {c}"),
            KamaeError::TypeMismatch { expected, found, context } => {
                write!(f, "type mismatch in {context}: expected {expected}, found {found}")
            }
            KamaeError::LengthMismatch { left, right, context } => {
                write!(f, "length mismatch in {context}: {left} vs {right}")
            }
            KamaeError::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            KamaeError::Serde(m) => write!(f, "serde error: {m}"),
            KamaeError::Io(e) => write!(f, "io error: {e}"),
            KamaeError::Xla(m) => write!(f, "xla error: {m}"),
            KamaeError::Unsupported(m) => write!(f, "unsupported: {m}"),
            KamaeError::Serving(m) => write!(f, "serving error: {m}"),
            KamaeError::UnknownTenant(m) => write!(f, "unknown tenant: {m}"),
            KamaeError::VersionConflict(m) => write!(f, "version conflict: {m}"),
            KamaeError::ShuttingDown => {
                write!(f, "serving error: server is shutting down (queue closed)")
            }
            KamaeError::DeadlineExceeded(m) => write!(f, "deadline exceeded: {m}"),
            KamaeError::PoisonRows(rows) => {
                write!(f, "poison rows: {} row(s) crashed the backend: {rows:?}", rows.len())
            }
        }
    }
}

impl std::error::Error for KamaeError {}

impl From<std::io::Error> for KamaeError {
    fn from(e: std::io::Error) -> Self {
        KamaeError::Io(e)
    }
}

impl From<xla::Error> for KamaeError {
    fn from(e: xla::Error) -> Self {
        KamaeError::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, KamaeError>;
