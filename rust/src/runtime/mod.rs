//! PJRT runtime — loads AOT-compiled preprocessing graphs and executes
//! them from the Rust serving hot path.
//!
//! The interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the bundled
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md`).
//!
//! Python is only involved at build time (`make artifacts`); this module is
//! what replaces the paper's "TensorFlow Java" inference dependency.

mod tensor;

pub use tensor::{Tensor, TensorData};

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::error::{KamaeError, Result};

/// A compiled preprocessing executable: one `.hlo.txt` artifact compiled
/// onto the PJRT CPU client.
///
/// `execute` takes positional input tensors (matching the GraphSpec input
/// order recorded at export time) and returns the graph's output tensors.
pub struct CompiledGraph {
    exe: xla::PjRtLoadedExecutable,
    name: String,
    /// Execution lock shared by every graph compiled on the same PJRT
    /// client: the `xla` crate's executables clone a non-atomic `Rc`
    /// client handle per output buffer, so *all* executes (and drops)
    /// touching one client must be serialized.
    lock: Arc<Mutex<()>>,
}

// SAFETY: the raw PJRT pointers inside are only dereferenced by
// `execute`, which holds the shared per-client lock for the full
// literal→buffer→literal round trip (the TfrtCpuClient itself is
// thread-safe); graphs are compiled before any cross-thread use and the
// backend that owns them drops them together.
unsafe impl Send for CompiledGraph {}
unsafe impl Sync for CompiledGraph {}

impl CompiledGraph {
    /// Load an HLO text file and compile it on the given client.
    /// `lock` must be the client-wide execution lock (one per client).
    pub fn load_locked(
        client: &xla::PjRtClient,
        path: &Path,
        lock: Arc<Mutex<()>>,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| KamaeError::Serde("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(CompiledGraph { exe, name: artifact_stem(path), lock })
    }

    /// Load with a fresh private lock (single-graph uses).
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        Self::load_locked(client, path, Arc::new(Mutex::new(())))
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute the graph. Inputs are marshalled to XLA literals; the
    /// (tuple) output is decomposed back into [`Tensor`]s.
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor::to_literal)
            .collect::<Result<_>>()?;
        let result = {
            let _guard = self
                .lock
                .lock()
                .map_err(|_| KamaeError::Serving("compiled graph lock poisoned".into()))?;
            self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?
        };
        // aot.py lowers with return_tuple=True, so output is always a tuple.
        let parts = result.to_tuple()?;
        parts.iter().map(tensor::from_literal).collect()
    }
}

/// `model.hlo.txt` → `model`.
fn artifact_stem(path: &Path) -> String {
    let file = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "graph".into());
    file.strip_suffix(".hlo.txt").unwrap_or(&file).to_string()
}

/// Registry of compiled graphs, keyed by artifact stem — the router's view
/// of "deployed models".
pub struct Runtime {
    client: xla::PjRtClient,
    graphs: HashMap<String, CompiledGraph>,
    exec_lock: Arc<Mutex<()>>,
}

impl Runtime {
    /// Create a PJRT CPU runtime.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            graphs: HashMap::new(),
            exec_lock: Arc::new(Mutex::new(())),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile a single artifact; registers under its file stem.
    pub fn load_graph(&mut self, path: &Path) -> Result<&CompiledGraph> {
        let g = CompiledGraph::load_locked(&self.client, path, Arc::clone(&self.exec_lock))?;
        let name = g.name().to_string();
        self.graphs.insert(name.clone(), g);
        Ok(&self.graphs[&name])
    }

    /// Load every `*.hlo.txt` in a directory (the artifacts dir).
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let mut loaded = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.to_string_lossy().ends_with(".hlo.txt") {
                self.load_graph(&path)?;
                loaded.push(artifact_stem(&path));
            }
        }
        loaded.sort();
        Ok(loaded)
    }

    pub fn graph(&self, name: &str) -> Result<&CompiledGraph> {
        self.graphs
            .get(name)
            .ok_or_else(|| KamaeError::Xla(format!("graph not loaded: {name}")))
    }

    pub fn graph_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.graphs.keys().map(String::as_str).collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_stem_strips_suffix() {
        assert_eq!(artifact_stem(Path::new("artifacts/movielens.hlo.txt")), "movielens");
        assert_eq!(artifact_stem(Path::new("plain")), "plain");
    }
}
