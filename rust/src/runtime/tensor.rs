//! Dense tensors and XLA literal marshaling.
//!
//! `Tensor` is the serving-side data representation: what the ingress
//! stage produces from a request batch and what the compiled graph
//! consumes/returns. Boolean columns travel as `i32` (0/1) because the
//! `xla` crate exposes no `Pred`-typed literal constructor — the GraphSpec
//! compiler on the python side uses the same convention.

use crate::error::{KamaeError, Result};

/// Typed flat buffer. Row-major (C) layout.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::F64(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::I64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            TensorData::F32(_) => "float32",
            TensorData::F64(_) => "float64",
            TensorData::I32(_) => "int32",
            TensorData::I64(_) => "int64",
        }
    }
}

/// A dense, row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: TensorData,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: TensorData, shape: Vec<usize>) -> Result<Self> {
        let expected: usize = shape.iter().product();
        if data.len() != expected {
            return Err(KamaeError::LengthMismatch {
                left: data.len(),
                right: expected,
                context: format!("Tensor::new shape {shape:?}"),
            });
        }
        Ok(Tensor { data, shape })
    }

    pub fn f32(v: Vec<f32>, shape: Vec<usize>) -> Result<Self> {
        Tensor::new(TensorData::F32(v), shape)
    }
    pub fn f64(v: Vec<f64>, shape: Vec<usize>) -> Result<Self> {
        Tensor::new(TensorData::F64(v), shape)
    }
    pub fn i32(v: Vec<i32>, shape: Vec<usize>) -> Result<Self> {
        Tensor::new(TensorData::I32(v), shape)
    }
    pub fn i64(v: Vec<i64>, shape: Vec<usize>) -> Result<Self> {
        Tensor::new(TensorData::I64(v), shape)
    }

    pub fn num_elements(&self) -> usize {
        self.data.len()
    }

    /// Leading dimension (batch size) or 0 for rank-0 tensors.
    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => Err(tensor_type_err("float32", other)),
        }
    }
    pub fn as_f64(&self) -> Result<&[f64]> {
        match &self.data {
            TensorData::F64(v) => Ok(v),
            other => Err(tensor_type_err("float64", other)),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => Err(tensor_type_err("int32", other)),
        }
    }
    pub fn as_i64(&self) -> Result<&[i64]> {
        match &self.data {
            TensorData::I64(v) => Ok(v),
            other => Err(tensor_type_err("int64", other)),
        }
    }

    /// Concatenate along axis 0 (dynamic batching). All tensors must agree
    /// on dtype and trailing dims.
    pub fn concat_batch(parts: &[&Tensor]) -> Result<Tensor> {
        let first = parts
            .first()
            .ok_or_else(|| KamaeError::InvalidConfig("concat of zero tensors".into()))?;
        let trailing = &first.shape[1..];
        let mut batch = 0usize;
        for p in parts {
            if &p.shape[1..] != trailing {
                return Err(KamaeError::LengthMismatch {
                    left: p.shape.len(),
                    right: first.shape.len(),
                    context: "concat_batch trailing dims".into(),
                });
            }
            batch += p.shape[0];
        }
        let mut shape = vec![batch];
        shape.extend_from_slice(trailing);
        macro_rules! cat {
            ($variant:ident, $as:ident) => {{
                let mut out = Vec::with_capacity(shape.iter().product());
                for p in parts {
                    out.extend_from_slice(p.$as()?);
                }
                Tensor::new(TensorData::$variant(out), shape)
            }};
        }
        match &first.data {
            TensorData::F32(_) => cat!(F32, as_f32),
            TensorData::F64(_) => cat!(F64, as_f64),
            TensorData::I32(_) => cat!(I32, as_i32),
            TensorData::I64(_) => cat!(I64, as_i64),
        }
    }

    /// Pad along axis 0 to `target` rows by repeating the final row
    /// (batch-bucket padding; padded rows are sliced off after execute).
    pub fn pad_batch(&self, target: usize) -> Tensor {
        let batch = self.batch();
        if batch >= target || batch == 0 {
            return self.clone();
        }
        let row: usize = self.shape[1..].iter().product();
        let extra = target - batch;
        let mut shape = self.shape.clone();
        shape[0] = target;
        macro_rules! pad {
            ($v:expr, $variant:ident) => {{
                let mut out = Vec::with_capacity(target * row);
                out.extend_from_slice($v);
                let last = &$v[(batch - 1) * row..batch * row];
                for _ in 0..extra {
                    out.extend_from_slice(last);
                }
                TensorData::$variant(out)
            }};
        }
        let data = match &self.data {
            TensorData::F32(v) => pad!(v, F32),
            TensorData::F64(v) => pad!(v, F64),
            TensorData::I32(v) => pad!(v, I32),
            TensorData::I64(v) => pad!(v, I64),
        };
        Tensor { data, shape }
    }

    /// Split along axis 0 into chunks of the given batch sizes (the inverse
    /// of [`Tensor::concat_batch`], used to scatter batched results back to
    /// requests).
    pub fn split_batch(&self, sizes: &[usize]) -> Result<Vec<Tensor>> {
        let row: usize = self.shape[1..].iter().product();
        let total: usize = sizes.iter().sum();
        if total != self.batch() {
            return Err(KamaeError::LengthMismatch {
                left: total,
                right: self.batch(),
                context: "split_batch".into(),
            });
        }
        let mut out = Vec::with_capacity(sizes.len());
        let mut start = 0usize;
        for &n in sizes {
            let mut shape = vec![n];
            shape.extend_from_slice(&self.shape[1..]);
            let range = start * row..(start + n) * row;
            let data = match &self.data {
                TensorData::F32(v) => TensorData::F32(v[range].to_vec()),
                TensorData::F64(v) => TensorData::F64(v[range].to_vec()),
                TensorData::I32(v) => TensorData::I32(v[range].to_vec()),
                TensorData::I64(v) => TensorData::I64(v[range].to_vec()),
            };
            out.push(Tensor::new(data, shape)?);
            start += n;
        }
        Ok(out)
    }
}

fn tensor_type_err(expected: &str, found: &TensorData) -> KamaeError {
    KamaeError::TypeMismatch {
        expected: expected.into(),
        found: found.dtype_name().into(),
        context: "tensor accessor".into(),
    }
}

/// Marshal to an XLA literal. Uses the raw-bytes constructor so the host
/// buffer is copied exactly once into the literal at its final row-major
/// shape (`vec1` + `reshape` would copy twice — §Perf L3 hot path).
pub(crate) fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    fn bytes_of<T>(v: &[T]) -> &[u8] {
        // SAFETY: plain-old-data element types, reading only.
        unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        }
    }
    let (ty, bytes) = match &t.data {
        TensorData::F32(v) => (xla::ElementType::F32, bytes_of(v)),
        TensorData::F64(v) => (xla::ElementType::F64, bytes_of(v)),
        TensorData::I32(v) => (xla::ElementType::S32, bytes_of(v)),
        TensorData::I64(v) => (xla::ElementType::S64, bytes_of(v)),
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        ty, &t.shape, bytes,
    )?)
}

/// Unmarshal an XLA literal back to a [`Tensor`].
pub(crate) fn from_literal(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = match shape.ty() {
        xla::ElementType::F32 => TensorData::F32(l.to_vec::<f32>()?),
        xla::ElementType::F64 => TensorData::F64(l.to_vec::<f64>()?),
        xla::ElementType::S32 => TensorData::I32(l.to_vec::<i32>()?),
        xla::ElementType::S64 => TensorData::I64(l.to_vec::<i64>()?),
        other => {
            return Err(KamaeError::Unsupported(format!(
                "literal element type {other:?}"
            )))
        }
    };
    Tensor::new(data, dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_validation() {
        assert!(Tensor::f32(vec![1.0, 2.0], vec![2, 2]).is_err());
        assert!(Tensor::f32(vec![1.0; 4], vec![2, 2]).is_ok());
    }

    #[test]
    fn concat_split_roundtrip() {
        let a = Tensor::i64(vec![1, 2, 3, 4], vec![2, 2]).unwrap();
        let b = Tensor::i64(vec![5, 6], vec![1, 2]).unwrap();
        let c = Tensor::concat_batch(&[&a, &b]).unwrap();
        assert_eq!(c.shape, vec![3, 2]);
        let parts = c.split_batch(&[2, 1]).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn concat_rejects_mismatched_trailing() {
        let a = Tensor::i64(vec![1, 2], vec![1, 2]).unwrap();
        let b = Tensor::i64(vec![1, 2, 3], vec![1, 3]).unwrap();
        assert!(Tensor::concat_batch(&[&a, &b]).is_err());
    }
}
