//! The pipeline API — Spark's `Pipeline`/`PipelineModel` programming model
//! over the Kamae transformer/estimator library.
//!
//! * A [`Transformer`] is a configured, stateless (or already-fitted)
//!   column operation: `DataFrame -> DataFrame`.
//! * An [`Estimator`] fits on a [`Dataset`] (distributed aggregation) and
//!   produces a fitted `Transformer` ("model" in Spark terms).
//! * A [`Pipeline`] is an ordered list of stages. `fit` runs stages in
//!   order, fitting each estimator on the data as transformed by all
//!   previous stages (Spark semantics), yielding a [`PipelineModel`].
//! * `PipelineModel::to_graph_spec` exports the fitted pipeline as a
//!   [`GraphSpec`] — the analogue of Kamae's `build_keras_model()`.

pub mod catalog;
pub mod tuner;

use crate::dataframe::DataFrame;
use crate::engine::Dataset;
use crate::error::{KamaeError, Result};
use crate::export::{GraphSpec, SpecBuilder, SpecInput};
use crate::optim::{OptReport, OptimizeLevel};
use crate::util::json::Json;

/// A configured column transformation. Implementations live in
/// [`crate::transformers`] (stateless) and as the fitted models of
/// [`crate::estimators`].
pub trait Transformer: Send + Sync {
    /// Unique stage name (Kamae's `layerName`).
    fn layer_name(&self) -> &str;

    /// Registry type tag used by save/load.
    fn type_name(&self) -> &'static str;

    /// Apply to a DataFrame in place (appends/replaces output columns).
    fn transform(&self, df: &mut DataFrame) -> Result<()>;

    /// Contribute this stage's ops to a GraphSpec under construction.
    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()>;

    /// Serialise parameters (without the type tag — the registry adds it).
    fn save(&self) -> Json;
}

/// An unfitted stage that learns state from data.
pub trait Estimator: Send + Sync {
    /// Unique stage name (Kamae's `layerName`).
    fn layer_name(&self) -> &str;

    /// Registry type tag used by save/load.
    fn type_name(&self) -> &'static str;

    /// Fit on a (partitioned) dataset, producing the fitted transformer.
    fn fit(&self, data: &Dataset) -> Result<Box<dyn Transformer>>;

    /// Serialise parameters (for saving unfitted pipelines).
    fn save(&self) -> Json;
}

/// A pipeline stage: either ready-to-run or needing a fit.
pub enum Stage {
    Transformer(Box<dyn Transformer>),
    Estimator(Box<dyn Estimator>),
}

impl Stage {
    /// Convenience constructor from a concrete transformer.
    pub fn transformer<T: Transformer + 'static>(t: T) -> Stage {
        Stage::Transformer(Box::new(t))
    }

    /// Convenience constructor from a concrete estimator.
    pub fn estimator<E: Estimator + 'static>(e: E) -> Stage {
        Stage::Estimator(Box::new(e))
    }

    pub fn layer_name(&self) -> &str {
        match self {
            Stage::Transformer(t) => t.layer_name(),
            Stage::Estimator(e) => e.layer_name(),
        }
    }
}

/// An ordered preprocessing pipeline (`KamaeSparkPipeline` in the paper's
/// Listing 1).
pub struct Pipeline {
    pub stages: Vec<Stage>,
}

impl Pipeline {
    pub fn new(stages: Vec<Stage>) -> Pipeline {
        Pipeline { stages }
    }

    /// Fit the pipeline: estimators fit on the data as transformed by all
    /// preceding stages; transformers apply eagerly so later estimators
    /// see their outputs.
    pub fn fit(&self, data: &Dataset) -> Result<PipelineModel> {
        let mut current = data.clone();
        let mut fitted: Vec<Box<dyn Transformer>> = Vec::with_capacity(self.stages.len());
        for stage in &self.stages {
            let t: Box<dyn Transformer> = match stage {
                Stage::Transformer(t) => {
                    // re-load through the registry to get an owned copy
                    crate::transformers::load(&with_type(t.save(), t.type_name()))?
                }
                Stage::Estimator(e) => e.fit(&current)?,
            };
            current = current.map(|df| {
                let mut df = df.clone();
                t.transform(&mut df)?;
                Ok(df)
            })?;
            fitted.push(t);
        }
        Ok(PipelineModel { stages: fitted })
    }
}

/// A fitted pipeline: pure transformers end-to-end.
pub struct PipelineModel {
    pub stages: Vec<Box<dyn Transformer>>,
}

impl PipelineModel {
    /// Transform a single DataFrame (one partition / one request batch).
    pub fn transform_df(&self, mut df: DataFrame) -> Result<DataFrame> {
        for t in &self.stages {
            t.transform(&mut df)?;
        }
        Ok(df)
    }

    /// Transform a partitioned dataset in parallel.
    pub fn transform(&self, data: &Dataset) -> Result<Dataset> {
        data.map(|df| self.transform_df(df.clone()))
    }

    /// Export as a GraphSpec (the `build_keras_model` analogue).
    ///
    /// `inputs` is the serving input schema (Listing 1's
    /// `tf_input_schema`); `outputs` the columns the compiled graph must
    /// return. The exported spec is optimized at the default level
    /// ([`OptimizeLevel::Full`] — bit-exact rewrites only); use
    /// [`Self::to_graph_spec_opt`] with [`OptimizeLevel::None`] to get
    /// the builder's graph verbatim.
    pub fn to_graph_spec(
        &self,
        name: &str,
        inputs: Vec<SpecInput>,
        outputs: &[&str],
    ) -> Result<GraphSpec> {
        Ok(self.to_graph_spec_opt(name, inputs, outputs, OptimizeLevel::default())?.0)
    }

    /// [`Self::to_graph_spec`] with an explicit optimization level,
    /// returning the per-pass [`OptReport`] alongside the spec.
    pub fn to_graph_spec_opt(
        &self,
        name: &str,
        inputs: Vec<SpecInput>,
        outputs: &[&str],
        level: OptimizeLevel,
    ) -> Result<(GraphSpec, OptReport)> {
        let mut b = SpecBuilder::new(name, inputs)?;
        for t in &self.stages {
            t.spec_nodes(&mut b)?;
        }
        crate::optim::optimize(b.finish(outputs)?, level)
    }

    // ---- persistence ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        let stages: Vec<Json> = self
            .stages
            .iter()
            .map(|t| with_type(t.save(), t.type_name()))
            .collect();
        let mut j = Json::object();
        j.set("format", "kamae-pipeline-model/1");
        j.set("stages", Json::Array(stages));
        j
    }

    pub fn from_json(j: &Json) -> Result<PipelineModel> {
        let format = j.req_str("format")?;
        if format != "kamae-pipeline-model/1" {
            return Err(KamaeError::Serde(format!("unknown pipeline format: {format}")));
        }
        let stages = j
            .req_array("stages")?
            .iter()
            .map(crate::transformers::load)
            .collect::<Result<_>>()?;
        Ok(PipelineModel { stages })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<PipelineModel> {
        let text = std::fs::read_to_string(path)?;
        PipelineModel::from_json(&Json::parse(&text)?)
    }
}

/// Attach the registry type tag to a transformer's parameter object.
pub(crate) fn with_type(mut params: Json, type_name: &str) -> Json {
    params.set("type", type_name);
    params
}
