//! Preprocessing hyperparameter search — the reproduction of the paper's
//! **Keras Tuner support**: "an exported preprocessing model can be fused
//! with a neural model … Keras Tuner can be configured to search for the
//! best hyperparameter settings of the preprocessing layers …
//! particularly useful for tuning parameters such as the number of hash
//! bins, embedding dimensions, or thresholds in feature engineering".
//!
//! Here the tunable is any closure `params -> Pipeline`; the tuner fits
//! each candidate on the training split and scores it on a validation
//! split with a user-supplied objective (e.g. downstream-proxy metrics
//! like collision rate, coverage, or a model's loss). Grid and random
//! search are provided — the search *strategy* is not the paper's
//! contribution, the tunable-preprocessing plumbing is.

use std::collections::BTreeMap;

use crate::engine::Dataset;
use crate::error::Result;
use crate::pipeline::{Pipeline, PipelineModel};
use crate::util::rng::Rng;

/// One hyperparameter assignment (name → integer-valued setting; Kamae's
/// tunables — bins, hash counts, list lengths, vocab caps — are integer).
pub type Params = BTreeMap<String, i64>;

/// A search space dimension.
#[derive(Debug, Clone)]
pub struct ParamRange {
    pub name: String,
    pub candidates: Vec<i64>,
}

/// Result of evaluating one candidate.
#[derive(Debug, Clone)]
pub struct Trial {
    pub params: Params,
    /// Lower is better.
    pub score: f64,
}

/// Tuner over a pipeline-builder closure.
pub struct Tuner<'a> {
    space: Vec<ParamRange>,
    build: Box<dyn Fn(&Params) -> Pipeline + 'a>,
    objective: Box<dyn Fn(&PipelineModel, &Dataset) -> Result<f64> + 'a>,
}

impl<'a> Tuner<'a> {
    pub fn new(
        space: Vec<ParamRange>,
        build: impl Fn(&Params) -> Pipeline + 'a,
        objective: impl Fn(&PipelineModel, &Dataset) -> Result<f64> + 'a,
    ) -> Tuner<'a> {
        Tuner { space, build: Box::new(build), objective: Box::new(objective) }
    }

    /// Exhaustive grid search. Returns trials sorted best-first.
    pub fn grid_search(&self, train: &Dataset, valid: &Dataset) -> Result<Vec<Trial>> {
        let mut trials = Vec::new();
        let mut idx = vec![0usize; self.space.len()];
        loop {
            let params: Params = self
                .space
                .iter()
                .zip(idx.iter())
                .map(|(dim, &i)| (dim.name.clone(), dim.candidates[i]))
                .collect();
            trials.push(self.run_trial(&params, train, valid)?);
            // odometer increment
            let mut d = 0;
            loop {
                if d == self.space.len() {
                    sort_trials(&mut trials);
                    return Ok(trials);
                }
                idx[d] += 1;
                if idx[d] < self.space[d].candidates.len() {
                    break;
                }
                idx[d] = 0;
                d += 1;
            }
        }
    }

    /// Random search with `budget` samples (with replacement).
    pub fn random_search(
        &self,
        train: &Dataset,
        valid: &Dataset,
        budget: usize,
        seed: u64,
    ) -> Result<Vec<Trial>> {
        let mut rng = Rng::new(seed);
        let mut trials = Vec::with_capacity(budget);
        for _ in 0..budget {
            let params: Params = self
                .space
                .iter()
                .map(|dim| {
                    let i = rng.below(dim.candidates.len() as u64) as usize;
                    (dim.name.clone(), dim.candidates[i])
                })
                .collect();
            trials.push(self.run_trial(&params, train, valid)?);
        }
        sort_trials(&mut trials);
        Ok(trials)
    }

    fn run_trial(&self, params: &Params, train: &Dataset, valid: &Dataset) -> Result<Trial> {
        let pipeline = (self.build)(params);
        let model = pipeline.fit(train)?;
        let score = (self.objective)(&model, valid)?;
        Ok(Trial { params: params.clone(), score })
    }
}

fn sort_trials(trials: &mut [Trial]) {
    trials.sort_by(|a, b| a.score.partial_cmp(&b.score).unwrap());
}

/// Ready-made objective: collision rate of an indexed column on the
/// validation split — the metric that tunes `numBins` (the paper's
/// canonical example of a tunable preprocessing parameter).
pub fn collision_objective<'a>(
    raw_col: &'a str,
    indexed_col: &'a str,
) -> impl Fn(&PipelineModel, &Dataset) -> Result<f64> + 'a {
    move |model, valid| {
        let df = model.transform_df(valid.collect()?)?;
        let raw = crate::ops::cast::to_string_vec(df.column(raw_col)?)?;
        let idx = df.column(indexed_col)?.as_i64()?;
        let mut first: std::collections::HashMap<&str, i64> = std::collections::HashMap::new();
        let mut codes: std::collections::HashMap<i64, &str> = std::collections::HashMap::new();
        let mut distinct = 0usize;
        let mut collided = 0usize;
        for (t, &i) in raw.iter().zip(idx.iter()) {
            if first.insert(t, i).is_none() {
                distinct += 1;
                match codes.get(&i) {
                    Some(other) if *other != t.as_str() => collided += 1,
                    _ => {
                        codes.insert(i, t);
                    }
                }
            }
        }
        Ok(collided as f64 / distinct.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::{Column, DataFrame};
    use crate::pipeline::Stage;
    use crate::transformers::HashIndexTransformer;

    fn token_ds(rows: usize, cardinality: u64, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let tokens: Vec<String> = (0..rows)
            .map(|_| format!("tok_{}", rng.below(cardinality)))
            .collect();
        Dataset::from_dataframe(
            DataFrame::new(vec![("t".into(), Column::from_str(tokens))]).unwrap(),
            2,
        )
    }

    #[test]
    fn grid_search_prefers_more_bins() {
        let train = token_ds(2_000, 800, 1);
        let valid = token_ds(2_000, 800, 2);
        let tuner = Tuner::new(
            vec![ParamRange {
                name: "numBins".into(),
                candidates: vec![64, 512, 8192],
            }],
            |p| {
                Pipeline::new(vec![Stage::transformer(HashIndexTransformer::new(
                    "t",
                    "t_idx",
                    p["numBins"],
                ))])
            },
            collision_objective("t", "t_idx"),
        );
        let trials = tuner.grid_search(&train, &valid).unwrap();
        assert_eq!(trials.len(), 3);
        // best trial must be the largest bin count, and strictly better
        assert_eq!(trials[0].params["numBins"], 8192);
        assert!(trials[0].score < trials.last().unwrap().score);
    }

    #[test]
    fn random_search_covers_space() {
        let train = token_ds(500, 100, 3);
        let valid = token_ds(500, 100, 4);
        let tuner = Tuner::new(
            vec![
                ParamRange { name: "numBins".into(), candidates: vec![32, 1024] },
            ],
            |p| {
                Pipeline::new(vec![Stage::transformer(HashIndexTransformer::new(
                    "t",
                    "t_idx",
                    p["numBins"],
                ))])
            },
            collision_objective("t", "t_idx"),
        );
        let trials = tuner.random_search(&train, &valid, 6, 9).unwrap();
        assert_eq!(trials.len(), 6);
        assert!(trials.windows(2).all(|w| w[0].score <= w[1].score));
    }

    #[test]
    fn multi_dimensional_grid() {
        let train = token_ds(300, 50, 5);
        let valid = token_ds(300, 50, 6);
        let tuner = Tuner::new(
            vec![
                ParamRange { name: "a".into(), candidates: vec![1, 2] },
                ParamRange { name: "b".into(), candidates: vec![10, 20, 30] },
            ],
            |_| {
                Pipeline::new(vec![Stage::transformer(HashIndexTransformer::new(
                    "t", "t_idx", 64,
                ))])
            },
            |_, _| Ok(0.0),
        );
        let trials = tuner.grid_search(&train, &valid).unwrap();
        assert_eq!(trials.len(), 6); // 2 x 3 grid
    }
}
