//! Canonical example pipelines — the paper's two use-cases, shared by
//! the CLI (`kamae export-examples`), the examples, the benchmarks and
//! the parity tests so every layer exercises identical definitions.

use crate::dataframe::DType;
use crate::estimators::*;
use crate::export::SpecInput;
use crate::pipeline::{Pipeline, Stage};
use crate::transformers::*;

/// Listing 1: the MovieLens preprocessing pipeline, verbatim.
pub fn movielens_pipeline() -> Pipeline {
    Pipeline::new(vec![
        Stage::transformer(
            HashIndexTransformer::new("UserID", "UserID_indexed", 10_000)
                .input_dtype(DType::Str) // force the id to be a string
                .layer_name("user_hash_indexer"),
        ),
        Stage::estimator(
            StringIndexEstimator::new("MovieID", "MovieID_indexed")
                .cast_to_string()
                .order(StringOrder::FrequencyDesc)
                .num_oov(1)
                .layer_name("movie_id_string_indexer"),
        ),
        Stage::estimator(
            OneHotEncodeEstimator::new("Occupation", "Occupation_indexed")
                .order(StringOrder::FrequencyDesc)
                .cast_to_string()
                .num_oov(1)
                .drop_unseen(true)
                .layer_name("occupation_one_hot_encoder"),
        ),
        Stage::transformer(
            StringToStringListTransformer::new("Genres", "Genres_split", "|", 6, "PADDED")
                .layer_name("genres_split_to_array_transform"),
        ),
        Stage::estimator(
            StringIndexEstimator::new("Genres_split", "Genres_indexed")
                .order(StringOrder::FrequencyDesc)
                .num_oov(1)
                .mask_token("PADDED")
                .layer_name("genres_string_indexer"),
        ),
    ])
}

/// Listing 1's `tf_input_schema`.
pub fn movielens_inputs() -> Vec<SpecInput> {
    vec![
        SpecInput { name: "UserID".into(), dtype: DType::I32, width: None },
        SpecInput { name: "MovieID".into(), dtype: DType::I32, width: None },
        SpecInput { name: "Occupation".into(), dtype: DType::I32, width: None },
        SpecInput { name: "Genres".into(), dtype: DType::Str, width: None },
    ]
}

/// Output columns of the MovieLens graph.
pub const MOVIELENS_OUTPUTS: [&str; 4] = [
    "UserID_indexed",
    "MovieID_indexed",
    "Occupation_indexed",
    "Genres_indexed",
];

/// The Expedia-style Learning-to-Rank search-filters pipeline (§3 of the
/// paper): date disassembly for seasonality, date subtraction for
/// durations, log transforms for wide-range numerics, delimiter splits,
/// assemble → standard-scale → disassemble, categorical indexing —
/// ~60 transforms, often chained.
pub fn ltr_pipeline() -> Pipeline {
    use crate::ops::date::DatePart;
    let num_features = [
        "price_log",
        "review_count_log",
        "review_score_imp",
        "dist_log",
        "ppp_log",
        "historical_ctr",
    ];
    let z_features = ["price_z", "review_count_z", "review_score_z", "dist_z", "ppp_z", "ctr_z"];
    Pipeline::new(vec![
        // --- date disassembly (seasonality) -----------------------------
        Stage::transformer(TimestampParseTransformer::new("search_ts", "search_secs")),
        Stage::transformer(SecondsToDaysTransformer::new("search_secs", "search_days")),
        Stage::transformer(DatePartTransformer::new("search_days", "search_month", DatePart::Month)),
        Stage::transformer(DatePartTransformer::new("search_days", "search_weekday", DatePart::Weekday)),
        Stage::transformer(DatePartTransformer::new("search_days", "search_doy", DatePart::DayOfYear)),
        Stage::transformer(DateParseTransformer::new("checkin", "checkin_days")),
        Stage::transformer(DateParseTransformer::new("checkout", "checkout_days")),
        Stage::transformer(DatePartTransformer::new("checkin_days", "checkin_month", DatePart::Month)),
        Stage::transformer(DatePartTransformer::new("checkin_days", "checkin_weekday", DatePart::Weekday)),
        // cyclic month encoding: sin/cos(2π·(m−1)/12)
        Stage::transformer(AddConstantTransformer::new("search_month", "sm0", -1.0)),
        Stage::transformer(MultiplyConstantTransformer::new("sm0", "sm_angle", std::f64::consts::TAU / 12.0)),
        Stage::transformer(SinTransformer::new("sm_angle", "search_month_sin")),
        Stage::transformer(CosTransformer::new("sm_angle", "search_month_cos")),
        Stage::transformer(AddConstantTransformer::new("checkin_month", "cm0", -1.0)),
        Stage::transformer(MultiplyConstantTransformer::new("cm0", "cm_angle", std::f64::consts::TAU / 12.0)),
        Stage::transformer(SinTransformer::new("cm_angle", "checkin_month_sin")),
        Stage::transformer(CosTransformer::new("cm_angle", "checkin_month_cos")),
        // --- durations ---------------------------------------------------
        Stage::transformer(DateDiffTransformer::new("checkout_days", "checkin_days", "stay_length")),
        Stage::transformer(DateDiffTransformer::new("checkin_days", "search_days", "lead_time")),
        // lead_time fans out into sibling bucketizes + a threshold flag —
        // the optimizer's MultiLaneBucketize merges the three into one
        // multi-output node sharing a single merged-splits search
        Stage::transformer(BucketizeTransformer::new("lead_time", "lead_bucket", vec![7.0, 30.0, 90.0])),
        Stage::transformer(BucketizeTransformer::new(
            "lead_time",
            "lead_bucket_fine",
            vec![1.0, 3.0, 7.0, 14.0, 30.0, 60.0, 90.0, 180.0],
        )),
        Stage::transformer(CompareConstantTransformer::new("lead_time", "is_last_minute", CmpOp::Le, 3.0)),
        Stage::transformer(CompareConstantTransformer::new("checkin_weekday", "is_weekend_checkin", CmpOp::Ge, 6.0)),
        Stage::transformer(CompareConstantTransformer::new("stay_length", "is_long_stay", CmpOp::Gt, 7.0)),
        // --- log transforms for wide-range numerics ----------------------
        Stage::transformer(LogTransformer::new("price", "price_log").log1p()),
        Stage::transformer(LogTransformer::new("review_count", "review_count_log").log1p()),
        Stage::estimator(ImputeEstimator::new("review_score", "review_score_imp", ImputeStrategy::Mean)),
        // --- geography ----------------------------------------------------
        Stage::transformer(HaversineTransformer::new("prop_lat", "prop_lon", "dest_lat", "dest_lon", "dist_to_center")),
        Stage::transformer(LogTransformer::new("dist_to_center", "dist_log").log1p()),
        // --- party-size arithmetic ---------------------------------------
        Stage::transformer(ArithmeticTransformer::new("num_adults", "num_children", "party_size", BinOp::Add)),
        Stage::transformer(ArithmeticTransformer::new("price", "party_size", "price_per_person", BinOp::Div)),
        Stage::transformer(LogTransformer::new("price_per_person", "ppp_log").log1p()),
        // --- delimiter splits + sequence indexing ------------------------
        Stage::transformer(StringToStringListTransformer::new("amenities", "amenities_list", ",", 8, "NONE")),
        Stage::estimator(
            StringIndexEstimator::new("amenities_list", "amenities_indexed").mask_token("NONE"),
        ),
        Stage::transformer(StringContainsTransformer::new("amenities", "has_pool", "pool", MatchMode::Contains)),
        Stage::transformer(StringContainsTransformer::new("amenities", "has_spa", "spa", MatchMode::Contains)),
        Stage::transformer(StringContainsTransformer::new("amenities", "has_wifi", "wifi", MatchMode::Contains)),
        // --- categorical indexing -----------------------------------------
        Stage::estimator(StringIndexEstimator::new("destination", "dest_indexed")),
        Stage::estimator(StringIndexEstimator::new("user_country", "country_indexed")),
        Stage::transformer(StringEqualsTransformer::new("device", "is_mobile", "mobile")),
        Stage::estimator(
            OneHotEncodeEstimator::new("star_rating", "star_onehot").cast_to_string().drop_unseen(true),
        ),
        Stage::transformer(HashIndexTransformer::new("property_id", "property_hashed", 50_000).input_dtype(DType::Str)),
        Stage::transformer(BloomEncodeTransformer::new("property_id", "property_bloom", 3, 8_192).input_dtype(DType::Str)),
        // --- assemble → standard scale → disassemble ----------------------
        Stage::transformer(VectorAssembleTransformer::new(&num_features, "num_vec")),
        Stage::estimator(StandardScaleEstimator::new("num_vec", "num_vec_scaled")),
        Stage::transformer(VectorDisassembleTransformer::new("num_vec_scaled", &z_features)),
        // --- extras on scaled features ------------------------------------
        Stage::transformer(SigmoidTransformer::new("ctr_z", "ctr_sig")),
        Stage::transformer(IfThenElseTransformer::new("is_long_stay", "ppp_log", "price_log", "stay_price_signal")),
        Stage::estimator(QuantileBinEstimator::new("price", "price_decile", 10)),
        Stage::transformer(ClipTransformer::new("stay_length", "stay_clipped", Some(1.0), Some(14.0))),
        Stage::transformer(DivideConstantTransformer::new("stay_clipped", "stay_norm", 14.0)),
        // --- threshold / seasonal conditionals ----------------------------
        // budget flag over the price deciles (a bucketize→compare ladder),
        // and a seasonal price signal whose summer mask is internal-only
        Stage::transformer(CompareConstantTransformer::new("price_decile", "is_budget_decile", CmpOp::Le, 2.0)),
        Stage::transformer(CompareConstantTransformer::new("search_doy", "is_summer", CmpOp::Ge, 172.0)),
        Stage::transformer(IfThenElseTransformer::new("is_summer", "ppp_log", "price_log", "seasonal_price_signal")),
    ])
}

/// Serving input schema for the LTR pipeline.
pub fn ltr_inputs() -> Vec<SpecInput> {
    let f = |name: &str, dtype: DType| SpecInput { name: name.into(), dtype, width: None };
    vec![
        f("search_ts", DType::Str),
        f("checkin", DType::Str),
        f("checkout", DType::Str),
        f("destination", DType::Str),
        f("user_country", DType::Str),
        f("device", DType::Str),
        f("num_adults", DType::I64),
        f("num_children", DType::I64),
        f("property_id", DType::I64),
        f("price", DType::F64),
        f("star_rating", DType::F64),
        f("review_score", DType::F64),
        f("review_count", DType::I64),
        f("amenities", DType::Str),
        f("prop_lat", DType::F64),
        f("prop_lon", DType::F64),
        f("dest_lat", DType::F64),
        f("dest_lon", DType::F64),
        f("historical_ctr", DType::F64),
    ]
}

/// Output columns of the LTR graph (what the ranking model consumes).
/// `is_summer` and `price_decile` stay internal: the optimizer fuses
/// them into `select_cmp` / `multi_bucketize` nodes at serving time.
/// `lead_bucket` / `lead_bucket_fine` / `is_last_minute` are the
/// sibling fan-out over `lead_time` that MultiLaneBucketize merges into
/// one multi-output node.
pub const LTR_OUTPUTS: [&str; 30] = [
    "search_month_sin",
    "search_month_cos",
    "search_weekday",
    "search_doy",
    "checkin_month_sin",
    "checkin_month_cos",
    "is_weekend_checkin",
    "stay_length",
    "lead_time",
    "lead_bucket",
    "lead_bucket_fine",
    "is_last_minute",
    "is_long_stay",
    "price_z",
    "review_count_z",
    "review_score_z",
    "dist_z",
    "ppp_z",
    "ctr_z",
    "ctr_sig",
    "amenities_indexed",
    "has_pool",
    "has_spa",
    "has_wifi",
    "dest_indexed",
    "country_indexed",
    "is_mobile",
    "star_onehot",
    "is_budget_decile",
    "seasonal_price_signal",
];

/// The "lite" ranking variant: a lightweight model serving a subset of
/// the full LTR feature set. Exporting the same fitted pipeline under
/// these outputs yields a second spec whose entire graph is a prefix of
/// the full one — the multi-variant serving shape
/// (`GraphSpec::merge_variants` + the CrossOutputDedup pass) serves
/// both for roughly the cost of the full variant alone.
pub const LTR_LITE_OUTPUTS: [&str; 10] = [
    "price_z",
    "review_count_z",
    "dist_z",
    "ctr_z",
    "stay_length",
    "lead_time",
    "lead_bucket",
    "amenities_indexed",
    "dest_indexed",
    "is_mobile",
];

/// Count of transformer applications in [`ltr_pipeline`] (the paper says
/// "around 60 transforms, often chained"; stages that expand to several
/// column ops — one-hot, disassemble into 6, bloom's 3 probes — push the
/// op count past the stage count).
pub fn ltr_stage_count() -> usize {
    ltr_pipeline().stages.len()
}

/// Tiny pipeline used by quickstart + smoke tests.
pub fn quickstart_pipeline() -> Pipeline {
    Pipeline::new(vec![
        Stage::transformer(LogTransformer::new("price", "price_log").log1p()),
        Stage::estimator(StandardScaleEstimator::new("price_log", "price_scaled")),
        Stage::transformer(HashIndexTransformer::new("city", "city_indexed", 1_000)),
    ])
}

pub fn quickstart_inputs() -> Vec<SpecInput> {
    vec![
        SpecInput { name: "price".into(), dtype: DType::F64, width: None },
        SpecInput { name: "city".into(), dtype: DType::Str, width: None },
    ]
}

pub const QUICKSTART_OUTPUTS: [&str; 2] = ["price_scaled", "city_indexed"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Dataset;
    use crate::synth;

    #[test]
    fn movielens_fit_transform_export() {
        let df = synth::gen_movielens(&synth::MovieLensConfig { rows: 2000, ..Default::default() });
        let ds = Dataset::from_dataframe(df.clone(), 4);
        let model = movielens_pipeline().fit(&ds).unwrap();
        let out = model.transform_df(df).unwrap();
        for col in MOVIELENS_OUTPUTS {
            assert!(out.has_column(col), "missing {col}");
        }
        // genre indices: fixed 6-wide, 0 = PADDED
        let g = out.column("Genres_indexed").unwrap().as_list_i64().unwrap();
        assert!(g.is_fixed_width(6));
        let spec = model
            .to_graph_spec("movielens", movielens_inputs(), &MOVIELENS_OUTPUTS)
            .unwrap();
        assert_eq!(spec.outputs.len(), 4);
        assert!(!spec.ingress.is_empty());
    }

    #[test]
    fn ltr_fit_transform_export() {
        let df = synth::gen_ltr(&synth::LtrConfig { rows: 2000, ..Default::default() });
        let ds = Dataset::from_dataframe(df.clone(), 4);
        let model = ltr_pipeline().fit(&ds).unwrap();
        let out = model.transform_df(df).unwrap();
        for col in LTR_OUTPUTS {
            assert!(out.has_column(col), "missing {col}");
        }
        assert!(ltr_stage_count() >= 45, "stage count {}", ltr_stage_count());
        let spec = model.to_graph_spec("ltr", ltr_inputs(), &LTR_OUTPUTS).unwrap();
        assert_eq!(spec.outputs.len(), LTR_OUTPUTS.len());
        // z-scores should be ~N(0,1)
        let z = out.column("price_z").unwrap().as_f64().unwrap();
        let mean: f64 = z.iter().sum::<f64>() / z.len() as f64;
        assert!(mean.abs() < 0.05, "price_z mean {mean}");
    }

    #[test]
    fn interp_runs_both_specs() {
        let df = synth::gen_movielens(&synth::MovieLensConfig { rows: 200, ..Default::default() });
        let ds = Dataset::from_dataframe(df.clone(), 2);
        let model = movielens_pipeline().fit(&ds).unwrap();
        let spec = model
            .to_graph_spec("movielens", movielens_inputs(), &MOVIELENS_OUTPUTS)
            .unwrap();
        let interp = crate::export::SpecInterpreter::new(spec);
        let out = interp.run(&df).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[3].shape, vec![200, 6]);
        // engine vs interp parity on the indexed outputs
        let engine = model.transform_df(df).unwrap();
        assert_eq!(
            out[0].as_i64().unwrap(),
            engine.column("UserID_indexed").unwrap().as_i64().unwrap()
        );
        let gl = engine.column("Genres_indexed").unwrap().as_list_i64().unwrap();
        assert_eq!(out[3].as_i64().unwrap(), &gl.values[..]);
    }
}
