//! Network serving front-end: a std-only threaded HTTP/1.1 listener in
//! front of the worker-pool [`Server`].
//!
//! The wire layer the "millions of users" story needs (MMLSpark ships
//! Spark pipelines as RESTful web services; this is that shape on the
//! pooled backend from PR 5), with the two production concerns the
//! in-process API cannot provide:
//!
//! - **Bounded admission.** In-flight requests are capped by a counting
//!   [`Semaphore`] window ([`NetConfig::admission`]) — the same primitive
//!   that bounds the streaming orchestrator's queue, used non-blockingly
//!   here: a request that finds no permit is answered `429 Too Many
//!   Requests` with a `Retry-After` hint *before* its body is even
//!   parsed, so shedding stays orders of magnitude cheaper than serving
//!   (`benches/net_serving.rs` gates this).
//! - **Typed wire errors.** Every failure mode is a [`WireError`] with a
//!   stable machine-readable `code` and a proper status, so clients can
//!   distinguish "fix your JSON" (400) from "back off" (429) from "the
//!   variant does not exist" (404) from "redeploy in progress" (503).
//!
//! ## Protocol
//!
//! ```text
//! POST /v1/infer          {"variant": "a", "rows": [{col: val, ...}, ...]}
//!   200  {"outputs": [{"name","dtype","shape","data"}, ...],
//!         "rows": N, "variant": "a"}          (variant key only if targeted)
//!   with [`NetConfig::validate`] on, also
//!        {"valid_rows": M, "verdicts": [{"row",
//!         "status": "ok"|"quarantined", ...}, ...]} — outputs cover
//!         only the valid rows; quarantined rows carry structured
//!         errors and land in the dead-letter sink
//!   4xx/5xx  {"error": {"code","message","status"}}
//! POST /v1/infer/<tenant> same, addressed to one registry tenant
//!                         (bare /v1/infer is the "default" tenant)
//! GET  /healthz           readiness: 200 while serving, 503 once draining
//! GET  /metrics           full ServeReport (incl. per-tenant splits) +
//!                         per-client counters as JSON
//! POST /admin/deploy      {"tenant", "spec"|"specs", "expect_version"?,
//!                          "level"?, "validation"?} — build off-thread,
//!                         hot-swap the tenant's active version (409 on
//!                         a lost CAS); "validation" attaches declarative
//!                         data-quality rules to the new version
//! POST /admin/rollback    {"tenant", "to_version"?} — re-activate a
//!                         previous version (409 when there is none)
//! GET  /admin/tenants     registry snapshot: versions + request gauges
//! POST /admin/shutdown    begin drain: stop accepting, finish in-flight
//! ```
//!
//! Requests may carry an `X-Kamae-Client` header; per-client
//! request/shed/latency counters are split by it in `/metrics` (clients
//! without one aggregate under `"anon"`). The client table is bounded
//! ([`NetConfig::max_clients`]): beyond the cap the least-recently-seen
//! client's counters fold into an `other_clients` rollup instead of
//! growing the map without bound.
//!
//! ## Registry mode
//!
//! [`NetServer::bind_registry`] serves a whole [`SpecRegistry`]: the
//! request schema, variant tables and output names all come from the
//! tenant version a request RESOLVES (not from bind-time state), so a
//! hot swap mid-request can never mix two versions' surfaces.
//! [`NetServer::bind`] is the one-tenant wrapper over it.
//!
//! Connections are keep-alive HTTP/1.1 (one thread per connection; the
//! accept loop polls a non-blocking listener so shutdown never hangs in
//! `accept`). Bodies are `Content-Length`-framed; reads run under a short
//! socket timeout so idle keep-alive connections notice the stop flag.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::dataframe::{dataframe_from_json_rows, dataframe_from_json_rows_lenient};
use crate::error::{KamaeError, Result};
use crate::export::GraphSpec;
use crate::optim::OptimizeLevel;
use crate::runtime::{Tensor, TensorData};
use crate::util::json::Json;
use crate::util::sync::Semaphore;

use super::backend::Backend;
use super::batcher::{BatchConfig, Server};
use super::metrics::{LatencyRecorder, TenantStats};
use super::registry::{SpecRegistry, TenantVersion, DEFAULT_TENANT};
use super::validate::{DeadLetterSink, JsonlDeadLetter, RowError, ValidationReport};

/// Listener configuration.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Worker-pool policy for the backing [`Server`].
    pub batch: BatchConfig,
    /// Admission window: max requests past the front door at once.
    /// Request `admission + 1` is shed with `429` instead of queueing.
    pub admission: usize,
    /// Max rows one request may carry (413 beyond it).
    pub max_request_rows: usize,
    /// Max request-body bytes (413 beyond it, connection closed without
    /// reading the body).
    pub max_body_bytes: usize,
    /// `Retry-After` hint (seconds) on shed responses.
    pub retry_after_secs: u64,
    /// Max distinct `X-Kamae-Client` ids tracked in `/metrics`. Beyond
    /// the cap, the least-recently-seen client's counters fold into the
    /// `other_clients` rollup — unique ids must not grow the map (and
    /// its report cost) without bound.
    pub max_clients: usize,
    /// Run the ingress data-quality gate: rows are decoded leniently,
    /// screened against the resolved tenant version's
    /// [`super::ValidationSpec`], and invalid rows are quarantined —
    /// the batch is served compacted and the response carries per-row
    /// `verdicts` with structured errors. Off (the default), a single
    /// bad cell still fails the whole request with a 400.
    pub validate: bool,
    /// Append quarantined rows (original wire JSON + their errors) to
    /// this JSONL dead-letter file. Requires [`Self::validate`]. The
    /// same sink receives poison rows isolated by the pool's bisection
    /// layer, so one file holds every row the service refused to serve.
    pub dead_letter: Option<PathBuf>,
    /// Flip `/healthz` to `"degraded"` (still 200 — the service IS
    /// serving, just refusing many rows) when any tenant's rolling
    /// quarantine rate reaches this fraction. Requires
    /// [`Self::validate`]; `None` never alerts.
    pub quarantine_alert: Option<f64>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            batch: BatchConfig::default(),
            admission: 64,
            max_request_rows: 1024,
            max_body_bytes: 1 << 22,
            retry_after_secs: 1,
            max_clients: 64,
            validate: false,
            dead_letter: None,
            quarantine_alert: None,
        }
    }
}

impl NetConfig {
    fn validate(&self) -> Result<()> {
        if self.admission == 0 {
            return Err(KamaeError::Serving(
                "NetConfig::admission must be >= 1 (a zero window sheds every request)".into(),
            ));
        }
        if self.max_request_rows == 0 {
            return Err(KamaeError::Serving(
                "NetConfig::max_request_rows must be >= 1".into(),
            ));
        }
        if self.max_body_bytes == 0 {
            return Err(KamaeError::Serving(
                "NetConfig::max_body_bytes must be >= 1".into(),
            ));
        }
        if self.max_clients == 0 {
            return Err(KamaeError::Serving(
                "NetConfig::max_clients must be >= 1 (every request has a client id)".into(),
            ));
        }
        if self.dead_letter.is_some() && !self.validate {
            return Err(KamaeError::Serving(
                "NetConfig::dead_letter is set but validate is off — nothing would \
                 ever be quarantined into it"
                    .into(),
            ));
        }
        if let Some(rate) = self.quarantine_alert {
            if !(rate > 0.0 && rate <= 1.0) {
                return Err(KamaeError::Serving(format!(
                    "NetConfig::quarantine_alert must be a fraction in (0, 1], got {rate}"
                )));
            }
            if !self.validate {
                return Err(KamaeError::Serving(
                    "NetConfig::quarantine_alert is set but validate is off — the \
                     quarantine rate would never move"
                        .into(),
                ));
            }
        }
        Ok(())
    }
}

/// Typed wire-error model: every failure a request can hit, with a
/// stable `code` string and its HTTP status. Serialised as
/// `{"error": {"code", "message", "status"}}`.
#[derive(Debug, Clone)]
pub enum WireError {
    /// Malformed request (bad JSON, wrong body shape, non-object rows).
    BadRequest(String),
    /// Unknown path.
    NotFound(String),
    /// Known path, wrong method.
    MethodNotAllowed(String),
    /// `variant` names nothing the backend can route.
    UnknownVariant(String),
    /// The request (or admin verb) addressed a tenant the registry does
    /// not know.
    UnknownTenant(String),
    /// A deploy/rollback named an expected version that no longer
    /// matches (optimistic concurrency lost, or nothing to roll back
    /// to). The registry is unchanged; re-read `/admin/tenants` and
    /// retry.
    VersionConflict(String),
    /// More rows than [`NetConfig::max_request_rows`].
    OversizedBatch { rows: usize, max_rows: usize },
    /// Body larger than [`NetConfig::max_body_bytes`].
    OversizedBody { bytes: usize, max_bytes: usize },
    /// Shed by admission control; carries the `Retry-After` hint.
    Overloaded { retry_after_secs: u64 },
    /// The listener is draining (or the pool is gone).
    ShuttingDown,
    /// The request aged past its deadline (`deadline_ms` on the body,
    /// or [`BatchConfig::request_deadline`]) while queued and was
    /// answered without ever occupying a batch.
    DeadlineExceeded(String),
    /// Backend-side failure.
    Internal(String),
}

impl WireError {
    pub fn status(&self) -> u16 {
        match self {
            WireError::BadRequest(_) => 400,
            WireError::NotFound(_)
            | WireError::UnknownVariant(_)
            | WireError::UnknownTenant(_) => 404,
            WireError::MethodNotAllowed(_) => 405,
            WireError::VersionConflict(_) => 409,
            WireError::OversizedBatch { .. } | WireError::OversizedBody { .. } => 413,
            WireError::Overloaded { .. } => 429,
            WireError::Internal(_) => 500,
            WireError::ShuttingDown => 503,
            WireError::DeadlineExceeded(_) => 504,
        }
    }

    pub fn code(&self) -> &'static str {
        match self {
            WireError::BadRequest(_) => "bad_request",
            WireError::NotFound(_) => "not_found",
            WireError::MethodNotAllowed(_) => "method_not_allowed",
            WireError::UnknownVariant(_) => "unknown_variant",
            WireError::UnknownTenant(_) => "unknown_tenant",
            WireError::VersionConflict(_) => "version_conflict",
            WireError::OversizedBatch { .. } => "oversized_batch",
            WireError::OversizedBody { .. } => "oversized_body",
            WireError::Overloaded { .. } => "overloaded",
            WireError::ShuttingDown => "shutting_down",
            WireError::DeadlineExceeded(_) => "deadline_exceeded",
            WireError::Internal(_) => "internal",
        }
    }

    pub fn message(&self) -> String {
        match self {
            WireError::BadRequest(m)
            | WireError::NotFound(m)
            | WireError::MethodNotAllowed(m)
            | WireError::UnknownVariant(m)
            | WireError::UnknownTenant(m)
            | WireError::VersionConflict(m)
            | WireError::DeadlineExceeded(m)
            | WireError::Internal(m) => m.clone(),
            WireError::OversizedBatch { rows, max_rows } => {
                format!("request has {rows} rows, max_request_rows is {max_rows}")
            }
            WireError::OversizedBody { bytes, max_bytes } => {
                format!("request body is {bytes} bytes, max_body_bytes is {max_bytes}")
            }
            WireError::Overloaded { retry_after_secs } => format!(
                "admission window full, request shed; retry after {retry_after_secs}s"
            ),
            WireError::ShuttingDown => "server is shutting down".to_string(),
        }
    }

    /// Response headers beyond the defaults (`Retry-After` on sheds).
    pub fn extra_headers(&self) -> Vec<(String, String)> {
        match self {
            WireError::Overloaded { retry_after_secs } => {
                vec![("Retry-After".to_string(), retry_after_secs.to_string())]
            }
            _ => Vec::new(),
        }
    }

    /// The `{"error": {...}}` response body.
    pub fn to_body(&self) -> String {
        let mut e = Json::object();
        e.set("code", self.code());
        e.set("message", self.message());
        e.set("status", self.status() as i64);
        let mut j = Json::object();
        j.set("error", e);
        j.to_string()
    }
}

type Handled = (u16, Vec<(String, String)>, String);

/// Per-client request/shed/latency counters, keyed by `X-Kamae-Client`.
#[derive(Debug, Default, Clone)]
struct ClientStats {
    requests: u64,
    shed: u64,
    latency_ns_sum: u64,
    latency_ns_max: u64,
}

#[derive(Debug, Default)]
struct ClientEntry {
    stats: ClientStats,
    /// Logical clock of the entry's last request — the LRU key.
    last_seen: u64,
}

/// Bounded per-client counter table. Unbounded unique client ids used
/// to grow the map (and every `/metrics` render) without limit; beyond
/// `cap` the least-recently-seen client's counters fold into the
/// `other` rollup, so totals are conserved while memory is bounded.
struct ClientTable {
    cap: usize,
    tick: u64,
    clients: BTreeMap<String, ClientEntry>,
    /// Sum of every evicted client's counters (`other_clients` in
    /// `/metrics`).
    other: ClientStats,
    /// Distinct client ids evicted so far (gates the rollup key).
    evicted: u64,
}

impl ClientTable {
    fn new(cap: usize) -> ClientTable {
        ClientTable {
            cap: cap.max(1),
            tick: 0,
            clients: BTreeMap::new(),
            other: ClientStats::default(),
            evicted: 0,
        }
    }

    /// The client's counters, bumping its recency. Inserting past the
    /// cap first evicts the least-recently-seen entry into the rollup.
    fn entry(&mut self, id: &str) -> &mut ClientStats {
        self.tick += 1;
        if !self.clients.contains_key(id) && self.clients.len() >= self.cap {
            let victim = self
                .clients
                .iter()
                .min_by_key(|(_, e)| e.last_seen)
                .map(|(k, _)| k.clone())
                .expect("cap >= 1, table non-empty");
            let e = self.clients.remove(&victim).expect("victim came from the map");
            self.other.requests += e.stats.requests;
            self.other.shed += e.stats.shed;
            self.other.latency_ns_sum += e.stats.latency_ns_sum;
            self.other.latency_ns_max = self.other.latency_ns_max.max(e.stats.latency_ns_max);
            self.evicted += 1;
        }
        let tick = self.tick;
        let e = self.clients.entry(id.to_string()).or_default();
        e.last_seen = tick;
        &mut e.stats
    }
}

/// Shared listener state: everything a connection thread needs.
struct NetState {
    /// The registry requests resolve against. Everything request-facing
    /// (schema, outputs, variants) lives on the resolved
    /// [`TenantVersion`], never here — bind-time state cannot go stale
    /// across a hot swap.
    registry: Arc<SpecRegistry>,
    /// The pooled server; `None` once drained. Handlers take the read
    /// lock only long enough to enqueue (responses arrive on a channel),
    /// so drain's `write()` never waits behind a slow request.
    server: RwLock<Option<Server>>,
    config: NetConfig,
    admission: Semaphore,
    in_flight: AtomicUsize,
    stop: AtomicBool,
    active_conns: AtomicUsize,
    started: Instant,
    recorder: LatencyRecorder,
    accepted: AtomicU64,
    shed: AtomicU64,
    clients: Mutex<ClientTable>,
    /// Per-tenant shed counts (sheds happen before latency samples
    /// exist, so they cannot live in the recorder).
    tenant_shed: Mutex<BTreeMap<String, u64>>,
    /// Dead-letter sink for quarantined rows ([`NetConfig::dead_letter`]).
    /// Shared (`Arc`) with the worker pool, which records poison rows
    /// isolated by bisection into the same file.
    dead_letter: Option<Arc<JsonlDeadLetter>>,
}

impl NetState {
    /// The "primary" tenant version for naming and health payloads: the
    /// default tenant when registered, else the first tenant, else
    /// `None` (an empty registry awaiting its first deploy).
    fn primary_version(&self) -> Option<Arc<TenantVersion>> {
        if let Ok(v) = self.registry.resolve(DEFAULT_TENANT) {
            return Some(v);
        }
        let names = self.registry.tenant_names();
        names.first().and_then(|n| self.registry.resolve(n).ok())
    }
}

/// Releases one admission permit (and the in-flight gauge) when a
/// request finishes, on every exit path including panics.
struct AdmissionGuard<'a> {
    state: &'a NetState,
}

impl Drop for AdmissionGuard<'_> {
    fn drop(&mut self) {
        self.state.in_flight.fetch_sub(1, Ordering::SeqCst);
        self.state.admission.release();
    }
}

/// Decrements the connection gauge when a connection thread exits, on
/// every path including panics (the drain loop waits on this gauge).
struct ConnGuard(Arc<NetState>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.active_conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The running listener. Dropping it (or calling [`Self::shutdown`])
/// stops accepting, waits for connection threads, then drains the pool.
pub struct NetServer {
    state: Arc<NetState>,
    accept: Option<std::thread::JoinHandle<()>>,
    addr: SocketAddr,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `backend` through a worker pool. The backend must
    /// expose its [`crate::export::GraphSpec`] — that is where the
    /// request schema and the per-variant output names come from.
    pub fn bind(backend: Arc<dyn Backend>, addr: &str, config: NetConfig) -> Result<NetServer> {
        if backend.spec().is_none() {
            return Err(KamaeError::Serving(format!(
                "backend '{}' ({} backend) exposes no GraphSpec; the network \
                 front-end needs one to derive the request schema",
                backend.name(),
                backend.kind()
            )));
        }
        let registry = SpecRegistry::single(DEFAULT_TENANT, backend)?;
        NetServer::bind_registry(registry, addr, config)
    }

    /// Bind `addr` and serve every tenant in `registry` through one
    /// shared worker pool. Requests address `POST /v1/infer/<tenant>`
    /// (the bare path is the default tenant), and the admin endpoints
    /// deploy, roll back, and list tenants at runtime.
    pub fn bind_registry(
        registry: Arc<SpecRegistry>,
        addr: &str,
        config: NetConfig,
    ) -> Result<NetServer> {
        config.validate()?;
        // one sink serves both layers: ingress quarantine (recorded
        // here) and pool-side poison rows (recorded by bisection)
        let dead_letter = match &config.dead_letter {
            Some(path) => Some(Arc::new(JsonlDeadLetter::create(path)?)),
            None => None,
        };
        let pool_sink = dead_letter
            .clone()
            .map(|s| s as Arc<dyn DeadLetterSink>);
        let server =
            Server::start_registry_sink(Arc::clone(&registry), config.batch.clone(), pool_sink)?;
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let max_clients = config.max_clients;
        let state = Arc::new(NetState {
            registry,
            server: RwLock::new(Some(server)),
            admission: Semaphore::new(config.admission),
            config,
            in_flight: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
            started: Instant::now(),
            recorder: LatencyRecorder::new(),
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            clients: Mutex::new(ClientTable::new(max_clients)),
            tenant_shed: Mutex::new(BTreeMap::new()),
            dead_letter,
        });
        let accept_state = Arc::clone(&state);
        let accept = std::thread::Builder::new()
            .name("kamae-net-accept".into())
            .spawn(move || accept_loop(accept_state, listener))
            .map_err(|e| KamaeError::Serving(format!("failed to spawn accept thread: {e}")))?;
        Ok(NetServer { state, accept: Some(accept), addr })
    }

    /// The bound address (resolves the actual port after binding `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until a drain begins (`POST /admin/shutdown` or
    /// [`Self::shutdown`] from another thread is not possible — this
    /// consumes the server), then finish the drain: `kamae serve
    /// --listen` parks here.
    pub fn wait(mut self) {
        while !self.state.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(100));
        }
        self.drain_in_place();
    }

    /// Begin and complete a drain: stop accepting, let in-flight
    /// connections finish, then shut the pool down (queued requests are
    /// still served).
    pub fn shutdown(mut self) {
        self.drain_in_place();
    }

    fn drain_in_place(&mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // connection threads notice the stop flag at their next read
        // timeout; don't wait forever on a peer that never hangs up
        let deadline = Instant::now() + Duration::from_secs(10);
        while self.state.active_conns.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        if let Some(server) = self.state.server.write().unwrap().take() {
            server.shutdown();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.drain_in_place();
    }
}

fn accept_loop(state: Arc<NetState>, listener: TcpListener) {
    loop {
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let conn_state = Arc::clone(&state);
                conn_state.active_conns.fetch_add(1, Ordering::SeqCst);
                let spawned = std::thread::Builder::new()
                    .name("kamae-net-conn".into())
                    .spawn(move || {
                        let guard = ConnGuard(Arc::clone(&conn_state));
                        handle_connection(&conn_state, stream);
                        drop(guard);
                    });
                if spawned.is_err() {
                    state.active_conns.fetch_sub(1, Ordering::SeqCst);
                }
            }
            // non-blocking listener: poll the stop flag between accepts
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn io_retryable(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut
            | std::io::ErrorKind::Interrupted
    )
}

/// Serve one keep-alive connection until the peer hangs up, an error
/// closes it, or the stop flag finds it idle.
fn handle_connection(state: &NetState, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    // short read timeout so idle keep-alive connections poll the stop flag
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut stream = stream;
    loop {
        // request line; on timeout a partial line stays buffered in
        // `line` (std keeps already-read valid UTF-8), so retrying
        // accumulates correctly
        let mut line = String::new();
        loop {
            match reader.read_line(&mut line) {
                Ok(0) => return, // peer closed
                Ok(_) => break,
                Err(e) if io_retryable(&e) => {
                    if state.stop.load(Ordering::SeqCst) && line.is_empty() {
                        return; // idle connection during drain
                    }
                }
                Err(_) => return,
            }
        }
        let request_line = line.trim().to_string();
        if request_line.is_empty() {
            continue; // stray CRLF between pipelined requests
        }
        let mut parts = request_line.split_whitespace();
        let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v.to_string()),
            _ => {
                let e = WireError::BadRequest("malformed request line".into());
                let _ = write_response(&mut stream, e.status(), &e.extra_headers(), &e.to_body(), true);
                return;
            }
        };
        // a started request must finish within this window or the
        // connection is dropped (slow-loris bound)
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut headers: BTreeMap<String, String> = BTreeMap::new();
        loop {
            let mut h = String::new();
            loop {
                match reader.read_line(&mut h) {
                    Ok(0) => return,
                    Ok(_) => break,
                    Err(e) if io_retryable(&e) => {
                        if Instant::now() > deadline {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            }
            let h = h.trim();
            if h.is_empty() {
                break;
            }
            match h.split_once(':') {
                Some((k, v)) => {
                    headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
                }
                None => {
                    let e = WireError::BadRequest(format!("malformed header line: {h:?}"));
                    let _ = write_response(
                        &mut stream,
                        e.status(),
                        &e.extra_headers(),
                        &e.to_body(),
                        true,
                    );
                    return;
                }
            }
        }
        let content_length = headers
            .get("content-length")
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(0);
        if content_length > state.config.max_body_bytes {
            // refuse without reading: the framing is lost, so close too
            let e = WireError::OversizedBody {
                bytes: content_length,
                max_bytes: state.config.max_body_bytes,
            };
            let _ = write_response(&mut stream, e.status(), &e.extra_headers(), &e.to_body(), true);
            return;
        }
        let mut body = vec![0u8; content_length];
        let mut filled = 0usize;
        while filled < content_length {
            match reader.read(&mut body[filled..]) {
                Ok(0) => return, // peer closed mid-body
                Ok(n) => filled += n,
                Err(e) if io_retryable(&e) => {
                    if Instant::now() > deadline {
                        return;
                    }
                }
                Err(_) => return,
            }
        }
        let body = match String::from_utf8(body) {
            Ok(b) => b,
            Err(_) => {
                let e = WireError::BadRequest("request body is not valid UTF-8".into());
                let _ = write_response(&mut stream, e.status(), &e.extra_headers(), &e.to_body(), true);
                return;
            }
        };
        let keep_alive = !version.eq_ignore_ascii_case("HTTP/1.0")
            && !headers
                .get("connection")
                .is_some_and(|c| c.eq_ignore_ascii_case("close"));
        let (status, extra, resp_body) = dispatch(state, &method, &path, &headers, &body);
        let close = !keep_alive || state.stop.load(Ordering::SeqCst);
        if write_response(&mut stream, status, &extra, &resp_body, close).is_err() || close {
            return;
        }
    }
}

fn dispatch(
    state: &NetState,
    method: &str,
    path: &str,
    headers: &BTreeMap<String, String>,
    body: &str,
) -> Handled {
    let result: std::result::Result<Handled, WireError> = match (method, path) {
        ("GET", "/healthz") => Ok(handle_healthz(state)),
        ("GET", "/metrics") => Ok(handle_metrics(state)),
        ("POST", "/v1/infer") => handle_infer(state, DEFAULT_TENANT, headers, body),
        ("POST", p) if p.starts_with("/v1/infer/") => {
            let tenant = &p["/v1/infer/".len()..];
            if tenant.is_empty() || tenant.contains('/') {
                Err(WireError::NotFound(format!("no route for {path}")))
            } else {
                handle_infer(state, tenant, headers, body)
            }
        }
        ("POST", "/admin/deploy") => handle_deploy(state, body),
        ("POST", "/admin/rollback") => handle_rollback(state, body),
        ("GET", "/admin/tenants") => Ok(handle_tenants(state)),
        ("POST", "/admin/shutdown") => {
            // respond first (the write happens after dispatch returns),
            // then the accept loop and idle connections wind down
            state.stop.store(true, Ordering::SeqCst);
            let mut j = Json::object();
            j.set("status", "draining");
            Ok((200, Vec::new(), j.to_string()))
        }
        (_, p)
            if p == "/healthz"
                || p == "/metrics"
                || p == "/v1/infer"
                || p.starts_with("/v1/infer/")
                || p == "/admin/deploy"
                || p == "/admin/rollback"
                || p == "/admin/tenants"
                || p == "/admin/shutdown" =>
        {
            Err(WireError::MethodNotAllowed(format!(
                "method {method} not allowed for {path}"
            )))
        }
        _ => Err(WireError::NotFound(format!("no route for {path}"))),
    };
    match result {
        Ok(handled) => handled,
        Err(e) => (e.status(), e.extra_headers(), e.to_body()),
    }
}

fn handle_healthz(state: &NetState) -> Handled {
    let mut j = Json::object();
    if state.stop.load(Ordering::SeqCst) {
        j.set("status", "draining");
        return (503, Vec::new(), j.to_string());
    }
    let workers = state
        .server
        .read()
        .unwrap()
        .as_ref()
        .map(|s| s.workers())
        .unwrap_or(0);
    j.set("status", "ok");
    // quarantine-rate alert: past the threshold the service stays UP
    // (still 200 — it IS serving) but reports degraded, naming the
    // worst-offending tenant so the pager points somewhere useful
    if let Some(threshold) = state.config.quarantine_alert {
        let offender = state
            .recorder
            .quarantine_rates()
            .into_iter()
            .filter(|(_, rate)| *rate >= threshold)
            .max_by(|a, b| a.1.total_cmp(&b.1));
        if let Some((tenant, rate)) = offender {
            j.set("status", "degraded");
            let mut alert = Json::object();
            alert.set("reason", "quarantine_rate");
            alert.set("tenant", tenant);
            alert.set("quarantine_rate", rate);
            alert.set("threshold", threshold);
            j.set("alert", alert);
        }
    }
    if let Some(primary) = state.primary_version() {
        j.set("backend", primary.backend().name());
        j.set("kind", primary.backend().kind());
        j.set(
            "variants",
            Json::Array(primary.variants().iter().map(|v| Json::Str(v.clone())).collect()),
        );
    }
    j.set(
        "tenants",
        Json::Array(
            state
                .registry
                .tenant_names()
                .into_iter()
                .map(Json::Str)
                .collect(),
        ),
    );
    j.set("workers", workers);
    j.set("admission_limit", state.config.admission);
    j.set("in_flight", state.in_flight.load(Ordering::SeqCst));
    (200, Vec::new(), j.to_string())
}

fn handle_metrics(state: &NetState) -> Handled {
    let accepted = state.accepted.load(Ordering::Relaxed) as usize;
    let worker_busy = state
        .server
        .read()
        .unwrap()
        .as_ref()
        .map(|s| s.worker_busy_times())
        .unwrap_or_default();
    let report_name = match state.primary_version() {
        Some(p) => format!("{}/net", p.backend().name()),
        None => "registry/net".to_string(),
    };
    let mut report = state.recorder.report_pool(
        &report_name,
        accepted,
        state.started.elapsed(),
        &worker_busy,
    );
    report.shed_requests = state.shed.load(Ordering::Relaxed) as usize;
    report.admission_limit = state.config.admission;
    // fault-containment counters live on the pool and the shared sink
    {
        let server = state.server.read().unwrap();
        if let Some(s) = server.as_ref() {
            report.worker_panics = s.worker_panics();
            report.deadline_expired = s.deadline_expired();
            report.poison_rows = s.poison_rows();
        }
    }
    if let Some(sink) = &state.dead_letter {
        report.dead_letter_errors = sink.errors();
    }
    // stamp the per-tenant split with what the recorder cannot know:
    // shed counts (no latency sample exists for a shed) and the
    // currently-active version from the registry
    {
        let quarantine_rates = state.recorder.quarantine_rates();
        let tenant_shed = state.tenant_shed.lock().unwrap();
        for t in report.tenants.iter_mut() {
            t.shed = tenant_shed.get(&t.tenant).copied().unwrap_or(0) as usize;
            t.quarantine_rate = quarantine_rates.get(&t.tenant).copied().unwrap_or(0.0);
            if let Ok(v) = state.registry.resolve(&t.tenant) {
                t.active_version = v.version();
            }
        }
        // a tenant that only ever shed has no latency samples; surface
        // it anyway so operators can see who is being refused
        for (tenant, &shed) in tenant_shed.iter() {
            if report.tenants.iter().any(|t| &t.tenant == tenant) {
                continue;
            }
            report.tenants.push(TenantStats {
                tenant: tenant.clone(),
                requests: 0,
                shed: shed as usize,
                active_version: state
                    .registry
                    .resolve(tenant)
                    .map(|v| v.version())
                    .unwrap_or(0),
                mean_ns: 0.0,
                p50_ns: 0.0,
                p95_ns: 0.0,
                p99_ns: 0.0,
                quarantine_rate: 0.0,
            });
        }
        report.tenants.sort_by(|a, b| a.tenant.cmp(&b.tenant));
    }
    let mut clients = Json::object();
    {
        let table = state.clients.lock().unwrap();
        for (id, e) in table.clients.iter() {
            let c = &e.stats;
            let mut o = Json::object();
            o.set("requests", c.requests as i64);
            o.set("shed", c.shed as i64);
            o.set(
                "mean_ns",
                if c.requests == 0 { 0.0 } else { c.latency_ns_sum as f64 / c.requests as f64 },
            );
            o.set("max_ns", c.latency_ns_max as f64);
            clients.set(id.as_str(), o);
        }
        // rollup for clients evicted from the bounded table — totals
        // across clients + other_clients are conserved
        if table.evicted > 0 {
            let c = &table.other;
            let mut o = Json::object();
            o.set("evicted", table.evicted as i64);
            o.set("requests", c.requests as i64);
            o.set("shed", c.shed as i64);
            o.set(
                "mean_ns",
                if c.requests == 0 { 0.0 } else { c.latency_ns_sum as f64 / c.requests as f64 },
            );
            o.set("max_ns", c.latency_ns_max as f64);
            clients.set("other_clients", o);
        }
    }
    let mut j = Json::object();
    j.set("serve_report", report.to_json());
    j.set("in_flight", state.in_flight.load(Ordering::SeqCst));
    j.set("clients", clients);
    (200, Vec::new(), j.to_string())
}

/// The `Retry-After` hint for a shed response, derived from live load:
/// the seconds the current queue needs to drain at the server's
/// observed lifetime service rate, floored at
/// [`NetConfig::retry_after_secs`] and capped at 60 (beyond a minute
/// the number is guesswork, not guidance). With no drain signal yet —
/// empty queue, cold server, or a rate of zero — the floor is the
/// hint, which is exactly the old constant behaviour.
fn retry_after_hint(queue_depth: usize, drain_rps: f64, floor: u64) -> u64 {
    if queue_depth == 0 || !drain_rps.is_finite() || drain_rps <= 0.0 {
        return floor;
    }
    let secs = (queue_depth as f64 / drain_rps).ceil() as u64;
    secs.clamp(floor, floor.max(60))
}

fn handle_infer(
    state: &NetState,
    tenant: &str,
    headers: &BTreeMap<String, String>,
    body: &str,
) -> std::result::Result<Handled, WireError> {
    if state.stop.load(Ordering::SeqCst) {
        return Err(WireError::ShuttingDown);
    }
    let client = headers
        .get("x-kamae-client")
        .cloned()
        .unwrap_or_else(|| "anon".to_string());
    // shed BEFORE parsing: refusal must stay cheap under overload
    if !state.admission.try_acquire() {
        state.shed.fetch_add(1, Ordering::Relaxed);
        state.clients.lock().unwrap().entry(&client).shed += 1;
        *state
            .tenant_shed
            .lock()
            .unwrap()
            .entry(tenant.to_string())
            .or_insert(0) += 1;
        // derive the hint from live load — a queue that needs 10 s to
        // drain should not invite a retry in 1 s
        let floor = state.config.retry_after_secs;
        let retry_after_secs = state
            .server
            .read()
            .unwrap()
            .as_ref()
            .map(|s| retry_after_hint(s.queue_depth(), s.drain_rate_rps(), floor))
            .unwrap_or(floor);
        return Err(WireError::Overloaded { retry_after_secs });
    }
    state.in_flight.fetch_add(1, Ordering::SeqCst);
    let _permit = AdmissionGuard { state };
    let t0 = Instant::now();

    // resolve the tenant's live version once; schema, outputs, and
    // variant routing all come from THIS snapshot, so a deploy landing
    // mid-request cannot mix versions within one response
    let resolved = state.registry.resolve(tenant).map_err(|e| match e {
        KamaeError::UnknownTenant(m) => WireError::UnknownTenant(m),
        other => WireError::Internal(other.to_string()),
    })?;

    let parsed = Json::parse(body)
        .map_err(|e| WireError::BadRequest(format!("bad request JSON: {e}")))?;
    if parsed.as_object().is_none() {
        return Err(WireError::BadRequest("request body is not a JSON object".into()));
    }
    let variant = match parsed.get("variant") {
        None | Some(Json::Null) => None,
        Some(Json::Str(v)) => Some(v.clone()),
        Some(_) => return Err(WireError::BadRequest("'variant' must be a string".into())),
    };
    // per-request deadline; overrides BatchConfig::request_deadline
    let deadline = match parsed.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_i64()
                .filter(|n| *n >= 1)
                .map(|n| Duration::from_millis(n as u64))
                .ok_or_else(|| {
                    WireError::BadRequest("'deadline_ms' must be a positive integer".into())
                })?,
        ),
    };
    let rows = parsed
        .get("rows")
        .and_then(Json::as_array)
        .ok_or_else(|| WireError::BadRequest("request needs a 'rows' array of row objects".into()))?;
    if rows.is_empty() {
        return Err(WireError::BadRequest("'rows' is empty".into()));
    }
    if rows.len() > state.config.max_request_rows {
        return Err(WireError::OversizedBatch {
            rows: rows.len(),
            max_rows: state.config.max_request_rows,
        });
    }
    // resolve the variant up front so the error is typed 404, not a 500
    // bounced off the pool
    let out_idx: Vec<usize> = resolved
        .output_indices(variant.as_deref())
        .map_err(|e| match e {
            KamaeError::Serving(m) => WireError::UnknownVariant(m),
            other => WireError::Internal(other.to_string()),
        })?;
    let schema = resolved.schema().ok_or_else(|| {
        WireError::Internal(format!(
            "tenant '{tenant}' backend '{}' exposes no request schema",
            resolved.backend().name()
        ))
    })?;
    let n_rows = rows.len();
    // ingress gate: decode leniently, screen against the resolved
    // version's validation spec, quarantine instead of failing the
    // whole request. The spec is part of the TenantVersion snapshot,
    // so a deploy swapping the rules mid-request cannot mix rule sets.
    let vspec = if state.config.validate { resolved.validation() } else { None };
    let (df, mut report) = match vspec {
        Some(vspec) => {
            let (df, structural) = dataframe_from_json_rows_lenient(rows, schema)
                .map_err(|e| WireError::BadRequest(e.to_string()))?;
            let report = vspec
                .evaluate(&df, structural)
                .map_err(|e| WireError::Internal(e.to_string()))?;
            // rolling per-tenant quarantine rate: record EVERY screened
            // request (clean ones too) so the window decays again once
            // healthy traffic returns
            state
                .recorder
                .record_tenant_rows(tenant, n_rows as u64, report.num_quarantined() as u64);
            if report.num_quarantined() > 0 {
                // dead-letter the ORIGINAL wire rows — what the client
                // sent, not the lenient decode's nulled-out shadow
                if let Some(sink) = &state.dead_letter {
                    for i in report.quarantined() {
                        sink.record(tenant, &rows[i], &report.errors[i]);
                    }
                }
                state
                    .recorder
                    .record_quarantine(&report.rule_counts(), report.num_quarantined() as u64);
            }
            let clean = if report.num_quarantined() == 0 {
                df
            } else {
                df.filter_rows(&report.keep)
                    .map_err(|e| WireError::Internal(e.to_string()))?
            };
            (clean, Some(report))
        }
        None => {
            let df = dataframe_from_json_rows(rows, schema)
                .map_err(|e| WireError::BadRequest(e.to_string()))?;
            (df, None)
        }
    };
    let valid_rows = df.num_rows();
    // Submit-and-retry loop for poison containment: a PoisonRows answer
    // names rows of the SUBMITTED frame that bisection isolated (and
    // already dead-lettered). Fold them into the verdicts as
    // quarantined-with-`poison` and resubmit the survivors — the client
    // gets per-row blame plus outputs for everything servable, instead
    // of a whole-request 500. One round normally suffices (bisection
    // names every poison row in the job); the cap is a backstop.
    let (tensors, served_rows) = if valid_rows == 0 {
        // every row quarantined: nothing to serve, but the request is
        // still answered (verdicts itemise each row) and still billed
        (Vec::new(), 0)
    } else {
        let mut df = df;
        let mut attempts = 0;
        loop {
            // take the read lock only to enqueue; the response channel
            // outlives it. DataFrame clones are O(columns) Arc bumps,
            // so keeping `df` for a potential resubmit copies nothing.
            let rx = {
                let server = state.server.read().unwrap();
                let server = server.as_ref().ok_or(WireError::ShuttingDown)?;
                server.submit_resolved_deadline(
                    df.clone(),
                    variant.clone(),
                    Arc::clone(&resolved),
                    deadline,
                )
            };
            match rx.recv() {
                Ok(Ok(t)) => break (t, df.num_rows()),
                Ok(Err(KamaeError::PoisonRows(poison))) => {
                    attempts += 1;
                    if attempts >= 3 {
                        return Err(WireError::Internal(format!(
                            "poison-row isolation did not converge after {attempts} attempts"
                        )));
                    }
                    // synthesise an all-valid report when validation is
                    // off so poison responses still carry verdicts
                    let rep = report.get_or_insert_with(|| ValidationReport::all_valid(n_rows));
                    // poison indices address the submitted (compacted)
                    // frame; map them back to original wire rows through
                    // the keep mask before updating the verdicts
                    let orig: Vec<usize> = rep
                        .keep
                        .iter()
                        .enumerate()
                        .filter_map(|(i, &k)| k.then_some(i))
                        .collect();
                    let mut survivors = vec![true; df.num_rows()];
                    for &p in &poison {
                        let Some(&oi) = orig.get(p) else {
                            return Err(WireError::Internal(format!(
                                "poison row {p} out of range for a {}-row frame",
                                df.num_rows()
                            )));
                        };
                        rep.keep[oi] = false;
                        rep.errors[oi].push(RowError::new(
                            "poison",
                            "",
                            "row crashed the backend; isolated by bisection and dead-lettered",
                        ));
                        survivors[p] = false;
                    }
                    if rep.num_valid() == 0 {
                        break (Vec::new(), 0);
                    }
                    df = df
                        .filter_rows(&survivors)
                        .map_err(|e| WireError::Internal(e.to_string()))?;
                }
                Ok(Err(KamaeError::ShuttingDown)) => return Err(WireError::ShuttingDown),
                Ok(Err(KamaeError::DeadlineExceeded(m))) => {
                    return Err(WireError::DeadlineExceeded(m))
                }
                Ok(Err(e)) => return Err(WireError::Internal(e.to_string())),
                Err(_) => return Err(WireError::ShuttingDown),
            }
        }
    };
    let elapsed = t0.elapsed();
    match &variant {
        Some(v) => state.recorder.record_variant(v, elapsed),
        None => state.recorder.record(elapsed),
    }
    state.recorder.record_tenant(tenant, elapsed);
    state.accepted.fetch_add(1, Ordering::Relaxed);
    {
        let mut clients = state.clients.lock().unwrap();
        let c = clients.entry(&client);
        c.requests += 1;
        let ns = elapsed.as_nanos() as u64;
        c.latency_ns_sum += ns;
        c.latency_ns_max = c.latency_ns_max.max(ns);
    }
    if served_rows > 0 && tensors.len() != out_idx.len() {
        return Err(WireError::Internal(format!(
            "backend returned {} outputs, expected {}",
            tensors.len(),
            out_idx.len()
        )));
    }
    let outputs = resolved.outputs();
    let outs: Vec<Json> = tensors
        .iter()
        .zip(out_idx.iter())
        .map(|(t, &i)| tensor_to_json(&outputs[i], t))
        .collect();
    let mut resp = Json::object();
    resp.set("outputs", Json::Array(outs));
    resp.set("rows", n_rows);
    if let Some(report) = &report {
        resp.set("valid_rows", report.num_valid() as i64);
        resp.set("verdicts", report.verdicts_json());
    }
    if let Some(v) = &variant {
        resp.set("variant", v.clone());
    }
    Ok((200, Vec::new(), resp.to_string()))
}

/// Map a registry error onto the wire: lost CAS races are 409, unknown
/// tenants 404, anything else (bad spec, merge failure) a 400 — the
/// caller supplied it.
fn registry_wire_error(e: KamaeError) -> WireError {
    match e {
        KamaeError::VersionConflict(m) => WireError::VersionConflict(m),
        KamaeError::UnknownTenant(m) => WireError::UnknownTenant(m),
        other => WireError::BadRequest(other.to_string()),
    }
}

/// `POST /admin/deploy` — build (optimize → merge → compile) happens on
/// this connection thread, entirely off the swap path; in-flight
/// requests keep being served by the old version throughout.
fn handle_deploy(state: &NetState, body: &str) -> std::result::Result<Handled, WireError> {
    let parsed = Json::parse(body)
        .map_err(|e| WireError::BadRequest(format!("bad request JSON: {e}")))?;
    if parsed.as_object().is_none() {
        return Err(WireError::BadRequest("request body is not a JSON object".into()));
    }
    let tenant = parsed
        .get("tenant")
        .and_then(Json::as_str)
        .ok_or_else(|| WireError::BadRequest("deploy needs a 'tenant' string".into()))?
        .to_string();
    let spec_jsons: Vec<&Json> = match (parsed.get("spec"), parsed.get("specs")) {
        (Some(s), None) => vec![s],
        (None, Some(Json::Array(a))) if !a.is_empty() => a.iter().collect(),
        (None, Some(_)) => {
            return Err(WireError::BadRequest("'specs' must be a non-empty array".into()))
        }
        (Some(_), Some(_)) => {
            return Err(WireError::BadRequest("give either 'spec' or 'specs', not both".into()))
        }
        (None, None) => {
            return Err(WireError::BadRequest(
                "deploy needs a 'spec' object or a 'specs' array".into(),
            ))
        }
    };
    let mut specs = Vec::with_capacity(spec_jsons.len());
    for (i, j) in spec_jsons.iter().enumerate() {
        specs.push(GraphSpec::from_json(j).map_err(|e| {
            WireError::BadRequest(format!("spec {i} does not parse as a GraphSpec: {e}"))
        })?);
    }
    let expect_version = match parsed.get("expect_version") {
        None | Some(Json::Null) => None,
        Some(v) => Some(v.as_i64().filter(|n| *n >= 0).ok_or_else(|| {
            WireError::BadRequest("'expect_version' must be a non-negative integer".into())
        })? as u64),
    };
    let level = match parsed.get("level") {
        None | Some(Json::Null) => None,
        Some(Json::Str(s)) => Some(
            OptimizeLevel::parse(s).map_err(|e| WireError::BadRequest(e.to_string()))?,
        ),
        Some(_) => return Err(WireError::BadRequest("'level' must be a string".into())),
    };
    // declarative data-quality rules ride the deploy body and version
    // WITH the backend — a rollback reverts rules and model together
    let rules = match parsed.get("validation") {
        None | Some(Json::Null) => None,
        Some(v @ Json::Array(_)) => Some(v),
        Some(_) => {
            return Err(WireError::BadRequest(
                "'validation' must be an array of rule objects".into(),
            ))
        }
    };
    let summary = state
        .registry
        .deploy_specs_rules(&tenant, &specs, expect_version, level, rules)
        .map_err(registry_wire_error)?;
    let mut j = Json::object();
    j.set("status", "deployed");
    j.set("tenant", summary.tenant.as_str());
    j.set("version", summary.version as i64);
    j.set("backend", summary.backend.as_str());
    j.set("swap_ns", summary.swap.as_nanos() as i64);
    Ok((200, Vec::new(), j.to_string()))
}

/// `POST /admin/rollback` — swap back to a still-warm prior version
/// (the previous one, or `to_version` explicitly). No rebuild happens.
fn handle_rollback(state: &NetState, body: &str) -> std::result::Result<Handled, WireError> {
    let parsed = Json::parse(body)
        .map_err(|e| WireError::BadRequest(format!("bad request JSON: {e}")))?;
    if parsed.as_object().is_none() {
        return Err(WireError::BadRequest("request body is not a JSON object".into()));
    }
    let tenant = parsed
        .get("tenant")
        .and_then(Json::as_str)
        .ok_or_else(|| WireError::BadRequest("rollback needs a 'tenant' string".into()))?
        .to_string();
    let to_version = match parsed.get("to_version") {
        None | Some(Json::Null) => None,
        Some(v) => Some(v.as_i64().filter(|n| *n >= 1).ok_or_else(|| {
            WireError::BadRequest("'to_version' must be a positive integer".into())
        })? as u64),
    };
    let summary = state
        .registry
        .rollback(&tenant, to_version)
        .map_err(registry_wire_error)?;
    let mut j = Json::object();
    j.set("status", "rolled_back");
    j.set("tenant", summary.tenant.as_str());
    j.set("version", summary.version as i64);
    j.set("backend", summary.backend.as_str());
    j.set("swap_ns", summary.swap.as_nanos() as i64);
    Ok((200, Vec::new(), j.to_string()))
}

/// `GET /admin/tenants` — every tenant with its version history and
/// per-version request counts.
fn handle_tenants(state: &NetState) -> Handled {
    let mut j = Json::object();
    j.set(
        "tenants",
        Json::Array(state.registry.snapshot().iter().map(|s| s.to_json()).collect()),
    );
    (200, Vec::new(), j.to_string())
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write one framed HTTP/1.1 response.
fn write_response(
    stream: &mut TcpStream,
    status: u16,
    extra_headers: &[(String, String)],
    body: &str,
    close: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\ncontent-type: application/json\r\ncontent-length: {}\r\n",
        status,
        reason_phrase(status),
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    if close {
        head.push_str("connection: close\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Serialise one output tensor for the wire. `f32` values survive the
/// round trip bit-exactly: the JSON writer prints the shortest `f64`
/// representation, and every finite `f32` widens to `f64` and back
/// losslessly (non-finite values serialise as `null` — the differential
/// bench would fail loudly if a spec ever emitted them).
pub fn tensor_to_json(name: &str, t: &Tensor) -> Json {
    let data = match &t.data {
        TensorData::F32(v) => Json::Array(v.iter().map(|&x| Json::Float(f64::from(x))).collect()),
        TensorData::F64(v) => Json::Array(v.iter().map(|&x| Json::Float(x)).collect()),
        TensorData::I32(v) => Json::Array(v.iter().map(|&x| Json::Int(i64::from(x))).collect()),
        TensorData::I64(v) => Json::Array(v.iter().map(|&x| Json::Int(x)).collect()),
    };
    let mut j = Json::object();
    j.set("name", name);
    j.set("dtype", t.data.dtype_name());
    j.set(
        "shape",
        Json::Array(t.shape.iter().map(|&d| Json::Int(d as i64)).collect()),
    );
    j.set("data", data);
    j
}

/// Decode one wire tensor back into a [`Tensor`] — the inverse of
/// [`tensor_to_json`], used by the protocol tests and the closed-loop
/// bench to compare wire results bit-for-bit against the in-process
/// oracle.
pub fn tensor_from_json(j: &Json) -> Result<Tensor> {
    let dtype = j.req_str("dtype")?.to_string();
    let shape: Vec<usize> = j
        .req_array("shape")?
        .iter()
        .map(|d| {
            d.as_i64()
                .map(|x| x as usize)
                .ok_or_else(|| KamaeError::Serde("tensor shape entry is not an integer".into()))
        })
        .collect::<Result<_>>()?;
    let data = j.req_array("data")?;
    let num = |x: &Json| {
        x.as_f64()
            .ok_or_else(|| KamaeError::Serde("tensor data entry is not a number".into()))
    };
    let int = |x: &Json| {
        x.as_i64()
            .ok_or_else(|| KamaeError::Serde("tensor data entry is not an integer".into()))
    };
    match dtype.as_str() {
        "float32" => Tensor::f32(
            data.iter().map(|x| num(x).map(|v| v as f32)).collect::<Result<_>>()?,
            shape,
        ),
        "float64" => Tensor::f64(data.iter().map(num).collect::<Result<_>>()?, shape),
        "int32" => Tensor::i32(
            data.iter().map(|x| int(x).map(|v| v as i32)).collect::<Result<_>>()?,
            shape,
        ),
        "int64" => Tensor::i64(data.iter().map(int).collect::<Result<_>>()?, shape),
        other => Err(KamaeError::Serde(format!("unknown tensor dtype on the wire: {other}"))),
    }
}

/// A minimal blocking HTTP/1.1 client for the listener's protocol —
/// keep-alive aware, used by the protocol tests, the closed-loop bench,
/// and the CLI integration test (no external HTTP crates in the vendor
/// set).
pub struct NetClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

/// One parsed HTTP response.
#[derive(Debug, Clone)]
pub struct NetResponse {
    pub status: u16,
    /// Header names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: String,
    /// The server asked to close the connection (reconnect before the
    /// next request).
    pub closed: bool,
}

impl NetResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn json(&self) -> Result<Json> {
        Json::parse(&self.body)
    }
}

impl NetClient {
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(NetClient { stream, reader })
    }

    /// Issue one request and block for the response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &str,
    ) -> Result<NetResponse> {
        let mut req = format!(
            "{method} {path} HTTP/1.1\r\nhost: kamae\r\ncontent-length: {}\r\n",
            body.len()
        );
        for (k, v) in headers {
            req.push_str(&format!("{k}: {v}\r\n"));
        }
        req.push_str("\r\n");
        self.stream.write_all(req.as_bytes())?;
        self.stream.write_all(body.as_bytes())?;
        self.stream.flush()?;

        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(KamaeError::Serving("connection closed before response".into()));
        }
        let status: u16 = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| KamaeError::Serving(format!("malformed status line: {line:?}")))?;
        let mut resp_headers = Vec::new();
        let mut content_length = 0usize;
        let mut closed = false;
        loop {
            let mut h = String::new();
            if self.reader.read_line(&mut h)? == 0 {
                return Err(KamaeError::Serving("connection closed mid-response".into()));
            }
            let h = h.trim();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                let k = k.trim().to_ascii_lowercase();
                let v = v.trim().to_string();
                if k == "content-length" {
                    content_length = v.parse().unwrap_or(0);
                }
                if k == "connection" && v.eq_ignore_ascii_case("close") {
                    closed = true;
                }
                resp_headers.push((k, v));
            }
        }
        let mut body_buf = vec![0u8; content_length];
        self.reader.read_exact(&mut body_buf)?;
        let body = String::from_utf8(body_buf)
            .map_err(|_| KamaeError::Serving("response body is not UTF-8".into()))?;
        Ok(NetResponse { status, headers: resp_headers, body, closed })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_errors_map_to_status_and_code() {
        let cases: Vec<(WireError, u16, &str)> = vec![
            (WireError::BadRequest("x".into()), 400, "bad_request"),
            (WireError::NotFound("x".into()), 404, "not_found"),
            (WireError::MethodNotAllowed("x".into()), 405, "method_not_allowed"),
            (WireError::UnknownVariant("x".into()), 404, "unknown_variant"),
            (WireError::UnknownTenant("x".into()), 404, "unknown_tenant"),
            (WireError::VersionConflict("x".into()), 409, "version_conflict"),
            (WireError::OversizedBatch { rows: 9, max_rows: 4 }, 413, "oversized_batch"),
            (WireError::OversizedBody { bytes: 9, max_bytes: 4 }, 413, "oversized_body"),
            (WireError::Overloaded { retry_after_secs: 1 }, 429, "overloaded"),
            (WireError::ShuttingDown, 503, "shutting_down"),
            (WireError::DeadlineExceeded("x".into()), 504, "deadline_exceeded"),
            (WireError::Internal("x".into()), 500, "internal"),
        ];
        for (e, status, code) in cases {
            assert_eq!(e.status(), status, "{code}");
            assert_eq!(e.code(), code);
            let body = Json::parse(&e.to_body()).unwrap();
            let err = body.get("error").unwrap();
            assert_eq!(err.req_str("code").unwrap(), code);
            assert_eq!(err.req_i64("status").unwrap(), i64::from(status));
            assert!(!err.req_str("message").unwrap().is_empty());
        }
        // only sheds carry the Retry-After hint
        let shed = WireError::Overloaded { retry_after_secs: 3 };
        assert_eq!(
            shed.extra_headers(),
            vec![("Retry-After".to_string(), "3".to_string())]
        );
        assert!(WireError::ShuttingDown.extra_headers().is_empty());
    }

    #[test]
    fn net_config_rejects_unserveable_windows() {
        assert!(NetConfig::default().validate().is_ok());
        for broken in [
            NetConfig { admission: 0, ..NetConfig::default() },
            NetConfig { max_request_rows: 0, ..NetConfig::default() },
            NetConfig { max_body_bytes: 0, ..NetConfig::default() },
            // a dead-letter path with the gate off would silently never
            // receive a row
            NetConfig {
                dead_letter: Some(PathBuf::from("/tmp/dl.jsonl")),
                ..NetConfig::default()
            },
            // alert thresholds must be meaningful fractions, and need
            // the gate on to ever observe a quarantine
            NetConfig { validate: true, quarantine_alert: Some(0.0), ..NetConfig::default() },
            NetConfig { validate: true, quarantine_alert: Some(1.5), ..NetConfig::default() },
            NetConfig { quarantine_alert: Some(0.5), ..NetConfig::default() },
        ] {
            assert!(broken.validate().is_err());
        }
        // the pairs are fine together
        let ok = NetConfig {
            validate: true,
            dead_letter: Some(PathBuf::from("/tmp/dl.jsonl")),
            quarantine_alert: Some(0.25),
            ..NetConfig::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn retry_after_hint_tracks_queue_drain_time() {
        // no load signal → the configured floor, the old constant hint
        assert_eq!(retry_after_hint(0, 100.0, 1), 1);
        assert_eq!(retry_after_hint(50, 0.0, 1), 1);
        assert_eq!(retry_after_hint(50, f64::NAN, 3), 3);
        // queue of 50 draining at 10/s → 5 s to clear
        assert_eq!(retry_after_hint(50, 10.0, 1), 5);
        // partial seconds round UP — never invite a retry into a still-
        // full queue
        assert_eq!(retry_after_hint(11, 10.0, 1), 2);
        // a fast drain never hints below the floor
        assert_eq!(retry_after_hint(3, 1000.0, 2), 2);
        // a glacial drain is capped: beyond a minute the number is
        // guesswork
        assert_eq!(retry_after_hint(10_000, 0.5, 1), 60);
        // a floor above the cap wins (operator said so explicitly)
        assert_eq!(retry_after_hint(10_000, 0.5, 90), 90);
    }

    #[test]
    fn tensor_json_round_trip_is_bit_exact() {
        let cases = vec![
            Tensor::f32(vec![1.5, -0.125, 3.0, f32::MIN_POSITIVE], vec![4]).unwrap(),
            Tensor::f64(vec![2.0, 1e-300, -7.25], vec![3]).unwrap(),
            Tensor::i32(vec![1, -2, 3, 4], vec![2, 2]).unwrap(),
            Tensor::i64(vec![i64::MAX, i64::MIN, 0], vec![3]).unwrap(),
        ];
        for t in cases {
            let j = tensor_to_json("out", &t);
            assert_eq!(j.req_str("name").unwrap(), "out");
            assert_eq!(j.req_str("dtype").unwrap(), t.data.dtype_name());
            // through the writer + parser, exactly as the wire sees it
            let reparsed = Json::parse(&j.to_string()).unwrap();
            let back = tensor_from_json(&reparsed).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn reason_phrases_cover_every_wire_status() {
        for status in [200u16, 400, 404, 405, 409, 413, 429, 500, 503, 504] {
            assert_ne!(reason_phrase(status), "Unknown", "{status}");
        }
    }

    // ---- bounded per-client counter table ----

    #[test]
    fn client_table_evicts_least_recent_into_rollup() {
        let mut t = ClientTable::new(2);
        t.entry("a").requests = 5;
        t.entry("a").latency_ns_sum = 500;
        t.entry("a").latency_ns_max = 120;
        t.entry("b").requests = 3;
        t.entry("b").shed = 2;
        t.entry("b").latency_ns_max = 90;
        // touching "a" makes "b" the LRU victim when "c" arrives
        t.entry("a").requests += 1;
        t.entry("c").requests = 1;
        assert!(t.clients.contains_key("a"));
        assert!(t.clients.contains_key("c"));
        assert!(!t.clients.contains_key("b"));
        assert_eq!(t.evicted, 1);
        // b's counters folded into the rollup — totals conserved
        assert_eq!(t.other.requests, 3);
        assert_eq!(t.other.shed, 2);
        assert_eq!(t.other.latency_ns_max, 90);
        let live: u64 = t.clients.values().map(|e| e.stats.requests).sum();
        assert_eq!(live + t.other.requests, 5 + 1 + 3 + 1);
        // a second eviction maxes, not overwrites, the rollup's max
        t.entry("d").requests = 1;
        assert_eq!(t.evicted, 2);
        assert_eq!(t.other.latency_ns_max, 120);
        assert_eq!(t.other.requests, 3 + 6);
        assert_eq!(t.clients.len(), 2);
    }

    #[test]
    fn client_table_reinserted_id_starts_fresh() {
        let mut t = ClientTable::new(1);
        t.entry("a").requests = 7;
        t.entry("b").requests = 1; // evicts a
        t.entry("a").requests += 1; // evicts b; a re-enters empty
        assert_eq!(t.evicted, 2);
        assert_eq!(t.clients.get("a").unwrap().stats.requests, 1);
        assert_eq!(t.other.requests, 7 + 1);
    }
}
