//! Ingress data-quality gate: declarative per-row validation with
//! quarantine.
//!
//! Real serving traffic is malformed in ways training data never is.
//! Before this gate, one bad row either failed the whole request (the
//! strict request decoder) or was silently coerced into a wrong
//! prediction (the lenient file reader). The gate takes the third road:
//! a [`ValidationSpec`] — derived automatically from the spec's input
//! schema, plus declarative per-tenant rules attached at deploy time —
//! is evaluated columnar-mask-style over the decoded batch, producing a
//! per-row verdict mask. Invalid rows are quarantined: the batch is
//! compacted ([`DataFrame::filter_rows`]) and served without them,
//! responses carry per-row verdicts with structured [`RowError`]s, and
//! the quarantined rows land in a pluggable [`DeadLetterSink`].
//!
//! Evaluation reuses the kernel program's null-bitmask machinery: the
//! union of the required columns' null masks ([`union_null_masks`]) IS
//! the not-null violation pre-mask, so a clean batch (no masks anywhere)
//! costs one allocation-free fold plus a handful of columnar scans.

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::dataframe::{union_null_masks, Column, DataFrame, DType, Schema};
use crate::error::{KamaeError, Result};
use crate::util::json::Json;

pub use crate::dataframe::RowError;

// ---------------------------------------------------------------------------
// rules

/// One declarative validation rule. `NotNull` rules are derived
/// automatically from the input schema; the rest attach per tenant at
/// deploy time (`"validation"` array in the deploy body, `--rules` on
/// the CLI).
#[derive(Debug, Clone, PartialEq)]
pub enum Rule {
    /// The column must not be null (schema-derived; every spec input is
    /// a feature the graph reads).
    NotNull { column: String },
    /// Numeric column value must lie in `[min, max]` (either bound
    /// optional, inclusive).
    Range { column: String, min: Option<f64>, max: Option<f64> },
    /// String column value must be one of the allowed set.
    OneOf { column: String, values: Vec<String> },
    /// String column value must match the (anchored) pattern.
    Pattern { column: String, pattern: String },
}

impl Rule {
    /// The rule identifier used in [`RowError::rule`] and the per-rule
    /// violation counters.
    pub fn name(&self) -> &'static str {
        match self {
            Rule::NotNull { .. } => "not_null",
            Rule::Range { .. } => "range",
            Rule::OneOf { .. } => "one_of",
            Rule::Pattern { .. } => "pattern",
        }
    }

    pub fn column(&self) -> &str {
        match self {
            Rule::NotNull { column }
            | Rule::Range { column, .. }
            | Rule::OneOf { column, .. }
            | Rule::Pattern { column, .. } => column,
        }
    }

    /// Declarative JSON shape (the deploy-body format, round-trippable).
    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("rule", self.name());
        j.set("column", self.column().to_string());
        match self {
            Rule::NotNull { .. } => {}
            Rule::Range { min, max, .. } => {
                if let Some(m) = min {
                    j.set("min", *m);
                }
                if let Some(m) = max {
                    j.set("max", *m);
                }
            }
            Rule::OneOf { values, .. } => {
                j.set(
                    "values",
                    Json::Array(values.iter().map(|v| Json::Str(v.clone())).collect()),
                );
            }
            Rule::Pattern { pattern, .. } => {
                j.set("pattern", pattern.clone());
            }
        }
        j
    }
}

// ---------------------------------------------------------------------------
// pattern matching (std-only regex subset)

/// Anchored pattern matcher over a regex subset: literals, `.`, `*`,
/// `+`, `?`, character classes `[a-z0-9_]` (with `^` negation), the
/// escapes `\d` `\w` `\s` and escaped metacharacters, and top-level
/// alternation `|`. Patterns match the ENTIRE value (an implicit
/// `^...$`); explicit leading `^` / trailing `$` anchors are accepted
/// and ignored. No groups — rule patterns are column formats
/// (`"city_[0-9]+"`), not parsers.
#[derive(Debug, Clone, PartialEq)]
struct Pattern {
    alts: Vec<Vec<Piece>>,
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Lit(char),
    Any,
    Digit,
    Word,
    Space,
    Class { neg: bool, items: Vec<ClassItem> },
}

#[derive(Debug, Clone, PartialEq)]
enum ClassItem {
    Ch(char),
    Range(char, char),
}

#[derive(Debug, Clone, PartialEq)]
enum Piece {
    One(Tok),
    Opt(Tok),
    Star(Tok),
    Plus(Tok),
}

impl Pattern {
    fn parse(pattern: &str) -> Result<Pattern> {
        let bad = |msg: &str| {
            KamaeError::InvalidConfig(format!("invalid validation pattern '{pattern}': {msg}"))
        };
        // split on top-level '|' (escapes and classes shield the bar)
        let chars: Vec<char> = pattern.chars().collect();
        let mut alts_src: Vec<Vec<char>> = vec![Vec::new()];
        let mut in_class = false;
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            match c {
                '\\' => {
                    if i + 1 >= chars.len() {
                        return Err(bad("dangling escape"));
                    }
                    alts_src.last_mut().unwrap().push(c);
                    alts_src.last_mut().unwrap().push(chars[i + 1]);
                    i += 2;
                    continue;
                }
                '[' if !in_class => {
                    in_class = true;
                    alts_src.last_mut().unwrap().push(c);
                }
                ']' if in_class => {
                    in_class = false;
                    alts_src.last_mut().unwrap().push(c);
                }
                '|' if !in_class => alts_src.push(Vec::new()),
                _ => alts_src.last_mut().unwrap().push(c),
            }
            i += 1;
        }
        if in_class {
            return Err(bad("unclosed character class"));
        }
        let mut alts = Vec::with_capacity(alts_src.len());
        for src in &alts_src {
            // strip the redundant explicit anchors (matching is anchored)
            let mut s: &[char] = src;
            if s.first() == Some(&'^') {
                s = &s[1..];
            }
            if s.last() == Some(&'$') && !s.ends_with(&['\\', '$']) {
                s = &s[..s.len() - 1];
            }
            alts.push(Self::parse_alt(s, &bad)?);
        }
        Ok(Pattern { alts })
    }

    fn parse_alt(s: &[char], bad: &dyn Fn(&str) -> KamaeError) -> Result<Vec<Piece>> {
        let mut pieces = Vec::new();
        let mut i = 0;
        while i < s.len() {
            let (tok, next) = match s[i] {
                '.' => (Tok::Any, i + 1),
                '\\' => {
                    let e = *s.get(i + 1).ok_or_else(|| bad("dangling escape"))?;
                    let tok = match e {
                        'd' => Tok::Digit,
                        'w' => Tok::Word,
                        's' => Tok::Space,
                        _ => Tok::Lit(e),
                    };
                    (tok, i + 2)
                }
                '[' => {
                    let close = s[i + 1..]
                        .iter()
                        .position(|&c| c == ']')
                        .ok_or_else(|| bad("unclosed character class"))?;
                    let body = &s[i + 1..i + 1 + close];
                    let (neg, body) = if body.first() == Some(&'^') {
                        (true, &body[1..])
                    } else {
                        (false, body)
                    };
                    if body.is_empty() {
                        return Err(bad("empty character class"));
                    }
                    let mut items = Vec::new();
                    let mut k = 0;
                    while k < body.len() {
                        if k + 2 < body.len() && body[k + 1] == '-' {
                            items.push(ClassItem::Range(body[k], body[k + 2]));
                            k += 3;
                        } else {
                            items.push(ClassItem::Ch(body[k]));
                            k += 1;
                        }
                    }
                    (Tok::Class { neg, items }, i + 2 + close)
                }
                '*' | '+' | '?' => return Err(bad("quantifier with nothing to repeat")),
                ']' => return Err(bad("unmatched ']'")),
                c => (Tok::Lit(c), i + 1),
            };
            let piece = match s.get(next) {
                Some('?') => Piece::Opt(tok),
                Some('*') => Piece::Star(tok),
                Some('+') => Piece::Plus(tok),
                _ => {
                    pieces.push(Piece::One(tok));
                    i = next;
                    continue;
                }
            };
            pieces.push(piece);
            i = next + 1;
        }
        Ok(pieces)
    }

    fn matches(&self, text: &str) -> bool {
        let chars: Vec<char> = text.chars().collect();
        self.alts.iter().any(|alt| match_here(alt, &chars))
    }
}

fn tok_match(t: &Tok, c: char) -> bool {
    match t {
        Tok::Lit(l) => *l == c,
        Tok::Any => true,
        Tok::Digit => c.is_ascii_digit(),
        Tok::Word => c.is_ascii_alphanumeric() || c == '_',
        Tok::Space => c.is_whitespace(),
        Tok::Class { neg, items } => {
            let hit = items.iter().any(|it| match it {
                ClassItem::Ch(x) => *x == c,
                ClassItem::Range(a, b) => (*a..=*b).contains(&c),
            });
            hit != *neg
        }
    }
}

fn match_here(pieces: &[Piece], text: &[char]) -> bool {
    let Some(first) = pieces.first() else {
        return text.is_empty();
    };
    let rest = &pieces[1..];
    match first {
        Piece::One(t) => !text.is_empty() && tok_match(t, text[0]) && match_here(rest, &text[1..]),
        Piece::Opt(t) => {
            match_here(rest, text)
                || (!text.is_empty() && tok_match(t, text[0]) && match_here(rest, &text[1..]))
        }
        Piece::Star(t) | Piece::Plus(t) => {
            let floor = if matches!(first, Piece::Plus(_)) { 1 } else { 0 };
            let mut k = 0;
            while k < text.len() && tok_match(t, text[k]) {
                k += 1;
            }
            // greedy with backtracking: longest take first
            loop {
                if k < floor {
                    return false;
                }
                if match_here(rest, &text[k..]) {
                    return true;
                }
                if k == 0 {
                    return false;
                }
                k -= 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the spec

/// A compiled set of validation rules for one tenant version: the
/// schema-derived not-null checks plus any deploy-time declarative
/// rules, with patterns parsed once at build time.
#[derive(Debug, Clone)]
pub struct ValidationSpec {
    rules: Vec<Rule>,
    /// Parsed matcher per rule index (only `Pattern` rules occupy slots).
    matchers: Vec<Option<Pattern>>,
    /// `OneOf` membership sets per rule index.
    sets: Vec<Option<HashSet<String>>>,
}

impl ValidationSpec {
    /// Schema-derived baseline: every input column is a feature the
    /// graph reads, so every one gets a not-null rule. Dtype/castability
    /// is enforced upstream by the lenient decoder
    /// ([`crate::dataframe::dataframe_from_json_rows_lenient`]), whose
    /// structural `RowError`s merge into the same verdicts.
    pub fn from_schema(schema: &Schema) -> ValidationSpec {
        let rules = schema
            .fields
            .iter()
            .map(|f| Rule::NotNull { column: f.name.clone() })
            .collect();
        Self::compile(rules).expect("not-null rules always compile")
    }

    /// Schema baseline plus declarative extra rules from a deploy-time
    /// JSON array (see [`Rule::to_json`] for the shape). Unknown rule
    /// names, unknown columns and dtype-incompatible rules are
    /// configuration errors — a deploy with a bad rule set is refused.
    pub fn from_json(extra: &Json, schema: &Schema) -> Result<ValidationSpec> {
        let mut rules: Vec<Rule> = schema
            .fields
            .iter()
            .map(|f| Rule::NotNull { column: f.name.clone() })
            .collect();
        let arr = extra.as_array().ok_or_else(|| {
            KamaeError::InvalidConfig("validation rules must be a JSON array".into())
        })?;
        for (i, r) in arr.iter().enumerate() {
            let bad = |msg: String| KamaeError::InvalidConfig(format!("validation rule {i}: {msg}"));
            let name = r
                .opt_str("rule")
                .ok_or_else(|| bad("missing 'rule'".into()))?;
            let column = r
                .opt_str("column")
                .ok_or_else(|| bad("missing 'column'".into()))?
                .to_string();
            let dtype = schema
                .dtype(&column)
                .ok_or_else(|| {
                    bad(format!(
                        "unknown column '{column}' (schema columns: {})",
                        schema.names().join(", ")
                    ))
                })?
                .clone();
            let rule = match name {
                "not_null" => Rule::NotNull { column },
                "range" => {
                    if !dtype.is_numeric() {
                        return Err(bad(format!(
                            "range rule on non-numeric column '{column}' ({})",
                            dtype.name()
                        )));
                    }
                    let min = r.opt_f64("min");
                    let max = r.opt_f64("max");
                    if min.is_none() && max.is_none() {
                        return Err(bad("range rule needs 'min' and/or 'max'".into()));
                    }
                    Rule::Range { column, min, max }
                }
                "one_of" => {
                    if dtype != DType::Str {
                        return Err(bad(format!(
                            "one_of rule on non-string column '{column}' ({})",
                            dtype.name()
                        )));
                    }
                    let values: Vec<String> = r
                        .get("values")
                        .and_then(Json::as_array)
                        .map(|a| a.iter().filter_map(Json::as_str).map(str::to_string).collect())
                        .unwrap_or_default();
                    if values.is_empty() {
                        return Err(bad("one_of rule needs a non-empty 'values' array".into()));
                    }
                    Rule::OneOf { column, values }
                }
                "pattern" => {
                    if dtype != DType::Str {
                        return Err(bad(format!(
                            "pattern rule on non-string column '{column}' ({})",
                            dtype.name()
                        )));
                    }
                    let pattern = r
                        .opt_str("pattern")
                        .ok_or_else(|| bad("pattern rule needs 'pattern'".into()))?
                        .to_string();
                    Rule::Pattern { column, pattern }
                }
                other => return Err(bad(format!("unknown rule '{other}'"))),
            };
            rules.push(rule);
        }
        Self::compile(rules)
    }

    /// Build from an explicit rule list (tests, embedded use).
    pub fn compile(rules: Vec<Rule>) -> Result<ValidationSpec> {
        let mut matchers = Vec::with_capacity(rules.len());
        let mut sets = Vec::with_capacity(rules.len());
        for r in &rules {
            matchers.push(match r {
                Rule::Pattern { pattern, .. } => Some(Pattern::parse(pattern)?),
                _ => None,
            });
            sets.push(match r {
                Rule::OneOf { values, .. } => Some(values.iter().cloned().collect()),
                _ => None,
            });
        }
        Ok(ValidationSpec { rules, matchers, sets })
    }

    pub fn rules(&self) -> &[Rule] {
        &self.rules
    }

    /// Number of deploy-time rules beyond the schema-derived baseline.
    pub fn num_extra_rules(&self) -> usize {
        self.rules
            .iter()
            .filter(|r| !matches!(r, Rule::NotNull { .. }))
            .count()
    }

    /// Declarative JSON array of every rule (snapshot/debug surface).
    pub fn to_json(&self) -> Json {
        Json::Array(self.rules.iter().map(Rule::to_json).collect())
    }

    /// Evaluate all rules columnar-mask-style over a decoded batch and
    /// merge in `structural` errors from the lenient decoder (may be
    /// empty). Returns the per-row verdicts. Columns a rule names that
    /// are absent from the frame are configuration drift and error out —
    /// the spec is built against the same schema the decoder used, so
    /// this cannot happen on the serving path.
    pub fn evaluate(
        &self,
        df: &DataFrame,
        structural: Vec<Vec<RowError>>,
    ) -> Result<ValidationReport> {
        let nrows = df.num_rows();
        let mut errors = structural;
        if errors.len() != nrows {
            if !errors.is_empty() {
                return Err(KamaeError::LengthMismatch {
                    left: errors.len(),
                    right: nrows,
                    context: "ValidationSpec::evaluate structural errors".into(),
                });
            }
            errors = vec![Vec::new(); nrows];
        }

        // not-null rules first, via the kernel machinery: the union of
        // the required columns' masks is the violation pre-mask. A clean
        // batch short-circuits without touching a single row.
        let not_null: Vec<&str> = self
            .rules
            .iter()
            .filter_map(|r| match r {
                Rule::NotNull { column } => Some(column.as_str()),
                _ => None,
            })
            .collect();
        let mut masks: Vec<Option<&[bool]>> = Vec::with_capacity(not_null.len());
        for col in &not_null {
            masks.push(df.column(col)?.nulls().map(|v| v.as_slice()));
        }
        if union_null_masks(&masks).is_some() {
            for (col, mask) in not_null.iter().zip(&masks) {
                let Some(mask) = mask else { continue };
                for (i, &null) in mask.iter().enumerate() {
                    if null {
                        errors[i].push(RowError::new(
                            "not_null",
                            *col,
                            format!("null value in required column '{col}'"),
                        ));
                    }
                }
            }
        }

        for (idx, rule) in self.rules.iter().enumerate() {
            match rule {
                Rule::NotNull { .. } => {} // handled above
                Rule::Range { column, min, max } => {
                    let col = df.column(column)?;
                    let check = |i: usize, v: f64, errors: &mut Vec<Vec<RowError>>| {
                        if col.is_null(i) {
                            return;
                        }
                        if let Some(lo) = min {
                            if v < *lo {
                                errors[i].push(RowError::new(
                                    "range",
                                    column.as_str(),
                                    format!("{column} value {v} below minimum {lo}"),
                                ));
                                return;
                            }
                        }
                        if let Some(hi) = max {
                            if v > *hi {
                                errors[i].push(RowError::new(
                                    "range",
                                    column.as_str(),
                                    format!("{column} value {v} above maximum {hi}"),
                                ));
                            }
                        }
                    };
                    match col {
                        Column::F64(v, _) => {
                            for (i, &x) in v.iter().enumerate() {
                                check(i, x, &mut errors);
                            }
                        }
                        Column::F32(v, _) => {
                            for (i, &x) in v.iter().enumerate() {
                                check(i, x as f64, &mut errors);
                            }
                        }
                        Column::I64(v, _) => {
                            for (i, &x) in v.iter().enumerate() {
                                check(i, x as f64, &mut errors);
                            }
                        }
                        Column::I32(v, _) => {
                            for (i, &x) in v.iter().enumerate() {
                                check(i, x as f64, &mut errors);
                            }
                        }
                        other => {
                            return Err(KamaeError::TypeMismatch {
                                expected: "numeric column".into(),
                                found: other.dtype().name(),
                                context: format!("range rule on '{column}'"),
                            })
                        }
                    }
                }
                Rule::OneOf { column, .. } => {
                    let set = self.sets[idx].as_ref().expect("compiled one_of");
                    let col = df.column(column)?;
                    for (i, v) in col.as_str()?.iter().enumerate() {
                        if !col.is_null(i) && !set.contains(v) {
                            errors[i].push(RowError::new(
                                "one_of",
                                column.as_str(),
                                format!("{column} value '{v}' not in the allowed set"),
                            ));
                        }
                    }
                }
                Rule::Pattern { column, pattern } => {
                    let matcher = self.matchers[idx].as_ref().expect("compiled pattern");
                    let col = df.column(column)?;
                    for (i, v) in col.as_str()?.iter().enumerate() {
                        if !col.is_null(i) && !matcher.matches(v) {
                            errors[i].push(RowError::new(
                                "pattern",
                                column.as_str(),
                                format!("{column} value '{v}' does not match pattern '{pattern}'"),
                            ));
                        }
                    }
                }
            }
        }

        let keep: Vec<bool> = errors.iter().map(Vec::is_empty).collect();
        Ok(ValidationReport { keep, errors })
    }
}

// ---------------------------------------------------------------------------
// verdicts

/// Per-row verdicts for one batch: `keep[i]` is true when row `i` passed
/// every rule; `errors[i]` holds the structured violations otherwise.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub keep: Vec<bool>,
    pub errors: Vec<Vec<RowError>>,
}

impl ValidationReport {
    /// A report that keeps every row (validation disabled / no rules).
    pub fn all_valid(nrows: usize) -> ValidationReport {
        ValidationReport { keep: vec![true; nrows], errors: vec![Vec::new(); nrows] }
    }

    pub fn num_rows(&self) -> usize {
        self.keep.len()
    }

    pub fn num_valid(&self) -> usize {
        self.keep.iter().filter(|&&k| k).count()
    }

    pub fn num_quarantined(&self) -> usize {
        self.keep.len() - self.num_valid()
    }

    /// Indices of quarantined rows, in original order.
    pub fn quarantined(&self) -> Vec<usize> {
        self.keep
            .iter()
            .enumerate()
            .filter(|(_, &k)| !k)
            .map(|(i, _)| i)
            .collect()
    }

    /// Violation count per rule name (feeds the `ServeReport` /
    /// `/metrics` counters).
    pub fn rule_counts(&self) -> BTreeMap<String, u64> {
        let mut counts = BTreeMap::new();
        for errs in &self.errors {
            for e in errs {
                *counts.entry(e.rule.clone()).or_insert(0u64) += 1;
            }
        }
        counts
    }

    /// The wire shape of the per-row verdicts, re-expanded to ORIGINAL
    /// row order: every input row gets an entry; valid rows carry the
    /// row index they occupy in the compacted outputs, quarantined rows
    /// carry their structured errors.
    pub fn verdicts_json(&self) -> Json {
        let mut out = Vec::with_capacity(self.keep.len());
        let mut output_row = 0usize;
        for (i, &keep) in self.keep.iter().enumerate() {
            let mut v = Json::object();
            v.set("row", i as i64);
            if keep {
                v.set("status", "ok");
                v.set("output_row", output_row as i64);
                output_row += 1;
            } else {
                v.set("status", "quarantined");
                v.set(
                    "errors",
                    Json::Array(self.errors[i].iter().map(RowError::to_json).collect()),
                );
            }
            out.push(v);
        }
        Json::Array(out)
    }
}

/// Evaluate `spec` over a decoded batch (merging the lenient decoder's
/// structural errors) and compact away the quarantined rows: the
/// returned frame holds exactly the valid rows, in original relative
/// order; the report maps them back. This is THE ingress gate both the
/// HTTP front-end and the embedded server API call.
pub fn screen_batch(
    spec: &ValidationSpec,
    df: &DataFrame,
    structural: Vec<Vec<RowError>>,
) -> Result<(DataFrame, ValidationReport)> {
    let report = spec.evaluate(df, structural)?;
    let clean = if report.num_valid() == report.num_rows() {
        df.clone() // clean fast path: O(columns) Arc bumps, no copy
    } else {
        df.filter_rows(&report.keep)?
    };
    Ok((clean, report))
}

// ---------------------------------------------------------------------------
// dead-letter sinks

/// Where quarantined rows go instead of the model. Implementations must
/// be cheap and non-blocking-ish: the sink sits on the serving path
/// (after the shed gate, before the batcher). Failures are swallowed —
/// a broken dead-letter store must never take serving down with it.
pub trait DeadLetterSink: Send + Sync {
    /// Record one quarantined row with its violations.
    fn record(&self, tenant: &str, row: &Json, errors: &[RowError]);

    /// Rows this sink failed to persist (disk full, unwritable file).
    /// Serving must be unaffected by sink failures — the counter is how
    /// operators find out rows are being dropped. Sinks that cannot
    /// fail report 0.
    fn errors(&self) -> u64 {
        0
    }
}

/// The JSONL entry shape shared by every sink:
/// `{"tenant": ..., "row": {...}, "errors": [{rule, column, message}]}`.
pub fn dead_letter_entry(tenant: &str, row: &Json, errors: &[RowError]) -> Json {
    let mut j = Json::object();
    j.set("tenant", tenant.to_string());
    j.set("row", row.clone());
    j.set("errors", Json::Array(errors.iter().map(RowError::to_json).collect()));
    j
}

/// Append-only JSONL file sink (`--dead-letter PATH`): one entry per
/// quarantined row, inspectable with `jq`/`grep` and replayable through
/// the offline readers once fixed.
pub struct JsonlDeadLetter {
    path: PathBuf,
    file: Mutex<std::fs::File>,
    /// Entries the file refused (ENOSPC, permissions yanked mid-run).
    /// A failing disk must never fail or block serving; the counter —
    /// surfaced as `dead_letter_errors` in `/metrics` — is the alarm.
    write_errors: std::sync::atomic::AtomicU64,
}

impl JsonlDeadLetter {
    /// Open (append) or create the file.
    pub fn create(path: &Path) -> Result<JsonlDeadLetter> {
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlDeadLetter {
            path: path.to_path_buf(),
            file: Mutex::new(file),
            write_errors: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl DeadLetterSink for JsonlDeadLetter {
    fn record(&self, tenant: &str, row: &Json, errors: &[RowError]) {
        let entry = dead_letter_entry(tenant, row, errors);
        let mut file = match self.file.lock() {
            Ok(f) => f,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Err(e) = writeln!(file, "{entry}") {
            self.write_errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            eprintln!("dead-letter write to {} failed: {e}", self.path.display());
        }
    }

    fn errors(&self) -> u64 {
        self.write_errors.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// Bounded in-memory ring sink for tests and embedded use: keeps the
/// most recent `cap` entries.
pub struct MemoryDeadLetter {
    cap: usize,
    ring: Mutex<VecDeque<Json>>,
}

impl MemoryDeadLetter {
    pub fn new(cap: usize) -> MemoryDeadLetter {
        MemoryDeadLetter { cap: cap.max(1), ring: Mutex::new(VecDeque::new()) }
    }

    /// Snapshot of the retained entries, oldest first.
    pub fn entries(&self) -> Vec<Json> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl DeadLetterSink for MemoryDeadLetter {
    fn record(&self, tenant: &str, row: &Json, errors: &[RowError]) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(dead_letter_entry(tenant, row, errors));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Field;

    fn schema() -> Schema {
        Schema {
            fields: vec![
                Field { name: "price".into(), dtype: DType::F64 },
                Field { name: "city".into(), dtype: DType::Str },
            ],
        }
    }

    #[test]
    fn pattern_subset_semantics() {
        let m = |p: &str, s: &str| Pattern::parse(p).unwrap().matches(s);
        // anchored full match
        assert!(m("abc", "abc"));
        assert!(!m("abc", "xabc"));
        assert!(!m("abc", "abcx"));
        // explicit anchors are accepted and redundant
        assert!(m("^abc$", "abc"));
        // quantifiers + classes + escapes
        assert!(m("city_[0-9]+", "city_42"));
        assert!(!m("city_[0-9]+", "city_"));
        assert!(m("a.c", "axc"));
        assert!(m("ab?c", "ac") && m("ab?c", "abc"));
        assert!(m("a*", "") && m("a*", "aaa") && !m("a*", "b"));
        assert!(m(r"\d\d-\w+", "42-x_9"));
        assert!(m(r"[^0-9]+", "abc") && !m(r"[^0-9]+", "a1"));
        assert!(m(r"a\.b", "a.b") && !m(r"a\.b", "axb"));
        // alternation
        assert!(m("cat|dog", "dog") && !m("cat|dog", "cow"));
        assert!(m("[a|b]", "|"), "class shields the bar");
        // star needs backtracking: .* must give back for the suffix
        assert!(m(".*x", "aax") && !m(".*x", "aay"));
        // parse errors, not panics
        assert!(Pattern::parse("*a").is_err());
        assert!(Pattern::parse("[ab").is_err());
        assert!(Pattern::parse("a\\").is_err());
    }

    #[test]
    fn schema_derived_spec_quarantines_nulls_only() {
        let spec = ValidationSpec::from_schema(&schema());
        assert_eq!(spec.rules().len(), 2);
        assert_eq!(spec.num_extra_rules(), 0);
        let df = DataFrame::new(vec![
            ("price".into(), Column::from_f64_opt(vec![Some(1.0), None, Some(3.0)])),
            ("city".into(), Column::from_str(vec!["a", "b", "c"])),
        ])
        .unwrap();
        let (clean, report) = screen_batch(&spec, &df, vec![]).unwrap();
        assert_eq!(report.keep, vec![true, false, true]);
        assert_eq!(clean.num_rows(), 2);
        let e = &report.errors[1];
        assert_eq!(e.len(), 1);
        assert_eq!((e[0].rule.as_str(), e[0].column.as_str()), ("not_null", "price"));
        // clean batch keeps the frame without copying
        let clean_df = df.filter_rows(&[true, false, true]).unwrap();
        let (again, r2) = screen_batch(&spec, &clean_df, vec![]).unwrap();
        assert_eq!(r2.num_quarantined(), 0);
        assert_eq!(again, clean_df);
    }

    #[test]
    fn declarative_rules_fire_per_row_and_count_per_rule() {
        let rules = Json::parse(
            r#"[
                {"rule": "range", "column": "price", "min": 0, "max": 100},
                {"rule": "one_of", "column": "city", "values": ["NYC", "SF"]},
                {"rule": "pattern", "column": "city", "pattern": "[A-Z]+"}
            ]"#,
        )
        .unwrap();
        let spec = ValidationSpec::from_json(&rules, &schema()).unwrap();
        assert_eq!(spec.num_extra_rules(), 3);
        let df = DataFrame::new(vec![
            ("price".into(), Column::from_f64(vec![50.0, -1.0, 101.0, 50.0])),
            ("city".into(), Column::from_str(vec!["NYC", "SF", "SF", "nyc"])),
        ])
        .unwrap();
        let report = spec.evaluate(&df, vec![]).unwrap();
        assert_eq!(report.keep, vec![true, false, false, false]);
        assert!(report.errors[1][0].message.contains("below minimum"));
        assert!(report.errors[2][0].message.contains("above maximum"));
        // row 3 violates BOTH string rules
        let rules3: Vec<&str> = report.errors[3].iter().map(|e| e.rule.as_str()).collect();
        assert_eq!(rules3, vec!["one_of", "pattern"]);
        let counts = report.rule_counts();
        assert_eq!(counts.get("range"), Some(&2));
        assert_eq!(counts.get("one_of"), Some(&1));
        assert_eq!(counts.get("pattern"), Some(&1));
        // verdict re-expansion keeps original order and maps output rows
        let verdicts = report.verdicts_json();
        let v = verdicts.as_array().unwrap();
        assert_eq!(v.len(), 4);
        assert_eq!(v[0].get("status").and_then(Json::as_str), Some("ok"));
        assert_eq!(v[0].get("output_row").and_then(Json::as_i64), Some(0));
        assert_eq!(v[1].get("status").and_then(Json::as_str), Some("quarantined"));
        let errs = v[1].get("errors").and_then(Json::as_array).unwrap();
        assert_eq!(errs[0].get("rule").and_then(Json::as_str), Some("range"));
        assert_eq!(errs[0].get("column").and_then(Json::as_str), Some("price"));
    }

    #[test]
    fn bad_rule_configs_are_refused() {
        let s = schema();
        let cases = [
            r#"[{"rule": "range", "column": "city", "min": 0}]"#, // non-numeric
            r#"[{"rule": "range", "column": "price"}]"#,          // no bounds
            r#"[{"rule": "one_of", "column": "price", "values": ["x"]}]"#, // non-string
            r#"[{"rule": "one_of", "column": "city", "values": []}]"#, // empty set
            r#"[{"rule": "pattern", "column": "city", "pattern": "*bad"}]"#, // bad pattern
            r#"[{"rule": "nope", "column": "city"}]"#,            // unknown rule
            r#"[{"rule": "range", "column": "ghost", "min": 0}]"#, // unknown column
        ];
        for c in cases {
            let rules = Json::parse(c).unwrap();
            assert!(ValidationSpec::from_json(&rules, &s).is_err(), "{c}");
        }
        // rule set round-trips through its JSON shape
        let rules = Json::parse(
            r#"[{"rule": "range", "column": "price", "min": 0.0, "max": 10.0},
                {"rule": "pattern", "column": "city", "pattern": "c_\\d+"}]"#,
        )
        .unwrap();
        let spec = ValidationSpec::from_json(&rules, &s).unwrap();
        let again = ValidationSpec::from_json(
            &Json::Array(
                spec.rules()
                    .iter()
                    .filter(|r| !matches!(r, Rule::NotNull { .. }))
                    .map(Rule::to_json)
                    .collect(),
            ),
            &s,
        )
        .unwrap();
        assert_eq!(spec.rules(), again.rules());
    }

    #[test]
    fn structural_errors_merge_into_verdicts() {
        let spec = ValidationSpec::from_schema(&schema());
        let df = DataFrame::new(vec![
            ("price".into(), Column::from_f64(vec![1.0, 2.0])),
            ("city".into(), Column::from_str(vec!["a", "b"])),
        ])
        .unwrap();
        let structural = vec![
            vec![],
            vec![RowError::new("dtype", "price", "column 'price' expects float64")],
        ];
        let report = spec.evaluate(&df, structural).unwrap();
        assert_eq!(report.keep, vec![true, false]);
        // a structural error vector of the wrong length is an error
        assert!(spec
            .evaluate(&df, vec![vec![]])
            .is_err());
    }

    #[test]
    fn sinks_record_the_shared_entry_shape() {
        let errors = vec![RowError::new("not_null", "price", "null value")];
        let mut row = Json::object();
        row.set("price", Json::Null);
        // memory ring caps at its bound, keeping the newest
        let ring = MemoryDeadLetter::new(2);
        for _ in 0..3 {
            ring.record("shop", &row, &errors);
        }
        assert_eq!(ring.len(), 2);
        let e = &ring.entries()[0];
        assert_eq!(e.get("tenant").and_then(Json::as_str), Some("shop"));
        assert!(e.get("row").is_some());
        let errs = e.get("errors").and_then(Json::as_array).unwrap();
        assert_eq!(errs[0].get("rule").and_then(Json::as_str), Some("not_null"));
        // jsonl sink appends parseable lines
        let path = std::env::temp_dir().join("kamae_dead_letter_test.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let sink = JsonlDeadLetter::create(&path).unwrap();
            sink.record("shop", &row, &errors);
            sink.record("shop", &row, &errors);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let parsed = Json::parse(lines[0]).unwrap();
        assert_eq!(parsed.get("tenant").and_then(Json::as_str), Some("shop"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn jsonl_sink_write_failure_counts_instead_of_failing() {
        // /dev/full accepts the append-open but fails every write with
        // ENOSPC — the "disk filled up mid-run" shape. record() must
        // swallow the failure (no panic, no Err — the signature has
        // none) and count it, so serving continues while operators see
        // dead_letter_errors climbing.
        let dev_full = Path::new("/dev/full");
        if !dev_full.exists() {
            eprintln!("SKIP: /dev/full not available on this platform");
            return;
        }
        let sink = JsonlDeadLetter::create(dev_full).unwrap();
        assert_eq!(sink.errors(), 0);
        let errors = vec![RowError::new("not_null", "price", "null value")];
        let mut row = Json::object();
        row.set("price", Json::Null);
        sink.record("shop", &row, &errors);
        sink.record("shop", &row, &errors);
        assert_eq!(sink.errors(), 2, "failed writes must be counted");
        // sinks that cannot fail keep the default 0
        let ring = MemoryDeadLetter::new(2);
        ring.record("shop", &row, &errors);
        assert_eq!(DeadLetterSink::errors(&ring), 0);
    }
}
