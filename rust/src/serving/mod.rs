//! The serving stack — the online half of the paper's story.
//!
//! A deployed preprocessing model is served by a [`Server`]: a request
//! router over named model variants, each with a dynamic batcher (the
//! `batcher` module behind [`Server`]) in front of a [`Backend`]:
//!
//! * [`CompiledBackend`] — Rust ingress (string ops via the engine
//!   kernels) + AOT-compiled HLO executed through PJRT, with batch-bucket
//!   padding. This is the paper's "Keras model in TensorFlow Java"
//!   replacement — python never runs here.
//! * [`InterpretedBackend`] — same ingress, graph section executed
//!   columnar without HLO (the ablation point: columnar but uncompiled).
//!   At load the spec is compiled once into a **kernel program**
//!   (typed, slot-indexed, attribute-pre-parsed — see
//!   [`crate::export::SpecInterpreter`]); specs the kernel compiler
//!   cannot handle fall back to the per-node `eval_node` oracle, which
//!   [`InterpretedBackend::new_oracle`] also exposes directly as the
//!   differential/benchmark baseline (`benches/kernel_program.rs`).
//! * [`MleapBackend`] — row-at-a-time boxed interpretation of the fitted
//!   pipeline ([`crate::baselines`]), the MLeap stand-in.
//!
//! End to end the serving pipeline is **spec → optimized IR → kernel
//! program → pooled server**: the optimizer rewrites the spec at load,
//! the interpreter compiles the rewritten spec into a kernel program,
//! and the worker pool below drains batches through it.
//!
//! `bench_serve` is the open-loop Poisson driver used for experiments
//! C3/C5 (latency vs mode, 200 req/s sustained service);
//! `bench_serve_variants` is its mixed-variant counterpart.
//!
//! ## Variant-routed request flow
//!
//! K catalog variants (e.g. the full `ltr` ranker and its `ltr_lite`
//! sibling) deploy as ONE backend: their specs are merged
//! ([`GraphSpec::merge_variants`]) and optimized so the shared
//! preprocessing prefix exists once (`CrossOutputDedup`). A request
//! then targets a variant end to end:
//!
//! 1. **submit** — [`Server::submit_variant`] tags the request with a
//!    variant name (untargeted [`Server::submit`] keeps meaning "all
//!    outputs");
//! 2. **batch** — the batcher coalesces mixed-variant submissions into
//!    one batch, sorted into contiguous per-variant row groups
//!    ([`VariantGroup`]);
//! 3. **evaluate** — [`Backend::process_routed`] walks only the
//!    ancestor cone of each group's outputs
//!    ([`crate::export::SpecInterpreter::run_routed`]): shared-prefix
//!    nodes run once over the whole mixed batch, variant-exclusive
//!    nodes run only on their variant's rows;
//! 4. **respond** — each request receives exactly its variant's output
//!    tensors, in the variant's own output order, and the per-variant
//!    request/latency split lands in [`ServeReport::variants`].
//!
//! `benches/variant_routing.rs` gates the win: routed mixed-variant
//! serving must strictly beat both all-outputs-per-request on the
//! merged backend and two separate single-variant backends.
//!
//! ## Worker pool
//!
//! A [`Server`] is a **pool**: one shared request queue feeding
//! [`BatchConfig::workers`] batcher threads that drain batches
//! concurrently against ONE shared backend —
//!
//! ```text
//!   submit / submit_variant
//!            │
//!            ▼
//!      ┌───────────┐     worker 0 ──┐
//!      │ JobQueue  │────▶ worker 1 ──┼──▶ Arc<dyn Backend>  (shared,
//!      │ (1 queue) │     …          │     immutable after load)
//!      └───────────┘     worker N-1 ┘
//!            ▲                │
//!     batch formation        │ per-worker metrics (no shared
//!     serialised by the      │ hot-path mutex)
//!     queue lock only        ▼
//!                      merged at report time:
//!                      ServeReport { workers, worker_utilization, … }
//! ```
//!
//! Backends are immutable after load (`&self` processing, `Send +
//! Sync`; the interpreter's regex cache is read-only and its per-variant
//! cone memo is pre-warmed/lock-free — see
//! [`crate::export::SpecInterpreter`]), so workers share one instance
//! with zero coordination: batch *formation* is serialised by the queue
//! mutex, batch *execution* is fully parallel, and responses route back
//! per request exactly as in the single-worker case. Metrics stay
//! contention-free — each worker owns its counters, and
//! [`LatencyRecorder::report_pool`] merges them into one
//! [`ServeReport`] carrying the pool size and per-worker utilization.
//! `bench_serve_pool` drives mixed routed traffic through an N-worker
//! pool; `benches/worker_pool.rs` gates that 4 workers strictly beat 1
//! on routed mixed-variant throughput (and that 1 worker does not
//! regress against the single-thread baseline) after pinning pooled
//! responses bit-for-bit against dedicated backends.
//!
//! ## Network front-end
//!
//! The `net` module ([`NetServer`]) puts a wire in front of the pool: a
//! std-only threaded
//! HTTP/1.1 listener (`kamae serve --listen`) that decodes JSON request
//! bodies into row batches, admits them through a bounded window, and
//! feeds the same [`Server`] —
//!
//! ```text
//!   HTTP clients (keep-alive)
//!        │  POST /v1/infer {"variant", "rows"}
//!        ▼
//!   ┌──────────┐  conn   ┌───────────────┐ try_acquire ┌───────────┐
//!   │ listener │────────▶│ admission     │────────────▶│ JobQueue  │
//!   │ (accept  │ thread  │ Semaphore     │  submit /   │ → worker  │──▶ Arc<dyn Backend>
//!   │  poll)   │  each   │ (window of M) │  submit_    │   pool    │    (ONE shared)
//!   └──────────┘         └───────┬───────┘  variant    └───────────┘
//!                                │ no permit
//!                                ▼
//!                429 {"error": {"code": "overloaded"}} + Retry-After
//!                (shed before the body is parsed — refusal stays cheap)
//! ```
//!
//! `GET /healthz` answers readiness (503 once draining); `GET /metrics`
//! surfaces the full [`ServeReport`] — per-variant, per-tenant and
//! per-worker splits
//! plus the shed/admission counters ([`ServeReport::shed_requests`],
//! [`ServeReport::admission_limit`]) — and per-client request/shed/
//! latency counters keyed by the `X-Kamae-Client` header (bounded table
//! with an `other_clients` rollup). Every failure
//! is a typed [`WireError`] with a stable `code` and status.
//! `benches/net_serving.rs` gates saturation throughput, wire
//! bit-identity against in-process submission, and cheap shedding under
//! 2× overload.
//!
//! ## Ingress data-quality gate
//!
//! The `validate` module quarantines bad rows at the front door instead
//! of letting one malformed row poison a whole batch. A
//! [`ValidationSpec`] is derived automatically from the tenant's
//! request schema (every column required and type-checked) and extended
//! with declarative per-tenant rules (`range`, `one_of`, `pattern` —
//! attached at deploy time, versioned WITH the backend inside
//! [`TenantVersion`] so deploy/rollback swaps rules and model as one
//! atomic snapshot):
//!
//! ```text
//!   rows ─▶ lenient decode ─▶ ValidationSpec::evaluate  (columnar
//!              │ structural        │   masks, union_null_masks fast
//!              │ RowErrors         │   path — clean batches cost one
//!              ▼                   ▼   mask fold)
//!          per-row verdict mask: keep[i] / Vec<RowError>
//!              │                       │
//!        valid rows                quarantined rows
//!              │                       │
//!      filter_rows → compacted   DeadLetterSink (JSONL file or
//!      batch → worker pool       in-memory ring) + per-rule
//!              │                 violation counters in ServeReport
//!              ▼
//!      response: outputs for valid rows + per-row "verdicts"
//!      (ok → output_row index; quarantined → structured RowErrors
//!       naming rule, column, message)
//! ```
//!
//! The batch is *compacted* — the backend never sees an invalid row,
//! and a batch whose rows are ALL quarantined short-circuits to an
//! empty output set (verdicts still itemise every row, latency is
//! still billed). Valid rows' outputs are bit-identical to serving the
//! same rows without corruption (`benches/ingress_validation.rs` pins
//! this differentially and gates clean-traffic overhead at < 5%).
//!
//! ## Spec registry & hot swap
//!
//! The `registry` module makes the backend a **runtime-resolved,
//! versioned entry** instead of a fixed constructor argument. The full
//! request path in registry mode is
//!
//! ```text
//!   submit_tenant(df, "shop", variant)        POST /v1/infer/shop
//!            │                                       │
//!            ▼                                       ▼
//!      resolve("shop") ──▶ Arc<TenantVersion>  (schema, outputs,
//!            │                                  variants, backend —
//!            ▼                                  ONE atomic snapshot)
//!      ┌───────────┐     worker pool drains per-version sub-batches
//!      │ JobQueue  │────▶ (jobs carry their resolved Arc; a deploy
//!      └───────────┘      never re-routes a queued request)
//!            │
//!            ▼
//!      merged metrics: ServeReport { variants, tenants, workers, … }
//! ```
//!
//! A deploy ([`SpecRegistry::deploy_specs`]) builds the new version —
//! optimize → merge → compile kernel program — entirely **off the swap
//! path**, then swaps the tenant's active `Arc<TenantVersion>` in O(1)
//! under a short write lock. In-flight and queued requests finish on
//! the version they resolved: zero requests dropped, zero mixed
//! versions. Rollback re-activates a still-warm prior version with no
//! rebuild. The single-spec constructors ([`Server::start`],
//! [`NetServer::bind`]) are thin wrappers over a one-tenant registry
//! under [`DEFAULT_TENANT`], so the pre-registry API keeps working
//! unchanged. `benches/hot_swap.rs` gates throughput under a
//! continuous swap storm at ≥ 90% of the no-swap baseline with zero
//! errors and bounded swap latency.
//!
//! ## Fault containment
//!
//! The pool treats the backend as untrusted code: panics, poison rows,
//! and stuck batches are contained per request instead of per process.
//! Three layers, innermost first:
//!
//! ```text
//!   worker thread (supervised: outer loop re-enters worker_loop after
//!   │              a panic escapes a batch — pool capacity never decays)
//!   ▼
//!   per-batch catch_unwind ── batch Ok ──▶ responses route back
//!   │ batch Err / panic
//!   ▼
//!   bisection (isolate_jobs → isolate_rows → bisect_rows):
//!     · lone re-probe first — faults caused by a NEIGHBOUR job, and
//!       transient faults (panic_every-style), are forgiven
//!     · single-row failures retried once more before condemnation
//!     · condemned rows → DeadLetterSink with a structured "poison"
//!       verdict; the job is answered KamaeError::PoisonRows(indices)
//!     · survivors are re-executed and served BIT-IDENTICAL to an
//!       un-faulted run (benches/fault_tolerance.rs pins this)
//!   ▼
//!   net layer folds poison rows into the response's per-row verdicts
//!   (rule "poison") and resubmits the survivors — the client sees
//!   per-row blame, not a whole-request 500
//! ```
//!
//! **Deadlines** bound queue time: [`BatchConfig::request_deadline`]
//! (or a per-request `deadline_ms` on the wire) stamps each job at
//! submit; workers drop expired jobs at pop, and a dedicated reaper
//! thread sweeps the queue every millisecond so a request stuck behind
//! a slow batch is answered a typed `504 deadline_exceeded` promptly —
//! expired requests never occupy a batch and never hang. The counters
//! ([`ServeReport::worker_panics`], [`ServeReport::deadline_expired`],
//! [`ServeReport::poison_rows`], [`ServeReport::dead_letter_errors`])
//! surface in `/metrics`; a per-tenant rolling quarantine rate
//! ([`TenantStats::quarantine_rate`]) drives the `/healthz` "degraded"
//! alert (`--quarantine-alert`).
//!
//! The `fault` module is the deterministic harness for all of this:
//! [`ChaosBackend`] misbehaves on a [`FaultPlan`] (panic every Nth
//! call, content-keyed poison rows, slow batches) and
//! [`FailingDeadLetter`] simulates sink IO failure, so
//! `benches/fault_tolerance.rs` can gate survivor bit-identity,
//! counter conservation, ≥ 90% throughput retention under a fault
//! storm, and full pool capacity after every panic.

mod backend;
mod batcher;
mod fault;
mod metrics;
mod net;
mod registry;
mod validate;

pub use backend::{Backend, CompiledBackend, InterpretedBackend, MleapBackend, VariantGroup};
pub use batcher::{BatchConfig, Server};
pub use fault::{ChaosBackend, FailingDeadLetter, FaultPlan, PoisonPredicate};
pub use metrics::{LatencyRecorder, ServeReport, TenantStats, VariantStats};
pub use net::{
    tensor_from_json, tensor_to_json, NetClient, NetConfig, NetResponse, NetServer, WireError,
};
pub use registry::{
    DeploySummary, SpecRegistry, TenantSnapshot, TenantVersion, VersionInfo, DEFAULT_TENANT,
};
pub use validate::{
    dead_letter_entry, screen_batch, DeadLetterSink, JsonlDeadLetter, MemoryDeadLetter, Rule,
    RowError, ValidationReport, ValidationSpec,
};

use std::path::Path;

use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};
use crate::export::GraphSpec;
use crate::optim::OptimizeLevel;
use crate::pipeline::PipelineModel;
use crate::util::rng::Rng;

/// Load a backend for `spec_name` from an artifacts directory laid out
/// by `make artifacts` (`specs/<name>.json`, `specs/<name>.model.json`,
/// `<name>@b<batch>.hlo.txt`).
///
/// Specs are optimized at load time at the default level, so the
/// interpreted and mleap ablations benefit from the same graph cleanup
/// the compiled path received at export time (and legacy unoptimized
/// spec files get it retroactively). Use [`load_backend_with`] to
/// control the level.
pub fn load_backend(artifacts: &Path, spec_name: &str, mode: &str) -> Result<Box<dyn Backend>> {
    load_backend_with(artifacts, spec_name, mode, OptimizeLevel::default())
}

/// [`load_backend`] with an explicit load-time optimization level.
///
/// The compiled mode never re-optimizes: its positional tensor contract
/// is against the HLO artifacts compiled from the spec JSON exactly as
/// it sits on disk.
pub fn load_backend_with(
    artifacts: &Path,
    spec_name: &str,
    mode: &str,
    level: OptimizeLevel,
) -> Result<Box<dyn Backend>> {
    let spec = GraphSpec::load(&artifacts.join("specs").join(format!("{spec_name}.json")))?;
    match mode {
        "compiled" => Ok(Box::new(CompiledBackend::load(artifacts, spec)?)),
        "interpreted" => {
            let (spec, _) = crate::optim::optimize(spec, level)?;
            Ok(Box::new(InterpretedBackend::new(spec)))
        }
        "mleap" => {
            let (spec, _) = crate::optim::optimize(spec, level)?;
            let model = PipelineModel::load(
                &artifacts.join("specs").join(format!("{spec_name}.model.json")),
            )?;
            Ok(Box::new(MleapBackend::new(model, &spec)))
        }
        other => Err(KamaeError::InvalidConfig(format!("unknown serving mode: {other}"))),
    }
}

/// Load K spec variants as ONE multi-variant interpreted backend
/// sharing a single evaluation env per request.
///
/// The variant specs are merged ([`GraphSpec::merge_variants`]) and
/// optimized at load time, so the `CrossOutputDedup` pass collapses the
/// preprocessing prefix the variants share — serving K overlapping
/// variants costs roughly one pass over the shared work instead of K.
/// Output tensors are the variants' outputs concatenated in variant
/// order under `"<variant>::<output>"` names (see
/// [`crate::export::GraphSpec::outputs`] on the returned backend's
/// spec). Only the interpreted mode exists for merged specs: compiled
/// artifacts are lowered per single-variant spec.
pub fn load_variant_backend(
    artifacts: &Path,
    spec_names: &[&str],
    level: OptimizeLevel,
) -> Result<Box<dyn Backend>> {
    Ok(Box::new(InterpretedBackend::new(load_variant_spec(
        artifacts, spec_names, level,
    )?)))
}

/// The merged, optimized multi-variant spec [`load_variant_backend`]
/// serves — exposed separately so callers (the `kamae serve` CLI, cost
/// tooling) can inspect per-variant structure and cost attribution
/// without loading a second copy.
pub fn load_variant_spec(
    artifacts: &Path,
    spec_names: &[&str],
    level: OptimizeLevel,
) -> Result<GraphSpec> {
    if spec_names.is_empty() {
        return Err(KamaeError::InvalidConfig("no spec variants given".into()));
    }
    let specs = spec_names
        .iter()
        .map(|name| GraphSpec::load(&artifacts.join("specs").join(format!("{name}.json"))))
        .collect::<Result<Vec<_>>>()?;
    let refs: Vec<&GraphSpec> = specs.iter().collect();
    let merged = GraphSpec::merge_variants(&spec_names.join("+"), &refs)?;
    let (merged, _) = crate::optim::optimize(merged, level)?;
    Ok(merged)
}

/// Open-loop Poisson serving benchmark: `rps` requests/second for
/// `seconds`, each request a small batch of rows drawn from the
/// synthetic workload matching `spec_name`. Returns the latency /
/// throughput / cost report (experiments C3 + C5).
pub fn bench_serve(
    artifacts: &Path,
    spec_name: &str,
    rps: usize,
    seconds: usize,
    mode: &str,
) -> Result<ServeReport> {
    let backend = load_backend(artifacts, spec_name, mode)?;
    let server = Server::start(backend, BatchConfig::default())?;

    // request pool: pre-generated rows, requests sample row-ranges
    let pool = request_pool(spec_name, 4096)?;
    let rows_per_request = 8; // an LTR request scores a small slate
    let total_requests = rps * seconds;
    let mut rng = Rng::new(0xBEEF);

    let recorder = LatencyRecorder::new();
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(total_requests);
    let mut next_arrival = 0.0f64;
    for _ in 0..total_requests {
        next_arrival += rng.exponential(rps as f64);
        // open-loop: wait until the scheduled arrival time
        let now = t0.elapsed().as_secs_f64();
        if next_arrival > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(next_arrival - now));
        }
        let start = rng.below((pool.num_rows() - rows_per_request) as u64) as usize;
        let req = pool.slice(start, rows_per_request);
        let sent = std::time::Instant::now();
        let rx = server.submit(req);
        pending.push((sent, rx));
        // drain completed responses opportunistically
        while let Some((sent, rx)) = pending.first() {
            match rx.try_recv() {
                Ok(res) => {
                    res?;
                    recorder.record(sent.elapsed());
                    pending.remove(0);
                }
                Err(_) => break,
            }
        }
    }
    for (sent, rx) in pending {
        rx.recv()
            .map_err(|_| KamaeError::Serving("server dropped response".into()))??;
        recorder.record(sent.elapsed());
    }
    let wall = t0.elapsed();
    let busy = server.busy_time();
    server.shutdown();

    Ok(recorder.report(
        &format!("{spec_name}/{mode}"),
        total_requests,
        wall,
        busy,
    ))
}

/// Open-loop Poisson serving benchmark over a MERGED multi-variant
/// backend with mixed traffic: requests cycle round-robin through
/// `spec_names` and, when `route` is set, target their variant via
/// [`Server::submit_variant`] (cone-restricted evaluation). With
/// `route` off every request is served the full merged output set — the
/// all-outputs-per-request baseline. Latencies are recorded per variant
/// so the returned report carries the split
/// ([`ServeReport::variants`]).
///
/// Requests draw rows from the FIRST variant's request pool: merged
/// variants share an input schema (the LTR full/lite shape); serving
/// variants with disjoint schemas would need a per-variant pool.
pub fn bench_serve_variants(
    artifacts: &Path,
    spec_names: &[&str],
    rps: usize,
    seconds: usize,
    level: OptimizeLevel,
    route: bool,
) -> Result<ServeReport> {
    if spec_names.is_empty() {
        return Err(KamaeError::InvalidConfig("no spec variants given".into()));
    }
    let backend = load_variant_backend(artifacts, spec_names, level)?;
    let config = BatchConfig { route_variants: route, ..BatchConfig::default() };
    let server = Server::start(backend, config)?;

    let recorder = LatencyRecorder::new();
    let (total_requests, wall) =
        drive_mixed_open_loop(&server, spec_names, rps, seconds, route, &recorder)?;
    let busy = server.busy_time();
    server.shutdown();

    Ok(recorder.report(
        &format!(
            "{}/{}",
            spec_names.join("+"),
            if route { "routed" } else { "merged-all" }
        ),
        total_requests,
        wall,
        busy,
    ))
}

/// Open-loop Poisson serving benchmark over a MERGED multi-variant
/// backend served by an N-worker pool ([`BatchConfig::workers`]):
/// mixed routed traffic exactly like [`bench_serve_variants`] with
/// `route` on, but drained by `workers` batcher threads against the one
/// shared backend. The report carries the pool size and per-worker
/// utilization ([`ServeReport::workers`] /
/// [`ServeReport::worker_utilization`]) under the
/// `"<specs>/pool<N>"` naming, so trajectory records separate pool
/// sizes without re-parsing. `benches/worker_pool.rs` is the gated
/// (closed-loop, saturating) counterpart; this open-loop driver is the
/// `kamae serve --workers N` entry point.
pub fn bench_serve_pool(
    artifacts: &Path,
    spec_names: &[&str],
    rps: usize,
    seconds: usize,
    level: OptimizeLevel,
    workers: usize,
) -> Result<ServeReport> {
    if spec_names.is_empty() {
        return Err(KamaeError::InvalidConfig("no spec variants given".into()));
    }
    let backend = load_variant_backend(artifacts, spec_names, level)?;
    let config = BatchConfig { workers, ..BatchConfig::default() };
    let server = Server::start(backend, config)?;

    let recorder = LatencyRecorder::new();
    let (total_requests, wall) =
        drive_mixed_open_loop(&server, spec_names, rps, seconds, true, &recorder)?;
    let worker_busy = server.worker_busy_times();
    server.shutdown();

    Ok(recorder.report_pool(
        &format!("{}/pool{workers}", spec_names.join("+")),
        total_requests,
        wall,
        &worker_busy,
    ))
}

/// Shared open-loop Poisson driver for the mixed-variant benches:
/// `rps * seconds` requests, round-robin through `spec_names`, targeted
/// via [`Server::submit_variant`] when `route` is set. Latencies land
/// in `recorder` per variant; returns (requests, wall time).
fn drive_mixed_open_loop(
    server: &Server,
    spec_names: &[&str],
    rps: usize,
    seconds: usize,
    route: bool,
    recorder: &LatencyRecorder,
) -> Result<(usize, std::time::Duration)> {
    let pool = request_pool(spec_names[0], 4096)?;
    let rows_per_request = 8;
    let total_requests = rps * seconds;
    let mut rng = Rng::new(0xBEEF);

    let t0 = std::time::Instant::now();
    let mut pending: Vec<(std::time::Instant, &str, RespRx)> = Vec::with_capacity(total_requests);
    let mut next_arrival = 0.0f64;
    for i in 0..total_requests {
        next_arrival += rng.exponential(rps as f64);
        let now = t0.elapsed().as_secs_f64();
        if next_arrival > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(next_arrival - now));
        }
        let start = rng.below((pool.num_rows() - rows_per_request) as u64) as usize;
        let req = pool.slice(start, rows_per_request);
        let variant = spec_names[i % spec_names.len()];
        let sent = std::time::Instant::now();
        let rx = if route { server.submit_variant(req, variant) } else { server.submit(req) };
        pending.push((sent, variant, rx));
        while let Some((sent, variant, rx)) = pending.first() {
            match rx.try_recv() {
                Ok(res) => {
                    res?;
                    recorder.record_variant(variant, sent.elapsed());
                    pending.remove(0);
                }
                Err(_) => break,
            }
        }
    }
    for (sent, variant, rx) in pending {
        rx.recv()
            .map_err(|_| KamaeError::Serving("server dropped response".into()))??;
        recorder.record_variant(variant, sent.elapsed());
    }
    Ok((total_requests, t0.elapsed()))
}

/// Response-channel alias for the pending-request bookkeeping above.
type RespRx = std::sync::mpsc::Receiver<Result<Vec<crate::runtime::Tensor>>>;

/// Synthetic request rows matching each catalog spec's input schema.
pub fn request_pool(spec_name: &str, rows: usize) -> Result<DataFrame> {
    match spec_name {
        "movielens" => {
            let df = crate::synth::gen_movielens(&crate::synth::MovieLensConfig {
                rows,
                seed: 999, // unseen at fit time: realistic OOV rate
                ..Default::default()
            });
            df.select(&["UserID", "MovieID", "Occupation", "Genres"])
        }
        "ltr" => {
            let df = crate::synth::gen_ltr(&crate::synth::LtrConfig {
                rows,
                seed: 999,
                ..Default::default()
            });
            Ok(df.drop(&["clicked"]))
        }
        "quickstart" => {
            let mut rng = Rng::new(999);
            crate::dataframe::DataFrame::new(vec![
                (
                    "price".into(),
                    crate::dataframe::Column::from_f64(
                        (0..rows).map(|_| rng.log_normal(4.0, 1.0)).collect(),
                    ),
                ),
                (
                    "city".into(),
                    crate::dataframe::Column::from_str(
                        (0..rows)
                            .map(|_| format!("city_{}", rng.below(80)))
                            .collect::<Vec<_>>(),
                    ),
                ),
            ])
        }
        other => Err(KamaeError::InvalidConfig(format!("no request pool for {other}"))),
    }
}
