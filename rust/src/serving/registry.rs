//! Multi-tenant spec registry with zero-downtime hot swap.
//!
//! A [`SpecRegistry`] holds one versioned serving entry per **tenant**:
//! the active [`TenantVersion`] wraps the tenant's merged, optimized
//! backend together with everything the wire layer derives from it
//! (request schema, output names, variant routing tables), so a request
//! resolves its ENTIRE serving surface in one atomic read.
//!
//! Deploys are built **off the swap path**: `deploy_specs` merges,
//! optimizes and kernel-compiles the new backend before any registry
//! lock is taken — the swap itself is an `Arc` replacement under the
//! tenant's version lock, O(1) and independent of spec size. In-flight
//! batches keep the `Arc` they resolved and finish on the old version
//! (the batcher groups drained jobs by resolved version, never mixing
//! two versions in one backend call), so a redeploy drops zero requests
//! and changes zero bits mid-flight. `benches/hot_swap.rs` gates the
//! throughput cost of a continuous swap storm; the swap-under-load
//! stress test below pins bit-identity against per-version oracles.
//!
//! Rollback re-activates a previously deployed version from the
//! tenant's history — the old `Arc` is still warm (kernel program and
//! all), so rolling back is as cheap as the swap itself.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::dataframe::Schema;
use crate::error::{KamaeError, Result};
use crate::export::GraphSpec;
use crate::optim::OptimizeLevel;
use crate::util::json::Json;

use super::backend::{Backend, InterpretedBackend};
use super::validate::ValidationSpec;

/// Tenant name the single-spec wrappers ([`super::Server::start`],
/// [`super::NetServer::bind`]) register their one backend under.
pub const DEFAULT_TENANT: &str = "default";

/// One immutable deployed version of a tenant's serving surface. Jobs
/// carry the `Arc<TenantVersion>` they resolved, so validation, output
/// naming and execution all see the SAME version even while a deploy
/// swaps the active entry underneath them.
pub struct TenantVersion {
    tenant: String,
    version: u64,
    backend: Arc<dyn Backend>,
    /// Request schema derived from the backend's spec at deploy time
    /// (`None` for spec-less backends, which cannot serve the wire).
    schema: Option<Schema>,
    /// Spec output names in merged order, with each variant's output
    /// indices precomputed — the per-request routing table.
    outputs: Vec<String>,
    variants: Vec<String>,
    variant_outputs: Vec<Vec<usize>>,
    /// Ingress data-quality gate for this version: the schema-derived
    /// not-null baseline plus any deploy-time declarative rules. `None`
    /// only for spec-less backends (no schema to derive from). Versioned
    /// WITH the backend so a deploy/rollback swaps rules and model as
    /// one atomic snapshot — queued requests validate against the same
    /// version they execute on.
    validation: Option<Arc<ValidationSpec>>,
    /// Requests this version answered — the per-version gauge the
    /// stress test sums to account for every request.
    requests: AtomicU64,
}

impl TenantVersion {
    fn new(
        tenant: &str,
        version: u64,
        backend: Arc<dyn Backend>,
        validation: Option<Arc<ValidationSpec>>,
    ) -> TenantVersion {
        let schema = backend.request_schema();
        let outputs = backend.spec().map(|s| s.outputs.clone()).unwrap_or_default();
        let variants = backend.variants().to_vec();
        // always variants.len() entries so output_indices can index by
        // variant position even for spec-less backends
        let variant_outputs = match backend.spec() {
            Some(s) => variants.iter().map(|v| s.variant_outputs(v)).collect(),
            None => vec![Vec::new(); variants.len()],
        };
        TenantVersion {
            tenant: tenant.to_string(),
            version,
            backend,
            schema,
            outputs,
            variants,
            variant_outputs,
            validation,
            requests: AtomicU64::new(0),
        }
    }

    /// Compile the version's validation spec from the backend's request
    /// schema plus optional deploy-time rules. Runs BEFORE any registry
    /// lock so a slow/bad rule set never stalls or poisons a swap.
    fn build_validation(
        tenant: &str,
        backend: &dyn Backend,
        rules: Option<&Json>,
    ) -> Result<Option<Arc<ValidationSpec>>> {
        match (backend.request_schema(), rules) {
            (Some(s), Some(r)) => Ok(Some(Arc::new(ValidationSpec::from_json(r, &s)?))),
            (Some(s), None) => Ok(Some(Arc::new(ValidationSpec::from_schema(&s)))),
            (None, Some(_)) => Err(KamaeError::InvalidConfig(format!(
                "tenant '{tenant}': validation rules given, but backend '{}' \
                 has no request schema to validate against",
                backend.name()
            ))),
            (None, None) => Ok(None),
        }
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    pub fn schema(&self) -> Option<&Schema> {
        self.schema.as_ref()
    }

    pub fn outputs(&self) -> &[String] {
        &self.outputs
    }

    pub fn variants(&self) -> &[String] {
        &self.variants
    }

    /// Output indices a request resolves to: the variant's own outputs,
    /// or every output when untargeted. The error message matches the
    /// batcher's submit-time rejection so wire and in-process callers
    /// agree.
    pub fn output_indices(&self, variant: Option<&str>) -> Result<Vec<usize>> {
        match variant {
            None => Ok((0..self.outputs.len()).collect()),
            Some(v) => self
                .variants
                .iter()
                .position(|x| x == v)
                .map(|i| self.variant_outputs[i].clone())
                .ok_or_else(|| {
                    KamaeError::Serving(format!(
                        "no variant '{v}' to route to (backend variants: {})",
                        self.variants.join(", ")
                    ))
                }),
        }
    }

    /// This version's ingress validation spec (`None` only for
    /// spec-less backends, which also cannot serve the wire).
    pub fn validation(&self) -> Option<&ValidationSpec> {
        self.validation.as_deref()
    }

    pub fn requests_served(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub(crate) fn record_served(&self, n: u64) {
        self.requests.fetch_add(n, Ordering::Relaxed);
    }
}

/// One tenant's version chain. The active pointer has its own lock so a
/// swap never contends with other tenants' resolves.
struct Tenant {
    active: RwLock<Arc<TenantVersion>>,
    /// Every version ever deployed, in deploy order (rollback targets).
    history: Mutex<Vec<Arc<TenantVersion>>>,
    next_version: AtomicU64,
}

/// What a deploy/rollback did.
#[derive(Debug, Clone)]
pub struct DeploySummary {
    pub tenant: String,
    /// The now-active version.
    pub version: u64,
    pub backend: String,
    /// How long the active-version write lock was held for the swap —
    /// the only stall a concurrent resolve can observe.
    pub swap: Duration,
}

/// Point-in-time view of one version, for `/admin/tenants` and metrics.
#[derive(Debug, Clone)]
pub struct VersionInfo {
    pub version: u64,
    pub backend: String,
    pub requests: u64,
    pub active: bool,
}

/// Point-in-time view of one tenant.
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    pub tenant: String,
    pub active_version: u64,
    pub versions: Vec<VersionInfo>,
}

impl TenantSnapshot {
    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("tenant", self.tenant.clone());
        j.set("active_version", self.active_version as i64);
        j.set(
            "versions",
            Json::Array(
                self.versions
                    .iter()
                    .map(|v| {
                        let mut o = Json::object();
                        o.set("version", v.version as i64);
                        o.set("backend", v.backend.clone());
                        o.set("requests", v.requests as i64);
                        o.set("active", v.active);
                        o
                    })
                    .collect(),
            ),
        );
        j
    }
}

/// In-process registry of versioned tenant backends — the runtime
/// resolution point the serving stack addresses instead of a fixed
/// constructor backend.
pub struct SpecRegistry {
    tenants: RwLock<BTreeMap<String, Arc<Tenant>>>,
    /// Optimization level `deploy_specs` applies when the deploy does
    /// not override it.
    level: OptimizeLevel,
}

impl SpecRegistry {
    pub fn new() -> SpecRegistry {
        SpecRegistry::with_level(OptimizeLevel::default())
    }

    pub fn with_level(level: OptimizeLevel) -> SpecRegistry {
        SpecRegistry { tenants: RwLock::new(BTreeMap::new()), level }
    }

    /// A one-tenant registry over an already-built backend — the thin
    /// wrapper the single-spec `Server::start` / `NetServer::bind` APIs
    /// are built on.
    pub fn single(tenant: &str, backend: Arc<dyn Backend>) -> Result<Arc<SpecRegistry>> {
        let registry = Arc::new(SpecRegistry::new());
        registry.deploy_backend(tenant, backend, None)?;
        Ok(registry)
    }

    /// Activate an already-built backend as `tenant`'s next version.
    /// All derivation work happens before the swap; the active-version
    /// write lock is held only for the `Arc` replacement.
    ///
    /// `expect_version` is an optimistic-concurrency guard: when given,
    /// the deploy only lands if the tenant's active version still
    /// matches (0 = "tenant must not exist yet"); a mismatch is a
    /// [`KamaeError::VersionConflict`] and nothing changes.
    pub fn deploy_backend(
        &self,
        tenant: &str,
        backend: Arc<dyn Backend>,
        expect_version: Option<u64>,
    ) -> Result<DeploySummary> {
        self.deploy_backend_rules(tenant, backend, expect_version, None)
    }

    /// [`Self::deploy_backend`] with declarative validation rules
    /// attached to the new version (a JSON array — see
    /// [`ValidationSpec::from_json`]). A bad rule set refuses the whole
    /// deploy before any lock is taken; the active version is untouched.
    pub fn deploy_backend_rules(
        &self,
        tenant: &str,
        backend: Arc<dyn Backend>,
        expect_version: Option<u64>,
        rules: Option<&Json>,
    ) -> Result<DeploySummary> {
        if tenant.is_empty() {
            return Err(KamaeError::InvalidConfig("tenant name must be non-empty".into()));
        }
        let backend_name = backend.name().to_string();
        let validation = TenantVersion::build_validation(tenant, backend.as_ref(), rules)?;
        let entry = {
            let mut tenants = self.tenants.write().unwrap();
            match tenants.get(tenant) {
                Some(t) => Arc::clone(t),
                None => {
                    if let Some(expect) = expect_version {
                        if expect != 0 {
                            return Err(KamaeError::VersionConflict(format!(
                                "tenant '{tenant}': expected active version {expect}, \
                                 but the tenant is not registered"
                            )));
                        }
                    }
                    let first = Arc::new(TenantVersion::new(tenant, 1, backend, validation));
                    let t = Arc::new(Tenant {
                        active: RwLock::new(Arc::clone(&first)),
                        history: Mutex::new(vec![first]),
                        next_version: AtomicU64::new(2),
                    });
                    tenants.insert(tenant.to_string(), t);
                    return Ok(DeploySummary {
                        tenant: tenant.to_string(),
                        version: 1,
                        backend: backend_name,
                        swap: Duration::ZERO,
                    });
                }
            }
        };
        // existing tenant: compare-and-swap under its own version lock
        let t0 = Instant::now();
        let mut active = entry.active.write().unwrap();
        if let Some(expect) = expect_version {
            if active.version != expect {
                return Err(KamaeError::VersionConflict(format!(
                    "tenant '{tenant}': expected active version {expect}, found {}",
                    active.version
                )));
            }
        }
        let version = entry.next_version.fetch_add(1, Ordering::Relaxed);
        let tv = Arc::new(TenantVersion::new(tenant, version, backend, validation));
        entry.history.lock().unwrap().push(Arc::clone(&tv));
        *active = tv;
        let swap = t0.elapsed();
        drop(active);
        Ok(DeploySummary { tenant: tenant.to_string(), version, backend: backend_name, swap })
    }

    /// Build and activate a new version from raw specs: merge (when
    /// more than one), optimize, compile the kernel program — ALL
    /// before any registry lock — then [`Self::deploy_backend`].
    pub fn deploy_specs(
        &self,
        tenant: &str,
        specs: &[GraphSpec],
        expect_version: Option<u64>,
        level: Option<OptimizeLevel>,
    ) -> Result<DeploySummary> {
        self.deploy_specs_rules(tenant, specs, expect_version, level, None)
    }

    /// [`Self::deploy_specs`] with declarative validation rules for the
    /// new version (the `"validation"` array of the `/admin/deploy`
    /// body / `kamae deploy --rules`).
    pub fn deploy_specs_rules(
        &self,
        tenant: &str,
        specs: &[GraphSpec],
        expect_version: Option<u64>,
        level: Option<OptimizeLevel>,
        rules: Option<&Json>,
    ) -> Result<DeploySummary> {
        if specs.is_empty() {
            return Err(KamaeError::InvalidConfig("deploy needs at least one spec".into()));
        }
        let merged = if specs.len() == 1 {
            specs[0].clone()
        } else {
            let name = specs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join("+");
            let refs: Vec<&GraphSpec> = specs.iter().collect();
            GraphSpec::merge_variants(&name, &refs)?
        };
        let (optimized, _) = crate::optim::optimize(merged, level.unwrap_or(self.level))?;
        let backend: Arc<dyn Backend> = Arc::new(InterpretedBackend::new(optimized));
        self.deploy_backend_rules(tenant, backend, expect_version, rules)
    }

    /// Re-activate a previously deployed version: `to_version` when
    /// given, else the version deployed immediately before the active
    /// one. The old `Arc` swaps back in — no rebuild. Rolling back past
    /// the first version (or to a version never deployed) is a
    /// [`KamaeError::VersionConflict`].
    pub fn rollback(&self, tenant: &str, to_version: Option<u64>) -> Result<DeploySummary> {
        let entry = self.tenant(tenant)?;
        let t0 = Instant::now();
        let mut active = entry.active.write().unwrap();
        let target = {
            let history = entry.history.lock().unwrap();
            match to_version {
                Some(v) => history.iter().find(|tv| tv.version == v).cloned().ok_or_else(|| {
                    KamaeError::VersionConflict(format!(
                        "tenant '{tenant}': version {v} was never deployed \
                         (history: {})",
                        history.iter().map(|tv| tv.version.to_string()).collect::<Vec<_>>().join(", ")
                    ))
                })?,
                None => {
                    let pos = history
                        .iter()
                        .position(|tv| tv.version == active.version)
                        .unwrap_or(0);
                    if pos == 0 {
                        return Err(KamaeError::VersionConflict(format!(
                            "tenant '{tenant}': no version before {} to roll back to",
                            active.version
                        )));
                    }
                    Arc::clone(&history[pos - 1])
                }
            }
        };
        let version = target.version;
        let backend = target.backend.name().to_string();
        *active = target;
        let swap = t0.elapsed();
        drop(active);
        Ok(DeploySummary { tenant: tenant.to_string(), version, backend, swap })
    }

    /// Resolve a tenant's active version — the per-request entry point.
    /// One map read + one version read, both uncontended unless a swap
    /// is mid-flight on this very tenant.
    pub fn resolve(&self, tenant: &str) -> Result<Arc<TenantVersion>> {
        let tenants = self.tenants.read().unwrap();
        match tenants.get(tenant) {
            Some(t) => Ok(Arc::clone(&t.active.read().unwrap())),
            None => {
                let known = if tenants.is_empty() {
                    "none".to_string()
                } else {
                    tenants.keys().cloned().collect::<Vec<_>>().join(", ")
                };
                Err(KamaeError::UnknownTenant(format!(
                    "no tenant '{tenant}' registered (tenants: {known})"
                )))
            }
        }
    }

    /// Registered tenant names, sorted.
    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.read().unwrap().keys().cloned().collect()
    }

    /// Point-in-time view of every tenant's version chain — the
    /// `/admin/tenants` payload and the per-tenant metrics gauges.
    pub fn snapshot(&self) -> Vec<TenantSnapshot> {
        let tenants = self.tenants.read().unwrap();
        tenants
            .iter()
            .map(|(name, t)| {
                let active = Arc::clone(&t.active.read().unwrap());
                let versions = t
                    .history
                    .lock()
                    .unwrap()
                    .iter()
                    .map(|tv| VersionInfo {
                        version: tv.version,
                        backend: tv.backend.name().to_string(),
                        requests: tv.requests_served(),
                        active: Arc::ptr_eq(tv, &active),
                    })
                    .collect();
                TenantSnapshot {
                    tenant: name.clone(),
                    active_version: active.version,
                    versions,
                }
            })
            .collect()
    }

    fn tenant(&self, tenant: &str) -> Result<Arc<Tenant>> {
        let tenants = self.tenants.read().unwrap();
        match tenants.get(tenant) {
            Some(t) => Ok(Arc::clone(t)),
            None => {
                let known = if tenants.is_empty() {
                    "none".to_string()
                } else {
                    tenants.keys().cloned().collect::<Vec<_>>().join(", ")
                };
                Err(KamaeError::UnknownTenant(format!(
                    "no tenant '{tenant}' registered (tenants: {known})"
                )))
            }
        }
    }
}

impl Default for SpecRegistry {
    fn default() -> Self {
        SpecRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::super::batcher::{BatchConfig, Server};
    use super::*;
    use crate::dataframe::{Column, DataFrame};
    use crate::runtime::Tensor;
    use std::sync::atomic::AtomicBool;

    /// Two-variant mock backend over one f64 column `x`: variant "a"
    /// serves `[ka*x]`, variant "b" serves `[kb*x]`, untargeted
    /// requests get both. Distinct `(ka, kb)` pairs make versions
    /// bit-distinguishable for every `x >= 1`.
    struct ScaleBackend {
        name: String,
        variants: Vec<String>,
        ka: f64,
        kb: f64,
    }

    impl ScaleBackend {
        fn new(name: &str, ka: f64, kb: f64) -> ScaleBackend {
            ScaleBackend {
                name: name.to_string(),
                variants: vec!["a".into(), "b".into()],
                ka,
                kb,
            }
        }

        fn scale(df: &DataFrame, k: f64) -> crate::error::Result<Tensor> {
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| (k * x) as f32).collect(), vec![v.len()])
        }
    }

    impl Backend for ScaleBackend {
        fn name(&self) -> &str {
            &self.name
        }

        fn process(&self, df: &DataFrame) -> crate::error::Result<Vec<Tensor>> {
            Ok(vec![Self::scale(df, self.ka)?, Self::scale(df, self.kb)?])
        }

        fn variants(&self) -> &[String] {
            &self.variants
        }

        fn process_routed(
            &self,
            df: &DataFrame,
            groups: &[super::super::backend::VariantGroup],
        ) -> crate::error::Result<Vec<Vec<Tensor>>> {
            groups
                .iter()
                .map(|g| {
                    let slice = df.slice(g.rows.start, g.rows.len());
                    match g.variant.as_deref() {
                        Some("a") => Ok(vec![Self::scale(&slice, self.ka)?]),
                        Some("b") => Ok(vec![Self::scale(&slice, self.kb)?]),
                        None => Ok(vec![
                            Self::scale(&slice, self.ka)?,
                            Self::scale(&slice, self.kb)?,
                        ]),
                        Some(other) => Err(KamaeError::Serving(format!(
                            "unknown variant {other}"
                        ))),
                    }
                })
                .collect()
        }
    }

    fn req(vals: &[f64]) -> DataFrame {
        DataFrame::new(vec![("x".into(), Column::from_f64(vals.to_vec()))]).unwrap()
    }

    /// Expected response tensors for one request under a given version's
    /// scale pair — the dedicated per-version oracle.
    fn oracle(vals: &[f64], ka: f64, kb: f64, variant: Option<&str>) -> Vec<Vec<f32>> {
        let s = |k: f64| vals.iter().map(|&x| (k * x) as f32).collect::<Vec<f32>>();
        match variant {
            Some("a") => vec![s(ka)],
            Some("b") => vec![s(kb)],
            _ => vec![s(ka), s(kb)],
        }
    }

    fn matches(got: &[Tensor], want: &[Vec<f32>]) -> bool {
        got.len() == want.len()
            && got
                .iter()
                .zip(want)
                .all(|(t, w)| t.as_f32().map(|d| d == w.as_slice()).unwrap_or(false))
    }

    #[test]
    fn unknown_tenant_and_version_conflicts_are_typed() {
        let registry = SpecRegistry::new();
        let err = registry.resolve("ghost").unwrap_err();
        assert!(matches!(err, KamaeError::UnknownTenant(_)), "{err}");
        assert!(err.to_string().contains("ghost"), "{err}");

        // expect_version on a missing tenant: 0 creates, anything else
        // conflicts
        let err = registry
            .deploy_backend("t", Arc::new(ScaleBackend::new("v", 2.0, 3.0)), Some(3))
            .unwrap_err();
        assert!(matches!(err, KamaeError::VersionConflict(_)), "{err}");
        let d = registry
            .deploy_backend("t", Arc::new(ScaleBackend::new("v1", 2.0, 3.0)), Some(0))
            .unwrap();
        assert_eq!(d.version, 1);

        // CAS guard: a stale expected version loses and changes nothing
        let err = registry
            .deploy_backend("t", Arc::new(ScaleBackend::new("v2", 5.0, 7.0)), Some(9))
            .unwrap_err();
        assert!(matches!(err, KamaeError::VersionConflict(_)), "{err}");
        assert_eq!(registry.resolve("t").unwrap().version(), 1);
        let d = registry
            .deploy_backend("t", Arc::new(ScaleBackend::new("v2", 5.0, 7.0)), Some(1))
            .unwrap();
        assert_eq!(d.version, 2);
        assert_eq!(registry.resolve("t").unwrap().version(), 2);
    }

    #[test]
    fn rollback_walks_history_and_redeploy_moves_forward() {
        let registry = SpecRegistry::new();
        for (name, ka, kb) in [("v1", 2.0, 3.0), ("v2", 5.0, 7.0), ("v3", 11.0, 13.0)] {
            registry
                .deploy_backend("t", Arc::new(ScaleBackend::new(name, ka, kb)), None)
                .unwrap();
        }
        assert_eq!(registry.resolve("t").unwrap().version(), 3);
        // default rollback: one step back, warm Arc, no rebuild
        let r = registry.rollback("t", None).unwrap();
        assert_eq!((r.version, r.backend.as_str()), (2, "v2"));
        assert_eq!(registry.resolve("t").unwrap().version(), 2);
        // again: back to v1; a third has nowhere to go
        registry.rollback("t", None).unwrap();
        assert_eq!(registry.resolve("t").unwrap().version(), 1);
        let err = registry.rollback("t", None).unwrap_err();
        assert!(matches!(err, KamaeError::VersionConflict(_)), "{err}");
        // targeted rollback jumps anywhere in history
        let r = registry.rollback("t", Some(3)).unwrap();
        assert_eq!(r.version, 3);
        let err = registry.rollback("t", Some(99)).unwrap_err();
        assert!(matches!(err, KamaeError::VersionConflict(_)), "{err}");
        let err = registry.rollback("ghost", None).unwrap_err();
        assert!(matches!(err, KamaeError::UnknownTenant(_)), "{err}");
        // a new deploy from the rolled-back state still gets a fresh
        // monotonic version
        let d = registry
            .deploy_backend("t", Arc::new(ScaleBackend::new("v4", 17.0, 19.0)), None)
            .unwrap();
        assert_eq!(d.version, 4);
        let snap = registry.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].active_version, 4);
        assert_eq!(snap[0].versions.len(), 4);
        assert!(snap[0].versions.iter().filter(|v| v.active).count() == 1);
    }

    #[test]
    fn swap_under_load_serves_each_request_from_exactly_one_version() {
        // 4 producers hammer one tenant with mixed-variant requests
        // while a deployer swaps between two bit-distinguishable scale
        // pairs ~25 times. Every response must be bit-identical to
        // exactly ONE version's dedicated oracle (a torn batch would
        // match neither), no request may error or drop, and the
        // per-version counters must account for every request.
        const PRODUCERS: i64 = 4;
        const REQUESTS: i64 = 80;
        const DEPLOYS: usize = 24;
        let registry = Arc::new(SpecRegistry::new());
        registry
            .deploy_backend("shop", Arc::new(ScaleBackend::new("v-2-3", 2.0, 3.0)), None)
            .unwrap();
        let server = Server::start_registry(
            Arc::clone(&registry),
            BatchConfig {
                workers: 4,
                max_batch_rows: 32,
                max_wait: Duration::from_micros(200),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let done = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let registry = &registry;
            let server = &server;
            let done = &done;
            scope.spawn(move || {
                for d in 0..DEPLOYS {
                    // alternate between the two scale pairs; every
                    // deploy is a full build-then-swap
                    let (name, ka, kb) =
                        if d % 2 == 0 { ("v-5-7", 5.0, 7.0) } else { ("v-2-3", 2.0, 3.0) };
                    registry
                        .deploy_backend("shop", Arc::new(ScaleBackend::new(name, ka, kb)), None)
                        .unwrap();
                    std::thread::sleep(Duration::from_micros(300));
                    if done.load(Ordering::Relaxed) {
                        break;
                    }
                }
            });
            for t in 0..PRODUCERS {
                scope.spawn(move || {
                    for i in 0..REQUESTS {
                        // x >= 1 so the scale pairs are bit-distinct
                        let v = (t * 1000 + i + 1) as f64;
                        let vals = [v, v + 0.5];
                        let variant = match i % 3 {
                            0 => Some("a"),
                            1 => Some("b"),
                            _ => None,
                        };
                        let rx = server.submit_tenant(req(&vals), "shop", variant);
                        let got = rx
                            .recv()
                            .expect("response channel dropped")
                            .unwrap_or_else(|e| panic!("request errored: {e}"));
                        let w1 = oracle(&vals, 2.0, 3.0, variant);
                        let w2 = oracle(&vals, 5.0, 7.0, variant);
                        let (m1, m2) = (matches(&got, &w1), matches(&got, &w2));
                        assert!(
                            m1 ^ m2,
                            "producer {t} request {i}: response matches {} version oracle",
                            if m1 { "more than one" } else { "no" }
                        );
                    }
                });
            }
        });
        done.store(true, Ordering::Relaxed);
        let (_, requests) = server.counts();
        assert_eq!(requests, (PRODUCERS * REQUESTS) as u64, "pool lost or duplicated requests");
        server.shutdown();
        // per-version counters account for every request
        let snap = registry.snapshot();
        assert_eq!(snap.len(), 1);
        let total: u64 = snap[0].versions.iter().map(|v| v.requests).sum();
        assert_eq!(total, (PRODUCERS * REQUESTS) as u64, "version counters lost requests");
        // the deployer really swapped: more than the initial version
        // exists and at least two versions served traffic (the swap
        // storm overlaps the producers)
        assert!(snap[0].versions.len() > 1, "no deploy landed during the stress run");
        assert!(
            snap[0].versions.iter().filter(|v| v.requests > 0).count() >= 2,
            "all traffic landed on one version — the swap was never observed"
        );
    }
}
