//! Dynamic batcher + worker-pool server loop.
//!
//! Requests (small DataFrames) queue onto one shared [`JobQueue`]; N
//! worker threads ([`BatchConfig::workers`]) each drain up to
//! `max_batch_rows` or until `max_wait` elapses from the first queued
//! request, concatenate their drained jobs into one batch, run the
//! job's resolved backend once, then split the output tensors back per
//! request — amortising graph-execution overhead exactly the way
//! TF-Serving's dynamic batching does for the paper's production
//! service, but across every core instead of one.
//!
//! ## Registry resolution & hot swap
//!
//! The pool no longer owns a backend: every job carries the
//! `Arc<TenantVersion>` it resolved from the shared
//! [`SpecRegistry`] at submit time ([`Server::submit_tenant`]), so ONE
//! pool serves many tenants and a live deploy never touches the pool.
//! Workers sub-batch the jobs they drained by resolved version
//! (`Arc::ptr_eq` — a version is identity, not equality) and run each
//! version's backend exactly once per sub-batch; a job drained across a
//! hot swap still executes on the version it resolved, so in-flight
//! requests finish on the old backend bit-for-bit while new arrivals
//! resolve the new one. The single-spec [`Server::start`] /
//! [`Server::start_shared`] API is a thin wrapper: a one-tenant
//! registry under [`DEFAULT_TENANT`].
//!
//! ## Worker pool
//!
//! Backends are shared (`Arc<dyn Backend>`, immutable once deployed),
//! so workers call them concurrently with no synchronisation of their
//! own: batch formation is serialised by the queue mutex (held only
//! while *draining*, never while *processing*), and everything after
//! the drain — concat, backend call, response split — runs outside any
//! lock. Each worker owns its [`WorkerMetrics`]; the hot path touches
//! no shared mutex, and [`Server::busy_time`] / [`Server::counts`] /
//! [`Server::variant_counts`] merge the per-worker counters at read
//! time.
//!
//! Per-request response order is unaffected by pooling: every job
//! carries its own response channel, and a batch's responses are sent in
//! the batch's original job order, whichever worker served it.
//!
//! ## Variant routing
//!
//! A request may target one **variant** of a merged multi-variant
//! backend ([`Server::submit_variant`]). Each worker still coalesces the
//! mixed-variant submissions it drained into ONE batch: jobs are sorted
//! into contiguous per-variant groups (arrival order preserved within
//! each group), the frames are concatenated in group order, and the
//! backend runs once via [`Backend::process_routed`] — the shared
//! preprocessing prefix executes a single time over the whole mixed
//! batch while each variant's exclusive work runs only on its own rows.
//! A targeted request's response carries exactly its variant's output
//! tensors, in that variant's output order.
//!
//! ## Fault containment
//!
//! Batch execution is **panic-isolated**: every backend call runs under
//! [`std::panic::catch_unwind`], so a bug in one backend can strand
//! neither the worker thread nor the other jobs riding its batch. A
//! failed (erroring or panicking) batch is re-executed by **bisection**
//! down to single rows: transient faults are forgiven (a single row is
//! retried once before being condemned), deterministic row-level
//! failures are isolated as **poison rows** — dead-lettered through the
//! pool's [`DeadLetterSink`] with a `poison` verdict and reported to
//! their own request as [`KamaeError::PoisonRows`] — while every other
//! job in the batch is served bit-identical to a clean run. Workers are
//! additionally supervised: if the drain loop itself ever unwinds, the
//! thread catches the panic and re-enters the loop, so pool capacity
//! never decays ([`Server::workers`] stays [`BatchConfig::workers`]).
//!
//! Requests may carry a **deadline** ([`BatchConfig::request_deadline`]
//! or per-submit): jobs that age out in the queue are answered with a
//! typed [`KamaeError::DeadlineExceeded`] instead of occupying a batch
//! — both by the workers at drain time and by a dedicated reaper thread
//! that sweeps the queue every millisecond, so an expired request gets
//! its 504 promptly even while every worker is stuck in a slow batch.
//! [`Server::worker_panics`] / [`Server::poison_rows`] /
//! [`Server::deadline_expired`] expose the fault counters
//! `/metrics` surfaces.

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};
use crate::runtime::Tensor;

use super::backend::{Backend, VariantGroup};
use super::registry::{SpecRegistry, TenantVersion, DEFAULT_TENANT};
use super::validate::{screen_batch, DeadLetterSink, ValidationReport};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Max rows merged into one backend call.
    pub max_batch_rows: usize,
    /// Max time the first request in a batch waits for company.
    pub max_wait: Duration,
    /// Route variant-tagged requests through
    /// [`Backend::process_routed`] (cone-restricted evaluation, one
    /// merged batch across variants). When `false` the tags are ignored
    /// and every request is served the backend's full output set — the
    /// all-outputs-per-request baseline the routing benchmark gates
    /// against.
    pub route_variants: bool,
    /// Batcher threads draining the shared queue against the ONE shared
    /// backend. `1` reproduces the single-threaded server exactly;
    /// higher values let concurrent batches execute on idle cores
    /// (`benches/worker_pool.rs` gates the scaling win).
    pub workers: usize,
    /// Default per-request deadline, measured from submit. A job still
    /// queued when its deadline passes is answered with a typed
    /// [`KamaeError::DeadlineExceeded`] instead of occupying a batch.
    /// `None` (the default) means requests wait indefinitely; the wire
    /// layer's `deadline_ms` overrides this per request.
    pub request_deadline: Option<Duration>,
}

impl Default for BatchConfig {
    fn default() -> Self {
        // max_wait 300µs: at production-like rates (~200 rps) requests
        // rarely overlap, so long waits only pad p50; under bursts the
        // queue drains in whole batches anyway because a worker picks
        // up everything already queued before waiting (§Perf L3 log).
        BatchConfig {
            max_batch_rows: 128,
            max_wait: Duration::from_micros(300),
            route_variants: true,
            workers: 1,
            request_deadline: None,
        }
    }
}

impl BatchConfig {
    /// Reject configurations the drain loop cannot serve: zero workers
    /// would strand every queued request (nothing ever drains), and a
    /// zero row budget used to make the greedy top-up loop a no-op that
    /// still flushed — but only after burning a full `max_wait` per
    /// request, and only by accident of loop ordering. Both are
    /// deployment mistakes that must fail at [`Server::start`], not
    /// hang (or spin) at the first request.
    fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(KamaeError::Serving(
                "BatchConfig::workers must be >= 1 (0 workers would never drain the queue)"
                    .into(),
            ));
        }
        if self.max_batch_rows == 0 {
            return Err(KamaeError::Serving(
                "BatchConfig::max_batch_rows must be >= 1 (a zero row budget cannot batch)"
                    .into(),
            ));
        }
        if self.request_deadline == Some(Duration::ZERO) {
            return Err(KamaeError::Serving(
                "BatchConfig::request_deadline must be > 0 (a zero deadline expires every \
                 request at submit)"
                    .into(),
            ));
        }
        Ok(())
    }
}

struct Job {
    df: DataFrame,
    /// Target variant of a merged multi-variant backend; `None` asks
    /// for the full output set.
    variant: Option<String>,
    /// The tenant version this request resolved at submit time. The job
    /// executes on THIS backend even if a deploy swaps the tenant's
    /// active version while it is queued — hot swaps never change a
    /// request mid-flight.
    resolved: Arc<TenantVersion>,
    resp: mpsc::Sender<Result<Vec<Tensor>>>,
    /// When the job entered the queue — the numerator of the "how long
    /// did it wait" half of a deadline-exceeded answer.
    enqueued: Instant,
    /// Absolute expiry instant (`enqueued + deadline`). `None` waits
    /// forever.
    deadline: Option<Instant>,
}

impl Job {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Answer an expired job with the typed deadline error and count it.
    fn answer_expired(self, now: Instant, stats: &PoolStats) {
        stats.deadline_expired.fetch_add(1, Ordering::Relaxed);
        let deadline = self.deadline.expect("answer_expired on a job without a deadline");
        let configured = deadline.saturating_duration_since(self.enqueued);
        let waited = now.saturating_duration_since(self.enqueued);
        let _ = self.resp.send(Err(KamaeError::DeadlineExceeded(format!(
            "request deadline {configured:?} exceeded after {waited:?} in queue"
        ))));
    }
}

/// Pool-level fault counters, shared by every worker and the reaper.
/// Surfaced through [`Server::worker_panics`] /
/// [`Server::deadline_expired`] / [`Server::poison_rows`] and stamped
/// into `ServeReport` by the network layer.
struct PoolStats {
    /// Panics caught at the batch-execution boundary (including
    /// bisection probes) plus drain-loop unwinds survived by the worker
    /// supervision wrapper.
    worker_panics: AtomicU64,
    /// Jobs answered with [`KamaeError::DeadlineExceeded`] instead of
    /// executing.
    deadline_expired: AtomicU64,
    /// Rows isolated by bisection as deterministic backend-crashers and
    /// dead-lettered with a `poison` verdict.
    poison_rows: AtomicU64,
}

impl PoolStats {
    fn new() -> PoolStats {
        PoolStats {
            worker_panics: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            poison_rows: AtomicU64::new(0),
        }
    }
}

/// The shared request queue: a deque + condvar that N workers drain in
/// batches. Replaces the PR 4 `mpsc` channel, whose receiver is
/// single-consumer by construction.
struct JobQueue {
    state: Mutex<QueueState>,
    cond: Condvar,
}

struct QueueState {
    jobs: VecDeque<Job>,
    /// Set at shutdown: producers are rejected, workers drain whatever
    /// is still queued and then exit.
    closed: bool,
}

impl JobQueue {
    fn new() -> JobQueue {
        JobQueue {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            cond: Condvar::new(),
        }
    }

    /// Enqueue a job, handing it back if the queue is already closed
    /// (the caller errors that request's own response channel).
    fn push(&self, job: Job) -> std::result::Result<(), Job> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(job);
        }
        s.jobs.push_back(job);
        drop(s);
        self.cond.notify_one();
        Ok(())
    }

    /// Close the queue: producers start bouncing, every worker wakes to
    /// drain the remainder and exit.
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    /// Jobs currently queued (not yet drained by a worker) — the load
    /// signal behind the shed path's dynamic `Retry-After` hint.
    fn depth(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    /// Remove every job whose deadline has passed, returning them for
    /// the caller to answer OUTSIDE the lock, plus whether the queue is
    /// finished (closed and empty) — the reaper's exit signal.
    fn take_expired(&self, now: Instant) -> (Vec<Job>, bool) {
        let mut s = self.state.lock().unwrap();
        let mut expired = Vec::new();
        if s.jobs.iter().any(|j| j.expired(now)) {
            let kept: VecDeque<Job> = std::mem::take(&mut s.jobs)
                .into_iter()
                .filter_map(|j| if j.expired(now) { expired.push(j); None } else { Some(j) })
                .collect();
            s.jobs = kept;
        }
        let done = s.closed && s.jobs.is_empty();
        (expired, done)
    }

    /// Drain the next batch for one worker: block for the first job,
    /// greedily take everything already queued up to `max_rows`, then
    /// wait at most `max_wait` (from the first job) for stragglers.
    /// Returns `None` once the queue is closed AND empty — the worker's
    /// exit signal. The lock is held only while moving jobs out of the
    /// deque; it is released during the straggler wait (other workers
    /// keep draining concurrently) and for the entire backend call.
    fn pop_batch(&self, max_rows: usize, max_wait: Duration) -> Option<Vec<Job>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(first) = s.jobs.pop_front() {
                let mut rows = first.df.num_rows();
                let mut jobs = vec![first];
                // greedily take everything already queued (free batching)
                while rows < max_rows {
                    match s.jobs.pop_front() {
                        Some(job) => {
                            rows += job.df.num_rows();
                            jobs.push(job);
                        }
                        None => break,
                    }
                }
                // then wait at most max_wait for stragglers — but only
                // if the batch still has headroom and nobody is
                // shutting down (a closing queue flushes immediately)
                let deadline = Instant::now() + max_wait;
                while rows < max_rows && !s.closed {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) =
                        self.cond.wait_timeout(s, deadline - now).unwrap();
                    s = guard;
                    while rows < max_rows {
                        match s.jobs.pop_front() {
                            Some(job) => {
                                rows += job.df.num_rows();
                                jobs.push(job);
                            }
                            None => break,
                        }
                    }
                    if timeout.timed_out() {
                        break;
                    }
                }
                return Some(jobs);
            }
            if s.closed {
                return None;
            }
            s = self.cond.wait(s).unwrap();
        }
    }
}

/// One worker's counters. Owned exclusively by that worker on the hot
/// path — the atomics exist so [`Server`] can *read* them while the
/// worker runs, and the variant map's mutex is only ever contended by
/// report-time readers, never by another worker.
struct WorkerMetrics {
    busy_ns: AtomicU64,
    batches: AtomicU64,
    requests: AtomicU64,
    /// Requests served per variant tag (untargeted requests count under
    /// `""`) — merged into the per-variant split
    /// [`crate::serving::ServeReport`] surfaces.
    variant_requests: Mutex<BTreeMap<String, u64>>,
}

impl WorkerMetrics {
    fn new() -> WorkerMetrics {
        WorkerMetrics {
            busy_ns: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            variant_requests: Mutex::new(BTreeMap::new()),
        }
    }
}

/// A running server: N batcher threads draining one shared queue, each
/// job executing on the tenant version it resolved from the shared
/// [`SpecRegistry`] at submit time.
pub struct Server {
    queue: Arc<JobQueue>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// The deadline reaper: sweeps expired jobs out of the queue every
    /// millisecond so a 504 answer never waits for a busy worker.
    reaper: Option<std::thread::JoinHandle<()>>,
    metrics: Vec<Arc<WorkerMetrics>>,
    /// Shared fault counters (panics caught, deadlines expired, poison
    /// rows isolated).
    stats: Arc<PoolStats>,
    /// The registry requests resolve against. Deploys/rollbacks through
    /// this handle take effect on the NEXT submit; nothing queued or
    /// in-flight changes.
    registry: Arc<SpecRegistry>,
    /// Captured from [`BatchConfig::route_variants`]: when off, variant
    /// tags are ignored rather than validated, so submits skip the
    /// known-variant check.
    route_variants: bool,
    /// When the pool started serving — the denominator of the lifetime
    /// drain rate behind the shed path's `Retry-After` hint.
    started: Instant,
    /// Captured from [`BatchConfig::request_deadline`]: the default
    /// deadline stamped on submits that don't carry their own.
    request_deadline: Option<Duration>,
}

impl Server {
    /// Spawn the worker pool over an owned backend. Rejects
    /// un-serveable configs ([`BatchConfig`] with zero workers or a
    /// zero row budget) with [`KamaeError::Serving`] instead of
    /// spawning a pool that can never answer.
    pub fn start(backend: Box<dyn Backend>, config: BatchConfig) -> Result<Server> {
        Server::start_shared(Arc::from(backend), config)
    }

    /// [`Server::start`] over an already-shared backend — callers that
    /// keep probing the backend while the server runs (benches, tests)
    /// clone the `Arc` instead of round-tripping raw pointers. A thin
    /// wrapper over [`Server::start_registry`] with a one-tenant
    /// registry ([`DEFAULT_TENANT`]) — the single-spec API is
    /// registry-backed underneath, so it inherits hot-swap support for
    /// free while behaving exactly as before.
    pub fn start_shared(backend: Arc<dyn Backend>, config: BatchConfig) -> Result<Server> {
        config.validate()?;
        Server::start_registry(SpecRegistry::single(DEFAULT_TENANT, backend)?, config)
    }

    /// Spawn the worker pool over a [`SpecRegistry`]: requests address
    /// tenants ([`Server::submit_tenant`]), deploys/rollbacks through
    /// the registry handle swap versions with zero downtime.
    pub fn start_registry(registry: Arc<SpecRegistry>, config: BatchConfig) -> Result<Server> {
        Server::start_registry_sink(registry, config, None)
    }

    /// [`Server::start_registry`] with a pool-level dead-letter sink:
    /// poison rows isolated by bisection are recorded here (as JSON
    /// re-encodings of the frame rows) with a `poison` verdict. The
    /// network front-end passes its JSONL sink so request-time
    /// quarantines and execution-time poison land in the same file.
    pub fn start_registry_sink(
        registry: Arc<SpecRegistry>,
        config: BatchConfig,
        sink: Option<Arc<dyn DeadLetterSink>>,
    ) -> Result<Server> {
        config.validate()?;
        let queue = Arc::new(JobQueue::new());
        let stats = Arc::new(PoolStats::new());
        let mut metrics = Vec::with_capacity(config.workers);
        let mut workers = Vec::with_capacity(config.workers);
        for i in 0..config.workers {
            let m = Arc::new(WorkerMetrics::new());
            metrics.push(Arc::clone(&m));
            let queue = Arc::clone(&queue);
            let stats = Arc::clone(&stats);
            let sink = sink.clone();
            let config = config.clone();
            let handle = std::thread::Builder::new()
                .name(format!("kamae-batcher-{i}"))
                // supervision wrapper: batch execution is already
                // panic-isolated inside worker_loop, but if the drain
                // loop itself ever unwinds, catch it and re-enter — the
                // worker "respawns" in place and pool capacity never
                // decays. Ok(()) means the queue closed: a clean exit.
                .spawn(move || loop {
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        worker_loop(&config, &queue, &m, &stats, sink.as_deref())
                    }));
                    match r {
                        Ok(()) => break,
                        Err(_) => {
                            stats.worker_panics.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                })
                .map_err(|e| {
                    KamaeError::Serving(format!("failed to spawn batcher worker {i}: {e}"))
                });
            match handle {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // unwind the partial pool before surfacing the error
                    queue.close();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e);
                }
            }
        }
        let reaper = {
            let queue = Arc::clone(&queue);
            let stats = Arc::clone(&stats);
            std::thread::Builder::new()
                .name("kamae-reaper".into())
                .spawn(move || reaper_loop(&queue, &stats))
                .ok()
        };
        Ok(Server {
            queue,
            workers,
            reaper,
            metrics,
            stats,
            registry,
            route_variants: config.route_variants,
            started: Instant::now(),
            request_deadline: config.request_deadline,
        })
    }

    /// The registry this pool resolves requests against — deploy /
    /// rollback / snapshot through this handle while the pool serves.
    pub fn registry(&self) -> &Arc<SpecRegistry> {
        &self.registry
    }

    /// Submit an untargeted request to the default tenant; the receiver
    /// yields the backend's full output tensors for this request's rows.
    pub fn submit(&self, df: DataFrame) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        self.submit_tenant(df, DEFAULT_TENANT, None)
    }

    /// Submit a request targeting one variant of the default tenant's
    /// merged multi-variant backend; the receiver yields only that
    /// variant's output tensors (in the variant's own output order).
    /// Unknown variants (or a backend without variant support) error on
    /// THIS request's receiver immediately — the bad tag never reaches
    /// a worker, so it cannot fail the requests it would have been
    /// coalesced with.
    pub fn submit_variant(
        &self,
        df: DataFrame,
        variant: &str,
    ) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        self.submit_tenant(df, DEFAULT_TENANT, Some(variant))
    }

    /// Submit a request addressed to `tenant` (optionally targeting one
    /// of its variants): resolves the tenant's active version ONCE,
    /// then rides that version to completion regardless of concurrent
    /// deploys. Unknown tenants and (when routing is on) unknown
    /// variants error on this request's own receiver immediately.
    pub fn submit_tenant(
        &self,
        df: DataFrame,
        tenant: &str,
        variant: Option<&str>,
    ) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        match self.registry.resolve(tenant) {
            Ok(resolved) => self.submit_resolved(df, variant.map(str::to_string), resolved),
            Err(e) => Self::reject(e),
        }
    }

    /// Submit against an already-resolved tenant version — callers that
    /// validated a request against a version (the network front-end)
    /// use this so validation, execution and output naming all see the
    /// SAME version even across a concurrent hot swap.
    pub fn submit_resolved(
        &self,
        df: DataFrame,
        variant: Option<String>,
        resolved: Arc<TenantVersion>,
    ) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        self.submit_resolved_deadline(df, variant, resolved, None)
    }

    /// [`Server::submit_resolved`] with a per-request deadline override.
    /// `None` falls back to [`BatchConfig::request_deadline`]; the wire
    /// layer passes the request's `deadline_ms` here.
    pub fn submit_resolved_deadline(
        &self,
        df: DataFrame,
        variant: Option<String>,
        resolved: Arc<TenantVersion>,
        deadline: Option<Duration>,
    ) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        if self.route_variants {
            if let Some(v) = &variant {
                let known = resolved.variants();
                if !known.iter().any(|k| k == v) {
                    return Self::reject(KamaeError::Serving(format!(
                        "no variant '{v}' to route to (backend variants: {})",
                        known.join(", ")
                    )));
                }
            }
        }
        let enqueued = Instant::now();
        let deadline = deadline.or(self.request_deadline).map(|d| enqueued + d);
        let (resp_tx, resp_rx) = mpsc::channel();
        let job = Job { df, variant, resolved, resp: resp_tx, enqueued, deadline };
        if let Err(job) = self.queue.push(job) {
            let _ = job.resp.send(Err(KamaeError::ShuttingDown));
        }
        resp_rx
    }

    /// [`Server::submit_tenant`] behind the ingress data-quality gate:
    /// the request is screened against the resolved version's
    /// [`ValidationSpec`](super::validate::ValidationSpec), quarantined
    /// rows are dead-lettered to `sink` (as JSON re-encodings of the
    /// frame rows — the wire layer dead-letters the original raw JSON
    /// instead), and the COMPACTED batch is submitted. The returned
    /// report maps the response tensors (valid rows only, original
    /// relative order) back to original row positions.
    ///
    /// A batch with zero valid rows short-circuits: the receiver is
    /// primed with an empty tensor list and no backend runs — the
    /// verdicts in the report are the entire answer. Versions without a
    /// validation spec (spec-less backends) pass through unscreened
    /// with an all-valid report.
    pub fn submit_tenant_validated(
        &self,
        df: DataFrame,
        tenant: &str,
        variant: Option<&str>,
        deadline: Option<Duration>,
        sink: Option<&dyn DeadLetterSink>,
    ) -> (mpsc::Receiver<Result<Vec<Tensor>>>, ValidationReport) {
        let nrows = df.num_rows();
        let resolved = match self.registry.resolve(tenant) {
            Ok(r) => r,
            Err(e) => return (Self::reject(e), ValidationReport::all_valid(nrows)),
        };
        let Some(spec) = resolved.validation() else {
            let rx =
                self.submit_resolved_deadline(df, variant.map(str::to_string), resolved, deadline);
            return (rx, ValidationReport::all_valid(nrows));
        };
        let (clean, report) = match screen_batch(spec, &df, Vec::new()) {
            Ok(v) => v,
            Err(e) => return (Self::reject(e), ValidationReport::all_valid(nrows)),
        };
        if let Some(sink) = sink {
            for i in report.quarantined() {
                sink.record(tenant, &crate::dataframe::row_to_json(&df, i), &report.errors[i]);
            }
        }
        if report.num_valid() == 0 {
            // all-quarantined: answer now, the backend never sees an
            // empty batch
            let (resp_tx, resp_rx) = mpsc::channel();
            let _ = resp_tx.send(Ok(Vec::new()));
            return (resp_rx, report);
        }
        let rx =
            self.submit_resolved_deadline(clean, variant.map(str::to_string), resolved, deadline);
        (rx, report)
    }

    /// Panics caught at the batch-execution boundary (plus drain-loop
    /// unwinds the worker supervision wrapper survived).
    pub fn worker_panics(&self) -> u64 {
        self.stats.worker_panics.load(Ordering::Relaxed)
    }

    /// Requests answered with [`KamaeError::DeadlineExceeded`] because
    /// they aged out in the queue.
    pub fn deadline_expired(&self) -> u64 {
        self.stats.deadline_expired.load(Ordering::Relaxed)
    }

    /// Rows isolated by bisection as deterministic backend-crashers and
    /// routed to the pool's dead-letter sink with a `poison` verdict.
    pub fn poison_rows(&self) -> u64 {
        self.stats.poison_rows.load(Ordering::Relaxed)
    }

    /// A receiver already primed with `err` — submit-time rejections
    /// fail their OWN request without touching the queue.
    fn reject(err: KamaeError) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let _ = resp_tx.send(Err(err));
        resp_rx
    }

    /// Requests queued but not yet drained by a worker.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Requests/second the pool has drained over its lifetime — with
    /// [`Server::queue_depth`], the inputs to the shed path's dynamic
    /// `Retry-After` hint. 0.0 until the first request completes.
    pub fn drain_rate_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.counts().1 as f64 / secs
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Total backend-execution time summed across workers (the cost
    /// proxy: CPU-seconds of preprocessing work).
    pub fn busy_time(&self) -> Duration {
        self.worker_busy_times().into_iter().sum()
    }

    /// Per-worker backend-execution time, in worker order — feeds the
    /// per-worker utilization split in
    /// [`crate::serving::ServeReport`].
    pub fn worker_busy_times(&self) -> Vec<Duration> {
        self.metrics
            .iter()
            .map(|m| Duration::from_nanos(m.busy_ns.load(Ordering::Relaxed)))
            .collect()
    }

    /// (batches executed, requests served) across the pool — batching
    /// efficiency.
    pub fn counts(&self) -> (u64, u64) {
        self.metrics.iter().fold((0, 0), |(b, r), m| {
            (
                b + m.batches.load(Ordering::Relaxed),
                r + m.requests.load(Ordering::Relaxed),
            )
        })
    }

    /// Requests served per variant tag (untargeted under `""`), merged
    /// across workers.
    pub fn variant_counts(&self) -> BTreeMap<String, u64> {
        let mut merged = BTreeMap::new();
        for m in &self.metrics {
            for (variant, n) in m.variant_requests.lock().unwrap().iter() {
                *merged.entry(variant.clone()).or_insert(0) += n;
            }
        }
        merged
    }

    /// Stop the pool and wait for every worker. Requests already queued
    /// are still served before the workers exit (the queue drains
    /// before disconnecting).
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(r) = self.reaper.take() {
            let _ = r.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_loop(
    config: &BatchConfig,
    queue: &JobQueue,
    metrics: &WorkerMetrics,
    stats: &PoolStats,
    sink: Option<&dyn DeadLetterSink>,
) {
    while let Some(jobs) = queue.pop_batch(config.max_batch_rows, config.max_wait) {
        // expired jobs never occupy a batch: answer them with the typed
        // deadline error before anything executes
        let now = Instant::now();
        let (jobs, expired): (Vec<Job>, Vec<Job>) =
            jobs.into_iter().partition(|j| !j.expired(now));
        for job in expired {
            job.answer_expired(now, stats);
        }
        if jobs.is_empty() {
            continue;
        }
        {
            // this worker is the map's only hot-path writer; the lock
            // is for report-time readers and therefore uncontended here
            let mut counts = metrics.variant_requests.lock().unwrap();
            for job in &jobs {
                *counts.entry(job.variant.clone().unwrap_or_default()).or_insert(0) += 1;
            }
        }
        // sub-batch by resolved tenant version (Arc identity): a drain
        // can straddle tenants — or a hot swap on ONE tenant — and each
        // version's backend must see only its own jobs. Arrival order
        // is preserved within each sub-batch; in the common steady
        // state (one tenant, no swap in flight) this is a single group
        // and the loop body is exactly the pre-registry hot path.
        let mut sub_batches: Vec<(Arc<TenantVersion>, Vec<Job>)> = Vec::new();
        for job in jobs {
            match sub_batches.iter_mut().find(|(v, _)| Arc::ptr_eq(v, &job.resolved)) {
                Some((_, members)) => members.push(job),
                None => {
                    let version = Arc::clone(&job.resolved);
                    sub_batches.push((version, vec![job]));
                }
            }
        }
        for (version, jobs) in sub_batches {
            let routed = config.route_variants && jobs.iter().any(|j| j.variant.is_some());
            let t0 = Instant::now();
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            metrics.requests.fetch_add(jobs.len() as u64, Ordering::Relaxed);
            version.record_served(jobs.len() as u64);

            match run_protected(&version, &jobs, routed, stats) {
                Ok(per_job) => {
                    for (job, tensors) in jobs.into_iter().zip(per_job) {
                        let _ = job.resp.send(Ok(tensors));
                    }
                }
                // the clean path failed (error or caught panic):
                // re-execute by bisection so one bad row cannot take
                // down the whole merged batch
                Err(_) => isolate_jobs(&version, jobs, routed, stats, sink),
            }
            metrics.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

/// The deadline reaper: while workers can be stuck inside a slow batch,
/// this loop sweeps the queue every millisecond and answers expired
/// jobs immediately — an aged-out request gets its typed 504 in
/// milliseconds, not after the pool frees up. Exits once the queue is
/// closed and drained.
fn reaper_loop(queue: &JobQueue, stats: &PoolStats) {
    loop {
        std::thread::sleep(Duration::from_millis(1));
        let now = Instant::now();
        let (expired, done) = queue.take_expired(now);
        for job in expired {
            job.answer_expired(now, stats);
        }
        if done {
            break;
        }
    }
}

/// What a protected batch execution can fail with: a backend error or a
/// panic caught at the isolation boundary.
enum Fault {
    Error(KamaeError),
    Panic(String),
}

impl Fault {
    fn message(&self) -> String {
        match self {
            Fault::Error(e) => e.to_string(),
            Fault::Panic(m) => format!("backend panicked: {m}"),
        }
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute one sub-batch behind the panic-isolation boundary. Backends
/// are `Sync` and immutable once deployed, so observing one mid-panic
/// cannot corrupt it — `AssertUnwindSafe` is sound here. Every caught
/// panic bumps the pool's `worker_panics` counter.
fn run_protected(
    version: &TenantVersion,
    jobs: &[Job],
    routed: bool,
    stats: &PoolStats,
) -> std::result::Result<Vec<Vec<Tensor>>, Fault> {
    let backend = version.backend();
    let result = catch_unwind(AssertUnwindSafe(|| {
        if routed {
            run_batch_routed(backend, jobs)
        } else {
            run_batch(backend, jobs)
        }
    }));
    match result {
        Ok(Ok(per_job)) => Ok(per_job),
        Ok(Err(e)) => Err(Fault::Error(e)),
        Err(payload) => {
            stats.worker_panics.fetch_add(1, Ordering::Relaxed);
            Err(Fault::Panic(panic_message(payload)))
        }
    }
}

/// Run one frame (a slice of a single job) behind the same boundary.
fn probe_frame(
    version: &TenantVersion,
    df: &DataFrame,
    variant: &Option<String>,
    routed: bool,
    stats: &PoolStats,
) -> std::result::Result<Vec<Tensor>, Fault> {
    let backend = version.backend();
    let result = catch_unwind(AssertUnwindSafe(|| {
        if routed {
            let groups = vec![VariantGroup { variant: variant.clone(), rows: 0..df.num_rows() }];
            backend.process_routed(df, &groups).map(|mut v| v.remove(0))
        } else {
            backend.process(df)
        }
    }));
    match result {
        Ok(Ok(tensors)) => Ok(tensors),
        Ok(Err(e)) => Err(Fault::Error(e)),
        Err(payload) => {
            stats.worker_panics.fetch_add(1, Ordering::Relaxed);
            Err(Fault::Panic(panic_message(payload)))
        }
    }
}

/// Bisect a failed sub-batch at JOB granularity: healthy halves are
/// served bit-identical to a clean run (execution is row-independent,
/// so any partition of the batch yields the same per-row outputs), and
/// a job that fails alone descends to row-level isolation.
fn isolate_jobs(
    version: &TenantVersion,
    mut jobs: Vec<Job>,
    routed: bool,
    stats: &PoolStats,
    sink: Option<&dyn DeadLetterSink>,
) {
    if jobs.len() == 1 {
        let job = jobs.pop().expect("non-empty");
        isolate_rows(version, job, routed, stats, sink);
        return;
    }
    let right = jobs.split_off(jobs.len() / 2);
    for half in [jobs, right] {
        match run_protected(version, &half, routed, stats) {
            Ok(per_job) => {
                for (job, tensors) in half.into_iter().zip(per_job) {
                    let _ = job.resp.send(Ok(tensors));
                }
            }
            Err(_) => isolate_jobs(version, half, routed, stats, sink),
        }
    }
}

/// Row-level isolation for a job that fails on its own: bisect the
/// frame to find the poison row(s), forgiving transients (a single row
/// is retried once before being condemned). Poison rows are
/// dead-lettered with a structured `poison` verdict; the request is
/// answered with [`KamaeError::PoisonRows`] naming them so the caller
/// (the network layer does this automatically) can resubmit the
/// surviving rows.
fn isolate_rows(
    version: &TenantVersion,
    job: Job,
    routed: bool,
    stats: &PoolStats,
    sink: Option<&dyn DeadLetterSink>,
) {
    let n = job.df.num_rows();
    // the job alone may simply work: the original fault could have been
    // transient, or caused by a co-batched neighbour
    let first = match probe_frame(version, &job.df, &job.variant, routed, stats) {
        Ok(tensors) => {
            let _ = job.resp.send(Ok(tensors));
            return;
        }
        Err(fault) => fault,
    };
    let mut poison = Vec::new();
    bisect_rows(version, &job, 0, n, routed, stats, &mut poison);
    if poison.is_empty() {
        // every row passes individually: the fault was transient (or
        // whole-batch-shaped). One more full attempt settles it.
        match probe_frame(version, &job.df, &job.variant, routed, stats) {
            Ok(tensors) => {
                let _ = job.resp.send(Ok(tensors));
            }
            Err(fault) => {
                let _ = job.resp.send(Err(KamaeError::Serving(fault.message())));
            }
        }
        return;
    }
    stats.poison_rows.fetch_add(poison.len() as u64, Ordering::Relaxed);
    if let Some(sink) = sink {
        let errors = [crate::dataframe::RowError {
            rule: "poison".into(),
            column: String::new(),
            message: format!(
                "row crashed the backend (isolated by bisection): {}",
                first.message()
            ),
        }];
        for &i in &poison {
            sink.record(version.tenant(), &crate::dataframe::row_to_json(&job.df, i), &errors);
        }
    }
    let _ = job.resp.send(Err(KamaeError::PoisonRows(poison)));
}

/// Recursive row bisection over `job.df[start..end)`: append the rows
/// that deterministically fail to `poison`. A single row gets one retry
/// so a transient fault (an Nth-batch panic, an allocation hiccup)
/// never condemns an innocent row.
#[allow(clippy::too_many_arguments)]
fn bisect_rows(
    version: &TenantVersion,
    job: &Job,
    start: usize,
    end: usize,
    routed: bool,
    stats: &PoolStats,
    poison: &mut Vec<usize>,
) {
    let slice = job.df.slice(start, end - start);
    if probe_frame(version, &slice, &job.variant, routed, stats).is_ok() {
        return;
    }
    if end - start == 1 {
        if probe_frame(version, &slice, &job.variant, routed, stats).is_ok() {
            return; // transient: forgiven on retry
        }
        poison.push(start);
        return;
    }
    let mid = start + (end - start) / 2;
    bisect_rows(version, job, start, mid, routed, stats, poison);
    bisect_rows(version, job, mid, end, routed, stats, poison);
}

/// Merge jobs, run the backend once, split outputs per job.
fn run_batch(backend: &dyn Backend, jobs: &[Job]) -> Result<Vec<Vec<Tensor>>> {
    let merged = if jobs.len() == 1 {
        jobs[0].df.clone()
    } else {
        let frames: Vec<&DataFrame> = jobs.iter().map(|j| &j.df).collect();
        DataFrame::concat(&frames)?
    };
    let outputs = backend.process(&merged)?;
    if jobs.len() == 1 {
        return Ok(vec![outputs]);
    }
    let sizes: Vec<usize> = jobs.iter().map(|j| j.df.num_rows()).collect();
    // transpose: per-output splits -> per-job tensor lists
    let mut per_job: Vec<Vec<Tensor>> = vec![Vec::with_capacity(outputs.len()); jobs.len()];
    for out in &outputs {
        let parts = out.split_batch(&sizes)?;
        for (slot, part) in per_job.iter_mut().zip(parts) {
            slot.push(part);
        }
    }
    Ok(per_job)
}

/// Variant-routed batch execution: reorder the drained jobs into
/// contiguous per-variant groups (first-appearance group order, arrival
/// order within each group), concatenate once, run the backend's routed
/// path once, then split each group's tensors back to its jobs. The
/// returned per-job tensor lists are in the ORIGINAL job order, so the
/// caller's response loop stays oblivious to the reordering.
fn run_batch_routed(backend: &dyn Backend, jobs: &[Job]) -> Result<Vec<Vec<Tensor>>> {
    // stable-partition job indices into per-variant groups
    let mut group_jobs: Vec<(Option<String>, Vec<usize>)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        match group_jobs.iter_mut().find(|(v, _)| *v == job.variant) {
            Some((_, members)) => members.push(i),
            None => group_jobs.push((job.variant.clone(), vec![i])),
        }
    }
    // concat in group order; build the contiguous row ranges
    let order: Vec<usize> = group_jobs.iter().flat_map(|(_, m)| m.iter().copied()).collect();
    let frames: Vec<&DataFrame> = order.iter().map(|&i| &jobs[i].df).collect();
    let merged = if frames.len() == 1 { frames[0].clone() } else { DataFrame::concat(&frames)? };
    let mut groups = Vec::with_capacity(group_jobs.len());
    let mut start = 0usize;
    for (variant, members) in &group_jobs {
        let len: usize = members.iter().map(|&i| jobs[i].df.num_rows()).sum();
        groups.push(VariantGroup { variant: variant.clone(), rows: start..start + len });
        start += len;
    }

    let per_group = backend.process_routed(&merged, &groups)?;

    // split each group's tensors across its jobs, back in job order
    let mut per_job: Vec<Vec<Tensor>> = jobs.iter().map(|_| Vec::new()).collect();
    for ((_, members), tensors) in group_jobs.iter().zip(per_group) {
        if members.len() == 1 {
            per_job[members[0]] = tensors;
            continue;
        }
        let sizes: Vec<usize> = members.iter().map(|&i| jobs[i].df.num_rows()).collect();
        for out in &tensors {
            let parts = out.split_batch(&sizes)?;
            for (&i, part) in members.iter().zip(parts) {
                per_job[i].push(part);
            }
        }
    }
    Ok(per_job)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    /// Backend that doubles an f64 column; records max batch seen.
    struct Doubler {
        max_batch: std::sync::atomic::AtomicUsize,
    }

    impl Doubler {
        fn new() -> Doubler {
            Doubler { max_batch: Default::default() }
        }
    }

    impl Backend for Doubler {
        fn name(&self) -> &str {
            "doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }
    }

    fn req(vals: &[f64]) -> DataFrame {
        DataFrame::new(vec![("x".into(), Column::from_f64(vals.to_vec()))]).unwrap()
    }

    #[test]
    fn responses_route_back_to_requests() {
        let server = Server::start(
            Box::new(Doubler::new()),
            BatchConfig {
                max_batch_rows: 64,
                max_wait: Duration::from_millis(5),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..20)
            .map(|i| (i, server.submit(req(&[i as f64, i as f64 + 0.5]))))
            .collect();
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].as_f32().unwrap(), &[2.0 * i as f32, 2.0 * i as f32 + 1.0]);
        }
        let (batches, requests) = server.counts();
        assert_eq!(requests, 20);
        assert!(batches <= 20);
        server.shutdown();
    }

    #[test]
    fn degenerate_configs_are_rejected_at_start() {
        // regression (pool refactor): workers == 0 would leave the
        // queue undrained — every submit would hang forever; a zero
        // row budget starved the greedy top-up loop. Both must be a
        // Serving error at start, before any thread spawns.
        for config in [
            BatchConfig { workers: 0, ..BatchConfig::default() },
            BatchConfig { max_batch_rows: 0, ..BatchConfig::default() },
        ] {
            let err = Server::start(Box::new(Doubler::new()), config).unwrap_err();
            assert!(matches!(err, KamaeError::Serving(_)), "{err}");
        }
        // the error message names the offending knob
        let err = Server::start(
            Box::new(Doubler::new()),
            BatchConfig { workers: 0, ..BatchConfig::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("workers"), "{err}");
        let err = Server::start(
            Box::new(Doubler::new()),
            BatchConfig { max_batch_rows: 0, ..BatchConfig::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("max_batch_rows"), "{err}");
    }

    #[test]
    fn batching_actually_merges() {
        let backend = Arc::new(Doubler::new());
        let server = Server::start_shared(
            backend.clone(),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(50),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        // burst of requests within the batching window
        let rxs: Vec<_> = (0..32).map(|_| server.submit(req(&[1.0]))).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let max_seen = backend.max_batch.load(Ordering::Relaxed);
        assert!(max_seen > 1, "batcher never merged (max batch {max_seen})");
        server.shutdown();
    }

    #[test]
    fn oversized_request_is_served_whole() {
        // a single request larger than max_batch_rows must run as its
        // own batch — never stall waiting for headroom, never split, and
        // never drop rows. (The drain loops only *top up* small batches;
        // an oversized first job skips them and executes immediately.)
        let backend = Arc::new(Doubler::new());
        let server = Server::start_shared(
            backend.clone(),
            BatchConfig {
                max_batch_rows: 8,
                max_wait: Duration::from_millis(5),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let vals: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let rx = server.submit(req(&vals));
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 1);
        let got = out[0].as_f32().unwrap();
        assert_eq!(got.len(), 50, "oversized request lost rows");
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, 2.0 * i as f32);
        }
        let (batches, requests) = server.counts();
        assert_eq!((batches, requests), (1, 1), "oversized request was split or retried");
        assert_eq!(
            backend.max_batch.load(Ordering::Relaxed),
            50,
            "backend saw a different batch than submitted"
        );
        server.shutdown();
    }

    #[test]
    fn error_propagates_to_all_requests() {
        struct Failing;
        impl Backend for Failing {
            fn name(&self) -> &str {
                "fail"
            }
            fn process(&self, _: &DataFrame) -> Result<Vec<Tensor>> {
                Err(KamaeError::Serving("boom".into()))
            }
        }
        let server = Server::start(Box::new(Failing), BatchConfig::default()).unwrap();
        let rx = server.submit(req(&[1.0]));
        assert!(rx.recv().unwrap().is_err());
        server.shutdown();
    }

    // ---- variant routing --------------------------------------------------

    /// Two-variant mock backend over one f64 column `x`: variant "dbl"
    /// serves [2x], variant "tri" serves [3x], untargeted requests get
    /// both in that order. Routed calls are counted so tests can pin
    /// which path executed.
    struct VariantDoubler {
        variants: Vec<String>,
        routed_calls: std::sync::atomic::AtomicUsize,
        max_batch: std::sync::atomic::AtomicUsize,
    }

    impl VariantDoubler {
        fn new() -> VariantDoubler {
            VariantDoubler {
                variants: vec!["dbl".into(), "tri".into()],
                routed_calls: Default::default(),
                max_batch: Default::default(),
            }
        }

        fn scale(df: &DataFrame, k: f64) -> Result<Tensor> {
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| (k * x) as f32).collect(), vec![v.len()])
        }
    }

    impl Backend for VariantDoubler {
        fn name(&self) -> &str {
            "variant-doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            Ok(vec![Self::scale(df, 2.0)?, Self::scale(df, 3.0)?])
        }

        fn variants(&self) -> &[String] {
            &self.variants
        }

        fn process_routed(
            &self,
            df: &DataFrame,
            groups: &[super::VariantGroup],
        ) -> Result<Vec<Vec<Tensor>>> {
            self.routed_calls.fetch_add(1, Ordering::Relaxed);
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            groups
                .iter()
                .map(|g| {
                    let slice = df.slice(g.rows.start, g.rows.len());
                    match g.variant.as_deref() {
                        Some("dbl") => Ok(vec![Self::scale(&slice, 2.0)?]),
                        Some("tri") => Ok(vec![Self::scale(&slice, 3.0)?]),
                        None => Ok(vec![Self::scale(&slice, 2.0)?, Self::scale(&slice, 3.0)?]),
                        Some(other) => {
                            Err(KamaeError::Serving(format!("unknown variant {other}")))
                        }
                    }
                })
                .collect()
        }
    }

    #[test]
    fn mixed_variant_batch_routes_back_to_each_request() {
        // interleaved dbl/tri/untargeted submissions within one batching
        // window: every response must carry exactly its variant's
        // outputs for its own rows, whatever the batcher reordered
        let backend = Arc::new(VariantDoubler::new());
        let server = Server::start_shared(
            backend.clone(),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(50),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..24 {
            let vals = [i as f64, i as f64 + 0.25];
            let rx = match i % 3 {
                0 => server.submit_variant(req(&vals), "dbl"),
                1 => server.submit_variant(req(&vals), "tri"),
                _ => server.submit(req(&vals)),
            };
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            let vals = [i as f64, i as f64 + 0.25];
            match i % 3 {
                0 => {
                    assert_eq!(out.len(), 1, "dbl request got {} tensors", out.len());
                    assert_eq!(out[0].as_f32().unwrap(), &[
                        2.0 * vals[0] as f32,
                        2.0 * vals[1] as f32
                    ]);
                }
                1 => {
                    assert_eq!(out.len(), 1, "tri request got {} tensors", out.len());
                    assert_eq!(out[0].as_f32().unwrap(), &[
                        3.0 * vals[0] as f32,
                        3.0 * vals[1] as f32
                    ]);
                }
                _ => {
                    assert_eq!(out.len(), 2, "untargeted request got {} tensors", out.len());
                    assert_eq!(out[0].as_f32().unwrap()[0], 2.0 * vals[0] as f32);
                    assert_eq!(out[1].as_f32().unwrap()[0], 3.0 * vals[0] as f32);
                }
            }
        }
        let counts = server.variant_counts();
        assert_eq!(counts.get("dbl"), Some(&8));
        assert_eq!(counts.get("tri"), Some(&8));
        assert_eq!(counts.get(""), Some(&8));
        let routed = backend.routed_calls.load(Ordering::Relaxed);
        let max_batch = backend.max_batch.load(Ordering::Relaxed);
        assert!(routed > 0, "no batch took the routed path");
        assert!(max_batch > 2, "mixed-variant batch never merged (max {max_batch})");
        server.shutdown();
    }

    #[test]
    fn route_off_serves_tagged_requests_the_full_output_set() {
        // the all-outputs baseline: with routing disabled the variant
        // tag is ignored and process() serves everything
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig { route_variants: false, ..BatchConfig::default() },
        )
        .unwrap();
        let out = server
            .submit_variant(req(&[2.0]), "dbl")
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(out.len(), 2, "route-off must serve the full output set");
        assert_eq!(out[0].as_f32().unwrap(), &[4.0]);
        assert_eq!(out[1].as_f32().unwrap(), &[6.0]);
        server.shutdown();
    }

    #[test]
    fn unknown_variant_errors_only_its_own_request() {
        // a bad tag is rejected at submit time, BEFORE batching — so a
        // valid request submitted in the same flush window (which the
        // batcher would have coalesced with it) still succeeds
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(50),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let bad = server.submit_variant(req(&[1.0]), "nope");
        let ok = server.submit_variant(req(&[1.0]), "dbl");
        let err = bad.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
        assert_eq!(ok.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        // the rejected request never reached the batcher
        let (_, requests) = server.counts();
        assert_eq!(requests, 1);
        server.shutdown();

        // with routing off, tags are ignored rather than validated: the
        // same bad tag serves the full output set
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig { route_variants: false, ..BatchConfig::default() },
        )
        .unwrap();
        let out = server.submit_variant(req(&[1.0]), "nope").recv().unwrap().unwrap();
        assert_eq!(out.len(), 2);
        server.shutdown();
    }

    #[test]
    fn flush_deadline_expires_partial_batches() {
        // requests spaced further apart than max_wait must not wait for
        // a full batch: each flushes as its own (partial) batch
        let server = Server::start(
            Box::new(Doubler::new()),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(20),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let rx1 = server.submit(req(&[1.0]));
        assert_eq!(rx1.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        // well past the first batch's deadline
        std::thread::sleep(Duration::from_millis(120));
        let rx2 = server.submit(req(&[2.0]));
        assert_eq!(rx2.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[4.0]);
        let (batches, requests) = server.counts();
        assert_eq!(requests, 2);
        assert_eq!(batches, 2, "spaced requests must flush as separate partial batches");
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_mixed_variant_requests() {
        // shutdown closes the queue but the workers drain what is
        // already queued: every submitted request still gets an answer
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                max_batch_rows: 8,
                max_wait: Duration::from_millis(1),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                let vals = [i as f64];
                match i % 3 {
                    0 => (i, server.submit_variant(req(&vals), "dbl"), 2.0f32),
                    1 => (i, server.submit_variant(req(&vals), "tri"), 3.0f32),
                    _ => (i, server.submit(req(&vals)), 2.0f32),
                }
            })
            .collect();
        server.shutdown(); // workers must finish the queue before exiting
        for (i, rx, k) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out[0].as_f32().unwrap(), &[k * i as f32], "request {i}");
        }
    }

    #[test]
    fn oversized_variant_request_is_served_whole_and_routed() {
        // a tagged request larger than max_batch_rows still runs as its
        // own (routed) batch: never split, never stalled, only its
        // variant's outputs
        let backend = Arc::new(VariantDoubler::new());
        let server = Server::start_shared(
            backend.clone(),
            BatchConfig {
                max_batch_rows: 8,
                max_wait: Duration::from_millis(5),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let vals: Vec<f64> = (0..40).map(|i| i as f64).collect();
        let rx = server.submit_variant(req(&vals), "tri");
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 1, "tagged oversized request must get only its variant");
        let got = out[0].as_f32().unwrap();
        assert_eq!(got.len(), 40, "oversized request lost rows");
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, 3.0 * i as f32);
        }
        let (batches, requests) = server.counts();
        assert_eq!((batches, requests), (1, 1), "oversized request was split or retried");
        assert_eq!(
            backend.routed_calls.load(Ordering::Relaxed),
            1,
            "oversized tagged request did not take the routed path"
        );
        assert_eq!(
            backend.max_batch.load(Ordering::Relaxed),
            40,
            "backend saw a different batch than submitted"
        );
        server.shutdown();
    }

    // ---- worker pool ------------------------------------------------------

    /// Bitwise tensor-list equality via the shared oracle
    /// ([`crate::util::prop::tensors_bit_identical`]), with a context
    /// prefix.
    fn assert_bitwise_eq(a: &[Tensor], b: &[Tensor], what: &str) {
        if let Err(e) = crate::util::prop::tensors_bit_identical(a, b) {
            panic!("{what}: {e}");
        }
    }

    #[test]
    fn pooled_mixed_variant_stress_matches_single_worker_oracle() {
        // M producer threads hammer a 4-worker pool with interleaved
        // mixed-variant requests while a 1-worker server (the PR 4
        // architecture) serves the IDENTICAL frames as the oracle:
        // every pooled response must be bit-identical to the oracle's,
        // whatever worker/batch each side landed in.
        let pool = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                workers: 4,
                max_batch_rows: 32,
                max_wait: Duration::from_micros(200),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let oracle = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                workers: 1,
                max_batch_rows: 32,
                max_wait: Duration::from_micros(200),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        std::thread::scope(|scope| {
            for t in 0..4i64 {
                let pool = &pool;
                let oracle = &oracle;
                scope.spawn(move || {
                    for i in 0..40i64 {
                        let v = (t * 1000 + i) as f64;
                        let frame = req(&[v, v + 0.5, v + 0.75]);
                        let (rx_pool, rx_oracle) = match i % 3 {
                            0 => (
                                pool.submit_variant(frame.clone(), "dbl"),
                                oracle.submit_variant(frame, "dbl"),
                            ),
                            1 => (
                                pool.submit_variant(frame.clone(), "tri"),
                                oracle.submit_variant(frame, "tri"),
                            ),
                            _ => (pool.submit(frame.clone()), oracle.submit(frame)),
                        };
                        let got = rx_pool.recv().unwrap().unwrap();
                        let want = rx_oracle.recv().unwrap().unwrap();
                        assert_bitwise_eq(&got, &want, &format!("producer {t} request {i}"));
                    }
                });
            }
        });
        assert_eq!(pool.workers(), 4);
        assert_eq!(pool.worker_busy_times().len(), 4);
        let (_, requests) = pool.counts();
        assert_eq!(requests, 160, "pool lost or duplicated requests");
        // per-worker variant splits merge into the correct totals
        let counts = pool.variant_counts();
        assert_eq!(counts.values().sum::<u64>(), 160);
        // per-worker busy times sum to the aggregate cost proxy
        let summed: Duration = pool.worker_busy_times().into_iter().sum();
        assert_eq!(summed, pool.busy_time());

        // shutdown drains: queue another burst without receiving, then
        // shut the pool down — every request must still be answered
        let parked: Vec<_> = (0..32)
            .map(|i| {
                let v = 9_000.0 + i as f64;
                (v, pool.submit_variant(req(&[v]), "dbl"))
            })
            .collect();
        pool.shutdown();
        for (v, rx) in parked {
            let out = rx.recv().expect("response channel dropped").unwrap();
            assert_eq!(out[0].as_f32().unwrap(), &[2.0 * v as f32]);
        }
        oracle.shutdown();
    }

    #[test]
    fn submits_after_shutdown_error_cleanly() {
        // a stopped pool must bounce new submissions on their own
        // channel, not panic or hang
        let backend = Arc::new(Doubler::new());
        let server = Server::start_shared(backend.clone(), BatchConfig::default()).unwrap();
        let queue = Arc::clone(&server.queue);
        let resolved = server.registry().resolve(DEFAULT_TENANT).unwrap();
        server.shutdown();
        // the queue is closed: a late push is handed back
        let (tx, rx) = mpsc::channel();
        let job = Job {
            df: req(&[1.0]),
            variant: None,
            resolved,
            resp: tx,
            enqueued: Instant::now(),
            deadline: None,
        };
        assert!(queue.push(job).is_err());
        drop(rx);
    }

    #[test]
    fn submit_after_shutdown_is_typed_shutting_down() {
        // satellite bugfix: a rejected-at-shutdown submit must surface
        // the typed ShuttingDown error (the wire layer maps it to 503
        // shutting_down), not a generic Serving string
        let registry = SpecRegistry::single(DEFAULT_TENANT, Arc::new(Doubler::new())).unwrap();
        let server = Server::start_registry(Arc::clone(&registry), BatchConfig::default()).unwrap();
        let resolved = registry.resolve(DEFAULT_TENANT).unwrap();
        server.queue.close();
        let rx = server.submit_resolved(req(&[1.0]), None, resolved);
        let err = rx.recv().unwrap().unwrap_err();
        assert!(matches!(err, KamaeError::ShuttingDown), "{err}");
        server.shutdown();
    }

    // ---- ingress validation gate ------------------------------------------

    /// [`Doubler`] with a request schema over `x: f64`, so the registry
    /// derives a validation spec for it (plain mocks skip the gate).
    struct SchemaDoubler;

    impl Backend for SchemaDoubler {
        fn name(&self) -> &str {
            "schema-doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            assert!(df.num_rows() > 0, "validated path leaked an empty batch to the backend");
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }

        fn request_schema(&self) -> Option<crate::dataframe::Schema> {
            Some(crate::dataframe::Schema {
                fields: vec![crate::dataframe::Field {
                    name: "x".into(),
                    dtype: crate::dataframe::DType::F64,
                }],
            })
        }
    }

    #[test]
    fn validated_submit_quarantines_dead_letters_and_serves_the_rest() {
        use super::super::validate::MemoryDeadLetter;
        let server = Server::start(Box::new(SchemaDoubler), BatchConfig::default()).unwrap();
        let sink = MemoryDeadLetter::new(16);
        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64_opt(vec![Some(1.0), None, Some(3.0), None]),
        )])
        .unwrap();
        let (rx, report) =
            server.submit_tenant_validated(df, DEFAULT_TENANT, None, None, Some(&sink));
        assert_eq!(report.keep, vec![true, false, true, false]);
        let out = rx.recv().unwrap().unwrap();
        // compacted batch: exactly the valid rows, in original order
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 6.0]);
        // quarantined rows landed in the sink with rule + column
        assert_eq!(sink.len(), 2);
        let entry = &sink.entries()[0];
        assert_eq!(
            entry.get("tenant").and_then(crate::util::json::Json::as_str),
            Some(DEFAULT_TENANT)
        );
        let errs = entry.get("errors").and_then(crate::util::json::Json::as_array).unwrap();
        assert_eq!(errs[0].get("rule").and_then(crate::util::json::Json::as_str), Some("not_null"));
        assert_eq!(errs[0].get("column").and_then(crate::util::json::Json::as_str), Some("x"));

        // all-quarantined: verdicts only, the backend never runs on an
        // empty frame (SchemaDoubler asserts), the response is prompt
        let df = DataFrame::new(vec![("x".into(), Column::from_f64_opt(vec![None, None]))])
            .unwrap();
        let (rx, report) =
            server.submit_tenant_validated(df, DEFAULT_TENANT, None, None, Some(&sink));
        assert_eq!(report.num_valid(), 0);
        assert_eq!(report.num_quarantined(), 2);
        assert!(rx.recv().unwrap().unwrap().is_empty());
        assert_eq!(sink.len(), 4);

        // load-signal accessors behave at idle
        assert_eq!(server.queue_depth(), 0);
        assert!(server.drain_rate_rps() >= 0.0);
        server.shutdown();
    }

    // ---- fault containment ------------------------------------------------

    /// [`Doubler`] that panics whenever the batch contains the poison
    /// value `666.0` — a deterministic, content-addressed crash, exactly
    /// what bisection is built to isolate.
    struct PanicDoubler;

    impl Backend for PanicDoubler {
        fn name(&self) -> &str {
            "panic-doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            let v = df.column("x")?.as_f64()?;
            assert!(!v.contains(&666.0), "poison row in batch");
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }
    }

    #[test]
    fn panic_is_isolated_to_the_poison_row_and_capacity_survives() {
        use super::super::validate::MemoryDeadLetter;
        let sink = Arc::new(MemoryDeadLetter::new(16));
        let registry = SpecRegistry::single(DEFAULT_TENANT, Arc::new(PanicDoubler)).unwrap();
        let server = Server::start_registry_sink(
            registry,
            BatchConfig {
                workers: 2,
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(30),
                ..BatchConfig::default()
            },
            Some(sink.clone() as Arc<dyn DeadLetterSink>),
        )
        .unwrap();

        // a clean job and a poison job coalesce into one batch: the
        // backend panics on the merged batch, bisection must serve the
        // clean job bit-identical and condemn only the poison row
        let rx_poison = server.submit(req(&[1.0, 666.0, 3.0]));
        let rx_clean = server.submit(req(&[5.0]));
        let err = rx_poison.recv().unwrap().unwrap_err();
        match &err {
            KamaeError::PoisonRows(rows) => assert_eq!(rows, &vec![1]),
            other => panic!("expected PoisonRows, got {other}"),
        }
        assert_eq!(rx_clean.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[10.0]);

        // the poison row was dead-lettered with a structured verdict
        assert_eq!(server.poison_rows(), 1);
        assert!(server.worker_panics() > 0, "no panic was caught");
        assert_eq!(sink.len(), 1);
        let entry = &sink.entries()[0];
        let errs = entry.get("errors").and_then(crate::util::json::Json::as_array).unwrap();
        assert_eq!(errs[0].get("rule").and_then(crate::util::json::Json::as_str), Some("poison"));
        let row = entry.get("row").unwrap();
        assert_eq!(row.get("x").and_then(crate::util::json::Json::as_f64), Some(666.0));

        // capacity never decays: the pool still has every worker and
        // keeps serving after the panic storm
        assert_eq!(server.workers(), 2);
        let rx = server.submit(req(&[7.0]));
        assert_eq!(rx.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[14.0]);
        server.shutdown();
    }

    /// Backend that panics on its first N calls, then behaves — the
    /// transient-fault shape (an Nth-batch hiccup, not a bad row).
    struct FlakyDoubler {
        remaining_faults: std::sync::atomic::AtomicUsize,
    }

    impl Backend for FlakyDoubler {
        fn name(&self) -> &str {
            "flaky-doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            let left = &self.remaining_faults;
            if left
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| n.checked_sub(1))
                .is_ok()
            {
                panic!("transient fault");
            }
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }
    }

    #[test]
    fn transient_panic_is_forgiven_and_the_request_still_serves() {
        // one injected panic: the batch fails, the lone-job re-probe
        // succeeds, the request is served Ok — no row is condemned
        let backend =
            Arc::new(FlakyDoubler { remaining_faults: std::sync::atomic::AtomicUsize::new(1) });
        let server = Server::start_shared(backend, BatchConfig::default()).unwrap();
        let out = server.submit(req(&[4.0])).recv().unwrap().unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[8.0]);
        assert_eq!(server.worker_panics(), 1);
        assert_eq!(server.poison_rows(), 0, "transient fault condemned a row");
        server.shutdown();
    }

    /// Doubler that sleeps per batch — pins deadline behaviour while the
    /// only worker is demonstrably busy.
    struct SlowDoubler {
        delay: Duration,
    }

    impl Backend for SlowDoubler {
        fn name(&self) -> &str {
            "slow-doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            std::thread::sleep(self.delay);
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }
    }

    #[test]
    fn queued_request_past_deadline_gets_typed_answer_from_the_reaper() {
        // worker 1 is stuck in a 80ms batch; a queued request with a
        // 5ms deadline must be answered ~promptly by the reaper (not
        // after the batch), with the typed error and the counter bumped
        let server = Server::start(
            Box::new(SlowDoubler { delay: Duration::from_millis(80) }),
            BatchConfig {
                workers: 1,
                max_wait: Duration::from_micros(50),
                request_deadline: Some(Duration::from_millis(5)),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let rx_busy = server.submit(req(&[1.0]));
        std::thread::sleep(Duration::from_millis(10)); // worker is now mid-batch
        let t0 = Instant::now();
        let rx_late = server.submit(req(&[2.0]));
        let err = rx_late.recv().unwrap().unwrap_err();
        let answered_in = t0.elapsed();
        assert!(matches!(err, KamaeError::DeadlineExceeded(_)), "{err}");
        assert!(err.to_string().contains("5ms"), "{err}");
        assert!(
            answered_in < Duration::from_millis(60),
            "deadline answer waited for the busy worker ({answered_in:?})"
        );
        assert_eq!(server.deadline_expired(), 1);
        // the job that made it into a batch is unaffected
        assert_eq!(rx_busy.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        let (_, requests) = server.counts();
        assert_eq!(requests, 1, "an expired job was counted as served");
        server.shutdown();
    }

    #[test]
    fn per_request_deadline_overrides_the_config_default() {
        // config has NO default deadline; the per-submit override alone
        // must expire the queued request
        let registry =
            SpecRegistry::single(DEFAULT_TENANT, Arc::new(SlowDoubler { delay: Duration::from_millis(60) }))
                .unwrap();
        let server = Server::start_registry(
            Arc::clone(&registry),
            BatchConfig { workers: 1, max_wait: Duration::from_micros(50), ..BatchConfig::default() },
        )
        .unwrap();
        let resolved = registry.resolve(DEFAULT_TENANT).unwrap();
        let rx_busy = server.submit(req(&[1.0]));
        std::thread::sleep(Duration::from_millis(10));
        let rx_late = server.submit_resolved_deadline(
            req(&[2.0]),
            None,
            resolved,
            Some(Duration::from_millis(3)),
        );
        let err = rx_late.recv().unwrap().unwrap_err();
        assert!(matches!(err, KamaeError::DeadlineExceeded(_)), "{err}");
        assert_eq!(server.deadline_expired(), 1);
        assert_eq!(rx_busy.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        server.shutdown();

        // and a zero config deadline is a refused deployment mistake
        let err = Server::start(
            Box::new(Doubler::new()),
            BatchConfig { request_deadline: Some(Duration::ZERO), ..BatchConfig::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("request_deadline"), "{err}");
    }

    // ---- registry addressing ----------------------------------------------

    #[test]
    fn unknown_tenant_errors_only_its_own_request() {
        // like an unknown variant, an unknown tenant is rejected at
        // submit time on its own channel — co-batched requests to real
        // tenants are untouched
        let server = Server::start(Box::new(Doubler::new()), BatchConfig::default()).unwrap();
        let bad = server.submit_tenant(req(&[1.0]), "ghost", None);
        let ok = server.submit(req(&[1.0]));
        let err = bad.recv().unwrap().unwrap_err();
        assert!(matches!(err, KamaeError::UnknownTenant(_)), "{err}");
        assert!(err.to_string().contains("ghost"), "{err}");
        assert_eq!(ok.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        let (_, requests) = server.counts();
        assert_eq!(requests, 1, "rejected tenant reached the batcher");
        server.shutdown();
    }

    #[test]
    fn one_pool_serves_multiple_tenants() {
        // two tenants with bit-distinguishable backends behind ONE
        // queue + worker: each request lands on its own tenant's
        // backend, and the single-spec submit keeps addressing the
        // default tenant
        let registry = Arc::new(SpecRegistry::new());
        registry
            .deploy_backend(DEFAULT_TENANT, Arc::new(Doubler::new()), None)
            .unwrap();
        registry
            .deploy_backend("variants", Arc::new(VariantDoubler::new()), None)
            .unwrap();
        let server = Server::start_registry(
            Arc::clone(&registry),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(20),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        // burst within one batching window so a drain can straddle both
        // tenants — the worker must still split per version
        let rx_default = server.submit(req(&[2.0]));
        let rx_tri = server.submit_tenant(req(&[2.0]), "variants", Some("tri"));
        let rx_both = server.submit_tenant(req(&[2.0]), "variants", None);
        assert_eq!(rx_default.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[4.0]);
        let tri = rx_tri.recv().unwrap().unwrap();
        assert_eq!(tri.len(), 1);
        assert_eq!(tri[0].as_f32().unwrap(), &[6.0]);
        let both = rx_both.recv().unwrap().unwrap();
        assert_eq!(both.len(), 2);
        assert_eq!(both[0].as_f32().unwrap(), &[4.0]);
        assert_eq!(both[1].as_f32().unwrap(), &[6.0]);
        // per-version counters saw each tenant's own traffic
        let snap = registry.snapshot();
        let by_name: BTreeMap<_, _> =
            snap.iter().map(|s| (s.tenant.as_str(), s)).collect();
        assert_eq!(by_name[DEFAULT_TENANT].versions[0].requests, 1);
        assert_eq!(by_name["variants"].versions[0].requests, 2);
        server.shutdown();
    }
}
