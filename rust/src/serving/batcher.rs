//! Dynamic batcher + server loop.
//!
//! Requests (small DataFrames) queue onto a channel; the worker thread
//! drains up to `max_batch_rows` or until `max_wait` elapses from the
//! first queued request, concatenates them into one batch, runs the
//! backend once, then splits the output tensors back per request —
//! amortising graph-execution overhead exactly the way TF-Serving's
//! dynamic batching does for the paper's production service.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};
use crate::runtime::Tensor;

use super::backend::Backend;

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Max rows merged into one backend call.
    pub max_batch_rows: usize,
    /// Max time the first request in a batch waits for company.
    pub max_wait: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        // max_wait 300µs: at production-like rates (~200 rps) requests
        // rarely overlap, so long waits only pad p50; under bursts the
        // queue drains in whole batches anyway because the worker picks
        // up everything already queued before waiting (§Perf L3 log).
        BatchConfig { max_batch_rows: 128, max_wait: Duration::from_micros(300) }
    }
}

struct Job {
    df: DataFrame,
    resp: mpsc::Sender<Result<Vec<Tensor>>>,
}

/// A running server: one batcher thread owning the backend.
pub struct Server {
    tx: Option<mpsc::Sender<Job>>,
    worker: Option<std::thread::JoinHandle<()>>,
    busy_ns: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
    requests: Arc<AtomicU64>,
}

impl Server {
    /// Spawn the batcher thread.
    pub fn start(backend: Box<dyn Backend>, config: BatchConfig) -> Server {
        let (tx, rx) = mpsc::channel::<Job>();
        let busy_ns = Arc::new(AtomicU64::new(0));
        let batches = Arc::new(AtomicU64::new(0));
        let requests = Arc::new(AtomicU64::new(0));
        let worker = {
            let busy_ns = Arc::clone(&busy_ns);
            let batches = Arc::clone(&batches);
            let requests = Arc::clone(&requests);
            std::thread::spawn(move || {
                batch_loop(backend, config, rx, busy_ns, batches, requests);
            })
        };
        Server { tx: Some(tx), worker: Some(worker), busy_ns, batches, requests }
    }

    /// Submit a request; the receiver yields the output tensors for this
    /// request's rows.
    pub fn submit(&self, df: DataFrame) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        if let Some(tx) = &self.tx {
            if tx.send(Job { df, resp: resp_tx.clone() }).is_err() {
                let _ = resp_tx.send(Err(KamaeError::Serving("server stopped".into())));
            }
        }
        resp_rx
    }

    /// Total backend-execution time (the cost proxy: CPU-seconds of
    /// preprocessing work, single worker).
    pub fn busy_time(&self) -> Duration {
        Duration::from_nanos(self.busy_ns.load(Ordering::Relaxed))
    }

    /// (batches executed, requests served) — batching efficiency.
    pub fn counts(&self) -> (u64, u64) {
        (self.batches.load(Ordering::Relaxed), self.requests.load(Ordering::Relaxed))
    }

    /// Stop the worker and wait for it.
    pub fn shutdown(mut self) {
        self.tx.take(); // close the channel
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batch_loop(
    backend: Box<dyn Backend>,
    config: BatchConfig,
    rx: mpsc::Receiver<Job>,
    busy_ns: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
    requests: Arc<AtomicU64>,
) {
    loop {
        // block for the first request of the next batch
        let first = match rx.recv() {
            Ok(job) => job,
            Err(_) => return, // channel closed: shutdown
        };
        let mut jobs = vec![first];
        let mut rows = jobs[0].df.num_rows();
        // greedily take everything already queued (free batching)
        while rows < config.max_batch_rows {
            match rx.try_recv() {
                Ok(job) => {
                    rows += job.df.num_rows();
                    jobs.push(job);
                }
                Err(_) => break,
            }
        }
        // then wait at most max_wait for stragglers — but only if the
        // batch still has meaningful headroom
        let deadline = Instant::now() + config.max_wait;
        while rows < config.max_batch_rows {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => {
                    rows += job.df.num_rows();
                    jobs.push(job);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        let t0 = Instant::now();
        let result = run_batch(backend.as_ref(), &jobs);
        busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        batches.fetch_add(1, Ordering::Relaxed);
        requests.fetch_add(jobs.len() as u64, Ordering::Relaxed);

        match result {
            Ok(per_job) => {
                for (job, tensors) in jobs.into_iter().zip(per_job) {
                    let _ = job.resp.send(Ok(tensors));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for job in jobs {
                    let _ = job.resp.send(Err(KamaeError::Serving(msg.clone())));
                }
            }
        }
    }
}

/// Merge jobs, run the backend once, split outputs per job.
fn run_batch(backend: &dyn Backend, jobs: &[Job]) -> Result<Vec<Vec<Tensor>>> {
    let merged = if jobs.len() == 1 {
        jobs[0].df.clone()
    } else {
        let frames: Vec<&DataFrame> = jobs.iter().map(|j| &j.df).collect();
        DataFrame::concat(&frames)?
    };
    let outputs = backend.process(&merged)?;
    if jobs.len() == 1 {
        return Ok(vec![outputs]);
    }
    let sizes: Vec<usize> = jobs.iter().map(|j| j.df.num_rows()).collect();
    // transpose: per-output splits -> per-job tensor lists
    let mut per_job: Vec<Vec<Tensor>> = vec![Vec::with_capacity(outputs.len()); jobs.len()];
    for out in &outputs {
        let parts = out.split_batch(&sizes)?;
        for (slot, part) in per_job.iter_mut().zip(parts) {
            slot.push(part);
        }
    }
    Ok(per_job)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    /// Backend that doubles an f64 column; records max batch seen.
    struct Doubler {
        max_batch: std::sync::atomic::AtomicUsize,
    }

    impl Backend for Doubler {
        fn name(&self) -> &str {
            "doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }
    }

    fn req(vals: &[f64]) -> DataFrame {
        DataFrame::new(vec![("x".into(), Column::from_f64(vals.to_vec()))]).unwrap()
    }

    #[test]
    fn responses_route_back_to_requests() {
        let server = Server::start(
            Box::new(Doubler { max_batch: Default::default() }),
            BatchConfig { max_batch_rows: 64, max_wait: Duration::from_millis(5) },
        );
        let rxs: Vec<_> = (0..20)
            .map(|i| (i, server.submit(req(&[i as f64, i as f64 + 0.5]))))
            .collect();
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].as_f32().unwrap(), &[2.0 * i as f32, 2.0 * i as f32 + 1.0]);
        }
        let (batches, requests) = server.counts();
        assert_eq!(requests, 20);
        assert!(batches <= 20);
        server.shutdown();
    }

    #[test]
    fn batching_actually_merges() {
        let backend = Box::new(Doubler { max_batch: Default::default() });
        let probe: *const Doubler = backend.as_ref();
        let server = Server::start(
            backend,
            BatchConfig { max_batch_rows: 1024, max_wait: Duration::from_millis(50) },
        );
        // burst of requests within the batching window
        let rxs: Vec<_> = (0..32).map(|_| server.submit(req(&[1.0]))).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        // SAFETY: server still alive, backend not moved
        let max_seen = unsafe { (*probe).max_batch.load(Ordering::Relaxed) };
        assert!(max_seen > 1, "batcher never merged (max batch {max_seen})");
        server.shutdown();
    }

    #[test]
    fn oversized_request_is_served_whole() {
        // a single request larger than max_batch_rows must run as its
        // own batch — never stall waiting for headroom, never split, and
        // never drop rows. (The drain loops only *top up* small batches;
        // an oversized first job skips them and executes immediately.)
        let backend = Box::new(Doubler { max_batch: Default::default() });
        let probe: *const Doubler = backend.as_ref();
        let server = Server::start(
            backend,
            BatchConfig { max_batch_rows: 8, max_wait: Duration::from_millis(5) },
        );
        let vals: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let rx = server.submit(req(&vals));
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 1);
        let got = out[0].as_f32().unwrap();
        assert_eq!(got.len(), 50, "oversized request lost rows");
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, 2.0 * i as f32);
        }
        let (batches, requests) = server.counts();
        assert_eq!((batches, requests), (1, 1), "oversized request was split or retried");
        // SAFETY: server still alive, backend not moved
        let max_seen = unsafe { (*probe).max_batch.load(Ordering::Relaxed) };
        assert_eq!(max_seen, 50, "backend saw a different batch than submitted");
        server.shutdown();
    }

    #[test]
    fn error_propagates_to_all_requests() {
        struct Failing;
        impl Backend for Failing {
            fn name(&self) -> &str {
                "fail"
            }
            fn process(&self, _: &DataFrame) -> Result<Vec<Tensor>> {
                Err(KamaeError::Serving("boom".into()))
            }
        }
        let server = Server::start(Box::new(Failing), BatchConfig::default());
        let rx = server.submit(req(&[1.0]));
        assert!(rx.recv().unwrap().is_err());
        server.shutdown();
    }
}
