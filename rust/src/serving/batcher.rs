//! Dynamic batcher + worker-pool server loop.
//!
//! Requests (small DataFrames) queue onto one shared [`JobQueue`]; N
//! worker threads ([`BatchConfig::workers`]) each drain up to
//! `max_batch_rows` or until `max_wait` elapses from the first queued
//! request, concatenate their drained jobs into one batch, run the
//! job's resolved backend once, then split the output tensors back per
//! request — amortising graph-execution overhead exactly the way
//! TF-Serving's dynamic batching does for the paper's production
//! service, but across every core instead of one.
//!
//! ## Registry resolution & hot swap
//!
//! The pool no longer owns a backend: every job carries the
//! `Arc<TenantVersion>` it resolved from the shared
//! [`SpecRegistry`] at submit time ([`Server::submit_tenant`]), so ONE
//! pool serves many tenants and a live deploy never touches the pool.
//! Workers sub-batch the jobs they drained by resolved version
//! (`Arc::ptr_eq` — a version is identity, not equality) and run each
//! version's backend exactly once per sub-batch; a job drained across a
//! hot swap still executes on the version it resolved, so in-flight
//! requests finish on the old backend bit-for-bit while new arrivals
//! resolve the new one. The single-spec [`Server::start`] /
//! [`Server::start_shared`] API is a thin wrapper: a one-tenant
//! registry under [`DEFAULT_TENANT`].
//!
//! ## Worker pool
//!
//! Backends are shared (`Arc<dyn Backend>`, immutable once deployed),
//! so workers call them concurrently with no synchronisation of their
//! own: batch formation is serialised by the queue mutex (held only
//! while *draining*, never while *processing*), and everything after
//! the drain — concat, backend call, response split — runs outside any
//! lock. Each worker owns its [`WorkerMetrics`]; the hot path touches
//! no shared mutex, and [`Server::busy_time`] / [`Server::counts`] /
//! [`Server::variant_counts`] merge the per-worker counters at read
//! time.
//!
//! Per-request response order is unaffected by pooling: every job
//! carries its own response channel, and a batch's responses are sent in
//! the batch's original job order, whichever worker served it.
//!
//! ## Variant routing
//!
//! A request may target one **variant** of a merged multi-variant
//! backend ([`Server::submit_variant`]). Each worker still coalesces the
//! mixed-variant submissions it drained into ONE batch: jobs are sorted
//! into contiguous per-variant groups (arrival order preserved within
//! each group), the frames are concatenated in group order, and the
//! backend runs once via [`Backend::process_routed`] — the shared
//! preprocessing prefix executes a single time over the whole mixed
//! batch while each variant's exclusive work runs only on its own rows.
//! A targeted request's response carries exactly its variant's output
//! tensors, in that variant's output order.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};
use crate::runtime::Tensor;

use super::backend::{Backend, VariantGroup};
use super::registry::{SpecRegistry, TenantVersion, DEFAULT_TENANT};
use super::validate::{screen_batch, DeadLetterSink, ValidationReport};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Max rows merged into one backend call.
    pub max_batch_rows: usize,
    /// Max time the first request in a batch waits for company.
    pub max_wait: Duration,
    /// Route variant-tagged requests through
    /// [`Backend::process_routed`] (cone-restricted evaluation, one
    /// merged batch across variants). When `false` the tags are ignored
    /// and every request is served the backend's full output set — the
    /// all-outputs-per-request baseline the routing benchmark gates
    /// against.
    pub route_variants: bool,
    /// Batcher threads draining the shared queue against the ONE shared
    /// backend. `1` reproduces the single-threaded server exactly;
    /// higher values let concurrent batches execute on idle cores
    /// (`benches/worker_pool.rs` gates the scaling win).
    pub workers: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        // max_wait 300µs: at production-like rates (~200 rps) requests
        // rarely overlap, so long waits only pad p50; under bursts the
        // queue drains in whole batches anyway because a worker picks
        // up everything already queued before waiting (§Perf L3 log).
        BatchConfig {
            max_batch_rows: 128,
            max_wait: Duration::from_micros(300),
            route_variants: true,
            workers: 1,
        }
    }
}

impl BatchConfig {
    /// Reject configurations the drain loop cannot serve: zero workers
    /// would strand every queued request (nothing ever drains), and a
    /// zero row budget used to make the greedy top-up loop a no-op that
    /// still flushed — but only after burning a full `max_wait` per
    /// request, and only by accident of loop ordering. Both are
    /// deployment mistakes that must fail at [`Server::start`], not
    /// hang (or spin) at the first request.
    fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(KamaeError::Serving(
                "BatchConfig::workers must be >= 1 (0 workers would never drain the queue)"
                    .into(),
            ));
        }
        if self.max_batch_rows == 0 {
            return Err(KamaeError::Serving(
                "BatchConfig::max_batch_rows must be >= 1 (a zero row budget cannot batch)"
                    .into(),
            ));
        }
        Ok(())
    }
}

struct Job {
    df: DataFrame,
    /// Target variant of a merged multi-variant backend; `None` asks
    /// for the full output set.
    variant: Option<String>,
    /// The tenant version this request resolved at submit time. The job
    /// executes on THIS backend even if a deploy swaps the tenant's
    /// active version while it is queued — hot swaps never change a
    /// request mid-flight.
    resolved: Arc<TenantVersion>,
    resp: mpsc::Sender<Result<Vec<Tensor>>>,
}

/// The shared request queue: a deque + condvar that N workers drain in
/// batches. Replaces the PR 4 `mpsc` channel, whose receiver is
/// single-consumer by construction.
struct JobQueue {
    state: Mutex<QueueState>,
    cond: Condvar,
}

struct QueueState {
    jobs: VecDeque<Job>,
    /// Set at shutdown: producers are rejected, workers drain whatever
    /// is still queued and then exit.
    closed: bool,
}

impl JobQueue {
    fn new() -> JobQueue {
        JobQueue {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            cond: Condvar::new(),
        }
    }

    /// Enqueue a job, handing it back if the queue is already closed
    /// (the caller errors that request's own response channel).
    fn push(&self, job: Job) -> std::result::Result<(), Job> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(job);
        }
        s.jobs.push_back(job);
        drop(s);
        self.cond.notify_one();
        Ok(())
    }

    /// Close the queue: producers start bouncing, every worker wakes to
    /// drain the remainder and exit.
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    /// Jobs currently queued (not yet drained by a worker) — the load
    /// signal behind the shed path's dynamic `Retry-After` hint.
    fn depth(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    /// Drain the next batch for one worker: block for the first job,
    /// greedily take everything already queued up to `max_rows`, then
    /// wait at most `max_wait` (from the first job) for stragglers.
    /// Returns `None` once the queue is closed AND empty — the worker's
    /// exit signal. The lock is held only while moving jobs out of the
    /// deque; it is released during the straggler wait (other workers
    /// keep draining concurrently) and for the entire backend call.
    fn pop_batch(&self, max_rows: usize, max_wait: Duration) -> Option<Vec<Job>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(first) = s.jobs.pop_front() {
                let mut rows = first.df.num_rows();
                let mut jobs = vec![first];
                // greedily take everything already queued (free batching)
                while rows < max_rows {
                    match s.jobs.pop_front() {
                        Some(job) => {
                            rows += job.df.num_rows();
                            jobs.push(job);
                        }
                        None => break,
                    }
                }
                // then wait at most max_wait for stragglers — but only
                // if the batch still has headroom and nobody is
                // shutting down (a closing queue flushes immediately)
                let deadline = Instant::now() + max_wait;
                while rows < max_rows && !s.closed {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) =
                        self.cond.wait_timeout(s, deadline - now).unwrap();
                    s = guard;
                    while rows < max_rows {
                        match s.jobs.pop_front() {
                            Some(job) => {
                                rows += job.df.num_rows();
                                jobs.push(job);
                            }
                            None => break,
                        }
                    }
                    if timeout.timed_out() {
                        break;
                    }
                }
                return Some(jobs);
            }
            if s.closed {
                return None;
            }
            s = self.cond.wait(s).unwrap();
        }
    }
}

/// One worker's counters. Owned exclusively by that worker on the hot
/// path — the atomics exist so [`Server`] can *read* them while the
/// worker runs, and the variant map's mutex is only ever contended by
/// report-time readers, never by another worker.
struct WorkerMetrics {
    busy_ns: AtomicU64,
    batches: AtomicU64,
    requests: AtomicU64,
    /// Requests served per variant tag (untargeted requests count under
    /// `""`) — merged into the per-variant split
    /// [`crate::serving::ServeReport`] surfaces.
    variant_requests: Mutex<BTreeMap<String, u64>>,
}

impl WorkerMetrics {
    fn new() -> WorkerMetrics {
        WorkerMetrics {
            busy_ns: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            variant_requests: Mutex::new(BTreeMap::new()),
        }
    }
}

/// A running server: N batcher threads draining one shared queue, each
/// job executing on the tenant version it resolved from the shared
/// [`SpecRegistry`] at submit time.
pub struct Server {
    queue: Arc<JobQueue>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Vec<Arc<WorkerMetrics>>,
    /// The registry requests resolve against. Deploys/rollbacks through
    /// this handle take effect on the NEXT submit; nothing queued or
    /// in-flight changes.
    registry: Arc<SpecRegistry>,
    /// Captured from [`BatchConfig::route_variants`]: when off, variant
    /// tags are ignored rather than validated, so submits skip the
    /// known-variant check.
    route_variants: bool,
    /// When the pool started serving — the denominator of the lifetime
    /// drain rate behind the shed path's `Retry-After` hint.
    started: Instant,
}

impl Server {
    /// Spawn the worker pool over an owned backend. Rejects
    /// un-serveable configs ([`BatchConfig`] with zero workers or a
    /// zero row budget) with [`KamaeError::Serving`] instead of
    /// spawning a pool that can never answer.
    pub fn start(backend: Box<dyn Backend>, config: BatchConfig) -> Result<Server> {
        Server::start_shared(Arc::from(backend), config)
    }

    /// [`Server::start`] over an already-shared backend — callers that
    /// keep probing the backend while the server runs (benches, tests)
    /// clone the `Arc` instead of round-tripping raw pointers. A thin
    /// wrapper over [`Server::start_registry`] with a one-tenant
    /// registry ([`DEFAULT_TENANT`]) — the single-spec API is
    /// registry-backed underneath, so it inherits hot-swap support for
    /// free while behaving exactly as before.
    pub fn start_shared(backend: Arc<dyn Backend>, config: BatchConfig) -> Result<Server> {
        config.validate()?;
        Server::start_registry(SpecRegistry::single(DEFAULT_TENANT, backend)?, config)
    }

    /// Spawn the worker pool over a [`SpecRegistry`]: requests address
    /// tenants ([`Server::submit_tenant`]), deploys/rollbacks through
    /// the registry handle swap versions with zero downtime.
    pub fn start_registry(registry: Arc<SpecRegistry>, config: BatchConfig) -> Result<Server> {
        config.validate()?;
        let queue = Arc::new(JobQueue::new());
        let mut metrics = Vec::with_capacity(config.workers);
        let mut workers = Vec::with_capacity(config.workers);
        for i in 0..config.workers {
            let m = Arc::new(WorkerMetrics::new());
            metrics.push(Arc::clone(&m));
            let queue = Arc::clone(&queue);
            let config = config.clone();
            let handle = std::thread::Builder::new()
                .name(format!("kamae-batcher-{i}"))
                .spawn(move || worker_loop(config, queue, m))
                .map_err(|e| {
                    KamaeError::Serving(format!("failed to spawn batcher worker {i}: {e}"))
                });
            match handle {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // unwind the partial pool before surfacing the error
                    queue.close();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(Server {
            queue,
            workers,
            metrics,
            registry,
            route_variants: config.route_variants,
            started: Instant::now(),
        })
    }

    /// The registry this pool resolves requests against — deploy /
    /// rollback / snapshot through this handle while the pool serves.
    pub fn registry(&self) -> &Arc<SpecRegistry> {
        &self.registry
    }

    /// Submit an untargeted request to the default tenant; the receiver
    /// yields the backend's full output tensors for this request's rows.
    pub fn submit(&self, df: DataFrame) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        self.submit_tenant(df, DEFAULT_TENANT, None)
    }

    /// Submit a request targeting one variant of the default tenant's
    /// merged multi-variant backend; the receiver yields only that
    /// variant's output tensors (in the variant's own output order).
    /// Unknown variants (or a backend without variant support) error on
    /// THIS request's receiver immediately — the bad tag never reaches
    /// a worker, so it cannot fail the requests it would have been
    /// coalesced with.
    pub fn submit_variant(
        &self,
        df: DataFrame,
        variant: &str,
    ) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        self.submit_tenant(df, DEFAULT_TENANT, Some(variant))
    }

    /// Submit a request addressed to `tenant` (optionally targeting one
    /// of its variants): resolves the tenant's active version ONCE,
    /// then rides that version to completion regardless of concurrent
    /// deploys. Unknown tenants and (when routing is on) unknown
    /// variants error on this request's own receiver immediately.
    pub fn submit_tenant(
        &self,
        df: DataFrame,
        tenant: &str,
        variant: Option<&str>,
    ) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        match self.registry.resolve(tenant) {
            Ok(resolved) => self.submit_resolved(df, variant.map(str::to_string), resolved),
            Err(e) => Self::reject(e),
        }
    }

    /// Submit against an already-resolved tenant version — callers that
    /// validated a request against a version (the network front-end)
    /// use this so validation, execution and output naming all see the
    /// SAME version even across a concurrent hot swap.
    pub fn submit_resolved(
        &self,
        df: DataFrame,
        variant: Option<String>,
        resolved: Arc<TenantVersion>,
    ) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        if self.route_variants {
            if let Some(v) = &variant {
                let known = resolved.variants();
                if !known.iter().any(|k| k == v) {
                    return Self::reject(KamaeError::Serving(format!(
                        "no variant '{v}' to route to (backend variants: {})",
                        known.join(", ")
                    )));
                }
            }
        }
        let (resp_tx, resp_rx) = mpsc::channel();
        if let Err(job) = self.queue.push(Job { df, variant, resolved, resp: resp_tx }) {
            let _ = job.resp.send(Err(KamaeError::Serving("server stopped".into())));
        }
        resp_rx
    }

    /// [`Server::submit_tenant`] behind the ingress data-quality gate:
    /// the request is screened against the resolved version's
    /// [`ValidationSpec`](super::validate::ValidationSpec), quarantined
    /// rows are dead-lettered to `sink` (as JSON re-encodings of the
    /// frame rows — the wire layer dead-letters the original raw JSON
    /// instead), and the COMPACTED batch is submitted. The returned
    /// report maps the response tensors (valid rows only, original
    /// relative order) back to original row positions.
    ///
    /// A batch with zero valid rows short-circuits: the receiver is
    /// primed with an empty tensor list and no backend runs — the
    /// verdicts in the report are the entire answer. Versions without a
    /// validation spec (spec-less backends) pass through unscreened
    /// with an all-valid report.
    pub fn submit_tenant_validated(
        &self,
        df: DataFrame,
        tenant: &str,
        variant: Option<&str>,
        sink: Option<&dyn DeadLetterSink>,
    ) -> (mpsc::Receiver<Result<Vec<Tensor>>>, ValidationReport) {
        let nrows = df.num_rows();
        let resolved = match self.registry.resolve(tenant) {
            Ok(r) => r,
            Err(e) => return (Self::reject(e), ValidationReport::all_valid(nrows)),
        };
        let Some(spec) = resolved.validation() else {
            let rx = self.submit_resolved(df, variant.map(str::to_string), resolved);
            return (rx, ValidationReport::all_valid(nrows));
        };
        let (clean, report) = match screen_batch(spec, &df, Vec::new()) {
            Ok(v) => v,
            Err(e) => return (Self::reject(e), ValidationReport::all_valid(nrows)),
        };
        if let Some(sink) = sink {
            for i in report.quarantined() {
                sink.record(tenant, &crate::dataframe::row_to_json(&df, i), &report.errors[i]);
            }
        }
        if report.num_valid() == 0 {
            // all-quarantined: answer now, the backend never sees an
            // empty batch
            let (resp_tx, resp_rx) = mpsc::channel();
            let _ = resp_tx.send(Ok(Vec::new()));
            return (resp_rx, report);
        }
        let rx = self.submit_resolved(clean, variant.map(str::to_string), resolved);
        (rx, report)
    }

    /// A receiver already primed with `err` — submit-time rejections
    /// fail their OWN request without touching the queue.
    fn reject(err: KamaeError) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let _ = resp_tx.send(Err(err));
        resp_rx
    }

    /// Requests queued but not yet drained by a worker.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Requests/second the pool has drained over its lifetime — with
    /// [`Server::queue_depth`], the inputs to the shed path's dynamic
    /// `Retry-After` hint. 0.0 until the first request completes.
    pub fn drain_rate_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.counts().1 as f64 / secs
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Total backend-execution time summed across workers (the cost
    /// proxy: CPU-seconds of preprocessing work).
    pub fn busy_time(&self) -> Duration {
        self.worker_busy_times().into_iter().sum()
    }

    /// Per-worker backend-execution time, in worker order — feeds the
    /// per-worker utilization split in
    /// [`crate::serving::ServeReport`].
    pub fn worker_busy_times(&self) -> Vec<Duration> {
        self.metrics
            .iter()
            .map(|m| Duration::from_nanos(m.busy_ns.load(Ordering::Relaxed)))
            .collect()
    }

    /// (batches executed, requests served) across the pool — batching
    /// efficiency.
    pub fn counts(&self) -> (u64, u64) {
        self.metrics.iter().fold((0, 0), |(b, r), m| {
            (
                b + m.batches.load(Ordering::Relaxed),
                r + m.requests.load(Ordering::Relaxed),
            )
        })
    }

    /// Requests served per variant tag (untargeted under `""`), merged
    /// across workers.
    pub fn variant_counts(&self) -> BTreeMap<String, u64> {
        let mut merged = BTreeMap::new();
        for m in &self.metrics {
            for (variant, n) in m.variant_requests.lock().unwrap().iter() {
                *merged.entry(variant.clone()).or_insert(0) += n;
            }
        }
        merged
    }

    /// Stop the pool and wait for every worker. Requests already queued
    /// are still served before the workers exit (the queue drains
    /// before disconnecting).
    pub fn shutdown(mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(config: BatchConfig, queue: Arc<JobQueue>, metrics: Arc<WorkerMetrics>) {
    while let Some(jobs) = queue.pop_batch(config.max_batch_rows, config.max_wait) {
        {
            // this worker is the map's only hot-path writer; the lock
            // is for report-time readers and therefore uncontended here
            let mut counts = metrics.variant_requests.lock().unwrap();
            for job in &jobs {
                *counts.entry(job.variant.clone().unwrap_or_default()).or_insert(0) += 1;
            }
        }
        // sub-batch by resolved tenant version (Arc identity): a drain
        // can straddle tenants — or a hot swap on ONE tenant — and each
        // version's backend must see only its own jobs. Arrival order
        // is preserved within each sub-batch; in the common steady
        // state (one tenant, no swap in flight) this is a single group
        // and the loop body is exactly the pre-registry hot path.
        let mut sub_batches: Vec<(Arc<TenantVersion>, Vec<Job>)> = Vec::new();
        for job in jobs {
            match sub_batches.iter_mut().find(|(v, _)| Arc::ptr_eq(v, &job.resolved)) {
                Some((_, members)) => members.push(job),
                None => {
                    let version = Arc::clone(&job.resolved);
                    sub_batches.push((version, vec![job]));
                }
            }
        }
        for (version, jobs) in sub_batches {
            let routed = config.route_variants && jobs.iter().any(|j| j.variant.is_some());
            let t0 = Instant::now();
            let result = if routed {
                run_batch_routed(version.backend(), &jobs)
            } else {
                run_batch(version.backend(), &jobs)
            };
            metrics.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            metrics.requests.fetch_add(jobs.len() as u64, Ordering::Relaxed);
            version.record_served(jobs.len() as u64);

            match result {
                Ok(per_job) => {
                    for (job, tensors) in jobs.into_iter().zip(per_job) {
                        let _ = job.resp.send(Ok(tensors));
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    for job in jobs {
                        let _ = job.resp.send(Err(KamaeError::Serving(msg.clone())));
                    }
                }
            }
        }
    }
}

/// Merge jobs, run the backend once, split outputs per job.
fn run_batch(backend: &dyn Backend, jobs: &[Job]) -> Result<Vec<Vec<Tensor>>> {
    let merged = if jobs.len() == 1 {
        jobs[0].df.clone()
    } else {
        let frames: Vec<&DataFrame> = jobs.iter().map(|j| &j.df).collect();
        DataFrame::concat(&frames)?
    };
    let outputs = backend.process(&merged)?;
    if jobs.len() == 1 {
        return Ok(vec![outputs]);
    }
    let sizes: Vec<usize> = jobs.iter().map(|j| j.df.num_rows()).collect();
    // transpose: per-output splits -> per-job tensor lists
    let mut per_job: Vec<Vec<Tensor>> = vec![Vec::with_capacity(outputs.len()); jobs.len()];
    for out in &outputs {
        let parts = out.split_batch(&sizes)?;
        for (slot, part) in per_job.iter_mut().zip(parts) {
            slot.push(part);
        }
    }
    Ok(per_job)
}

/// Variant-routed batch execution: reorder the drained jobs into
/// contiguous per-variant groups (first-appearance group order, arrival
/// order within each group), concatenate once, run the backend's routed
/// path once, then split each group's tensors back to its jobs. The
/// returned per-job tensor lists are in the ORIGINAL job order, so the
/// caller's response loop stays oblivious to the reordering.
fn run_batch_routed(backend: &dyn Backend, jobs: &[Job]) -> Result<Vec<Vec<Tensor>>> {
    // stable-partition job indices into per-variant groups
    let mut group_jobs: Vec<(Option<String>, Vec<usize>)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        match group_jobs.iter_mut().find(|(v, _)| *v == job.variant) {
            Some((_, members)) => members.push(i),
            None => group_jobs.push((job.variant.clone(), vec![i])),
        }
    }
    // concat in group order; build the contiguous row ranges
    let order: Vec<usize> = group_jobs.iter().flat_map(|(_, m)| m.iter().copied()).collect();
    let frames: Vec<&DataFrame> = order.iter().map(|&i| &jobs[i].df).collect();
    let merged = if frames.len() == 1 { frames[0].clone() } else { DataFrame::concat(&frames)? };
    let mut groups = Vec::with_capacity(group_jobs.len());
    let mut start = 0usize;
    for (variant, members) in &group_jobs {
        let len: usize = members.iter().map(|&i| jobs[i].df.num_rows()).sum();
        groups.push(VariantGroup { variant: variant.clone(), rows: start..start + len });
        start += len;
    }

    let per_group = backend.process_routed(&merged, &groups)?;

    // split each group's tensors across its jobs, back in job order
    let mut per_job: Vec<Vec<Tensor>> = jobs.iter().map(|_| Vec::new()).collect();
    for ((_, members), tensors) in group_jobs.iter().zip(per_group) {
        if members.len() == 1 {
            per_job[members[0]] = tensors;
            continue;
        }
        let sizes: Vec<usize> = members.iter().map(|&i| jobs[i].df.num_rows()).collect();
        for out in &tensors {
            let parts = out.split_batch(&sizes)?;
            for (&i, part) in members.iter().zip(parts) {
                per_job[i].push(part);
            }
        }
    }
    Ok(per_job)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    /// Backend that doubles an f64 column; records max batch seen.
    struct Doubler {
        max_batch: std::sync::atomic::AtomicUsize,
    }

    impl Doubler {
        fn new() -> Doubler {
            Doubler { max_batch: Default::default() }
        }
    }

    impl Backend for Doubler {
        fn name(&self) -> &str {
            "doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }
    }

    fn req(vals: &[f64]) -> DataFrame {
        DataFrame::new(vec![("x".into(), Column::from_f64(vals.to_vec()))]).unwrap()
    }

    #[test]
    fn responses_route_back_to_requests() {
        let server = Server::start(
            Box::new(Doubler::new()),
            BatchConfig {
                max_batch_rows: 64,
                max_wait: Duration::from_millis(5),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..20)
            .map(|i| (i, server.submit(req(&[i as f64, i as f64 + 0.5]))))
            .collect();
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].as_f32().unwrap(), &[2.0 * i as f32, 2.0 * i as f32 + 1.0]);
        }
        let (batches, requests) = server.counts();
        assert_eq!(requests, 20);
        assert!(batches <= 20);
        server.shutdown();
    }

    #[test]
    fn degenerate_configs_are_rejected_at_start() {
        // regression (pool refactor): workers == 0 would leave the
        // queue undrained — every submit would hang forever; a zero
        // row budget starved the greedy top-up loop. Both must be a
        // Serving error at start, before any thread spawns.
        for config in [
            BatchConfig { workers: 0, ..BatchConfig::default() },
            BatchConfig { max_batch_rows: 0, ..BatchConfig::default() },
        ] {
            let err = Server::start(Box::new(Doubler::new()), config).unwrap_err();
            assert!(matches!(err, KamaeError::Serving(_)), "{err}");
        }
        // the error message names the offending knob
        let err = Server::start(
            Box::new(Doubler::new()),
            BatchConfig { workers: 0, ..BatchConfig::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("workers"), "{err}");
        let err = Server::start(
            Box::new(Doubler::new()),
            BatchConfig { max_batch_rows: 0, ..BatchConfig::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("max_batch_rows"), "{err}");
    }

    #[test]
    fn batching_actually_merges() {
        let backend = Arc::new(Doubler::new());
        let server = Server::start_shared(
            backend.clone(),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(50),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        // burst of requests within the batching window
        let rxs: Vec<_> = (0..32).map(|_| server.submit(req(&[1.0]))).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let max_seen = backend.max_batch.load(Ordering::Relaxed);
        assert!(max_seen > 1, "batcher never merged (max batch {max_seen})");
        server.shutdown();
    }

    #[test]
    fn oversized_request_is_served_whole() {
        // a single request larger than max_batch_rows must run as its
        // own batch — never stall waiting for headroom, never split, and
        // never drop rows. (The drain loops only *top up* small batches;
        // an oversized first job skips them and executes immediately.)
        let backend = Arc::new(Doubler::new());
        let server = Server::start_shared(
            backend.clone(),
            BatchConfig {
                max_batch_rows: 8,
                max_wait: Duration::from_millis(5),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let vals: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let rx = server.submit(req(&vals));
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 1);
        let got = out[0].as_f32().unwrap();
        assert_eq!(got.len(), 50, "oversized request lost rows");
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, 2.0 * i as f32);
        }
        let (batches, requests) = server.counts();
        assert_eq!((batches, requests), (1, 1), "oversized request was split or retried");
        assert_eq!(
            backend.max_batch.load(Ordering::Relaxed),
            50,
            "backend saw a different batch than submitted"
        );
        server.shutdown();
    }

    #[test]
    fn error_propagates_to_all_requests() {
        struct Failing;
        impl Backend for Failing {
            fn name(&self) -> &str {
                "fail"
            }
            fn process(&self, _: &DataFrame) -> Result<Vec<Tensor>> {
                Err(KamaeError::Serving("boom".into()))
            }
        }
        let server = Server::start(Box::new(Failing), BatchConfig::default()).unwrap();
        let rx = server.submit(req(&[1.0]));
        assert!(rx.recv().unwrap().is_err());
        server.shutdown();
    }

    // ---- variant routing --------------------------------------------------

    /// Two-variant mock backend over one f64 column `x`: variant "dbl"
    /// serves [2x], variant "tri" serves [3x], untargeted requests get
    /// both in that order. Routed calls are counted so tests can pin
    /// which path executed.
    struct VariantDoubler {
        variants: Vec<String>,
        routed_calls: std::sync::atomic::AtomicUsize,
        max_batch: std::sync::atomic::AtomicUsize,
    }

    impl VariantDoubler {
        fn new() -> VariantDoubler {
            VariantDoubler {
                variants: vec!["dbl".into(), "tri".into()],
                routed_calls: Default::default(),
                max_batch: Default::default(),
            }
        }

        fn scale(df: &DataFrame, k: f64) -> Result<Tensor> {
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| (k * x) as f32).collect(), vec![v.len()])
        }
    }

    impl Backend for VariantDoubler {
        fn name(&self) -> &str {
            "variant-doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            Ok(vec![Self::scale(df, 2.0)?, Self::scale(df, 3.0)?])
        }

        fn variants(&self) -> &[String] {
            &self.variants
        }

        fn process_routed(
            &self,
            df: &DataFrame,
            groups: &[super::VariantGroup],
        ) -> Result<Vec<Vec<Tensor>>> {
            self.routed_calls.fetch_add(1, Ordering::Relaxed);
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            groups
                .iter()
                .map(|g| {
                    let slice = df.slice(g.rows.start, g.rows.len());
                    match g.variant.as_deref() {
                        Some("dbl") => Ok(vec![Self::scale(&slice, 2.0)?]),
                        Some("tri") => Ok(vec![Self::scale(&slice, 3.0)?]),
                        None => Ok(vec![Self::scale(&slice, 2.0)?, Self::scale(&slice, 3.0)?]),
                        Some(other) => {
                            Err(KamaeError::Serving(format!("unknown variant {other}")))
                        }
                    }
                })
                .collect()
        }
    }

    #[test]
    fn mixed_variant_batch_routes_back_to_each_request() {
        // interleaved dbl/tri/untargeted submissions within one batching
        // window: every response must carry exactly its variant's
        // outputs for its own rows, whatever the batcher reordered
        let backend = Arc::new(VariantDoubler::new());
        let server = Server::start_shared(
            backend.clone(),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(50),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let mut rxs = Vec::new();
        for i in 0..24 {
            let vals = [i as f64, i as f64 + 0.25];
            let rx = match i % 3 {
                0 => server.submit_variant(req(&vals), "dbl"),
                1 => server.submit_variant(req(&vals), "tri"),
                _ => server.submit(req(&vals)),
            };
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            let vals = [i as f64, i as f64 + 0.25];
            match i % 3 {
                0 => {
                    assert_eq!(out.len(), 1, "dbl request got {} tensors", out.len());
                    assert_eq!(out[0].as_f32().unwrap(), &[
                        2.0 * vals[0] as f32,
                        2.0 * vals[1] as f32
                    ]);
                }
                1 => {
                    assert_eq!(out.len(), 1, "tri request got {} tensors", out.len());
                    assert_eq!(out[0].as_f32().unwrap(), &[
                        3.0 * vals[0] as f32,
                        3.0 * vals[1] as f32
                    ]);
                }
                _ => {
                    assert_eq!(out.len(), 2, "untargeted request got {} tensors", out.len());
                    assert_eq!(out[0].as_f32().unwrap()[0], 2.0 * vals[0] as f32);
                    assert_eq!(out[1].as_f32().unwrap()[0], 3.0 * vals[0] as f32);
                }
            }
        }
        let counts = server.variant_counts();
        assert_eq!(counts.get("dbl"), Some(&8));
        assert_eq!(counts.get("tri"), Some(&8));
        assert_eq!(counts.get(""), Some(&8));
        let routed = backend.routed_calls.load(Ordering::Relaxed);
        let max_batch = backend.max_batch.load(Ordering::Relaxed);
        assert!(routed > 0, "no batch took the routed path");
        assert!(max_batch > 2, "mixed-variant batch never merged (max {max_batch})");
        server.shutdown();
    }

    #[test]
    fn route_off_serves_tagged_requests_the_full_output_set() {
        // the all-outputs baseline: with routing disabled the variant
        // tag is ignored and process() serves everything
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig { route_variants: false, ..BatchConfig::default() },
        )
        .unwrap();
        let out = server
            .submit_variant(req(&[2.0]), "dbl")
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(out.len(), 2, "route-off must serve the full output set");
        assert_eq!(out[0].as_f32().unwrap(), &[4.0]);
        assert_eq!(out[1].as_f32().unwrap(), &[6.0]);
        server.shutdown();
    }

    #[test]
    fn unknown_variant_errors_only_its_own_request() {
        // a bad tag is rejected at submit time, BEFORE batching — so a
        // valid request submitted in the same flush window (which the
        // batcher would have coalesced with it) still succeeds
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(50),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let bad = server.submit_variant(req(&[1.0]), "nope");
        let ok = server.submit_variant(req(&[1.0]), "dbl");
        let err = bad.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
        assert_eq!(ok.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        // the rejected request never reached the batcher
        let (_, requests) = server.counts();
        assert_eq!(requests, 1);
        server.shutdown();

        // with routing off, tags are ignored rather than validated: the
        // same bad tag serves the full output set
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig { route_variants: false, ..BatchConfig::default() },
        )
        .unwrap();
        let out = server.submit_variant(req(&[1.0]), "nope").recv().unwrap().unwrap();
        assert_eq!(out.len(), 2);
        server.shutdown();
    }

    #[test]
    fn flush_deadline_expires_partial_batches() {
        // requests spaced further apart than max_wait must not wait for
        // a full batch: each flushes as its own (partial) batch
        let server = Server::start(
            Box::new(Doubler::new()),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(20),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let rx1 = server.submit(req(&[1.0]));
        assert_eq!(rx1.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        // well past the first batch's deadline
        std::thread::sleep(Duration::from_millis(120));
        let rx2 = server.submit(req(&[2.0]));
        assert_eq!(rx2.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[4.0]);
        let (batches, requests) = server.counts();
        assert_eq!(requests, 2);
        assert_eq!(batches, 2, "spaced requests must flush as separate partial batches");
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_mixed_variant_requests() {
        // shutdown closes the queue but the workers drain what is
        // already queued: every submitted request still gets an answer
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                max_batch_rows: 8,
                max_wait: Duration::from_millis(1),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                let vals = [i as f64];
                match i % 3 {
                    0 => (i, server.submit_variant(req(&vals), "dbl"), 2.0f32),
                    1 => (i, server.submit_variant(req(&vals), "tri"), 3.0f32),
                    _ => (i, server.submit(req(&vals)), 2.0f32),
                }
            })
            .collect();
        server.shutdown(); // workers must finish the queue before exiting
        for (i, rx, k) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out[0].as_f32().unwrap(), &[k * i as f32], "request {i}");
        }
    }

    #[test]
    fn oversized_variant_request_is_served_whole_and_routed() {
        // a tagged request larger than max_batch_rows still runs as its
        // own (routed) batch: never split, never stalled, only its
        // variant's outputs
        let backend = Arc::new(VariantDoubler::new());
        let server = Server::start_shared(
            backend.clone(),
            BatchConfig {
                max_batch_rows: 8,
                max_wait: Duration::from_millis(5),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let vals: Vec<f64> = (0..40).map(|i| i as f64).collect();
        let rx = server.submit_variant(req(&vals), "tri");
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 1, "tagged oversized request must get only its variant");
        let got = out[0].as_f32().unwrap();
        assert_eq!(got.len(), 40, "oversized request lost rows");
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, 3.0 * i as f32);
        }
        let (batches, requests) = server.counts();
        assert_eq!((batches, requests), (1, 1), "oversized request was split or retried");
        assert_eq!(
            backend.routed_calls.load(Ordering::Relaxed),
            1,
            "oversized tagged request did not take the routed path"
        );
        assert_eq!(
            backend.max_batch.load(Ordering::Relaxed),
            40,
            "backend saw a different batch than submitted"
        );
        server.shutdown();
    }

    // ---- worker pool ------------------------------------------------------

    /// Bitwise tensor-list equality via the shared oracle
    /// ([`crate::util::prop::tensors_bit_identical`]), with a context
    /// prefix.
    fn assert_bitwise_eq(a: &[Tensor], b: &[Tensor], what: &str) {
        if let Err(e) = crate::util::prop::tensors_bit_identical(a, b) {
            panic!("{what}: {e}");
        }
    }

    #[test]
    fn pooled_mixed_variant_stress_matches_single_worker_oracle() {
        // M producer threads hammer a 4-worker pool with interleaved
        // mixed-variant requests while a 1-worker server (the PR 4
        // architecture) serves the IDENTICAL frames as the oracle:
        // every pooled response must be bit-identical to the oracle's,
        // whatever worker/batch each side landed in.
        let pool = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                workers: 4,
                max_batch_rows: 32,
                max_wait: Duration::from_micros(200),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        let oracle = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                workers: 1,
                max_batch_rows: 32,
                max_wait: Duration::from_micros(200),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        std::thread::scope(|scope| {
            for t in 0..4i64 {
                let pool = &pool;
                let oracle = &oracle;
                scope.spawn(move || {
                    for i in 0..40i64 {
                        let v = (t * 1000 + i) as f64;
                        let frame = req(&[v, v + 0.5, v + 0.75]);
                        let (rx_pool, rx_oracle) = match i % 3 {
                            0 => (
                                pool.submit_variant(frame.clone(), "dbl"),
                                oracle.submit_variant(frame, "dbl"),
                            ),
                            1 => (
                                pool.submit_variant(frame.clone(), "tri"),
                                oracle.submit_variant(frame, "tri"),
                            ),
                            _ => (pool.submit(frame.clone()), oracle.submit(frame)),
                        };
                        let got = rx_pool.recv().unwrap().unwrap();
                        let want = rx_oracle.recv().unwrap().unwrap();
                        assert_bitwise_eq(&got, &want, &format!("producer {t} request {i}"));
                    }
                });
            }
        });
        assert_eq!(pool.workers(), 4);
        assert_eq!(pool.worker_busy_times().len(), 4);
        let (_, requests) = pool.counts();
        assert_eq!(requests, 160, "pool lost or duplicated requests");
        // per-worker variant splits merge into the correct totals
        let counts = pool.variant_counts();
        assert_eq!(counts.values().sum::<u64>(), 160);
        // per-worker busy times sum to the aggregate cost proxy
        let summed: Duration = pool.worker_busy_times().into_iter().sum();
        assert_eq!(summed, pool.busy_time());

        // shutdown drains: queue another burst without receiving, then
        // shut the pool down — every request must still be answered
        let parked: Vec<_> = (0..32)
            .map(|i| {
                let v = 9_000.0 + i as f64;
                (v, pool.submit_variant(req(&[v]), "dbl"))
            })
            .collect();
        pool.shutdown();
        for (v, rx) in parked {
            let out = rx.recv().expect("response channel dropped").unwrap();
            assert_eq!(out[0].as_f32().unwrap(), &[2.0 * v as f32]);
        }
        oracle.shutdown();
    }

    #[test]
    fn submits_after_shutdown_error_cleanly() {
        // a stopped pool must bounce new submissions on their own
        // channel, not panic or hang
        let backend = Arc::new(Doubler::new());
        let server = Server::start_shared(backend.clone(), BatchConfig::default()).unwrap();
        let queue = Arc::clone(&server.queue);
        let resolved = server.registry().resolve(DEFAULT_TENANT).unwrap();
        server.shutdown();
        // the queue is closed: a late push is handed back
        let (tx, rx) = mpsc::channel();
        let job = Job { df: req(&[1.0]), variant: None, resolved, resp: tx };
        assert!(queue.push(job).is_err());
        drop(rx);
    }

    // ---- ingress validation gate ------------------------------------------

    /// [`Doubler`] with a request schema over `x: f64`, so the registry
    /// derives a validation spec for it (plain mocks skip the gate).
    struct SchemaDoubler;

    impl Backend for SchemaDoubler {
        fn name(&self) -> &str {
            "schema-doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            assert!(df.num_rows() > 0, "validated path leaked an empty batch to the backend");
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }

        fn request_schema(&self) -> Option<crate::dataframe::Schema> {
            Some(crate::dataframe::Schema {
                fields: vec![crate::dataframe::Field {
                    name: "x".into(),
                    dtype: crate::dataframe::DType::F64,
                }],
            })
        }
    }

    #[test]
    fn validated_submit_quarantines_dead_letters_and_serves_the_rest() {
        use super::super::validate::MemoryDeadLetter;
        let server = Server::start(Box::new(SchemaDoubler), BatchConfig::default()).unwrap();
        let sink = MemoryDeadLetter::new(16);
        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64_opt(vec![Some(1.0), None, Some(3.0), None]),
        )])
        .unwrap();
        let (rx, report) = server.submit_tenant_validated(df, DEFAULT_TENANT, None, Some(&sink));
        assert_eq!(report.keep, vec![true, false, true, false]);
        let out = rx.recv().unwrap().unwrap();
        // compacted batch: exactly the valid rows, in original order
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 6.0]);
        // quarantined rows landed in the sink with rule + column
        assert_eq!(sink.len(), 2);
        let entry = &sink.entries()[0];
        assert_eq!(
            entry.get("tenant").and_then(crate::util::json::Json::as_str),
            Some(DEFAULT_TENANT)
        );
        let errs = entry.get("errors").and_then(crate::util::json::Json::as_array).unwrap();
        assert_eq!(errs[0].get("rule").and_then(crate::util::json::Json::as_str), Some("not_null"));
        assert_eq!(errs[0].get("column").and_then(crate::util::json::Json::as_str), Some("x"));

        // all-quarantined: verdicts only, the backend never runs on an
        // empty frame (SchemaDoubler asserts), the response is prompt
        let df = DataFrame::new(vec![("x".into(), Column::from_f64_opt(vec![None, None]))])
            .unwrap();
        let (rx, report) = server.submit_tenant_validated(df, DEFAULT_TENANT, None, Some(&sink));
        assert_eq!(report.num_valid(), 0);
        assert_eq!(report.num_quarantined(), 2);
        assert!(rx.recv().unwrap().unwrap().is_empty());
        assert_eq!(sink.len(), 4);

        // load-signal accessors behave at idle
        assert_eq!(server.queue_depth(), 0);
        assert!(server.drain_rate_rps() >= 0.0);
        server.shutdown();
    }

    // ---- registry addressing ----------------------------------------------

    #[test]
    fn unknown_tenant_errors_only_its_own_request() {
        // like an unknown variant, an unknown tenant is rejected at
        // submit time on its own channel — co-batched requests to real
        // tenants are untouched
        let server = Server::start(Box::new(Doubler::new()), BatchConfig::default()).unwrap();
        let bad = server.submit_tenant(req(&[1.0]), "ghost", None);
        let ok = server.submit(req(&[1.0]));
        let err = bad.recv().unwrap().unwrap_err();
        assert!(matches!(err, KamaeError::UnknownTenant(_)), "{err}");
        assert!(err.to_string().contains("ghost"), "{err}");
        assert_eq!(ok.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        let (_, requests) = server.counts();
        assert_eq!(requests, 1, "rejected tenant reached the batcher");
        server.shutdown();
    }

    #[test]
    fn one_pool_serves_multiple_tenants() {
        // two tenants with bit-distinguishable backends behind ONE
        // queue + worker: each request lands on its own tenant's
        // backend, and the single-spec submit keeps addressing the
        // default tenant
        let registry = Arc::new(SpecRegistry::new());
        registry
            .deploy_backend(DEFAULT_TENANT, Arc::new(Doubler::new()), None)
            .unwrap();
        registry
            .deploy_backend("variants", Arc::new(VariantDoubler::new()), None)
            .unwrap();
        let server = Server::start_registry(
            Arc::clone(&registry),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(20),
                ..BatchConfig::default()
            },
        )
        .unwrap();
        // burst within one batching window so a drain can straddle both
        // tenants — the worker must still split per version
        let rx_default = server.submit(req(&[2.0]));
        let rx_tri = server.submit_tenant(req(&[2.0]), "variants", Some("tri"));
        let rx_both = server.submit_tenant(req(&[2.0]), "variants", None);
        assert_eq!(rx_default.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[4.0]);
        let tri = rx_tri.recv().unwrap().unwrap();
        assert_eq!(tri.len(), 1);
        assert_eq!(tri[0].as_f32().unwrap(), &[6.0]);
        let both = rx_both.recv().unwrap().unwrap();
        assert_eq!(both.len(), 2);
        assert_eq!(both[0].as_f32().unwrap(), &[4.0]);
        assert_eq!(both[1].as_f32().unwrap(), &[6.0]);
        // per-version counters saw each tenant's own traffic
        let snap = registry.snapshot();
        let by_name: BTreeMap<_, _> =
            snap.iter().map(|s| (s.tenant.as_str(), s)).collect();
        assert_eq!(by_name[DEFAULT_TENANT].versions[0].requests, 1);
        assert_eq!(by_name["variants"].versions[0].requests, 2);
        server.shutdown();
    }
}
