//! Dynamic batcher + server loop.
//!
//! Requests (small DataFrames) queue onto a channel; the worker thread
//! drains up to `max_batch_rows` or until `max_wait` elapses from the
//! first queued request, concatenates them into one batch, runs the
//! backend once, then splits the output tensors back per request —
//! amortising graph-execution overhead exactly the way TF-Serving's
//! dynamic batching does for the paper's production service.
//!
//! ## Variant routing
//!
//! A request may target one **variant** of a merged multi-variant
//! backend ([`Server::submit_variant`]). The batcher still coalesces
//! mixed-variant submissions into ONE batch: jobs are sorted into
//! contiguous per-variant groups (arrival order preserved within each
//! group), the frames are concatenated in group order, and the backend
//! runs once via [`Backend::process_routed`] — the shared preprocessing
//! prefix executes a single time over the whole mixed batch while each
//! variant's exclusive work runs only on its own rows. A targeted
//! request's response carries exactly its variant's output tensors, in
//! that variant's output order.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};
use crate::runtime::Tensor;

use super::backend::{Backend, VariantGroup};

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Max rows merged into one backend call.
    pub max_batch_rows: usize,
    /// Max time the first request in a batch waits for company.
    pub max_wait: Duration,
    /// Route variant-tagged requests through
    /// [`Backend::process_routed`] (cone-restricted evaluation, one
    /// merged batch across variants). When `false` the tags are ignored
    /// and every request is served the backend's full output set — the
    /// all-outputs-per-request baseline the routing benchmark gates
    /// against.
    pub route_variants: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        // max_wait 300µs: at production-like rates (~200 rps) requests
        // rarely overlap, so long waits only pad p50; under bursts the
        // queue drains in whole batches anyway because the worker picks
        // up everything already queued before waiting (§Perf L3 log).
        BatchConfig {
            max_batch_rows: 128,
            max_wait: Duration::from_micros(300),
            route_variants: true,
        }
    }
}

struct Job {
    df: DataFrame,
    /// Target variant of a merged multi-variant backend; `None` asks
    /// for the full output set.
    variant: Option<String>,
    resp: mpsc::Sender<Result<Vec<Tensor>>>,
}

/// A running server: one batcher thread owning the backend.
pub struct Server {
    tx: Option<mpsc::Sender<Job>>,
    worker: Option<std::thread::JoinHandle<()>>,
    busy_ns: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
    requests: Arc<AtomicU64>,
    /// Requests served per variant tag (untargeted requests count under
    /// `""`) — the per-variant split [`crate::serving::ServeReport`]
    /// surfaces.
    variant_requests: Arc<Mutex<BTreeMap<String, u64>>>,
    /// Variant names the backend can route, captured before the backend
    /// moves into the worker; `None` when routing is disabled
    /// ([`BatchConfig::route_variants`] off — tags are ignored, so
    /// nothing is validated). Used to reject unknown variants at submit
    /// time: a bad tag must error its OWN request, never poison the
    /// co-batched ones.
    known_variants: Option<Vec<String>>,
}

impl Server {
    /// Spawn the batcher thread.
    pub fn start(backend: Box<dyn Backend>, config: BatchConfig) -> Server {
        let known_variants =
            if config.route_variants { Some(backend.variants().to_vec()) } else { None };
        let (tx, rx) = mpsc::channel::<Job>();
        let busy_ns = Arc::new(AtomicU64::new(0));
        let batches = Arc::new(AtomicU64::new(0));
        let requests = Arc::new(AtomicU64::new(0));
        let variant_requests = Arc::new(Mutex::new(BTreeMap::new()));
        let worker = {
            let busy_ns = Arc::clone(&busy_ns);
            let batches = Arc::clone(&batches);
            let requests = Arc::clone(&requests);
            let variant_requests = Arc::clone(&variant_requests);
            std::thread::spawn(move || {
                batch_loop(backend, config, rx, busy_ns, batches, requests, variant_requests);
            })
        };
        Server {
            tx: Some(tx),
            worker: Some(worker),
            busy_ns,
            batches,
            requests,
            variant_requests,
            known_variants,
        }
    }

    /// Submit an untargeted request; the receiver yields the backend's
    /// full output tensors for this request's rows.
    pub fn submit(&self, df: DataFrame) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        self.enqueue(df, None)
    }

    /// Submit a request targeting one variant of a merged multi-variant
    /// backend; the receiver yields only that variant's output tensors
    /// (in the variant's own output order). Unknown variants (or a
    /// backend without variant support) error on THIS request's
    /// receiver immediately — the bad tag never reaches the batcher, so
    /// it cannot fail the requests it would have been coalesced with.
    pub fn submit_variant(
        &self,
        df: DataFrame,
        variant: &str,
    ) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        if let Some(known) = &self.known_variants {
            if !known.iter().any(|v| v == variant) {
                let (resp_tx, resp_rx) = mpsc::channel();
                let _ = resp_tx.send(Err(KamaeError::Serving(format!(
                    "no variant '{variant}' to route to (backend variants: {})",
                    known.join(", ")
                ))));
                return resp_rx;
            }
        }
        self.enqueue(df, Some(variant.to_string()))
    }

    fn enqueue(
        &self,
        df: DataFrame,
        variant: Option<String>,
    ) -> mpsc::Receiver<Result<Vec<Tensor>>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        if let Some(tx) = &self.tx {
            if tx.send(Job { df, variant, resp: resp_tx.clone() }).is_err() {
                let _ = resp_tx.send(Err(KamaeError::Serving("server stopped".into())));
            }
        }
        resp_rx
    }

    /// Total backend-execution time (the cost proxy: CPU-seconds of
    /// preprocessing work, single worker).
    pub fn busy_time(&self) -> Duration {
        Duration::from_nanos(self.busy_ns.load(Ordering::Relaxed))
    }

    /// (batches executed, requests served) — batching efficiency.
    pub fn counts(&self) -> (u64, u64) {
        (self.batches.load(Ordering::Relaxed), self.requests.load(Ordering::Relaxed))
    }

    /// Requests served per variant tag (untargeted under `""`).
    pub fn variant_counts(&self) -> BTreeMap<String, u64> {
        self.variant_requests.lock().unwrap().clone()
    }

    /// Stop the worker and wait for it. Requests already queued are
    /// still served before the worker exits (the channel drains before
    /// disconnecting).
    pub fn shutdown(mut self) {
        self.tx.take(); // close the channel
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batch_loop(
    backend: Box<dyn Backend>,
    config: BatchConfig,
    rx: mpsc::Receiver<Job>,
    busy_ns: Arc<AtomicU64>,
    batches: Arc<AtomicU64>,
    requests: Arc<AtomicU64>,
    variant_requests: Arc<Mutex<BTreeMap<String, u64>>>,
) {
    loop {
        // block for the first request of the next batch
        let first = match rx.recv() {
            Ok(job) => job,
            Err(_) => return, // channel closed: shutdown
        };
        let mut jobs = vec![first];
        let mut rows = jobs[0].df.num_rows();
        // greedily take everything already queued (free batching)
        while rows < config.max_batch_rows {
            match rx.try_recv() {
                Ok(job) => {
                    rows += job.df.num_rows();
                    jobs.push(job);
                }
                Err(_) => break,
            }
        }
        // then wait at most max_wait for stragglers — but only if the
        // batch still has meaningful headroom
        let deadline = Instant::now() + config.max_wait;
        while rows < config.max_batch_rows {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(job) => {
                    rows += job.df.num_rows();
                    jobs.push(job);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        {
            let mut counts = variant_requests.lock().unwrap();
            for job in &jobs {
                *counts.entry(job.variant.clone().unwrap_or_default()).or_insert(0) += 1;
            }
        }
        let routed = config.route_variants && jobs.iter().any(|j| j.variant.is_some());
        let t0 = Instant::now();
        let result = if routed {
            run_batch_routed(backend.as_ref(), &jobs)
        } else {
            run_batch(backend.as_ref(), &jobs)
        };
        busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        batches.fetch_add(1, Ordering::Relaxed);
        requests.fetch_add(jobs.len() as u64, Ordering::Relaxed);

        match result {
            Ok(per_job) => {
                for (job, tensors) in jobs.into_iter().zip(per_job) {
                    let _ = job.resp.send(Ok(tensors));
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for job in jobs {
                    let _ = job.resp.send(Err(KamaeError::Serving(msg.clone())));
                }
            }
        }
    }
}

/// Merge jobs, run the backend once, split outputs per job.
fn run_batch(backend: &dyn Backend, jobs: &[Job]) -> Result<Vec<Vec<Tensor>>> {
    let merged = if jobs.len() == 1 {
        jobs[0].df.clone()
    } else {
        let frames: Vec<&DataFrame> = jobs.iter().map(|j| &j.df).collect();
        DataFrame::concat(&frames)?
    };
    let outputs = backend.process(&merged)?;
    if jobs.len() == 1 {
        return Ok(vec![outputs]);
    }
    let sizes: Vec<usize> = jobs.iter().map(|j| j.df.num_rows()).collect();
    // transpose: per-output splits -> per-job tensor lists
    let mut per_job: Vec<Vec<Tensor>> = vec![Vec::with_capacity(outputs.len()); jobs.len()];
    for out in &outputs {
        let parts = out.split_batch(&sizes)?;
        for (slot, part) in per_job.iter_mut().zip(parts) {
            slot.push(part);
        }
    }
    Ok(per_job)
}

/// Variant-routed batch execution: reorder the drained jobs into
/// contiguous per-variant groups (first-appearance group order, arrival
/// order within each group), concatenate once, run the backend's routed
/// path once, then split each group's tensors back to its jobs. The
/// returned per-job tensor lists are in the ORIGINAL job order, so the
/// caller's response loop stays oblivious to the reordering.
fn run_batch_routed(backend: &dyn Backend, jobs: &[Job]) -> Result<Vec<Vec<Tensor>>> {
    // stable-partition job indices into per-variant groups
    let mut group_jobs: Vec<(Option<String>, Vec<usize>)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        match group_jobs.iter_mut().find(|(v, _)| *v == job.variant) {
            Some((_, members)) => members.push(i),
            None => group_jobs.push((job.variant.clone(), vec![i])),
        }
    }
    // concat in group order; build the contiguous row ranges
    let order: Vec<usize> = group_jobs.iter().flat_map(|(_, m)| m.iter().copied()).collect();
    let frames: Vec<&DataFrame> = order.iter().map(|&i| &jobs[i].df).collect();
    let merged = if frames.len() == 1 { frames[0].clone() } else { DataFrame::concat(&frames)? };
    let mut groups = Vec::with_capacity(group_jobs.len());
    let mut start = 0usize;
    for (variant, members) in &group_jobs {
        let len: usize = members.iter().map(|&i| jobs[i].df.num_rows()).sum();
        groups.push(VariantGroup { variant: variant.clone(), rows: start..start + len });
        start += len;
    }

    let per_group = backend.process_routed(&merged, &groups)?;

    // split each group's tensors across its jobs, back in job order
    let mut per_job: Vec<Vec<Tensor>> = jobs.iter().map(|_| Vec::new()).collect();
    for ((_, members), tensors) in group_jobs.iter().zip(per_group) {
        if members.len() == 1 {
            per_job[members[0]] = tensors;
            continue;
        }
        let sizes: Vec<usize> = members.iter().map(|&i| jobs[i].df.num_rows()).collect();
        for out in &tensors {
            let parts = out.split_batch(&sizes)?;
            for (&i, part) in members.iter().zip(parts) {
                per_job[i].push(part);
            }
        }
    }
    Ok(per_job)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    /// Backend that doubles an f64 column; records max batch seen.
    struct Doubler {
        max_batch: std::sync::atomic::AtomicUsize,
    }

    impl Backend for Doubler {
        fn name(&self) -> &str {
            "doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }
    }

    fn req(vals: &[f64]) -> DataFrame {
        DataFrame::new(vec![("x".into(), Column::from_f64(vals.to_vec()))]).unwrap()
    }

    #[test]
    fn responses_route_back_to_requests() {
        let server = Server::start(
            Box::new(Doubler { max_batch: Default::default() }),
            BatchConfig {
                max_batch_rows: 64,
                max_wait: Duration::from_millis(5),
                ..BatchConfig::default()
            },
        );
        let rxs: Vec<_> = (0..20)
            .map(|i| (i, server.submit(req(&[i as f64, i as f64 + 0.5]))))
            .collect();
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].as_f32().unwrap(), &[2.0 * i as f32, 2.0 * i as f32 + 1.0]);
        }
        let (batches, requests) = server.counts();
        assert_eq!(requests, 20);
        assert!(batches <= 20);
        server.shutdown();
    }

    #[test]
    fn batching_actually_merges() {
        let backend = Box::new(Doubler { max_batch: Default::default() });
        let probe: *const Doubler = backend.as_ref();
        let server = Server::start(
            backend,
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(50),
                ..BatchConfig::default()
            },
        );
        // burst of requests within the batching window
        let rxs: Vec<_> = (0..32).map(|_| server.submit(req(&[1.0]))).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        // SAFETY: server still alive, backend not moved
        let max_seen = unsafe { (*probe).max_batch.load(Ordering::Relaxed) };
        assert!(max_seen > 1, "batcher never merged (max batch {max_seen})");
        server.shutdown();
    }

    #[test]
    fn oversized_request_is_served_whole() {
        // a single request larger than max_batch_rows must run as its
        // own batch — never stall waiting for headroom, never split, and
        // never drop rows. (The drain loops only *top up* small batches;
        // an oversized first job skips them and executes immediately.)
        let backend = Box::new(Doubler { max_batch: Default::default() });
        let probe: *const Doubler = backend.as_ref();
        let server = Server::start(
            backend,
            BatchConfig {
                max_batch_rows: 8,
                max_wait: Duration::from_millis(5),
                ..BatchConfig::default()
            },
        );
        let vals: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let rx = server.submit(req(&vals));
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 1);
        let got = out[0].as_f32().unwrap();
        assert_eq!(got.len(), 50, "oversized request lost rows");
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, 2.0 * i as f32);
        }
        let (batches, requests) = server.counts();
        assert_eq!((batches, requests), (1, 1), "oversized request was split or retried");
        // SAFETY: server still alive, backend not moved
        let max_seen = unsafe { (*probe).max_batch.load(Ordering::Relaxed) };
        assert_eq!(max_seen, 50, "backend saw a different batch than submitted");
        server.shutdown();
    }

    #[test]
    fn error_propagates_to_all_requests() {
        struct Failing;
        impl Backend for Failing {
            fn name(&self) -> &str {
                "fail"
            }
            fn process(&self, _: &DataFrame) -> Result<Vec<Tensor>> {
                Err(KamaeError::Serving("boom".into()))
            }
        }
        let server = Server::start(Box::new(Failing), BatchConfig::default());
        let rx = server.submit(req(&[1.0]));
        assert!(rx.recv().unwrap().is_err());
        server.shutdown();
    }

    // ---- variant routing --------------------------------------------------

    /// Two-variant mock backend over one f64 column `x`: variant "dbl"
    /// serves [2x], variant "tri" serves [3x], untargeted requests get
    /// both in that order. Routed calls are counted so tests can pin
    /// which path executed.
    struct VariantDoubler {
        variants: Vec<String>,
        routed_calls: std::sync::atomic::AtomicUsize,
        max_batch: std::sync::atomic::AtomicUsize,
    }

    impl VariantDoubler {
        fn new() -> VariantDoubler {
            VariantDoubler {
                variants: vec!["dbl".into(), "tri".into()],
                routed_calls: Default::default(),
                max_batch: Default::default(),
            }
        }

        fn scale(df: &DataFrame, k: f64) -> Result<Tensor> {
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| (k * x) as f32).collect(), vec![v.len()])
        }
    }

    impl Backend for VariantDoubler {
        fn name(&self) -> &str {
            "variant-doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            Ok(vec![Self::scale(df, 2.0)?, Self::scale(df, 3.0)?])
        }

        fn variants(&self) -> &[String] {
            &self.variants
        }

        fn process_routed(
            &self,
            df: &DataFrame,
            groups: &[super::VariantGroup],
        ) -> Result<Vec<Vec<Tensor>>> {
            self.routed_calls.fetch_add(1, Ordering::Relaxed);
            self.max_batch.fetch_max(df.num_rows(), Ordering::Relaxed);
            groups
                .iter()
                .map(|g| {
                    let slice = df.slice(g.rows.start, g.rows.len());
                    match g.variant.as_deref() {
                        Some("dbl") => Ok(vec![Self::scale(&slice, 2.0)?]),
                        Some("tri") => Ok(vec![Self::scale(&slice, 3.0)?]),
                        None => Ok(vec![Self::scale(&slice, 2.0)?, Self::scale(&slice, 3.0)?]),
                        Some(other) => {
                            Err(KamaeError::Serving(format!("unknown variant {other}")))
                        }
                    }
                })
                .collect()
        }
    }

    #[test]
    fn mixed_variant_batch_routes_back_to_each_request() {
        // interleaved dbl/tri/untargeted submissions within one batching
        // window: every response must carry exactly its variant's
        // outputs for its own rows, whatever the batcher reordered
        let backend = Box::new(VariantDoubler::new());
        let probe: *const VariantDoubler = backend.as_ref();
        let server = Server::start(
            backend,
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(50),
                ..BatchConfig::default()
            },
        );
        let mut rxs = Vec::new();
        for i in 0..24 {
            let vals = [i as f64, i as f64 + 0.25];
            let rx = match i % 3 {
                0 => server.submit_variant(req(&vals), "dbl"),
                1 => server.submit_variant(req(&vals), "tri"),
                _ => server.submit(req(&vals)),
            };
            rxs.push((i, rx));
        }
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            let vals = [i as f64, i as f64 + 0.25];
            match i % 3 {
                0 => {
                    assert_eq!(out.len(), 1, "dbl request got {} tensors", out.len());
                    assert_eq!(out[0].as_f32().unwrap(), &[
                        2.0 * vals[0] as f32,
                        2.0 * vals[1] as f32
                    ]);
                }
                1 => {
                    assert_eq!(out.len(), 1, "tri request got {} tensors", out.len());
                    assert_eq!(out[0].as_f32().unwrap(), &[
                        3.0 * vals[0] as f32,
                        3.0 * vals[1] as f32
                    ]);
                }
                _ => {
                    assert_eq!(out.len(), 2, "untargeted request got {} tensors", out.len());
                    assert_eq!(out[0].as_f32().unwrap()[0], 2.0 * vals[0] as f32);
                    assert_eq!(out[1].as_f32().unwrap()[0], 3.0 * vals[0] as f32);
                }
            }
        }
        let counts = server.variant_counts();
        assert_eq!(counts.get("dbl"), Some(&8));
        assert_eq!(counts.get("tri"), Some(&8));
        assert_eq!(counts.get(""), Some(&8));
        // SAFETY: server still alive, backend not moved
        let (routed, max_batch) = unsafe {
            (
                (*probe).routed_calls.load(Ordering::Relaxed),
                (*probe).max_batch.load(Ordering::Relaxed),
            )
        };
        assert!(routed > 0, "no batch took the routed path");
        assert!(max_batch > 2, "mixed-variant batch never merged (max {max_batch})");
        server.shutdown();
    }

    #[test]
    fn route_off_serves_tagged_requests_the_full_output_set() {
        // the all-outputs baseline: with routing disabled the variant
        // tag is ignored and process() serves everything
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig { route_variants: false, ..BatchConfig::default() },
        );
        let out = server
            .submit_variant(req(&[2.0]), "dbl")
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(out.len(), 2, "route-off must serve the full output set");
        assert_eq!(out[0].as_f32().unwrap(), &[4.0]);
        assert_eq!(out[1].as_f32().unwrap(), &[6.0]);
        server.shutdown();
    }

    #[test]
    fn unknown_variant_errors_only_its_own_request() {
        // a bad tag is rejected at submit time, BEFORE batching — so a
        // valid request submitted in the same flush window (which the
        // batcher would have coalesced with it) still succeeds
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(50),
                ..BatchConfig::default()
            },
        );
        let bad = server.submit_variant(req(&[1.0]), "nope");
        let ok = server.submit_variant(req(&[1.0]), "dbl");
        let err = bad.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
        assert_eq!(ok.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        // the rejected request never reached the batcher
        let (_, requests) = server.counts();
        assert_eq!(requests, 1);
        server.shutdown();

        // with routing off, tags are ignored rather than validated: the
        // same bad tag serves the full output set
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig { route_variants: false, ..BatchConfig::default() },
        );
        let out = server.submit_variant(req(&[1.0]), "nope").recv().unwrap().unwrap();
        assert_eq!(out.len(), 2);
        server.shutdown();
    }

    #[test]
    fn flush_deadline_expires_partial_batches() {
        // requests spaced further apart than max_wait must not wait for
        // a full batch: each flushes as its own (partial) batch
        let server = Server::start(
            Box::new(Doubler { max_batch: Default::default() }),
            BatchConfig {
                max_batch_rows: 1024,
                max_wait: Duration::from_millis(20),
                ..BatchConfig::default()
            },
        );
        let rx1 = server.submit(req(&[1.0]));
        assert_eq!(rx1.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[2.0]);
        // well past the first batch's deadline
        std::thread::sleep(Duration::from_millis(120));
        let rx2 = server.submit(req(&[2.0]));
        assert_eq!(rx2.recv().unwrap().unwrap()[0].as_f32().unwrap(), &[4.0]);
        let (batches, requests) = server.counts();
        assert_eq!(requests, 2);
        assert_eq!(batches, 2, "spaced requests must flush as separate partial batches");
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_mixed_variant_requests() {
        // shutdown closes the channel but the worker drains what is
        // already queued: every submitted request still gets an answer
        let server = Server::start(
            Box::new(VariantDoubler::new()),
            BatchConfig {
                max_batch_rows: 8,
                max_wait: Duration::from_millis(1),
                ..BatchConfig::default()
            },
        );
        let rxs: Vec<_> = (0..16)
            .map(|i| {
                let vals = [i as f64];
                match i % 3 {
                    0 => (i, server.submit_variant(req(&vals), "dbl"), 2.0f32),
                    1 => (i, server.submit_variant(req(&vals), "tri"), 3.0f32),
                    _ => (i, server.submit(req(&vals)), 2.0f32),
                }
            })
            .collect();
        server.shutdown(); // worker must finish the queue before exiting
        for (i, rx, k) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out[0].as_f32().unwrap(), &[k * i as f32], "request {i}");
        }
    }

    #[test]
    fn oversized_variant_request_is_served_whole_and_routed() {
        // a tagged request larger than max_batch_rows still runs as its
        // own (routed) batch: never split, never stalled, only its
        // variant's outputs
        let backend = Box::new(VariantDoubler::new());
        let probe: *const VariantDoubler = backend.as_ref();
        let server = Server::start(
            backend,
            BatchConfig {
                max_batch_rows: 8,
                max_wait: Duration::from_millis(5),
                ..BatchConfig::default()
            },
        );
        let vals: Vec<f64> = (0..40).map(|i| i as f64).collect();
        let rx = server.submit_variant(req(&vals), "tri");
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 1, "tagged oversized request must get only its variant");
        let got = out[0].as_f32().unwrap();
        assert_eq!(got.len(), 40, "oversized request lost rows");
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, 3.0 * i as f32);
        }
        let (batches, requests) = server.counts();
        assert_eq!((batches, requests), (1, 1), "oversized request was split or retried");
        // SAFETY: server still alive, backend not moved
        let (routed, max_batch) = unsafe {
            (
                (*probe).routed_calls.load(Ordering::Relaxed),
                (*probe).max_batch.load(Ordering::Relaxed),
            )
        };
        assert_eq!(routed, 1, "oversized tagged request did not take the routed path");
        assert_eq!(max_batch, 40, "backend saw a different batch than submitted");
        server.shutdown();
    }
}
