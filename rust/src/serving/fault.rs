//! Deterministic fault injection for the fault-containment gates.
//!
//! The serving stack claims three containment properties: a panicking
//! backend call never takes a worker (or an innocent neighbour's
//! request) down with it, a poison row is isolated by bisection and
//! dead-lettered while its batch-mates are served bit-identically, and
//! a queued request past its deadline is answered with a typed 504
//! instead of hanging. Claims like that rot unless something exercises
//! them on every run — this module is that something.
//!
//! [`ChaosBackend`] wraps any real [`Backend`] and misbehaves on a
//! [`FaultPlan`]: panic on every Nth call, panic whenever a batch
//! contains a row matching a poison predicate, sleep before every Nth
//! call. Every fault is **counter- or content-triggered, never
//! random** — the same plan over the same traffic misbehaves at exactly
//! the same points, so `benches/fault_tolerance.rs` can pin survivor
//! outputs bit-for-bit against an un-faulted oracle and CI failures
//! reproduce locally. [`FailingDeadLetter`] does the same for the sink
//! IO-failure path: it drops every Nth record, counting the drops, so
//! the "a broken dead-letter store never takes serving down" property
//! is testable without filling a disk.
//!
//! The two fault kinds interact with the batcher's transient
//! forgiveness deliberately: a `panic_every` fault is keyed to the
//! *call counter*, so the bisection re-probe (a fresh call) succeeds
//! and the request is forgiven; a poison fault is keyed to *row
//! content*, so it panics on every probe and is condemned. That is
//! exactly the transient-vs-deterministic distinction the isolation
//! layer is designed around.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::dataframe::DataFrame;
use crate::error::Result;
use crate::export::GraphSpec;
use crate::runtime::Tensor;
use crate::util::json::Json;

use super::backend::{Backend, VariantGroup};
use super::validate::{DeadLetterSink, RowError};

/// Content-keyed poison predicate: `true` marks a row whose presence
/// panics the batch (on every probe — poison is deterministic, not
/// transient).
pub type PoisonPredicate = Arc<dyn Fn(&DataFrame, usize) -> bool + Send + Sync>;

/// A deterministic misbehaviour schedule for [`ChaosBackend`].
///
/// The default plan injects nothing; switch on individual faults per
/// scenario. All counters are 1-based over backend *calls* (batch
/// executions and bisection probes both count), so fault positions are
/// a pure function of the traffic.
#[derive(Clone, Default)]
pub struct FaultPlan {
    /// Panic on every Nth backend call (`0` = never). Transient by
    /// construction: the bisection re-probe is a later call and
    /// (usually) passes.
    pub panic_every: u64,
    /// Panic whenever the batch contains a matching row (`None` =
    /// never). Deterministic: every probe of the row fails, so
    /// bisection condemns it.
    pub poison: Option<PoisonPredicate>,
    /// Sleep this long before every Nth call (`0` = never) — stalls a
    /// worker inside a batch so deadline expiry and reaper behaviour
    /// become reachable under test.
    pub slow_every: Option<(u64, Duration)>,
}

impl FaultPlan {
    /// A plan that poisons rows matched by `pred` and injects nothing
    /// else.
    pub fn poison_rows<F>(pred: F) -> FaultPlan
    where
        F: Fn(&DataFrame, usize) -> bool + Send + Sync + 'static,
    {
        FaultPlan { poison: Some(Arc::new(pred)), ..FaultPlan::default() }
    }
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan")
            .field("panic_every", &self.panic_every)
            .field("poison", &self.poison.as_ref().map(|_| "<predicate>"))
            .field("slow_every", &self.slow_every)
            .finish()
    }
}

/// A [`Backend`] wrapper that misbehaves on a [`FaultPlan`] before
/// delegating to the real backend. Successful calls are transparent —
/// same spec, same schema, same variants, same outputs — so survivor
/// responses stay bit-identical to the un-faulted oracle.
pub struct ChaosBackend {
    inner: Arc<dyn Backend>,
    plan: FaultPlan,
    calls: AtomicU64,
}

impl ChaosBackend {
    pub fn new(inner: Arc<dyn Backend>, plan: FaultPlan) -> ChaosBackend {
        ChaosBackend { inner, plan, calls: AtomicU64::new(0) }
    }

    /// Backend calls observed so far (batches + bisection probes).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::SeqCst)
    }

    /// Run the plan against this call: maybe sleep, maybe panic. The
    /// order is slow → nth-call panic → poison scan, so a slow fault
    /// still stalls the worker even on a call that will then panic.
    fn misbehave(&self, df: &DataFrame) {
        let call = self.calls.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some((every, delay)) = self.plan.slow_every {
            if every > 0 && call % every == 0 {
                std::thread::sleep(delay);
            }
        }
        if self.plan.panic_every > 0 && call % self.plan.panic_every == 0 {
            panic!("chaos: injected panic on backend call {call}");
        }
        if let Some(pred) = &self.plan.poison {
            for i in 0..df.num_rows() {
                if pred(df, i) {
                    panic!(
                        "chaos: poison row {i} in a {}-row batch (call {call})",
                        df.num_rows()
                    );
                }
            }
        }
    }
}

impl Backend for ChaosBackend {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn kind(&self) -> &'static str {
        self.inner.kind()
    }

    fn spec(&self) -> Option<&GraphSpec> {
        self.inner.spec()
    }

    fn request_schema(&self) -> Option<crate::dataframe::Schema> {
        self.inner.request_schema()
    }

    fn variants(&self) -> &[String] {
        self.inner.variants()
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        self.misbehave(df);
        self.inner.process(df)
    }

    fn process_routed(&self, df: &DataFrame, groups: &[VariantGroup]) -> Result<Vec<Vec<Tensor>>> {
        self.misbehave(df);
        self.inner.process_routed(df, groups)
    }
}

/// A [`DeadLetterSink`] wrapper that deterministically drops every Nth
/// record (simulated IO failure), counting what it dropped. Serving
/// must not notice: the containment contract is that sink failures cost
/// a counter increment, never a request.
pub struct FailingDeadLetter {
    inner: Arc<dyn DeadLetterSink>,
    /// Drop every Nth record (`0` = never fail, pure pass-through).
    fail_every: u64,
    calls: AtomicU64,
    dropped: AtomicU64,
}

impl FailingDeadLetter {
    pub fn new(inner: Arc<dyn DeadLetterSink>, fail_every: u64) -> FailingDeadLetter {
        FailingDeadLetter { inner, fail_every, calls: AtomicU64::new(0), dropped: AtomicU64::new(0) }
    }

    /// Records this wrapper refused to pass through.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::SeqCst)
    }
}

impl DeadLetterSink for FailingDeadLetter {
    fn record(&self, tenant: &str, row: &Json, errors: &[RowError]) {
        let call = self.calls.fetch_add(1, Ordering::SeqCst) + 1;
        if self.fail_every > 0 && call % self.fail_every == 0 {
            self.dropped.fetch_add(1, Ordering::SeqCst);
            return;
        }
        self.inner.record(tenant, row, errors);
    }

    fn errors(&self) -> u64 {
        self.dropped() + self.inner.errors()
    }
}

#[cfg(test)]
mod tests {
    use super::super::validate::MemoryDeadLetter;
    use super::*;
    use crate::dataframe::Column;

    /// Minimal deterministic backend: doubles the `x` column.
    struct Doubler;

    impl Backend for Doubler {
        fn name(&self) -> &str {
            "doubler"
        }

        fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
            let v = df.column("x")?.as_f64()?;
            Tensor::f32(v.iter().map(|&x| 2.0 * x as f32).collect(), vec![v.len()])
                .map(|t| vec![t])
        }
    }

    fn req(vals: &[f64]) -> DataFrame {
        DataFrame::new(vec![("x".into(), Column::from_f64(vals.to_vec()))]).unwrap()
    }

    fn poison_666() -> FaultPlan {
        FaultPlan::poison_rows(|df, i| {
            df.column("x")
                .ok()
                .and_then(|c| c.as_f64().ok())
                .is_some_and(|v| v[i] == 666.0)
        })
    }

    #[test]
    fn chaos_is_transparent_without_faults() {
        let inner: Arc<dyn Backend> = Arc::new(Doubler);
        let chaos = ChaosBackend::new(Arc::clone(&inner), FaultPlan::default());
        let df = req(&[1.0, 2.0, 3.0]);
        let want = inner.process(&df).unwrap();
        let got = chaos.process(&df).unwrap();
        assert_eq!(got, want);
        assert_eq!(chaos.calls(), 1);
        assert_eq!(chaos.name(), inner.name());
        assert_eq!(chaos.kind(), inner.kind());
        assert!(chaos.spec().is_none());
    }

    #[test]
    fn chaos_faults_fire_deterministically() {
        let chaos = ChaosBackend::new(
            Arc::new(Doubler),
            FaultPlan { panic_every: 2, ..FaultPlan::default() },
        );
        let df = req(&[1.0]);
        // calls 1, 3 pass; calls 2, 4 panic — same schedule every run
        for call in 1..=4u64 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                chaos.process(&df).unwrap()
            }));
            assert_eq!(r.is_err(), call % 2 == 0, "call {call}");
        }
        let poison = ChaosBackend::new(Arc::new(Doubler), poison_666());
        for _ in 0..2 {
            assert!(poison.process(&req(&[1.0, 2.0])).is_ok());
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                poison.process(&req(&[1.0, 666.0])).unwrap()
            }));
            assert!(r.is_err(), "poison is content-keyed: fails on every probe");
        }
    }

    #[test]
    fn failing_sink_drops_every_nth_and_counts() {
        let ring = Arc::new(MemoryDeadLetter::new(16));
        let sink = FailingDeadLetter::new(Arc::clone(&ring) as Arc<dyn DeadLetterSink>, 3);
        let row = Json::object();
        for _ in 0..6 {
            sink.record("t", &row, &[]);
        }
        // calls 3 and 6 dropped, the rest passed through
        assert_eq!(sink.dropped(), 2);
        assert_eq!(sink.errors(), 2);
        assert_eq!(ring.len(), 4);
    }
}
