//! Serving metrics: latency recording and the benchmark report.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::bench::{fmt_ns, percentile};
use crate::util::json::Json;

/// Thread-safe latency sample collector. Samples may optionally carry a
/// variant tag ([`Self::record_variant`]); the report then includes the
/// per-variant request/latency split alongside the aggregate.
pub struct LatencyRecorder {
    samples_ns: Mutex<Vec<f64>>,
    tagged_ns: Mutex<BTreeMap<String, Vec<f64>>>,
    /// Per-tenant samples of a registry-mode run. Kept separate from
    /// the variant map so one request tagged both ways is never double
    /// counted in either split.
    tenant_ns: Mutex<BTreeMap<String, Vec<f64>>>,
    /// Per-rule ingress-validation violation counters (rule name →
    /// violating cells). Touched only when the ingress gate actually
    /// quarantines, so clean traffic never takes this lock.
    violations: Mutex<BTreeMap<String, u64>>,
    /// Rows the ingress gate quarantined instead of serving.
    quarantined: AtomicU64,
    /// Per-tenant rolling quarantine rate over the last
    /// [`RATE_WINDOW_REQUESTS`] validated requests — the signal behind
    /// `--quarantine-alert` (a lifetime ratio would never recover after
    /// one bad burst; a rolling one decays as clean traffic flows).
    tenant_rates: Mutex<BTreeMap<String, RollingRate>>,
}

/// Validated requests per tenant the rolling quarantine rate looks back
/// over. Big enough to smooth single-request spikes, small enough that
/// an incident (or its recovery) shows within seconds at serving rates.
const RATE_WINDOW_REQUESTS: usize = 256;

/// Windowed rows/quarantined sums over the last N validated requests.
struct RollingRate {
    window: std::collections::VecDeque<(u64, u64)>,
    rows: u64,
    quarantined: u64,
}

impl RollingRate {
    fn new() -> RollingRate {
        RollingRate { window: std::collections::VecDeque::new(), rows: 0, quarantined: 0 }
    }

    fn push(&mut self, rows: u64, quarantined: u64) {
        self.window.push_back((rows, quarantined));
        self.rows += rows;
        self.quarantined += quarantined;
        while self.window.len() > RATE_WINDOW_REQUESTS {
            let (r, q) = self.window.pop_front().expect("len > cap >= 1");
            self.rows -= r;
            self.quarantined -= q;
        }
    }

    fn rate(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.quarantined as f64 / self.rows as f64
        }
    }
}

impl LatencyRecorder {
    pub fn new() -> LatencyRecorder {
        LatencyRecorder {
            samples_ns: Mutex::new(Vec::new()),
            tagged_ns: Mutex::new(BTreeMap::new()),
            tenant_ns: Mutex::new(BTreeMap::new()),
            violations: Mutex::new(BTreeMap::new()),
            quarantined: AtomicU64::new(0),
            tenant_rates: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn record(&self, latency: Duration) {
        self.samples_ns.lock().unwrap().push(latency.as_nanos() as f64);
    }

    /// Record a sample under a variant tag AND in the aggregate.
    pub fn record_variant(&self, variant: &str, latency: Duration) {
        let ns = latency.as_nanos() as f64;
        self.samples_ns.lock().unwrap().push(ns);
        self.tagged_ns
            .lock()
            .unwrap()
            .entry(variant.to_string())
            .or_default()
            .push(ns);
    }

    /// Record a sample under a tenant — ONLY in the per-tenant split;
    /// the caller records the aggregate (and any variant tag)
    /// separately, so tenant splits never inflate the overall stats.
    pub fn record_tenant(&self, tenant: &str, latency: Duration) {
        self.tenant_ns
            .lock()
            .unwrap()
            .entry(tenant.to_string())
            .or_default()
            .push(latency.as_nanos() as f64);
    }

    /// Fold one batch's per-rule violation counts and quarantined-row
    /// count into the ingress-validation counters (see
    /// [`crate::serving::ValidationReport::rule_counts`]).
    pub fn record_quarantine(&self, rule_counts: &BTreeMap<String, u64>, rows: u64) {
        if rows > 0 {
            self.quarantined.fetch_add(rows, Ordering::Relaxed);
        }
        if !rule_counts.is_empty() {
            let mut v = self.violations.lock().unwrap();
            for (rule, n) in rule_counts {
                *v.entry(rule.clone()).or_insert(0) += n;
            }
        }
    }

    /// Feed one VALIDATED request's row counts into the tenant's rolling
    /// quarantine rate. Call for every screened request — including
    /// fully-clean ones — so the rate decays as healthy traffic flows.
    pub fn record_tenant_rows(&self, tenant: &str, rows: u64, quarantined: u64) {
        self.tenant_rates
            .lock()
            .unwrap()
            .entry(tenant.to_string())
            .or_insert_with(RollingRate::new)
            .push(rows, quarantined);
    }

    /// Each tenant's rolling quarantine rate (quarantined / screened
    /// rows over the last [`RATE_WINDOW_REQUESTS`] validated requests).
    /// Tenants that never passed through the gate are absent.
    pub fn quarantine_rates(&self) -> BTreeMap<String, f64> {
        self.tenant_rates
            .lock()
            .unwrap()
            .iter()
            .map(|(t, r)| (t.clone(), r.rate()))
            .collect()
    }

    /// Produce the final report.
    ///
    /// Zero-request / zero-sample runs (a bench aborted before traffic,
    /// a variant that received nothing) must still produce a fully
    /// finite report: a `0/0` here used to put `NaN`/`inf` into the
    /// `BENCH_<name>.json` trajectory files. Rates and percentiles
    /// report 0 when there is nothing to aggregate.
    pub fn report(
        &self,
        name: &str,
        requests: usize,
        wall: Duration,
        busy: Duration,
    ) -> ServeReport {
        let mut ns = self.samples_ns.lock().unwrap().clone();
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let wall_secs = wall.as_secs_f64();
        let pct = |p: f64| if ns.is_empty() { 0.0 } else { percentile(&ns, p) };
        let variants = self
            .tagged_ns
            .lock()
            .unwrap()
            .iter()
            .map(|(variant, samples)| {
                let mut vs = samples.clone();
                vs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let vp = |p: f64| if vs.is_empty() { 0.0 } else { percentile(&vs, p) };
                VariantStats {
                    variant: variant.clone(),
                    requests: vs.len(),
                    mean_ns: vs.iter().sum::<f64>() / vs.len().max(1) as f64,
                    p50_ns: vp(50.0),
                    p95_ns: vp(95.0),
                    p99_ns: vp(99.0),
                }
            })
            .collect();
        let tenants = self
            .tenant_ns
            .lock()
            .unwrap()
            .iter()
            .map(|(tenant, samples)| {
                let mut ts = samples.clone();
                ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let tp = |p: f64| if ts.is_empty() { 0.0 } else { percentile(&ts, p) };
                TenantStats {
                    tenant: tenant.clone(),
                    requests: ts.len(),
                    shed: 0,
                    active_version: 0,
                    quarantine_rate: 0.0,
                    mean_ns: ts.iter().sum::<f64>() / ts.len().max(1) as f64,
                    p50_ns: tp(50.0),
                    p95_ns: tp(95.0),
                    p99_ns: tp(99.0),
                }
            })
            .collect();
        ServeReport {
            name: name.to_string(),
            requests,
            wall_secs,
            throughput_rps: if requests == 0 || wall_secs == 0.0 {
                0.0
            } else {
                requests as f64 / wall_secs
            },
            p50_ns: pct(50.0),
            p95_ns: pct(95.0),
            p99_ns: pct(99.0),
            mean_ns: ns.iter().sum::<f64>() / ns.len().max(1) as f64,
            busy_secs: busy.as_secs_f64(),
            cost_cpu_s_per_1k: if requests == 0 {
                0.0
            } else {
                busy.as_secs_f64() / (requests as f64 / 1000.0)
            },
            variants,
            tenants,
            workers: 1,
            worker_utilization: Vec::new(),
            shed_requests: 0,
            admission_limit: 0,
            violations: self.violations.lock().unwrap().clone(),
            quarantined_rows: self.quarantined.load(Ordering::Relaxed),
            worker_panics: 0,
            deadline_expired: 0,
            poison_rows: 0,
            dead_letter_errors: 0,
        }
    }

    /// [`Self::report`] for a worker-pool run: total busy time is the
    /// SUM of the per-worker busy times (the same cost proxy — pool
    /// CPU-seconds), and the report carries the pool size plus each
    /// worker's utilization (busy / wall). The per-worker counters are
    /// contention-free on the serving hot path
    /// ([`crate::serving::Server::worker_busy_times`]); this merge is
    /// the only place they meet.
    pub fn report_pool(
        &self,
        name: &str,
        requests: usize,
        wall: Duration,
        worker_busy: &[Duration],
    ) -> ServeReport {
        let busy: Duration = worker_busy.iter().sum();
        let mut report = self.report(name, requests, wall, busy);
        report.workers = worker_busy.len().max(1);
        let wall_secs = wall.as_secs_f64();
        report.worker_utilization = worker_busy
            .iter()
            .map(|b| if wall_secs == 0.0 { 0.0 } else { b.as_secs_f64() / wall_secs })
            .collect();
        report
    }
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-variant request/latency split of a routed serving run.
#[derive(Debug, Clone)]
pub struct VariantStats {
    pub variant: String,
    pub requests: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
}

impl VariantStats {
    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("variant", self.variant.clone());
        j.set("requests", self.requests);
        j.set("mean_ns", self.mean_ns);
        j.set("p50_ns", self.p50_ns);
        j.set("p95_ns", self.p95_ns);
        j.set("p99_ns", self.p99_ns);
        j
    }
}

/// Per-tenant request/latency/shed split of a registry-mode serving
/// run, with the tenant's active-version gauge. Latency fields come
/// from [`LatencyRecorder::record_tenant`] samples; `shed` and
/// `active_version` are stamped by the layer that owns those counters
/// (the network front-end's per-tenant shed map and the registry
/// snapshot).
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub tenant: String,
    pub requests: usize,
    /// Requests for this tenant refused by admission control.
    pub shed: usize,
    /// The tenant's active registry version at report time (gauge);
    /// 0 when the run was not registry-backed.
    pub active_version: u64,
    /// Rolling quarantine rate over the tenant's recent validated
    /// requests ([`LatencyRecorder::record_tenant_rows`]); 0.0 when the
    /// gate is off or traffic has been clean.
    pub quarantine_rate: f64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
}

impl TenantStats {
    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("tenant", self.tenant.clone());
        j.set("requests", self.requests);
        if self.shed > 0 {
            j.set("shed", self.shed);
        }
        if self.active_version > 0 {
            j.set("active_version", self.active_version as i64);
        }
        // gated like shed: tenants outside the ingress gate keep their
        // exact pre-validation record shape
        if self.quarantine_rate > 0.0 {
            j.set("quarantine_rate", self.quarantine_rate);
        }
        j.set("mean_ns", self.mean_ns);
        j.set("p50_ns", self.p50_ns);
        j.set("p95_ns", self.p95_ns);
        j.set("p99_ns", self.p99_ns);
        j
    }
}

/// One serving benchmark run's results (experiments C3/C5).
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub name: String,
    pub requests: usize,
    pub wall_secs: f64,
    pub throughput_rps: f64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    /// Total backend busy time — the service-cost proxy.
    pub busy_secs: f64,
    pub cost_cpu_s_per_1k: f64,
    /// Per-variant split of a routed run (empty when nothing was
    /// recorded per variant — single-variant benches are unchanged).
    pub variants: Vec<VariantStats>,
    /// Per-tenant split of a registry-mode run (empty when nothing was
    /// recorded per tenant — single-spec runs are unchanged).
    pub tenants: Vec<TenantStats>,
    /// Batcher threads that served the run ([`Self::report`] runs are
    /// single-worker; [`LatencyRecorder::report_pool`] records the pool
    /// size).
    pub workers: usize,
    /// Per-worker busy/wall ratio of a pool run, in worker order —
    /// empty for single-worker reports. Low utilization with high
    /// latency means queueing, not compute, is the bottleneck.
    pub worker_utilization: Vec<f64>,
    /// Requests refused with `429` by the network front-end's admission
    /// control. 0 for in-process runs (and for net runs that never shed);
    /// merged contention-free like the per-worker counters — the atomic
    /// shed counter is read once at report time.
    pub shed_requests: usize,
    /// The admission window (max in-flight requests) the run was served
    /// under. 0 when no admission control was in front of the server.
    pub admission_limit: usize,
    /// Per-rule ingress-validation violation counts (rule name →
    /// violating cells). Empty when the gate is off or traffic was
    /// clean.
    pub violations: BTreeMap<String, u64>,
    /// Rows the ingress gate quarantined (dead-lettered) instead of
    /// serving. 0 when the gate is off or nothing was quarantined.
    pub quarantined_rows: u64,
    /// Panics caught at the pool's batch-execution isolation boundary
    /// (the worker survived each one). 0 on a healthy run.
    pub worker_panics: u64,
    /// Requests answered `deadline_exceeded` instead of executing.
    pub deadline_expired: u64,
    /// Rows bisection isolated as deterministic backend-crashers and
    /// dead-lettered with a `poison` verdict.
    pub poison_rows: u64,
    /// Dead-letter sink write failures (rows the sink could not
    /// persist; serving was unaffected).
    pub dead_letter_errors: u64,
}

impl ServeReport {
    /// Machine-readable benchmark record, for appending to the
    /// `BENCH_*.json` perf-trajectory files. Report names follow the
    /// `<spec>/<mode>` convention (see [`crate::serving::bench_serve`]);
    /// both halves are emitted as separate fields so trajectory tooling
    /// never has to re-parse them. The `variants` key appears only on
    /// routed runs, so single-variant trajectory records keep their
    /// exact pre-routing shape.
    pub fn to_json(&self) -> Json {
        let (spec, mode) = match self.name.split_once('/') {
            Some((s, m)) => (s, m),
            None => (self.name.as_str(), ""),
        };
        let mut j = Json::object();
        j.set("name", self.name.clone());
        j.set("spec", spec);
        j.set("mode", mode);
        j.set("requests", self.requests);
        j.set("wall_secs", self.wall_secs);
        j.set("throughput_rps", self.throughput_rps);
        j.set("mean_ns", self.mean_ns);
        j.set("p50_ns", self.p50_ns);
        j.set("p95_ns", self.p95_ns);
        j.set("p99_ns", self.p99_ns);
        j.set("busy_secs", self.busy_secs);
        j.set("cost_cpu_s_per_1k", self.cost_cpu_s_per_1k);
        if !self.variants.is_empty() {
            j.set(
                "variants",
                Json::Array(self.variants.iter().map(VariantStats::to_json).collect()),
            );
        }
        // tenant keys appear only on registry-mode runs, so single-spec
        // trajectory records keep their exact pre-registry shape
        if !self.tenants.is_empty() {
            j.set(
                "tenants",
                Json::Array(self.tenants.iter().map(TenantStats::to_json).collect()),
            );
        }
        // pool keys appear only on multi-worker runs, so single-worker
        // trajectory records keep their exact pre-pool shape
        if self.workers > 1 {
            j.set("workers", self.workers);
            j.set(
                "worker_utilization",
                Json::Array(self.worker_utilization.iter().map(|&u| Json::Float(u)).collect()),
            );
        }
        // admission keys appear only on runs that had an admission window
        // or actually shed, so pre-net trajectory records keep their shape
        if self.shed_requests > 0 {
            j.set("shed_requests", self.shed_requests);
        }
        if self.admission_limit > 0 {
            j.set("admission_limit", self.admission_limit);
        }
        // validation keys appear only on runs where the ingress gate
        // actually quarantined, so ungated trajectory records keep
        // their exact pre-validation shape
        if self.quarantined_rows > 0 {
            j.set("quarantined_rows", self.quarantined_rows as i64);
        }
        if !self.violations.is_empty() {
            let mut v = Json::object();
            for (rule, n) in &self.violations {
                v.set(rule.clone(), *n as i64);
            }
            j.set("violations", v);
        }
        // fault keys appear only on runs that actually faulted, so
        // healthy trajectory records keep their exact pre-fault shape
        if self.worker_panics > 0 {
            j.set("worker_panics", self.worker_panics as i64);
        }
        if self.deadline_expired > 0 {
            j.set("deadline_expired", self.deadline_expired as i64);
        }
        if self.poison_rows > 0 {
            j.set("poison_rows", self.poison_rows as i64);
        }
        if self.dead_letter_errors > 0 {
            j.set("dead_letter_errors", self.dead_letter_errors as i64);
        }
        j
    }
}

impl std::fmt::Display for ServeReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "=== serving report: {} ===", self.name)?;
        writeln!(f, "requests        {}", self.requests)?;
        writeln!(f, "wall time       {:.2} s", self.wall_secs)?;
        writeln!(f, "throughput      {:.1} req/s", self.throughput_rps)?;
        writeln!(f, "latency mean    {}", fmt_ns(self.mean_ns))?;
        writeln!(f, "latency p50     {}", fmt_ns(self.p50_ns))?;
        writeln!(f, "latency p95     {}", fmt_ns(self.p95_ns))?;
        writeln!(f, "latency p99     {}", fmt_ns(self.p99_ns))?;
        writeln!(f, "backend busy    {:.2} s", self.busy_secs)?;
        write!(f, "cost proxy      {:.3} cpu-s / 1k req", self.cost_cpu_s_per_1k)?;
        if self.workers > 1 {
            let util: Vec<String> = self
                .worker_utilization
                .iter()
                .map(|u| format!("{:.0}%", 100.0 * u))
                .collect();
            write!(
                f,
                "\nworkers         {} (utilization {})",
                self.workers,
                util.join(" ")
            )?;
        }
        if self.admission_limit > 0 || self.shed_requests > 0 {
            write!(
                f,
                "\nadmission       window {}  shed {}",
                self.admission_limit, self.shed_requests
            )?;
        }
        if self.quarantined_rows > 0 || !self.violations.is_empty() {
            let rules: Vec<String> =
                self.violations.iter().map(|(rule, n)| format!("{rule} {n}")).collect();
            write!(
                f,
                "\nquarantine      rows {}  ({})",
                self.quarantined_rows,
                rules.join("  ")
            )?;
        }
        if self.worker_panics > 0
            || self.deadline_expired > 0
            || self.poison_rows > 0
            || self.dead_letter_errors > 0
        {
            write!(
                f,
                "\nfaults          panics {}  deadline_expired {}  poison_rows {}  \
                 dead_letter_errors {}",
                self.worker_panics,
                self.deadline_expired,
                self.poison_rows,
                self.dead_letter_errors
            )?;
        }
        for v in &self.variants {
            write!(
                f,
                "\n  variant {:<12} {:>6} req  p50 {}  p95 {}  p99 {}",
                v.variant,
                v.requests,
                fmt_ns(v.p50_ns),
                fmt_ns(v.p95_ns),
                fmt_ns(v.p99_ns)
            )?;
        }
        for t in &self.tenants {
            write!(
                f,
                "\n  tenant  {:<12} {:>6} req  shed {:>4}  v{}  p50 {}  p99 {}",
                t.tenant,
                t.requests,
                t.shed,
                t.active_version,
                fmt_ns(t.p50_ns),
                fmt_ns(t.p99_ns)
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_math() {
        let r = LatencyRecorder::new();
        for ms in [1u64, 2, 3, 4, 100] {
            r.record(Duration::from_millis(ms));
        }
        let rep = r.report("t", 5, Duration::from_secs(1), Duration::from_millis(110));
        assert_eq!(rep.requests, 5);
        assert!((rep.throughput_rps - 5.0).abs() < 1e-9);
        assert!(rep.p50_ns >= 2e6 && rep.p50_ns <= 4e6);
        assert!(rep.p99_ns > 9e7);
        assert!((rep.cost_cpu_s_per_1k - 22.0).abs() < 0.01);
        let text = rep.to_string();
        assert!(text.contains("p99"));
    }

    #[test]
    fn zero_request_report_is_finite() {
        // regression: requests == 0 (and an empty sample set) used to
        // produce NaN throughput / inf cost that corrupted the
        // BENCH_<name>.json trajectory files
        let r = LatencyRecorder::new();
        let rep = r.report("empty/interpreted", 0, Duration::ZERO, Duration::ZERO);
        for (what, v) in [
            ("throughput_rps", rep.throughput_rps),
            ("mean_ns", rep.mean_ns),
            ("p50_ns", rep.p50_ns),
            ("p95_ns", rep.p95_ns),
            ("p99_ns", rep.p99_ns),
            ("cost_cpu_s_per_1k", rep.cost_cpu_s_per_1k),
        ] {
            assert!(v.is_finite(), "{what} = {v}");
            assert_eq!(v, 0.0, "{what}");
        }
        // the record is accepted by the trajectory writer
        let j = rep.to_json();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn per_variant_split_lands_in_report_and_json() {
        let r = LatencyRecorder::new();
        r.record_variant("ltr", Duration::from_millis(4));
        r.record_variant("ltr", Duration::from_millis(6));
        r.record_variant("ltr_lite", Duration::from_millis(1));
        let rep = r.report(
            "ltr+ltr_lite/routed",
            3,
            Duration::from_secs(1),
            Duration::from_millis(11),
        );
        // tagged samples aggregate into the overall stats too
        assert_eq!(rep.requests, 3);
        assert!(rep.p99_ns >= 5e6, "{}", rep.p99_ns);
        assert_eq!(rep.variants.len(), 2);
        let ltr = &rep.variants[0];
        assert_eq!((ltr.variant.as_str(), ltr.requests), ("ltr", 2));
        assert!(ltr.p50_ns >= 4e6 && ltr.p50_ns <= 6e6, "{}", ltr.p50_ns);
        let lite = &rep.variants[1];
        assert_eq!((lite.variant.as_str(), lite.requests), ("ltr_lite", 1));
        assert!(lite.p99_ns <= 2e6, "{}", lite.p99_ns);
        // the split shows up in the trajectory record and round-trips
        let j = rep.to_json();
        let vs = j.req_array("variants").unwrap();
        assert_eq!(vs.len(), 2);
        assert_eq!(vs[0].req_str("variant").unwrap(), "ltr");
        assert_eq!(vs[0].req_i64("requests").unwrap(), 2);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        // untagged reports keep the exact pre-routing record shape
        let plain = LatencyRecorder::new();
        plain.record(Duration::from_millis(1));
        let j = plain
            .report("ltr/interpreted", 1, Duration::from_secs(1), Duration::ZERO)
            .to_json();
        assert!(j.get("variants").is_none());
        // display renders the split
        assert!(rep.to_string().contains("variant ltr_lite"));
    }

    #[test]
    fn per_tenant_split_gates_like_variants() {
        let r = LatencyRecorder::new();
        // the handler records aggregate and tenant separately — the
        // tenant split must not inflate the overall sample set
        r.record(Duration::from_millis(4));
        r.record_tenant("shop", Duration::from_millis(4));
        r.record(Duration::from_millis(2));
        r.record_tenant("ads", Duration::from_millis(2));
        let mut rep =
            r.report("registry/net", 2, Duration::from_secs(1), Duration::from_millis(6));
        assert_eq!(rep.requests, 2);
        assert_eq!(rep.tenants.len(), 2);
        let ads = &rep.tenants[0];
        assert_eq!((ads.tenant.as_str(), ads.requests), ("ads", 1));
        assert!(ads.p99_ns <= 3e6, "{}", ads.p99_ns);
        let shop = &rep.tenants[1];
        assert_eq!((shop.tenant.as_str(), shop.requests), ("shop", 1));
        assert!(shop.p50_ns >= 3e6, "{}", shop.p50_ns);
        // shed / active_version are stamped by the owning layer and
        // gate their own keys inside each tenant record
        rep.tenants[1].shed = 3;
        rep.tenants[1].active_version = 2;
        let j = rep.to_json();
        let ts = j.req_array("tenants").unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].req_str("tenant").unwrap(), "ads");
        assert!(ts[0].get("shed").is_none());
        assert!(ts[0].get("active_version").is_none());
        assert_eq!(ts[1].req_i64("shed").unwrap(), 3);
        assert_eq!(ts[1].req_i64("active_version").unwrap(), 2);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        // display renders the split
        assert!(rep.to_string().contains("tenant  shop"));
        // untenanted reports keep the exact pre-registry record shape
        let plain = LatencyRecorder::new();
        plain.record(Duration::from_millis(1));
        let j = plain
            .report("ltr/interpreted", 1, Duration::from_secs(1), Duration::ZERO)
            .to_json();
        assert!(j.get("tenants").is_none());
    }

    #[test]
    fn pool_report_merges_worker_busy_and_gates_json_keys() {
        let r = LatencyRecorder::new();
        r.record(Duration::from_millis(2));
        r.record(Duration::from_millis(4));
        let rep = r.report_pool(
            "ltr+ltr_lite/pool4",
            2,
            Duration::from_secs(2),
            &[
                Duration::from_millis(1000),
                Duration::from_millis(500),
                Duration::from_millis(0),
                Duration::from_millis(250),
            ],
        );
        assert_eq!(rep.workers, 4);
        // busy is the pool SUM (the cost proxy counts every core)
        assert!((rep.busy_secs - 1.75).abs() < 1e-9, "{}", rep.busy_secs);
        assert_eq!(rep.worker_utilization.len(), 4);
        assert!((rep.worker_utilization[0] - 0.5).abs() < 1e-9);
        assert!((rep.worker_utilization[2] - 0.0).abs() < 1e-9);
        let j = rep.to_json();
        assert_eq!(j.req_i64("workers").unwrap(), 4);
        assert_eq!(j.req_array("worker_utilization").unwrap().len(), 4);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        // display renders the pool line
        assert!(rep.to_string().contains("workers         4"));

        // single-worker pool reports keep the pre-pool record shape
        let rep1 = r.report_pool(
            "ltr/pool1",
            2,
            Duration::from_secs(2),
            &[Duration::from_millis(100)],
        );
        assert_eq!(rep1.workers, 1);
        let j1 = rep1.to_json();
        assert!(j1.get("workers").is_none());
        assert!(j1.get("worker_utilization").is_none());
        // zero wall must not divide into NaN utilization
        let rep0 = r.report_pool("z/pool2", 0, Duration::ZERO, &[Duration::ZERO, Duration::ZERO]);
        assert!(rep0.worker_utilization.iter().all(|u| u.is_finite()));
        assert_eq!(Json::parse(&rep0.to_json().to_string()).unwrap(), rep0.to_json());
    }

    #[test]
    fn shed_and_admission_keys_gate_on_non_zero() {
        let r = LatencyRecorder::new();
        r.record(Duration::from_millis(2));
        let mut rep =
            r.report("ltr/net", 1, Duration::from_secs(1), Duration::from_millis(2));
        // default reports keep the exact pre-net record shape
        assert_eq!(rep.shed_requests, 0);
        assert_eq!(rep.admission_limit, 0);
        let j = rep.to_json();
        assert!(j.get("shed_requests").is_none());
        assert!(j.get("admission_limit").is_none());
        // once set (the net layer stamps them from its atomic counters),
        // both keys land in the record and round-trip
        rep.shed_requests = 7;
        rep.admission_limit = 4;
        let j = rep.to_json();
        assert_eq!(j.req_i64("shed_requests").unwrap(), 7);
        assert_eq!(j.req_i64("admission_limit").unwrap(), 4);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        // display renders the admission line only when present
        assert!(rep.to_string().contains("admission       window 4  shed 7"));
        rep.shed_requests = 0;
        rep.admission_limit = 0;
        assert!(!rep.to_string().contains("admission"));
    }

    #[test]
    fn quarantine_keys_gate_on_non_zero() {
        let r = LatencyRecorder::new();
        r.record(Duration::from_millis(2));
        let rep = r.report("ltr/net", 1, Duration::from_secs(1), Duration::from_millis(2));
        // ungated runs keep the exact pre-validation record shape
        assert_eq!(rep.quarantined_rows, 0);
        assert!(rep.violations.is_empty());
        let j = rep.to_json();
        assert!(j.get("quarantined_rows").is_none());
        assert!(j.get("violations").is_none());
        assert!(!rep.to_string().contains("quarantine"));
        // batches fold their per-rule counts in; the report carries both
        let mut counts = BTreeMap::new();
        counts.insert("not_null".to_string(), 2u64);
        counts.insert("range".to_string(), 1u64);
        r.record_quarantine(&counts, 3);
        let mut one = BTreeMap::new();
        one.insert("range".to_string(), 4u64);
        r.record_quarantine(&one, 2);
        let rep = r.report("ltr/net", 1, Duration::from_secs(1), Duration::from_millis(2));
        assert_eq!(rep.quarantined_rows, 5);
        assert_eq!(rep.violations.get("not_null"), Some(&2));
        assert_eq!(rep.violations.get("range"), Some(&5));
        let j = rep.to_json();
        assert_eq!(j.req_i64("quarantined_rows").unwrap(), 5);
        let v = j.req("violations").unwrap();
        assert_eq!(v.req_i64("not_null").unwrap(), 2);
        assert_eq!(v.req_i64("range").unwrap(), 5);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        // display renders the quarantine line
        let text = rep.to_string();
        assert!(text.contains("quarantine      rows 5"), "{text}");
        assert!(text.contains("range 5"), "{text}");
    }

    #[test]
    fn fault_keys_gate_on_non_zero() {
        let r = LatencyRecorder::new();
        r.record(Duration::from_millis(2));
        let mut rep = r.report("ltr/net", 1, Duration::from_secs(1), Duration::from_millis(2));
        // healthy runs keep the exact pre-fault record shape
        let j = rep.to_json();
        for key in ["worker_panics", "deadline_expired", "poison_rows", "dead_letter_errors"] {
            assert!(j.get(key).is_none(), "{key} leaked into a healthy record");
        }
        assert!(!rep.to_string().contains("faults"));
        // the owning layers stamp the counters; the keys land and
        // round-trip once non-zero
        rep.worker_panics = 3;
        rep.deadline_expired = 7;
        rep.poison_rows = 2;
        rep.dead_letter_errors = 1;
        let j = rep.to_json();
        assert_eq!(j.req_i64("worker_panics").unwrap(), 3);
        assert_eq!(j.req_i64("deadline_expired").unwrap(), 7);
        assert_eq!(j.req_i64("poison_rows").unwrap(), 2);
        assert_eq!(j.req_i64("dead_letter_errors").unwrap(), 1);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        let text = rep.to_string();
        assert!(text.contains("faults          panics 3"), "{text}");
        assert!(text.contains("poison_rows 2"), "{text}");
    }

    #[test]
    fn rolling_quarantine_rate_decays_with_clean_traffic() {
        let r = LatencyRecorder::new();
        // no validated traffic yet: no rate entries at all
        assert!(r.quarantine_rates().is_empty());
        // a dirty burst: 8 rows, 4 quarantined → rate 0.5
        r.record_tenant_rows("shop", 8, 4);
        assert_eq!(r.quarantine_rates().get("shop"), Some(&0.5));
        // another tenant's clean traffic does not bleed in
        r.record_tenant_rows("ads", 10, 0);
        let rates = r.quarantine_rates();
        assert_eq!(rates.get("shop"), Some(&0.5));
        assert_eq!(rates.get("ads"), Some(&0.0));
        // clean traffic decays the rate within the window...
        for _ in 0..8 {
            r.record_tenant_rows("shop", 8, 0);
        }
        let rate = r.quarantine_rates()["shop"];
        assert!(rate < 0.1, "rate did not decay: {rate}");
        // ...and the dirty request ages OUT entirely past the window
        for _ in 0..super::RATE_WINDOW_REQUESTS {
            r.record_tenant_rows("shop", 1, 0);
        }
        assert_eq!(r.quarantine_rates()["shop"], 0.0);
        // the tenant split's quarantine_rate key gates on > 0
        let mut stats = TenantStats {
            tenant: "shop".into(),
            requests: 1,
            shed: 0,
            active_version: 0,
            quarantine_rate: 0.0,
            mean_ns: 1.0,
            p50_ns: 1.0,
            p95_ns: 1.0,
            p99_ns: 1.0,
        };
        assert!(stats.to_json().get("quarantine_rate").is_none());
        stats.quarantine_rate = 0.25;
        assert_eq!(stats.to_json().req_f64("quarantine_rate").unwrap(), 0.25);
    }

    #[test]
    fn report_json_record() {
        let r = LatencyRecorder::new();
        r.record(Duration::from_millis(2));
        let rep = r.report("ltr/interpreted", 1, Duration::from_secs(1), Duration::from_millis(2));
        let j = rep.to_json();
        assert_eq!(j.req_str("spec").unwrap(), "ltr");
        assert_eq!(j.req_str("mode").unwrap(), "interpreted");
        assert_eq!(j.req_i64("requests").unwrap(), 1);
        assert!(j.req_f64("p99_ns").unwrap() > 0.0);
        // record must survive a JSON round trip (trajectory files)
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
    }
}
