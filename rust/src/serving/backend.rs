//! Serving backends: compiled (PJRT), interpreted (columnar), and
//! MLeap-like (row-wise boxed).

use std::collections::BTreeMap;
use std::path::Path;

use crate::baselines::RowPipeline;
use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};
use crate::export::{GraphSpec, SpecInterpreter};
use crate::pipeline::PipelineModel;
use crate::runtime::{CompiledGraph, Tensor};

/// A preprocessing execution backend: request batch in, output tensors
/// out. Implementations must be `Send + Sync` (the batcher worker owns
/// one; benches probe them directly).
pub trait Backend: Send + Sync {
    fn name(&self) -> &str;

    /// Process one (possibly merged) request batch.
    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>>;
}

/// Rust ingress + AOT-compiled HLO via PJRT, with batch-bucket padding.
pub struct CompiledBackend {
    interp: SpecInterpreter,
    /// batch-bucket size -> compiled executable.
    graphs: BTreeMap<usize, CompiledGraph>,
    name: String,
}

impl CompiledBackend {
    /// Load every `<spec>@b<batch>.hlo.txt` artifact for this spec.
    pub fn load(artifacts: &Path, spec: GraphSpec) -> Result<CompiledBackend> {
        let client = xla::PjRtClient::cpu()?;
        let exec_lock = std::sync::Arc::new(std::sync::Mutex::new(()));
        let mut graphs = BTreeMap::new();
        let prefix = format!("{}@b", spec.name);
        for entry in std::fs::read_dir(artifacts)? {
            let path = entry?.path();
            let fname = path
                .file_name()
                .map(|f| f.to_string_lossy().into_owned())
                .unwrap_or_default();
            if let Some(rest) = fname
                .strip_prefix(&prefix)
                .and_then(|r| r.strip_suffix(".hlo.txt"))
            {
                if let Ok(batch) = rest.parse::<usize>() {
                    graphs.insert(
                        batch,
                        CompiledGraph::load_locked(&client, &path, exec_lock.clone())?,
                    );
                }
            }
        }
        if graphs.is_empty() {
            return Err(KamaeError::Xla(format!(
                "no compiled artifacts found for spec {} in {}",
                spec.name,
                artifacts.display()
            )));
        }
        Ok(CompiledBackend {
            name: format!("{}-compiled", spec.name),
            interp: SpecInterpreter::new(spec),
            graphs,
        })
    }

    /// Smallest compiled bucket that fits `batch`, or the largest bucket
    /// (larger batches chunk).
    fn bucket_for(&self, batch: usize) -> usize {
        self.graphs
            .range(batch..)
            .next()
            .map(|(&b, _)| b)
            .unwrap_or_else(|| *self.graphs.keys().next_back().expect("non-empty"))
    }

    pub fn buckets(&self) -> Vec<usize> {
        self.graphs.keys().copied().collect()
    }

    fn execute_bucketed(&self, inputs: &[Tensor], batch: usize) -> Result<Vec<Tensor>> {
        let bucket = self.bucket_for(batch);
        let max = *self.graphs.keys().next_back().expect("non-empty");
        if batch > max {
            // chunk oversized batches through the largest bucket
            let mut out: Option<Vec<Tensor>> = None;
            let mut start = 0;
            while start < batch {
                let n = (batch - start).min(max);
                let chunk: Vec<Tensor> = inputs
                    .iter()
                    .map(|t| {
                        t.split_batch(&[start, n, batch - start - n])
                            .map(|mut parts| parts.swap_remove(1))
                    })
                    .collect::<Result<_>>()?;
                let res = self.execute_bucketed(&chunk, n)?;
                out = Some(match out {
                    None => res,
                    Some(acc) => acc
                        .iter()
                        .zip(res.iter())
                        .map(|(a, b)| Tensor::concat_batch(&[a, b]))
                        .collect::<Result<_>>()?,
                });
                start += n;
            }
            return Ok(out.expect("batch > 0"));
        }
        let graph = &self.graphs[&bucket];
        if bucket == batch {
            return graph.execute(inputs);
        }
        // pad to bucket, execute, slice back
        let padded: Vec<Tensor> = inputs.iter().map(|t| t.pad_batch(bucket)).collect();
        let full = graph.execute(&padded)?;
        full.iter()
            .map(|t| {
                t.split_batch(&[batch, bucket - batch])
                    .map(|mut parts| parts.swap_remove(0))
            })
            .collect()
    }
}

impl Backend for CompiledBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        let inputs = self.interp.run_ingress(df)?;
        self.execute_bucketed(&inputs, df.num_rows())
    }
}

/// Columnar interpreted backend (no compilation).
pub struct InterpretedBackend {
    interp: SpecInterpreter,
    name: String,
}

impl InterpretedBackend {
    pub fn new(spec: GraphSpec) -> InterpretedBackend {
        InterpretedBackend {
            name: format!("{}-interpreted", spec.name),
            interp: SpecInterpreter::new(spec),
        }
    }
}

impl Backend for InterpretedBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        self.interp.run(df)
    }
}

/// Row-at-a-time MLeap-like backend.
pub struct MleapBackend {
    rows: RowPipeline,
    name: String,
}

impl MleapBackend {
    pub fn new(model: PipelineModel, spec: &GraphSpec) -> MleapBackend {
        MleapBackend {
            name: format!("{}-mleap", spec.name),
            rows: RowPipeline::from_spec(model, spec),
        }
    }
}

impl Backend for MleapBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        self.rows.process(df)
    }
}
