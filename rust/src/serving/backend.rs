//! Serving backends: compiled (PJRT), interpreted (columnar), and
//! MLeap-like (row-wise boxed).

use std::collections::BTreeMap;
use std::path::Path;

use crate::baselines::RowPipeline;
use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};
use crate::export::{GraphSpec, SpecInterpreter};
use crate::pipeline::PipelineModel;
use crate::runtime::{CompiledGraph, Tensor};

/// A preprocessing execution backend: request batch in, output tensors
/// out. Implementations must be `Send + Sync` (the batcher worker owns
/// one; benches probe them directly).
pub trait Backend: Send + Sync {
    fn name(&self) -> &str;

    /// Process one (possibly merged) request batch.
    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>>;
}

/// Rust ingress + AOT-compiled HLO via PJRT, with batch-bucket padding.
pub struct CompiledBackend {
    interp: SpecInterpreter,
    /// batch-bucket size -> compiled executable.
    graphs: BTreeMap<usize, CompiledGraph>,
    name: String,
}

impl CompiledBackend {
    /// Load every `<spec>@b<batch>.hlo.txt` artifact for this spec.
    pub fn load(artifacts: &Path, spec: GraphSpec) -> Result<CompiledBackend> {
        let client = xla::PjRtClient::cpu()?;
        let exec_lock = std::sync::Arc::new(std::sync::Mutex::new(()));
        let mut graphs = BTreeMap::new();
        let prefix = format!("{}@b", spec.name);
        for entry in std::fs::read_dir(artifacts)? {
            let path = entry?.path();
            let fname = path
                .file_name()
                .map(|f| f.to_string_lossy().into_owned())
                .unwrap_or_default();
            if let Some(rest) = fname
                .strip_prefix(&prefix)
                .and_then(|r| r.strip_suffix(".hlo.txt"))
            {
                if let Ok(batch) = rest.parse::<usize>() {
                    graphs.insert(
                        batch,
                        CompiledGraph::load_locked(&client, &path, exec_lock.clone())?,
                    );
                }
            }
        }
        if graphs.is_empty() {
            // a Serving error, not an Xla one: this is a deployment
            // problem (nothing to route requests to), and it must
            // surface at construction — not as an `expect` panic on the
            // first request
            return Err(KamaeError::Serving(format!(
                "no compiled artifacts found for spec {} in {}",
                spec.name,
                artifacts.display()
            )));
        }
        Ok(CompiledBackend {
            name: format!("{}-compiled", spec.name),
            interp: SpecInterpreter::new(spec),
            graphs,
        })
    }

    pub fn buckets(&self) -> Vec<usize> {
        self.graphs.keys().copied().collect()
    }

    fn execute_bucketed(&self, inputs: &[Tensor], batch: usize) -> Result<Vec<Tensor>> {
        let (bucket, max) = pick_bucket(&self.graphs, batch)?;
        if batch > max {
            // chunk oversized batches through the largest bucket
            let mut out: Option<Vec<Tensor>> = None;
            let mut start = 0;
            while start < batch {
                let n = (batch - start).min(max);
                let chunk: Vec<Tensor> = inputs
                    .iter()
                    .map(|t| {
                        t.split_batch(&[start, n, batch - start - n])
                            .map(|mut parts| parts.swap_remove(1))
                    })
                    .collect::<Result<_>>()?;
                let res = self.execute_bucketed(&chunk, n)?;
                out = Some(match out {
                    None => res,
                    Some(acc) => acc
                        .iter()
                        .zip(res.iter())
                        .map(|(a, b)| Tensor::concat_batch(&[a, b]))
                        .collect::<Result<_>>()?,
                });
                start += n;
            }
            return out.ok_or_else(|| {
                KamaeError::Serving("empty batch reached the compiled executor".into())
            });
        }
        let graph = &self.graphs[&bucket];
        if bucket == batch {
            return graph.execute(inputs);
        }
        // pad to bucket, execute, slice back
        let padded: Vec<Tensor> = inputs.iter().map(|t| t.pad_batch(bucket)).collect();
        let full = graph.execute(&padded)?;
        full.iter()
            .map(|t| {
                t.split_batch(&[batch, bucket - batch])
                    .map(|mut parts| parts.swap_remove(0))
            })
            .collect()
    }
}

impl Backend for CompiledBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        let inputs = self.interp.run_ingress(df)?;
        self.execute_bucketed(&inputs, df.num_rows())
    }
}

/// Pick the serving bucket for `batch` from the bucket map: the
/// smallest bucket that fits, else the largest (the caller chunks
/// oversized batches). Returns `(bucket, largest)`. Allocation-free —
/// this sits on the per-request hot path.
///
/// An empty bucket map is a [`KamaeError::Serving`] error, never a
/// panic: construction already rejects it, but a request-time lookup
/// must not be able to take the worker thread down either.
fn pick_bucket<V>(graphs: &BTreeMap<usize, V>, batch: usize) -> Result<(usize, usize)> {
    let max = *graphs
        .keys()
        .next_back()
        .ok_or_else(|| KamaeError::Serving("no compiled batch buckets loaded".into()))?;
    let bucket = graphs.range(batch..).next().map(|(&b, _)| b).unwrap_or(max);
    Ok((bucket, max))
}

/// Columnar interpreted backend (no compilation).
pub struct InterpretedBackend {
    interp: SpecInterpreter,
    name: String,
}

impl InterpretedBackend {
    pub fn new(spec: GraphSpec) -> InterpretedBackend {
        InterpretedBackend {
            name: format!("{}-interpreted", spec.name),
            interp: SpecInterpreter::new(spec),
        }
    }
}

impl Backend for InterpretedBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        self.interp.run(df)
    }
}

/// Row-at-a-time MLeap-like backend.
pub struct MleapBackend {
    rows: RowPipeline,
    name: String,
}

impl MleapBackend {
    pub fn new(model: PipelineModel, spec: &GraphSpec) -> MleapBackend {
        MleapBackend {
            name: format!("{}-mleap", spec.name),
            rows: RowPipeline::from_spec(model, spec),
        }
    }
}

impl Backend for MleapBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        self.rows.process(df)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_lookup_errors_instead_of_panicking() {
        // regression: an empty bucket map used to hit
        // `expect("non-empty")` at request time
        let empty: BTreeMap<usize, ()> = BTreeMap::new();
        let err = pick_bucket(&empty, 8).unwrap_err();
        assert!(matches!(err, KamaeError::Serving(_)), "{err}");
    }

    #[test]
    fn bucket_lookup_picks_smallest_fit_then_largest() {
        let buckets: BTreeMap<usize, ()> =
            [1usize, 8, 32, 128].into_iter().map(|b| (b, ())).collect();
        assert_eq!(pick_bucket(&buckets, 0).unwrap(), (1, 128));
        assert_eq!(pick_bucket(&buckets, 1).unwrap(), (1, 128));
        assert_eq!(pick_bucket(&buckets, 2).unwrap(), (8, 128));
        assert_eq!(pick_bucket(&buckets, 8).unwrap(), (8, 128));
        assert_eq!(pick_bucket(&buckets, 100).unwrap(), (128, 128));
        // oversized: the largest bucket comes back so the caller chunks
        assert_eq!(pick_bucket(&buckets, 1000).unwrap(), (128, 128));
    }
}
