//! Serving backends: compiled (PJRT), interpreted (columnar), and
//! MLeap-like (row-wise boxed).

use std::collections::BTreeMap;
use std::path::Path;

use crate::baselines::RowPipeline;
use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};
use crate::export::{GraphSpec, RouteGroup, SpecInterpreter};
use crate::pipeline::PipelineModel;
use crate::runtime::{CompiledGraph, Tensor};

/// One contiguous per-variant row range of a routed batch: the batcher
/// sorts variant-tagged requests into these groups before the single
/// backend call ([`Backend::process_routed`]).
#[derive(Debug, Clone)]
pub struct VariantGroup {
    /// Requested variant, or `None` for an untargeted request (the full
    /// output set).
    pub variant: Option<String>,
    pub rows: std::ops::Range<usize>,
}

/// A preprocessing execution backend: request batch in, output tensors
/// out. Implementations must be `Send + Sync` (the batcher worker owns
/// one; benches probe them directly).
pub trait Backend: Send + Sync {
    fn name(&self) -> &str;

    /// Execution-strategy label ("compiled", "interpreted", "mleap", …)
    /// used in error messages and surfaced over the wire so routed
    /// rejections are actionable from the error JSON alone.
    fn kind(&self) -> &'static str {
        "opaque"
    }

    /// The graph spec this backend serves, when it has one. The network
    /// front-end uses it to derive the request schema and the per-variant
    /// output names; backends without a spec cannot be bound to a
    /// listener.
    fn spec(&self) -> Option<&GraphSpec> {
        None
    }

    /// Process one (possibly merged) request batch.
    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>>;

    /// The wire-facing request schema, derived from the spec's declared
    /// inputs. `None` for spec-less backends — the registry carries it
    /// per deployed version so the network layer decodes rows against
    /// the SAME version that will execute them.
    fn request_schema(&self) -> Option<crate::dataframe::Schema> {
        self.spec().map(|s| crate::dataframe::Schema {
            fields: s
                .inputs
                .iter()
                .map(|i| crate::dataframe::Field { name: i.name.clone(), dtype: i.dtype.clone() })
                .collect(),
        })
    }

    /// Named variants requests may target ([`VariantGroup::variant`] /
    /// `Server::submit_variant`) — the `"<variant>::"` output prefixes
    /// of a merged multi-variant spec. Empty for single-variant
    /// backends, which only accept untargeted requests.
    fn variants(&self) -> &[String] {
        &[]
    }

    /// Process a batch whose contiguous row groups each target one
    /// variant (or `None` for all outputs), returning each group's
    /// output tensors — for a targeted group, only its variant's
    /// outputs, in that variant's output order.
    ///
    /// The default is the un-routed fallback: evaluate everything once
    /// and hand every group the full output set sliced to its rows —
    /// correct for untargeted groups, an error for targeted ones
    /// (backends that cannot restrict evaluation must not silently
    /// return the wrong tensor list). [`InterpretedBackend`] overrides
    /// this with real cone-restricted evaluation.
    fn process_routed(&self, df: &DataFrame, groups: &[VariantGroup]) -> Result<Vec<Vec<Tensor>>> {
        if let Some(g) = groups.iter().find(|g| g.variant.is_some()) {
            return Err(KamaeError::Serving(format!(
                "backend '{}' ({} backend) cannot route variant '{}': routed \
                 evaluation needs variant support (serve this spec on the \
                 interpreted backend, or submit untargeted requests)",
                self.name(),
                self.kind(),
                g.variant.as_deref().unwrap_or_default()
            )));
        }
        let outputs = self.process(df)?;
        split_by_groups(&outputs, df.num_rows(), groups)
    }
}

/// Slice every output tensor into the groups' row ranges and transpose
/// to per-group tensor lists (the un-routed fallback shape).
fn split_by_groups(
    outputs: &[Tensor],
    batch: usize,
    groups: &[VariantGroup],
) -> Result<Vec<Vec<Tensor>>> {
    let mut per_group: Vec<Vec<Tensor>> =
        groups.iter().map(|_| Vec::with_capacity(outputs.len())).collect();
    for g in groups {
        if g.rows.end > batch || g.rows.start > g.rows.end {
            return Err(KamaeError::Serving(format!(
                "variant group rows {}..{} outside batch of {batch}",
                g.rows.start, g.rows.end
            )));
        }
    }
    for out in outputs {
        for (slot, g) in per_group.iter_mut().zip(groups) {
            let part = out
                .split_batch(&[g.rows.start, g.rows.len(), batch - g.rows.end])?
                .swap_remove(1);
            slot.push(part);
        }
    }
    Ok(per_group)
}

/// Rust ingress + AOT-compiled HLO via PJRT, with batch-bucket padding.
pub struct CompiledBackend {
    interp: SpecInterpreter,
    /// batch-bucket size -> compiled executable.
    graphs: BTreeMap<usize, CompiledGraph>,
    name: String,
}

impl CompiledBackend {
    /// Load every `<spec>@b<batch>.hlo.txt` artifact for this spec.
    pub fn load(artifacts: &Path, spec: GraphSpec) -> Result<CompiledBackend> {
        let client = xla::PjRtClient::cpu()?;
        let exec_lock = std::sync::Arc::new(std::sync::Mutex::new(()));
        let mut graphs = BTreeMap::new();
        let prefix = format!("{}@b", spec.name);
        for entry in std::fs::read_dir(artifacts)? {
            let path = entry?.path();
            let fname = path
                .file_name()
                .map(|f| f.to_string_lossy().into_owned())
                .unwrap_or_default();
            if let Some(rest) = fname
                .strip_prefix(&prefix)
                .and_then(|r| r.strip_suffix(".hlo.txt"))
            {
                if let Ok(batch) = rest.parse::<usize>() {
                    graphs.insert(
                        batch,
                        CompiledGraph::load_locked(&client, &path, exec_lock.clone())?,
                    );
                }
            }
        }
        if graphs.is_empty() {
            // a Serving error, not an Xla one: this is a deployment
            // problem (nothing to route requests to), and it must
            // surface at construction — not as an `expect` panic on the
            // first request
            return Err(KamaeError::Serving(format!(
                "no compiled artifacts found for spec {} in {}",
                spec.name,
                artifacts.display()
            )));
        }
        Ok(CompiledBackend {
            name: format!("{}-compiled", spec.name),
            interp: SpecInterpreter::new(spec),
            graphs,
        })
    }

    pub fn buckets(&self) -> Vec<usize> {
        self.graphs.keys().copied().collect()
    }

    fn execute_bucketed(&self, inputs: &[Tensor], batch: usize) -> Result<Vec<Tensor>> {
        let (bucket, max) = pick_bucket(&self.graphs, batch)?;
        if batch > max {
            // chunk oversized batches through the largest bucket
            let mut out: Option<Vec<Tensor>> = None;
            let mut start = 0;
            while start < batch {
                let n = (batch - start).min(max);
                let chunk: Vec<Tensor> = inputs
                    .iter()
                    .map(|t| {
                        t.split_batch(&[start, n, batch - start - n])
                            .map(|mut parts| parts.swap_remove(1))
                    })
                    .collect::<Result<_>>()?;
                let res = self.execute_bucketed(&chunk, n)?;
                out = Some(match out {
                    None => res,
                    Some(acc) => acc
                        .iter()
                        .zip(res.iter())
                        .map(|(a, b)| Tensor::concat_batch(&[a, b]))
                        .collect::<Result<_>>()?,
                });
                start += n;
            }
            return out.ok_or_else(|| {
                KamaeError::Serving("empty batch reached the compiled executor".into())
            });
        }
        let graph = &self.graphs[&bucket];
        if bucket == batch {
            return graph.execute(inputs);
        }
        // pad to bucket, execute, slice back
        let padded: Vec<Tensor> = inputs.iter().map(|t| t.pad_batch(bucket)).collect();
        let full = graph.execute(&padded)?;
        full.iter()
            .map(|t| {
                t.split_batch(&[batch, bucket - batch])
                    .map(|mut parts| parts.swap_remove(0))
            })
            .collect()
    }
}

impl Backend for CompiledBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> &'static str {
        "compiled"
    }

    fn spec(&self) -> Option<&GraphSpec> {
        Some(self.interp.spec())
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        let inputs = self.interp.run_ingress(df)?;
        self.execute_bucketed(&inputs, df.num_rows())
    }
}

/// Pick the serving bucket for `batch` from the bucket map: the
/// smallest bucket that fits, else the largest (the caller chunks
/// oversized batches). Returns `(bucket, largest)`. Allocation-free —
/// this sits on the per-request hot path.
///
/// An empty bucket map is a [`KamaeError::Serving`] error, never a
/// panic: construction already rejects it, but a request-time lookup
/// must not be able to take the worker thread down either.
fn pick_bucket<V>(graphs: &BTreeMap<usize, V>, batch: usize) -> Result<(usize, usize)> {
    let max = *graphs
        .keys()
        .next_back()
        .ok_or_else(|| KamaeError::Serving("no compiled batch buckets loaded".into()))?;
    let bucket = graphs.range(batch..).next().map(|(&b, _)| b).unwrap_or(max);
    Ok((bucket, max))
}

/// Columnar interpreted backend (no compilation). On a merged
/// multi-variant spec it is variant-aware: targeted requests evaluate
/// only the ancestor cone of their variant's outputs
/// ([`SpecInterpreter::run_routed`]).
pub struct InterpretedBackend {
    interp: SpecInterpreter,
    name: String,
    /// Variant names parsed from the spec's `"<variant>::"` output
    /// prefixes (empty on ordinary single-variant specs), with each
    /// variant's output indices precomputed for request routing.
    variants: Vec<String>,
    variant_outputs: Vec<Vec<usize>>,
}

impl InterpretedBackend {
    pub fn new(spec: GraphSpec) -> InterpretedBackend {
        let variants: Vec<String> = spec.variants().into_iter().map(str::to_string).collect();
        let variant_outputs = variants.iter().map(|v| spec.variant_outputs(v)).collect();
        InterpretedBackend {
            name: format!("{}-interpreted", spec.name),
            variants,
            variant_outputs,
            interp: SpecInterpreter::new(spec),
        }
    }

    /// Backend over the `eval_node` oracle interpreter — no kernel
    /// program is compiled, every request walks the original per-node
    /// env path. This is the differential / benchmark baseline for the
    /// kernel-program hot path (`benches/kernel_program.rs`), never the
    /// backend `load_backend` serves.
    pub fn new_oracle(spec: GraphSpec) -> InterpretedBackend {
        let variants: Vec<String> = spec.variants().into_iter().map(str::to_string).collect();
        let variant_outputs = variants.iter().map(|v| spec.variant_outputs(v)).collect();
        InterpretedBackend {
            name: format!("{}-interpreted-oracle", spec.name),
            variants,
            variant_outputs,
            interp: SpecInterpreter::new_oracle(spec),
        }
    }

    /// Output indices a routed group resolves to: the variant's own
    /// outputs, or every output for untargeted groups.
    fn outputs_for(&self, variant: Option<&str>) -> Result<Vec<usize>> {
        match variant {
            None => Ok((0..self.interp.spec().outputs.len()).collect()),
            Some(v) => self
                .variants
                .iter()
                .position(|name| name == v)
                .map(|i| self.variant_outputs[i].clone())
                .ok_or_else(|| {
                    KamaeError::Serving(format!(
                        "backend {} has no variant '{v}' (variants: {})",
                        self.name,
                        self.variants.join(", ")
                    ))
                }),
        }
    }
}

impl Backend for InterpretedBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> &'static str {
        "interpreted"
    }

    fn spec(&self) -> Option<&GraphSpec> {
        Some(self.interp.spec())
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        self.interp.run(df)
    }

    fn variants(&self) -> &[String] {
        &self.variants
    }

    fn process_routed(&self, df: &DataFrame, groups: &[VariantGroup]) -> Result<Vec<Vec<Tensor>>> {
        let route_groups: Vec<RouteGroup> = groups
            .iter()
            .map(|g| {
                Ok(RouteGroup {
                    outputs: self.outputs_for(g.variant.as_deref())?,
                    rows: g.rows.clone(),
                })
            })
            .collect::<Result<_>>()?;
        self.interp.run_routed(df, &route_groups)
    }
}

/// Row-at-a-time MLeap-like backend.
pub struct MleapBackend {
    rows: RowPipeline,
    name: String,
    spec: GraphSpec,
}

impl MleapBackend {
    pub fn new(model: PipelineModel, spec: &GraphSpec) -> MleapBackend {
        MleapBackend {
            name: format!("{}-mleap", spec.name),
            rows: RowPipeline::from_spec(model, spec),
            spec: spec.clone(),
        }
    }
}

impl Backend for MleapBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> &'static str {
        "mleap"
    }

    fn spec(&self) -> Option<&GraphSpec> {
        Some(&self.spec)
    }

    fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        self.rows.process(df)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_lookup_errors_instead_of_panicking() {
        // regression: an empty bucket map used to hit
        // `expect("non-empty")` at request time
        let empty: BTreeMap<usize, ()> = BTreeMap::new();
        let err = pick_bucket(&empty, 8).unwrap_err();
        assert!(matches!(err, KamaeError::Serving(_)), "{err}");
    }

    #[test]
    fn default_routed_path_slices_untargeted_and_rejects_targeted() {
        use crate::dataframe::Column;

        struct Echo;
        impl Backend for Echo {
            fn name(&self) -> &str {
                "echo"
            }
            fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
                let v = df.column("x")?.as_f64()?;
                Tensor::f32(v.iter().map(|&x| x as f32).collect(), vec![v.len()])
                    .map(|t| vec![t])
            }
        }
        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64(vec![1.0, 2.0, 3.0, 4.0, 5.0]),
        )])
        .unwrap();
        let groups = vec![
            VariantGroup { variant: None, rows: 0..2 },
            VariantGroup { variant: None, rows: 2..5 },
        ];
        let per_group = Echo.process_routed(&df, &groups).unwrap();
        assert_eq!(per_group.len(), 2);
        assert_eq!(per_group[0][0].as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(per_group[1][0].as_f32().unwrap(), &[3.0, 4.0, 5.0]);
        // a targeted group must error, not silently return all outputs —
        // and the message must name the variant, the backend, and its
        // kind so wire-level error JSON is actionable
        let targeted = vec![VariantGroup { variant: Some("a".into()), rows: 0..5 }];
        let err = Echo.process_routed(&df, &targeted).unwrap_err();
        assert!(matches!(err, KamaeError::Serving(_)), "{err}");
        let msg = err.to_string();
        assert!(msg.contains("variant 'a'"), "{msg}");
        assert!(msg.contains("'echo'"), "{msg}");
        assert!(msg.contains("opaque"), "{msg}");
        // trait defaults: no strategy label override, no spec
        assert_eq!(Echo.kind(), "opaque");
        assert!(Echo.spec().is_none());
        // out-of-range groups error instead of slicing garbage
        let oob = vec![VariantGroup { variant: None, rows: 0..9 }];
        assert!(Echo.process_routed(&df, &oob).is_err());
        // a backend without variants advertises none
        assert!(Echo.variants().is_empty());
    }

    #[test]
    fn bucket_lookup_picks_smallest_fit_then_largest() {
        let buckets: BTreeMap<usize, ()> =
            [1usize, 8, 32, 128].into_iter().map(|b| (b, ())).collect();
        assert_eq!(pick_bucket(&buckets, 0).unwrap(), (1, 128));
        assert_eq!(pick_bucket(&buckets, 1).unwrap(), (1, 128));
        assert_eq!(pick_bucket(&buckets, 2).unwrap(), (8, 128));
        assert_eq!(pick_bucket(&buckets, 8).unwrap(), (8, 128));
        assert_eq!(pick_bucket(&buckets, 100).unwrap(), (128, 128));
        // oversized: the largest bucket comes back so the caller chunks
        assert_eq!(pick_bucket(&buckets, 1000).unwrap(), (128, 128));
    }
}
