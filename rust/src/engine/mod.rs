//! The distributed execution engine — the "Spark" substrate.
//!
//! A [`Dataset`] is a partitioned collection of [`DataFrame`]s. Narrow
//! transformations (`map`) run partition-parallel on worker threads;
//! estimator fitting uses mergeable accumulators via [`tree_aggregate`]
//! (the Spark `treeAggregate` pattern). The streaming orchestrator with
//! bounded-queue backpressure lives in [`stream`]; shard rebalancing in
//! [`shard`].

pub mod shard;
pub mod stream;

use crate::dataframe::DataFrame;
use crate::error::Result;
use crate::util::pool;

/// A partitioned dataset. Partitions are independent row-range shards
/// with identical schemas.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub partitions: Vec<DataFrame>,
    threads: usize,
}

impl Dataset {
    /// Split a DataFrame into `n` contiguous partitions.
    pub fn from_dataframe(df: DataFrame, n: usize) -> Dataset {
        let n = n.max(1);
        let rows = df.num_rows();
        if rows == 0 || n == 1 {
            return Dataset { partitions: vec![df], threads: pool::default_threads() };
        }
        let n = n.min(rows);
        let base = rows / n;
        let extra = rows % n;
        let mut partitions = Vec::with_capacity(n);
        let mut start = 0;
        for i in 0..n {
            let len = base + usize::from(i < extra);
            partitions.push(df.slice(start, len));
            start += len;
        }
        Dataset { partitions, threads: pool::default_threads() }
    }

    /// Wrap pre-built partitions.
    pub fn from_partitions(partitions: Vec<DataFrame>) -> Dataset {
        Dataset { partitions, threads: pool::default_threads() }
    }

    /// Cap/raise the worker-thread count (benchmarks sweep this).
    pub fn with_threads(mut self, threads: usize) -> Dataset {
        self.threads = threads.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn num_rows(&self) -> usize {
        self.partitions.iter().map(|p| p.num_rows()).sum()
    }

    /// Partition-parallel narrow transformation.
    pub fn map(&self, f: impl Fn(&DataFrame) -> Result<DataFrame> + Sync) -> Result<Dataset> {
        let results = pool::parallel_map(&self.partitions, self.threads, |_, df| f(df));
        let partitions = results.into_iter().collect::<Result<Vec<_>>>()?;
        Ok(Dataset { partitions, threads: self.threads })
    }

    /// Gather all partitions into one DataFrame (Spark `collect`).
    pub fn collect(&self) -> Result<DataFrame> {
        let refs: Vec<&DataFrame> = self.partitions.iter().collect();
        DataFrame::concat(&refs)
    }
}

/// A mergeable accumulator for distributed fitting (Spark's
/// `treeAggregate`): each partition folds into a fresh accumulator on a
/// worker thread, then accumulators merge pairwise.
pub trait Accumulator: Send + Sized {
    /// Fold one partition into this accumulator.
    fn add_partition(&mut self, df: &DataFrame) -> Result<()>;

    /// Merge another accumulator into this one.
    fn merge(&mut self, other: Self) -> Result<()>;
}

/// Run a tree aggregation over the dataset: `init()` per partition,
/// `add_partition`, then pairwise merge. Deterministic regardless of
/// thread schedule as long as `merge` is associative (all estimator
/// accumulators here are associative + commutative or order-normalised).
pub fn tree_aggregate<A: Accumulator>(
    data: &Dataset,
    init: impl Fn() -> A + Sync,
) -> Result<A> {
    let partials = pool::parallel_map(&data.partitions, data.threads(), |_, df| {
        let mut acc = init();
        acc.add_partition(df)?;
        Ok::<A, crate::error::KamaeError>(acc)
    });
    let mut iter = partials.into_iter();
    let mut acc = match iter.next() {
        Some(a) => a?,
        None => init(),
    };
    for next in iter {
        acc.merge(next?)?;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    fn df(n: usize) -> DataFrame {
        DataFrame::new(vec![(
            "x".into(),
            Column::from_i64((0..n as i64).collect()),
        )])
        .unwrap()
    }

    #[test]
    fn partitioning_covers_all_rows() {
        let d = Dataset::from_dataframe(df(10), 3);
        assert_eq!(d.num_partitions(), 3);
        assert_eq!(d.num_rows(), 10);
        let sizes: Vec<usize> = d.partitions.iter().map(|p| p.num_rows()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
        let back = d.collect().unwrap();
        assert_eq!(back, df(10));
    }

    #[test]
    fn more_partitions_than_rows() {
        let d = Dataset::from_dataframe(df(2), 8);
        assert_eq!(d.num_partitions(), 2);
        assert_eq!(d.num_rows(), 2);
    }

    #[test]
    fn map_is_partitionwise() {
        let d = Dataset::from_dataframe(df(100), 4);
        let out = d
            .map(|p| {
                let mut p = p.clone();
                let doubled = crate::ops::math::unary(
                    p.column("x")?,
                    &crate::ops::math::UnaryOp::MulScalar { c: 2.0 },
                )?;
                p.push_column("x2", doubled)?;
                Ok(p)
            })
            .unwrap();
        let c = out.collect().unwrap();
        assert_eq!(c.column("x2").unwrap().as_f64().unwrap()[99], 198.0);
    }

    struct SumAcc(i64);
    impl Accumulator for SumAcc {
        fn add_partition(&mut self, df: &DataFrame) -> Result<()> {
            self.0 += df.column("x")?.as_i64()?.iter().sum::<i64>();
            Ok(())
        }
        fn merge(&mut self, other: Self) -> Result<()> {
            self.0 += other.0;
            Ok(())
        }
    }

    #[test]
    fn tree_aggregate_sums() {
        let d = Dataset::from_dataframe(df(1000), 7);
        let acc = tree_aggregate(&d, || SumAcc(0)).unwrap();
        assert_eq!(acc.0, 999 * 1000 / 2);
    }
}
