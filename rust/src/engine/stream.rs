//! Streaming ingestion orchestrator with bounded-queue backpressure.
//!
//! The data-pipeline L3 shape of the paper's offline stage: a producer
//! reads/generates micro-batches, a bounded channel applies backpressure,
//! N workers run the fitted pipeline on each micro-batch, and a sink
//! collects results in order. Throughput is bounded by the slowest stage
//! rather than memory (the queue never exceeds `queue_cap` batches).
//!
//! Built on std mpsc + the shared counting semaphore from [`crate::util::sync`]
//! (no tokio in the offline vendor set); the structure matches an async
//! implementation 1:1. The same semaphore also backs admission control in
//! the network front-end (`serving::net`).

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::dataframe::DataFrame;
use crate::error::{KamaeError, Result};

/// Counting semaphore used for the bounded-queue backpressure window
/// (re-exported so existing `engine::stream::Semaphore` users keep working).
pub use crate::util::sync::Semaphore;

/// Statistics of one streaming run.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub batches: usize,
    pub rows: usize,
    /// Max number of batches that were in flight at once (≤ queue_cap).
    pub peak_in_flight: usize,
}

/// Configuration for [`run_stream`].
pub struct StreamConfig {
    /// Worker threads transforming micro-batches.
    pub workers: usize,
    /// Bounded-queue capacity (backpressure window).
    pub queue_cap: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig { workers: crate::util::pool::default_threads(), queue_cap: 8 }
    }
}

/// Run a streaming job: `source` yields micro-batches until `None`;
/// `transform` runs on workers; `sink` receives (index, result) strictly
/// in source order.
///
/// The producer blocks once `queue_cap` batches are in flight — that is
/// the backpressure contract: memory use is `O(queue_cap · batch_size)`
/// no matter how slow the consumer is.
pub fn run_stream(
    config: &StreamConfig,
    mut source: impl FnMut() -> Option<DataFrame> + Send,
    transform: impl Fn(DataFrame) -> Result<DataFrame> + Sync,
    mut sink: impl FnMut(usize, DataFrame) -> Result<()> + Send,
) -> Result<StreamStats> {
    let workers = config.workers.max(1);
    let slots = Arc::new(Semaphore::new(config.queue_cap.max(1)));
    let (work_tx, work_rx) = mpsc::channel::<(usize, DataFrame)>();
    let work_rx = Arc::new(Mutex::new(work_rx));
    let (done_tx, done_rx) = mpsc::channel::<(usize, Result<DataFrame>)>();

    let in_flight = Arc::new(Mutex::new((0usize, 0usize))); // (current, peak)
    let stats = Mutex::new(StreamStats::default());

    std::thread::scope(|scope| -> Result<()> {
        // workers
        for _ in 0..workers {
            let work_rx = Arc::clone(&work_rx);
            let done_tx = done_tx.clone();
            let transform = &transform;
            scope.spawn(move || loop {
                let job = { work_rx.lock().unwrap().recv() };
                match job {
                    Ok((idx, df)) => {
                        let res = transform(df);
                        if done_tx.send((idx, res)).is_err() {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            });
        }
        drop(done_tx);

        // producer
        let producer_slots = Arc::clone(&slots);
        let producer_in_flight = Arc::clone(&in_flight);
        let producer = scope.spawn(move || {
            let mut idx = 0usize;
            while let Some(batch) = source() {
                producer_slots.acquire();
                {
                    let mut f = producer_in_flight.lock().unwrap();
                    f.0 += 1;
                    f.1 = f.1.max(f.0);
                }
                if work_tx.send((idx, batch)).is_err() {
                    break;
                }
                idx += 1;
            }
            drop(work_tx); // signal workers to finish
            idx
        });

        // sink: reorder buffer for strict source order
        let mut pending: BTreeMap<usize, DataFrame> = BTreeMap::new();
        let mut next = 0usize;
        for (idx, res) in done_rx.iter() {
            // decrement BEFORE releasing the slot, else the producer can
            // acquire + increment first and peak_in_flight overshoots
            {
                let mut f = in_flight.lock().unwrap();
                f.0 -= 1;
            }
            slots.release();
            let df = res?;
            pending.insert(idx, df);
            while let Some(df) = pending.remove(&next) {
                let mut s = stats.lock().unwrap();
                s.batches += 1;
                s.rows += df.num_rows();
                drop(s);
                sink(next, df)?;
                next += 1;
            }
        }
        let total = producer.join().map_err(|_| {
            KamaeError::Serving("stream producer panicked".into())
        })?;
        if next != total {
            return Err(KamaeError::Serving(format!(
                "stream sink saw {next} of {total} batches"
            )));
        }
        Ok(())
    })?;

    let mut s = stats.into_inner().unwrap();
    s.peak_in_flight = in_flight.lock().unwrap().1;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    fn batch(i: i64, rows: usize) -> DataFrame {
        DataFrame::new(vec![("x".into(), Column::from_i64(vec![i; rows]))]).unwrap()
    }

    #[test]
    fn processes_all_batches_in_order() {
        let mut produced = 0;
        let seen = Mutex::new(Vec::new());
        let stats = run_stream(
            &StreamConfig { workers: 4, queue_cap: 3 },
            move || {
                if produced < 20 {
                    produced += 1;
                    Some(batch(produced - 1, 5))
                } else {
                    None
                }
            },
            |df| Ok(df),
            |idx, df| {
                seen.lock().unwrap().push((idx, df.column("x")?.as_i64()?[0]));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(stats.batches, 20);
        assert_eq!(stats.rows, 100);
        let seen = seen.into_inner().unwrap();
        for (i, &(idx, val)) in seen.iter().enumerate() {
            assert_eq!(idx, i);
            assert_eq!(val, i as i64);
        }
    }

    #[test]
    fn backpressure_bounds_in_flight() {
        let mut produced = 0;
        let stats = run_stream(
            &StreamConfig { workers: 2, queue_cap: 2 },
            move || {
                if produced < 30 {
                    produced += 1;
                    Some(batch(0, 1))
                } else {
                    None
                }
            },
            |df| {
                std::thread::sleep(std::time::Duration::from_millis(1));
                Ok(df)
            },
            |_, _| Ok(()),
        )
        .unwrap();
        assert!(stats.peak_in_flight <= 2, "peak={}", stats.peak_in_flight);
    }

    #[test]
    fn transform_error_propagates() {
        let mut produced = 0;
        let res = run_stream(
            &StreamConfig { workers: 2, queue_cap: 2 },
            move || {
                if produced < 5 {
                    produced += 1;
                    Some(batch(produced as i64 - 1, 1))
                } else {
                    None
                }
            },
            |df| {
                if df.column("x")?.as_i64()?[0] == 3 {
                    Err(KamaeError::InvalidConfig("boom".into()))
                } else {
                    Ok(df)
                }
            },
            |_, _| Ok(()),
        );
        assert!(res.is_err());
    }
}
