//! Shard sizing and rebalancing.
//!
//! When partitions become skewed (filtering, ragged list growth), worker
//! utilisation drops; `rebalance` re-cuts a dataset into even row-count
//! shards, and `coalesce` merges small shards to amortise per-partition
//! overhead — the engine-side knobs Spark jobs tune with
//! `repartition`/`coalesce`.

use crate::dataframe::DataFrame;
use crate::engine::Dataset;
use crate::error::Result;

/// Relative row-count imbalance: (max - min) / mean over partitions.
/// 0.0 = perfectly balanced. Empty/1-partition datasets report 0.
pub fn imbalance(data: &Dataset) -> f64 {
    if data.num_partitions() <= 1 {
        return 0.0;
    }
    let sizes: Vec<usize> = data.partitions.iter().map(|p| p.num_rows()).collect();
    let (min, max) = (
        *sizes.iter().min().unwrap(),
        *sizes.iter().max().unwrap(),
    );
    let mean = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
    if mean == 0.0 {
        0.0
    } else {
        (max - min) as f64 / mean
    }
}

/// Re-cut into `n` even contiguous shards (a full shuffle-free rewrite;
/// Spark's `repartition` without the hash shuffle, sufficient for the
/// row-independent transforms this engine runs). `n = 0` clamps to 1,
/// matching [`coalesce`] — degenerate targets must not depend on which
/// downstream constructor happens to guard them.
pub fn rebalance(data: &Dataset, n: usize) -> Result<Dataset> {
    let n = n.max(1);
    let all = data.collect()?;
    Ok(Dataset::from_dataframe(all, n).with_threads(data.threads()))
}

/// Merge adjacent shards until at most `n` remain (Spark `coalesce`).
pub fn coalesce(data: &Dataset, n: usize) -> Result<Dataset> {
    let n = n.max(1);
    if data.num_partitions() <= n {
        return Ok(data.clone());
    }
    let per = data.num_partitions().div_ceil(n);
    let mut out = Vec::with_capacity(n);
    for chunk in data.partitions.chunks(per) {
        let refs: Vec<&DataFrame> = chunk.iter().collect();
        out.push(DataFrame::concat(&refs)?);
    }
    Ok(Dataset::from_partitions(out).with_threads(data.threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    fn ds(sizes: &[usize]) -> Dataset {
        let parts = sizes
            .iter()
            .map(|&n| {
                DataFrame::new(vec![("x".into(), Column::from_i64(vec![1; n]))]).unwrap()
            })
            .collect();
        Dataset::from_partitions(parts)
    }

    #[test]
    fn imbalance_metric() {
        assert_eq!(imbalance(&ds(&[10, 10, 10])), 0.0);
        let skewed = imbalance(&ds(&[1, 10, 1]));
        assert!(skewed > 1.0, "skewed={skewed}");
    }

    #[test]
    fn rebalance_evens_out() {
        let d = ds(&[100, 1, 1]);
        let r = rebalance(&d, 3).unwrap();
        assert_eq!(r.num_rows(), 102);
        assert!(imbalance(&r) < 0.1);
    }

    #[test]
    fn degenerate_targets_clamp_consistently() {
        // property-style sweep over n ∈ {0, 1, partitions, 10×partitions}:
        // rebalance and coalesce must both survive every target (n = 0
        // included), preserve content, and produce ≥ 1 partition
        let d = ds(&[7, 0, 5, 3]);
        let parts = d.num_partitions();
        let content = d.collect().unwrap();
        for n in [0usize, 1, parts, 10 * parts] {
            let r = rebalance(&d, n).unwrap();
            let c = coalesce(&d, n).unwrap();
            for (what, out) in [("rebalance", &r), ("coalesce", &c)] {
                assert!(out.num_partitions() >= 1, "{what}({n}) produced no partitions");
                assert_eq!(out.collect().unwrap(), content, "{what}({n}) changed rows");
            }
            assert!(r.num_partitions() <= n.max(1), "rebalance({n}) overshot");
        }
    }

    #[test]
    fn coalesce_merges() {
        let d = ds(&[5, 5, 5, 5, 5]);
        let c = coalesce(&d, 2).unwrap();
        assert_eq!(c.num_partitions(), 2);
        assert_eq!(c.num_rows(), 25);
        // already small enough: untouched
        assert_eq!(coalesce(&c, 4).unwrap().num_partitions(), 2);
    }
}
