//! Scaling estimators: standard scaling (z-score) and min-max.
//!
//! Fit runs a single-pass distributed moment aggregation (count / sum /
//! sum-of-squares / min / max per element position), supporting both
//! scalar columns and fixed-width vector columns — the paper's LTR
//! pattern "assemble → standard scale → disassemble" needs the vector
//! form. Standard deviation is the *sample* std (ddof=1), matching
//! Spark's `StandardScaler`.

use crate::dataframe::{Column, DataFrame, ListColumn};
use crate::engine::{tree_aggregate, Accumulator, Dataset};
use crate::error::{KamaeError, Result};
use crate::export::{SpecBuilder, SpecDType};
use crate::pipeline::{Estimator, Transformer};
use crate::util::json::Json;
use crate::optim::names as op_names;

/// Moments accumulator per element position.
struct MomentsAcc {
    input: String,
    count: u64,
    sum: Vec<f64>,
    sumsq: Vec<f64>,
    min: Vec<f64>,
    max: Vec<f64>,
}

impl MomentsAcc {
    fn new(input: &str) -> Self {
        MomentsAcc {
            input: input.to_string(),
            count: 0,
            sum: vec![],
            sumsq: vec![],
            min: vec![],
            max: vec![],
        }
    }

    fn ensure_width(&mut self, w: usize) -> Result<()> {
        if self.sum.is_empty() {
            self.sum = vec![0.0; w];
            self.sumsq = vec![0.0; w];
            self.min = vec![f64::INFINITY; w];
            self.max = vec![f64::NEG_INFINITY; w];
        } else if self.sum.len() != w {
            return Err(KamaeError::InvalidConfig(format!(
                "scale fit: inconsistent vector width {} vs {}",
                self.sum.len(),
                w
            )));
        }
        Ok(())
    }

    fn add_row(&mut self, row: &[f64]) {
        self.count += 1;
        for (j, &x) in row.iter().enumerate() {
            self.sum[j] += x;
            self.sumsq[j] += x * x;
            self.min[j] = self.min[j].min(x);
            self.max[j] = self.max[j].max(x);
        }
    }
}

impl Accumulator for MomentsAcc {
    fn add_partition(&mut self, df: &DataFrame) -> Result<()> {
        let col = df.column(&self.input)?;
        match col {
            Column::ListF64(_) | Column::ListF32(_) | Column::ListI64(_) | Column::ListI32(_) => {
                let (values, offsets) = crate::ops::math::list_f64_parts(col)?;
                let l = ListColumn { values, offsets };
                let w = l.fixed_width().ok_or_else(|| {
                    KamaeError::InvalidConfig(
                        "scale fit requires a fixed-width vector column".into(),
                    )
                })?;
                self.ensure_width(w)?;
                for i in 0..l.len() {
                    self.add_row(l.row(i));
                }
            }
            _ => {
                let v = crate::ops::cast::to_f64_vec(col)?;
                self.ensure_width(1)?;
                for (i, &x) in v.iter().enumerate() {
                    if !col.is_null(i) {
                        self.add_row(&[x]);
                    }
                }
            }
        }
        Ok(())
    }

    fn merge(&mut self, other: Self) -> Result<()> {
        if other.count == 0 {
            return Ok(());
        }
        if self.count == 0 {
            self.sum = other.sum;
            self.sumsq = other.sumsq;
            self.min = other.min;
            self.max = other.max;
            self.count = other.count;
            return Ok(());
        }
        self.ensure_width(other.sum.len())?;
        self.count += other.count;
        for j in 0..self.sum.len() {
            self.sum[j] += other.sum[j];
            self.sumsq[j] += other.sumsq[j];
            self.min[j] = self.min[j].min(other.min[j]);
            self.max[j] = self.max[j].max(other.max[j]);
        }
        Ok(())
    }
}

/// z-score scaling estimator (Spark `StandardScaler`).
#[derive(Debug, Clone)]
pub struct StandardScaleEstimator {
    pub input_col: String,
    pub output_col: String,
    pub layer_name: String,
    pub with_mean: bool,
    pub with_std: bool,
}

impl StandardScaleEstimator {
    pub fn new(input: &str, output: &str) -> Self {
        StandardScaleEstimator {
            input_col: input.to_string(),
            output_col: output.to_string(),
            layer_name: format!("{output}_layer"),
            with_mean: true,
            with_std: true,
        }
    }

    pub fn with_mean(mut self, b: bool) -> Self {
        self.with_mean = b;
        self
    }

    pub fn with_std(mut self, b: bool) -> Self {
        self.with_std = b;
        self
    }

    pub fn layer_name(mut self, name: &str) -> Self {
        self.layer_name = name.to_string();
        self
    }
}

impl Estimator for StandardScaleEstimator {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StandardScaleEstimator"
    }

    fn fit(&self, data: &Dataset) -> Result<Box<dyn Transformer>> {
        let acc = tree_aggregate(data, || MomentsAcc::new(&self.input_col))?;
        if acc.count == 0 {
            return Err(KamaeError::InvalidConfig(
                "StandardScaleEstimator: no non-null rows to fit on".into(),
            ));
        }
        let n = acc.count as f64;
        let w = acc.sum.len();
        let mut scale = Vec::with_capacity(w);
        let mut shift = Vec::with_capacity(w);
        for j in 0..w {
            let mean = acc.sum[j] / n;
            // sample variance (ddof=1), like Spark's StandardScaler
            let var = if acc.count > 1 {
                ((acc.sumsq[j] - n * mean * mean) / (n - 1.0)).max(0.0)
            } else {
                0.0
            };
            let std = var.sqrt();
            let s = if self.with_std && std > 0.0 { 1.0 / std } else { 1.0 };
            let m = if self.with_mean { mean } else { 0.0 };
            scale.push(s);
            shift.push(-m * s);
        }
        Ok(Box::new(ScaleModel {
            input_col: self.input_col.clone(),
            output_col: self.output_col.clone(),
            layer_name: self.layer_name.clone(),
            scale,
            shift,
            kind: "StandardScaleModel",
        }))
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("inputCol", self.input_col.clone());
        j.set("outputCol", self.output_col.clone());
        j.set("layerName", self.layer_name.clone());
        j.set("withMean", self.with_mean);
        j.set("withStd", self.with_std);
        j
    }
}

/// Min-max scaling estimator: (x − min) / (max − min) → [0, 1].
#[derive(Debug, Clone)]
pub struct MinMaxScaleEstimator {
    pub input_col: String,
    pub output_col: String,
    pub layer_name: String,
}

impl MinMaxScaleEstimator {
    pub fn new(input: &str, output: &str) -> Self {
        MinMaxScaleEstimator {
            input_col: input.to_string(),
            output_col: output.to_string(),
            layer_name: format!("{output}_layer"),
        }
    }

    pub fn layer_name(mut self, name: &str) -> Self {
        self.layer_name = name.to_string();
        self
    }
}

impl Estimator for MinMaxScaleEstimator {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        "MinMaxScaleEstimator"
    }

    fn fit(&self, data: &Dataset) -> Result<Box<dyn Transformer>> {
        let acc = tree_aggregate(data, || MomentsAcc::new(&self.input_col))?;
        if acc.count == 0 {
            return Err(KamaeError::InvalidConfig(
                "MinMaxScaleEstimator: no non-null rows to fit on".into(),
            ));
        }
        let w = acc.sum.len();
        let mut scale = Vec::with_capacity(w);
        let mut shift = Vec::with_capacity(w);
        for j in 0..w {
            let range = acc.max[j] - acc.min[j];
            let s = if range > 0.0 { 1.0 / range } else { 1.0 };
            scale.push(s);
            shift.push(-acc.min[j] * s);
        }
        Ok(Box::new(ScaleModel {
            input_col: self.input_col.clone(),
            output_col: self.output_col.clone(),
            layer_name: self.layer_name.clone(),
            scale,
            shift,
            kind: "MinMaxScaleModel",
        }))
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("inputCol", self.input_col.clone());
        j.set("outputCol", self.output_col.clone());
        j.set("layerName", self.layer_name.clone());
        j
    }
}

/// Fitted affine scaling: y = x·scale + shift, per element position.
/// Shared by standard and min-max scaling (they export identically —
/// the Pallas fused scale kernel runs both).
#[derive(Debug, Clone)]
pub struct ScaleModel {
    pub input_col: String,
    pub output_col: String,
    pub layer_name: String,
    pub scale: Vec<f64>,
    pub shift: Vec<f64>,
    kind: &'static str,
}

impl Transformer for ScaleModel {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        self.kind
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let col = df.column(&self.input_col)?;
        let out = if col.dtype().element().is_some() {
            let (values, offsets) = crate::ops::math::list_f64_parts(col)?;
            let l = ListColumn { values, offsets };
            let w = l.fixed_width().ok_or_else(|| {
                KamaeError::InvalidConfig("scale transform requires fixed-width vectors".into())
            })?;
            if w != self.scale.len() {
                return Err(KamaeError::LengthMismatch {
                    left: w,
                    right: self.scale.len(),
                    context: "scale width".into(),
                });
            }
            let values: Vec<f64> = l
                .values
                .iter()
                .enumerate()
                .map(|(i, &x)| x * self.scale[i % w] + self.shift[i % w])
                .collect();
            Column::ListF64(ListColumn { values, offsets: l.offsets })
        } else {
            let v = crate::ops::cast::to_f64_vec(col)?;
            Column::F64(
                v.iter().map(|&x| x * self.scale[0] + self.shift[0]).collect(),
                col.nulls().cloned(),
            )
        };
        df.set_column(self.output_col.clone(), out)
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(&self.input_col)?;
        let mut attrs = Json::object();
        attrs.set("scale", Json::Array(self.scale.iter().map(|&x| Json::Float(x)).collect()));
        attrs.set("shift", Json::Array(self.shift.iter().map(|&x| Json::Float(x)).collect()));
        b.graph_node(
            op_names::SCALE_VEC,
            &[&self.input_col],
            attrs,
            &self.output_col,
            SpecDType::F32,
            width,
        )?;
        Ok(())
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("inputCol", self.input_col.clone());
        j.set("outputCol", self.output_col.clone());
        j.set("layerName", self.layer_name.clone());
        j.set("scale", Json::Array(self.scale.iter().map(|&x| Json::Float(x)).collect()));
        j.set("shift", Json::Array(self.shift.iter().map(|&x| Json::Float(x)).collect()));
        j
    }
}

pub(crate) fn scale_model_from_json(j: &Json, kind: &'static str) -> Result<Box<dyn Transformer>> {
    let floats = |key: &str| -> Result<Vec<f64>> {
        j.req_array(key)?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| KamaeError::Serde(format!("{key} entry"))))
            .collect()
    };
    Ok(Box::new(ScaleModel {
        input_col: j.req_str("inputCol")?.to_string(),
        output_col: j.req_str("outputCol")?.to_string(),
        layer_name: j.req_str("layerName")?.to_string(),
        scale: floats("scale")?,
        shift: floats("shift")?,
        kind,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_scale_scalar() {
        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64(vec![2.0, 4.0, 6.0, 8.0]),
        )])
        .unwrap();
        let model = StandardScaleEstimator::new("x", "z")
            .fit(&Dataset::from_dataframe(df.clone(), 2))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        let z = out.column("z").unwrap().as_f64().unwrap();
        let mean: f64 = z.iter().sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-12);
        // sample std of z should be 1
        let var: f64 = z.iter().map(|&v| (v - mean) * (v - mean)).sum::<f64>() / 3.0;
        assert!((var - 1.0).abs() < 1e-12, "var={var}");
    }

    #[test]
    fn vector_scaling_assemble_pattern() {
        // the paper's assemble -> scale -> disassemble flow
        let df = DataFrame::new(vec![(
            "v".into(),
            Column::from_f64_rows(vec![vec![1.0, 100.0], vec![3.0, 300.0]]),
        )])
        .unwrap();
        let model = StandardScaleEstimator::new("v", "vs")
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        let l = out.column("vs").unwrap().as_list_f64().unwrap();
        // each element position independently standardised
        assert!((l.row(0)[0] + l.row(1)[0]).abs() < 1e-12);
        assert!((l.row(0)[1] + l.row(1)[1]).abs() < 1e-12);
    }

    #[test]
    fn min_max_scale() {
        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64(vec![10.0, 20.0, 30.0]),
        )])
        .unwrap();
        let model = MinMaxScaleEstimator::new("x", "m")
            .fit(&Dataset::from_dataframe(df.clone(), 3))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        assert_eq!(out.column("m").unwrap().as_f64().unwrap(), &[0.0, 0.5, 1.0]);
    }

    #[test]
    fn constant_column_degenerates_gracefully() {
        let df = DataFrame::new(vec![("x".into(), Column::from_f64(vec![5.0, 5.0]))]).unwrap();
        let model = StandardScaleEstimator::new("x", "z")
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        // std = 0 -> scale 1, just mean-centering
        assert_eq!(out.column("z").unwrap().as_f64().unwrap(), &[0.0, 0.0]);
    }

    #[test]
    fn nulls_excluded_from_fit() {
        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64_opt(vec![Some(1.0), None, Some(3.0)]),
        )])
        .unwrap();
        let model = StandardScaleEstimator::new("x", "z")
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let j = model.save();
        // mean of [1,3] = 2; shift = -2/std, std = sqrt(2)
        let shift = j.req_array("shift").unwrap()[0].as_f64().unwrap();
        assert!((shift + 2.0 / 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn save_load() {
        let df = DataFrame::new(vec![("x".into(), Column::from_f64(vec![1.0, 2.0]))]).unwrap();
        let model = StandardScaleEstimator::new("x", "z")
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let j = crate::pipeline::with_type(model.save(), model.type_name());
        let loaded = crate::transformers::load(&j).unwrap();
        let mut a = df.clone();
        let mut b = df;
        model.transform(&mut a).unwrap();
        loaded.transform(&mut b).unwrap();
        assert_eq!(a, b);
    }
}
