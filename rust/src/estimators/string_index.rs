//! String indexing estimators (Listing 1's `StringIndexEstimator` and the
//! shared-vocabulary variant).
//!
//! Index layout (identical in the engine, the interpreter and the
//! compiled graph — the python side receives it via vocab-hash constants):
//!
//! ```text
//! 0                      mask token (only when maskToken is set)
//! base .. base+numOOV-1  OOV buckets (hash-distributed)
//! base+numOOV + rank     vocabulary labels, rank per stringOrderType
//! ```
//! with `base = 1` if a mask token is configured, else `0`.

use std::collections::HashMap;

use crate::dataframe::{Column, DataFrame, DType, ListColumn};
use crate::engine::{tree_aggregate, Accumulator, Dataset};
use crate::error::{KamaeError, Result};
use crate::export::{SpecBuilder, SpecDType};
use crate::ops::hash;
use crate::pipeline::{Estimator, Transformer};
use crate::util::json::Json;
use crate::optim::names as op_names;

/// Vocabulary ordering (Kamae `stringOrderType`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StringOrder {
    FrequencyDesc,
    FrequencyAsc,
    AlphabeticalAsc,
    AlphabeticalDesc,
}

impl StringOrder {
    pub fn name(&self) -> &'static str {
        match self {
            StringOrder::FrequencyDesc => "frequencyDesc",
            StringOrder::FrequencyAsc => "frequencyAsc",
            StringOrder::AlphabeticalAsc => "alphabeticalAsc",
            StringOrder::AlphabeticalDesc => "alphabeticalDesc",
        }
    }

    pub fn parse(s: &str) -> Result<StringOrder> {
        Ok(match s {
            "frequencyDesc" => StringOrder::FrequencyDesc,
            "frequencyAsc" => StringOrder::FrequencyAsc,
            "alphabeticalAsc" => StringOrder::AlphabeticalAsc,
            "alphabeticalDesc" => StringOrder::AlphabeticalDesc,
            other => {
                return Err(KamaeError::InvalidConfig(format!("unknown stringOrderType: {other}")))
            }
        })
    }
}

/// Unfitted string indexer. `fit` builds the vocabulary over the input
/// column(s) with a distributed count aggregation.
#[derive(Debug, Clone)]
pub struct StringIndexEstimator {
    pub input_cols: Vec<String>,
    pub output_cols: Vec<String>,
    pub layer_name: String,
    pub order: StringOrder,
    pub num_oov: usize,
    pub mask_token: Option<String>,
    /// Cap the vocabulary to the top-N labels (by the configured order).
    pub max_vocab_size: Option<usize>,
    /// Cast inputs to string before indexing (`inputDtype="string"`).
    pub cast_to_string: bool,
}

impl StringIndexEstimator {
    pub fn new(input: &str, output: &str) -> Self {
        StringIndexEstimator {
            input_cols: vec![input.to_string()],
            output_cols: vec![output.to_string()],
            layer_name: format!("{output}_layer"),
            order: StringOrder::FrequencyDesc,
            num_oov: 1,
            mask_token: None,
            max_vocab_size: None,
            cast_to_string: false,
        }
    }

    /// Shared-vocabulary indexer over multiple columns (Kamae's
    /// `SharedStringIndexEstimator`).
    pub fn shared(inputs: &[&str], outputs: &[&str]) -> Self {
        StringIndexEstimator {
            input_cols: inputs.iter().map(|s| s.to_string()).collect(),
            output_cols: outputs.iter().map(|s| s.to_string()).collect(),
            layer_name: format!("{}_shared_layer", outputs.first().copied().unwrap_or("idx")),
            order: StringOrder::FrequencyDesc,
            num_oov: 1,
            mask_token: None,
            max_vocab_size: None,
            cast_to_string: false,
        }
    }

    pub fn order(mut self, order: StringOrder) -> Self {
        self.order = order;
        self
    }

    pub fn num_oov(mut self, n: usize) -> Self {
        self.num_oov = n;
        self
    }

    pub fn mask_token(mut self, token: &str) -> Self {
        self.mask_token = Some(token.to_string());
        self
    }

    pub fn max_vocab_size(mut self, n: usize) -> Self {
        self.max_vocab_size = Some(n);
        self
    }

    pub fn layer_name(mut self, name: &str) -> Self {
        self.layer_name = name.to_string();
        self
    }

    pub fn cast_to_string(mut self) -> Self {
        self.cast_to_string = true;
        self
    }

    fn params_json(&self) -> Json {
        let mut j = Json::object();
        j.set(
            "inputCols",
            Json::Array(self.input_cols.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j.set(
            "outputCols",
            Json::Array(self.output_cols.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j.set("layerName", self.layer_name.clone());
        j.set("stringOrderType", self.order.name());
        j.set("numOOVIndices", self.num_oov);
        if let Some(m) = &self.mask_token {
            j.set("maskToken", m.clone());
        }
        if let Some(n) = self.max_vocab_size {
            j.set("maxVocabSize", n);
        }
        j.set("castToString", self.cast_to_string);
        j
    }
}

/// Count accumulator for the fit.
struct CountAcc {
    counts: HashMap<String, u64>,
    inputs: Vec<String>,
    cast: bool,
}

impl Accumulator for CountAcc {
    fn add_partition(&mut self, df: &DataFrame) -> Result<()> {
        for name in &self.inputs.clone() {
            let col = df.column(name)?;
            let col = if self.cast && !matches!(col.dtype(), DType::Str | DType::List(_)) {
                crate::ops::cast::cast(col, &DType::Str)?
            } else {
                col.clone()
            };
            match &col {
                Column::Str(v, nulls) => {
                    for (i, s) in v.iter().enumerate() {
                        if nulls.as_ref().map(|n| n[i]).unwrap_or(false) {
                            continue;
                        }
                        *self.counts.entry(s.clone()).or_insert(0) += 1;
                    }
                }
                Column::ListStr(l) => {
                    for s in &l.values {
                        *self.counts.entry(s.clone()).or_insert(0) += 1;
                    }
                }
                other => {
                    return Err(KamaeError::TypeMismatch {
                        expected: "string".into(),
                        found: other.dtype().name(),
                        context: format!("StringIndexEstimator fit on {name}"),
                    })
                }
            }
        }
        Ok(())
    }

    fn merge(&mut self, other: Self) -> Result<()> {
        for (k, v) in other.counts {
            *self.counts.entry(k).or_insert(0) += v;
        }
        Ok(())
    }
}

impl Estimator for StringIndexEstimator {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringIndexEstimator"
    }

    fn fit(&self, data: &Dataset) -> Result<Box<dyn Transformer>> {
        if self.input_cols.len() != self.output_cols.len() {
            return Err(KamaeError::InvalidConfig(
                "StringIndexEstimator: inputCols/outputCols length mismatch".into(),
            ));
        }
        if self.num_oov == 0 {
            return Err(KamaeError::InvalidConfig(
                "StringIndexEstimator: numOOVIndices must be >= 1".into(),
            ));
        }
        let acc = tree_aggregate(data, || CountAcc {
            counts: HashMap::new(),
            inputs: self.input_cols.clone(),
            cast: self.cast_to_string,
        })?;
        let mut items: Vec<(String, u64)> = acc
            .counts
            .into_iter()
            .filter(|(s, _)| Some(s) != self.mask_token.as_ref())
            .collect();
        match self.order {
            StringOrder::FrequencyDesc => {
                items.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)))
            }
            StringOrder::FrequencyAsc => {
                items.sort_by(|a, b| a.1.cmp(&b.1).then_with(|| a.0.cmp(&b.0)))
            }
            StringOrder::AlphabeticalAsc => items.sort_by(|a, b| a.0.cmp(&b.0)),
            StringOrder::AlphabeticalDesc => items.sort_by(|a, b| b.0.cmp(&a.0)),
        }
        if let Some(n) = self.max_vocab_size {
            items.truncate(n);
        }
        let labels: Vec<String> = items.into_iter().map(|(s, _)| s).collect();
        Ok(Box::new(StringIndexModel {
            input_cols: self.input_cols.clone(),
            output_cols: self.output_cols.clone(),
            layer_name: self.layer_name.clone(),
            num_oov: self.num_oov,
            mask_token: self.mask_token.clone(),
            cast_to_string: self.cast_to_string,
            lookup: labels.iter().cloned().zip(0u32..).collect(),
            labels,
        }))
    }

    fn save(&self) -> Json {
        self.params_json()
    }
}

/// Fitted string indexer.
#[derive(Debug, Clone)]
pub struct StringIndexModel {
    pub input_cols: Vec<String>,
    pub output_cols: Vec<String>,
    pub layer_name: String,
    pub num_oov: usize,
    pub mask_token: Option<String>,
    pub cast_to_string: bool,
    pub labels: Vec<String>,
    lookup: HashMap<String, u32>,
}

impl StringIndexModel {
    /// Base offset (1 when a mask token occupies index 0).
    fn base(&self) -> i64 {
        i64::from(self.mask_token.is_some())
    }

    /// Index for one token — THE semantics shared with the compiled graph.
    pub fn index_of(&self, s: &str) -> i64 {
        if Some(s) == self.mask_token.as_deref() {
            return 0;
        }
        match self.lookup.get(s) {
            Some(&rank) => self.base() + self.num_oov as i64 + rank as i64,
            None => self.base() + hash::bucket(hash::fnv1a64(s), 0, self.num_oov as i64),
        }
    }

    /// Total index space size (for embedding tables / one-hot depth).
    pub fn cardinality(&self) -> usize {
        self.base() as usize + self.num_oov + self.labels.len()
    }

    fn index_column(&self, col: &Column) -> Result<Column> {
        let col = if self.cast_to_string && !matches!(col.dtype(), DType::Str | DType::List(_)) {
            crate::ops::cast::cast(col, &DType::Str)?
        } else {
            col.clone()
        };
        match &col {
            Column::Str(v, nulls) => Ok(Column::I64(
                v.iter().map(|s| self.index_of(s)).collect(),
                nulls.clone(),
            )),
            Column::ListStr(l) => Ok(Column::ListI64(ListColumn {
                values: l.values.iter().map(|s| self.index_of(s)).collect(),
                offsets: l.offsets.clone(),
            })),
            other => Err(KamaeError::TypeMismatch {
                expected: "string".into(),
                found: other.dtype().name(),
                context: "StringIndexModel".into(),
            }),
        }
    }

    /// Export constants: (sorted label hashes, rank per sorted hash).
    /// Verifies hash-injectivity over the vocabulary (collision would be a
    /// silent semantic change — refuse to export instead).
    pub fn sorted_hash_ranks(&self) -> Result<(Vec<i64>, Vec<i64>)> {
        let mut pairs: Vec<(i64, i64)> = self
            .labels
            .iter()
            .enumerate()
            .map(|(rank, s)| (hash::fnv1a64(s), rank as i64))
            .collect();
        pairs.sort();
        for w in pairs.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(KamaeError::Unsupported(format!(
                    "vocabulary hash collision between labels ranked {} and {}",
                    w[0].1, w[1].1
                )));
            }
        }
        Ok(pairs.into_iter().unzip())
    }
}

impl Transformer for StringIndexModel {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        "StringIndexModel"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        for (input, output) in self.input_cols.iter().zip(self.output_cols.iter()) {
            let col = df.column(input)?.clone();
            let out = self.index_column(&col)?;
            df.set_column(output.clone(), out)?;
        }
        Ok(())
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let (hashes, ranks) = self.sorted_hash_ranks()?;
        for (input, output) in self.input_cols.iter().zip(self.output_cols.iter()) {
            let width = b.width(input)?;
            let href = crate::transformers::indexing_hash_ref(b, input, width)?;
            let mut attrs = Json::object();
            attrs.set("vocab_hashes", Json::Array(hashes.iter().map(|&h| Json::Int(h)).collect()));
            attrs.set("vocab_ranks", Json::Array(ranks.iter().map(|&r| Json::Int(r)).collect()));
            attrs.set("num_oov", self.num_oov);
            attrs.set("base", self.base());
            match &self.mask_token {
                Some(m) => attrs.set("mask_hash", hash::fnv1a64(m)),
                None => attrs.set("mask_hash", Json::Null),
            };
            b.graph_node(op_names::VOCAB_LOOKUP, &[&href], attrs, output, SpecDType::I64, width)?;
        }
        Ok(())
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set(
            "inputCols",
            Json::Array(self.input_cols.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j.set(
            "outputCols",
            Json::Array(self.output_cols.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j.set("layerName", self.layer_name.clone());
        j.set("numOOVIndices", self.num_oov);
        if let Some(m) = &self.mask_token {
            j.set("maskToken", m.clone());
        }
        j.set("castToString", self.cast_to_string);
        j.set(
            "labels",
            Json::Array(self.labels.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j
    }
}

pub(crate) fn model_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    let strings = |key: &str| -> Result<Vec<String>> {
        j.req_array(key)?
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| KamaeError::Serde(format!("{key} entry")))
            })
            .collect()
    };
    let labels = strings("labels")?;
    Ok(Box::new(StringIndexModel {
        input_cols: strings("inputCols")?,
        output_cols: strings("outputCols")?,
        layer_name: j.req_str("layerName")?.to_string(),
        num_oov: j.req_i64("numOOVIndices")? as usize,
        mask_token: j.opt_str("maskToken").map(str::to_string),
        cast_to_string: j.opt_bool("castToString").unwrap_or(false),
        lookup: labels.iter().cloned().zip(0u32..).collect(),
        labels,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data() -> Dataset {
        let df = DataFrame::new(vec![(
            "genre".into(),
            Column::from_str(vec!["Drama", "Comedy", "Drama", "Action", "Drama", "Comedy"]),
        )])
        .unwrap();
        Dataset::from_dataframe(df, 3)
    }

    #[test]
    fn frequency_desc_layout() {
        let model = StringIndexEstimator::new("genre", "g")
            .num_oov(1)
            .fit(&data())
            .unwrap();
        let mut df = data().collect().unwrap();
        model.transform(&mut df).unwrap();
        let idx = df.column("g").unwrap().as_i64().unwrap();
        // Drama(3) -> rank0 -> 1+0=1; Comedy(2) -> 2; Action(1) -> 3; oov bucket = 0
        assert_eq!(idx, &[1, 2, 1, 3, 1, 2]);
    }

    #[test]
    fn mask_and_oov() {
        let train = DataFrame::new(vec![(
            "g".into(),
            Column::from_str(vec!["a", "b", "PAD"]),
        )])
        .unwrap();
        let est = StringIndexEstimator::new("g", "gi").mask_token("PAD").num_oov(2);
        let model = est.fit(&Dataset::from_dataframe(train, 1)).unwrap();
        // transform data containing a token NOT seen at fit time
        let mut out = DataFrame::new(vec![(
            "g".into(),
            Column::from_str(vec!["a", "b", "PAD", "zzz_unseen"]),
        )])
        .unwrap();
        model.transform(&mut out).unwrap();
        let idx = out.column("gi").unwrap().as_i64().unwrap();
        assert_eq!(idx[2], 0); // mask -> 0
        // a/b have count 1 each -> alpha tiebreak: a rank0 -> 1+2+0=3, b -> 4
        assert_eq!(idx[0], 3);
        assert_eq!(idx[1], 4);
        // unseen -> oov bucket in [1, 2]
        assert!((1..=2).contains(&idx[3]));
    }

    #[test]
    fn list_column_indexing() {
        // Listing 1: string indexing applied element-wise to genre lists
        let df = DataFrame::new(vec![(
            "genres".into(),
            Column::from_str_rows(vec![
                vec!["Action", "Comedy", "PAD"],
                vec!["Comedy", "PAD", "PAD"],
            ]),
        )])
        .unwrap();
        let model = StringIndexEstimator::new("genres", "gi")
            .mask_token("PAD")
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        let l = out.column("gi").unwrap().as_list_i64().unwrap();
        // Comedy(2) rank0 -> 2, Action(1) rank1 -> 3, PAD -> 0
        assert_eq!(l.row(0), &[3, 2, 0]);
        assert_eq!(l.row(1), &[2, 0, 0]);
    }

    #[test]
    fn shared_vocab() {
        let df = DataFrame::new(vec![
            ("a".into(), Column::from_str(vec!["x", "y"])),
            ("b".into(), Column::from_str(vec!["y", "z"])),
        ])
        .unwrap();
        let model = StringIndexEstimator::shared(&["a", "b"], &["ai", "bi"])
            .order(StringOrder::AlphabeticalAsc)
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        // shared vocab: x,y,z -> 1,2,3 in both columns
        assert_eq!(out.column("ai").unwrap().as_i64().unwrap(), &[1, 2]);
        assert_eq!(out.column("bi").unwrap().as_i64().unwrap(), &[2, 3]);
    }

    #[test]
    fn orders_and_cap() {
        let est = StringIndexEstimator::new("genre", "g")
            .order(StringOrder::FrequencyAsc)
            .max_vocab_size(2);
        let model = est.fit(&data()).unwrap();
        let mut df = data().collect().unwrap();
        model.transform(&mut df).unwrap();
        let idx = df.column("g").unwrap().as_i64().unwrap();
        // freqAsc: Action(1) rank0 -> 1, Comedy(2) rank1 -> 2; Drama cut off -> oov 0
        assert_eq!(idx[3], 1);
        assert_eq!(idx[1], 2);
        assert_eq!(idx[0], 0);
    }

    #[test]
    fn model_save_load_roundtrip() {
        let model = StringIndexEstimator::new("genre", "g").fit(&data()).unwrap();
        let j = crate::pipeline::with_type(model.save(), model.type_name());
        let loaded = crate::transformers::load(&j).unwrap();
        let mut a = data().collect().unwrap();
        let mut b = a.clone();
        model.transform(&mut a).unwrap();
        loaded.transform(&mut b).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn numeric_input_with_cast() {
        let df = DataFrame::new(vec![("id".into(), Column::from_i64(vec![7, 8, 7]))]).unwrap();
        let model = StringIndexEstimator::new("id", "idx")
            .cast_to_string()
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        let idx = out.column("idx").unwrap().as_i64().unwrap();
        assert_eq!(idx[0], idx[2]);
        assert_ne!(idx[0], idx[1]);
    }
}
