//! Imputation estimator (mean / median / mode fill for missing values).
//!
//! "Missing" means: null mask set, NaN (floats), or equal to the
//! configured `maskValue` sentinel. The fitted fill value exports into
//! the compiled graph as an `impute` node (NaN/sentinel test + select);
//! medians are computed from a bounded per-partition reservoir sample
//! (exact for datasets under the reservoir size — documented substitution
//! for a full distributed quantile sketch).

use crate::dataframe::{Column, DataFrame};
use crate::engine::{tree_aggregate, Accumulator, Dataset};
use crate::error::{KamaeError, Result};
use crate::export::{SpecBuilder, SpecDType};
use crate::pipeline::{Estimator, Transformer};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::optim::names as op_names;

/// Fill strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImputeStrategy {
    Mean,
    Median,
    /// Most frequent value.
    Mode,
}

impl ImputeStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            ImputeStrategy::Mean => "mean",
            ImputeStrategy::Median => "median",
            ImputeStrategy::Mode => "mode",
        }
    }

    pub fn parse(s: &str) -> Result<ImputeStrategy> {
        Ok(match s {
            "mean" => ImputeStrategy::Mean,
            "median" => ImputeStrategy::Median,
            "mode" => ImputeStrategy::Mode,
            other => {
                return Err(KamaeError::InvalidConfig(format!("unknown impute strategy: {other}")))
            }
        })
    }
}

const RESERVOIR: usize = 100_000;

struct ImputeAcc {
    input: String,
    mask_value: Option<f64>,
    count: u64,
    sum: f64,
    /// Reservoir sample for the median.
    sample: Vec<f64>,
    seen: u64,
    rng: Rng,
    /// Value counts for the mode (bit-keyed).
    counts: std::collections::HashMap<u64, u64>,
}

impl ImputeAcc {
    fn is_missing(&self, col: &Column, i: usize, x: f64) -> bool {
        col.is_null(i) || x.is_nan() || Some(x) == self.mask_value
    }
}

impl Accumulator for ImputeAcc {
    fn add_partition(&mut self, df: &DataFrame) -> Result<()> {
        let col = df.column(&self.input)?;
        let v = crate::ops::cast::to_f64_vec(col)?;
        for (i, &x) in v.iter().enumerate() {
            if self.is_missing(col, i, x) {
                continue;
            }
            self.count += 1;
            self.sum += x;
            *self.counts.entry(x.to_bits()).or_insert(0) += 1;
            self.seen += 1;
            if self.sample.len() < RESERVOIR {
                self.sample.push(x);
            } else {
                let j = self.rng.below(self.seen) as usize;
                if j < RESERVOIR {
                    self.sample[j] = x;
                }
            }
        }
        Ok(())
    }

    fn merge(&mut self, other: Self) -> Result<()> {
        self.count += other.count;
        self.sum += other.sum;
        for (k, v) in other.counts {
            *self.counts.entry(k).or_insert(0) += v;
        }
        // merge reservoirs (simple concatenate-and-trim; keeps exactness
        // below the cap and a fair-enough sample above it)
        self.seen += other.seen;
        self.sample.extend(other.sample);
        if self.sample.len() > RESERVOIR {
            self.rng.shuffle(&mut self.sample);
            self.sample.truncate(RESERVOIR);
        }
        Ok(())
    }
}

/// Unfitted imputer.
#[derive(Debug, Clone)]
pub struct ImputeEstimator {
    pub input_col: String,
    pub output_col: String,
    pub layer_name: String,
    pub strategy: ImputeStrategy,
    /// Sentinel treated as missing in addition to null/NaN.
    pub mask_value: Option<f64>,
}

impl ImputeEstimator {
    pub fn new(input: &str, output: &str, strategy: ImputeStrategy) -> Self {
        ImputeEstimator {
            input_col: input.to_string(),
            output_col: output.to_string(),
            layer_name: format!("{output}_layer"),
            strategy,
            mask_value: None,
        }
    }

    pub fn mask_value(mut self, v: f64) -> Self {
        self.mask_value = Some(v);
        self
    }

    pub fn layer_name(mut self, name: &str) -> Self {
        self.layer_name = name.to_string();
        self
    }
}

impl Estimator for ImputeEstimator {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        "ImputeEstimator"
    }

    fn fit(&self, data: &Dataset) -> Result<Box<dyn Transformer>> {
        let acc = tree_aggregate(data, || ImputeAcc {
            input: self.input_col.clone(),
            mask_value: self.mask_value,
            count: 0,
            sum: 0.0,
            sample: Vec::new(),
            seen: 0,
            rng: Rng::new(0xC0FFEE),
            counts: std::collections::HashMap::new(),
        })?;
        if acc.count == 0 {
            return Err(KamaeError::InvalidConfig(
                "ImputeEstimator: no non-missing rows to fit on".into(),
            ));
        }
        let fill = match self.strategy {
            ImputeStrategy::Mean => acc.sum / acc.count as f64,
            ImputeStrategy::Median => {
                let mut s = acc.sample;
                s.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let n = s.len();
                if n % 2 == 1 {
                    s[n / 2]
                } else {
                    (s[n / 2 - 1] + s[n / 2]) / 2.0
                }
            }
            ImputeStrategy::Mode => {
                let (&bits, _) = acc
                    .counts
                    .iter()
                    .max_by_key(|(bits, &c)| (c, std::cmp::Reverse(*bits)))
                    .expect("count > 0");
                f64::from_bits(bits)
            }
        };
        Ok(Box::new(ImputeModel {
            input_col: self.input_col.clone(),
            output_col: self.output_col.clone(),
            layer_name: self.layer_name.clone(),
            fill,
            mask_value: self.mask_value,
        }))
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("inputCol", self.input_col.clone());
        j.set("outputCol", self.output_col.clone());
        j.set("layerName", self.layer_name.clone());
        j.set("strategy", self.strategy.name());
        if let Some(m) = self.mask_value {
            j.set("maskValue", m);
        }
        j
    }
}

/// Fitted imputer: replaces null/NaN/sentinel with the learned fill.
#[derive(Debug, Clone)]
pub struct ImputeModel {
    pub input_col: String,
    pub output_col: String,
    pub layer_name: String,
    pub fill: f64,
    pub mask_value: Option<f64>,
}

impl Transformer for ImputeModel {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        "ImputeModel"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let col = df.column(&self.input_col)?;
        let v = crate::ops::cast::to_f64_vec(col)?;
        let data: Vec<f64> = v
            .iter()
            .enumerate()
            .map(|(i, &x)| {
                if col.is_null(i) || x.is_nan() || Some(x) == self.mask_value {
                    self.fill
                } else {
                    x
                }
            })
            .collect();
        // imputation resolves all missingness: no null mask on the output
        df.set_column(self.output_col.clone(), Column::from_f64(data))
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let width = b.width(&self.input_col)?;
        let mut attrs = Json::object();
        attrs.set("fill", self.fill);
        match self.mask_value {
            Some(m) => attrs.set("mask_value", m),
            None => attrs.set("mask_value", Json::Null),
        };
        b.graph_node(op_names::IMPUTE, &[&self.input_col], attrs, &self.output_col, SpecDType::F32, width)?;
        Ok(())
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("inputCol", self.input_col.clone());
        j.set("outputCol", self.output_col.clone());
        j.set("layerName", self.layer_name.clone());
        j.set("fill", self.fill);
        if let Some(m) = self.mask_value {
            j.set("maskValue", m);
        }
        j
    }
}

pub(crate) fn model_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    Ok(Box::new(ImputeModel {
        input_col: j.req_str("inputCol")?.to_string(),
        output_col: j.req_str("outputCol")?.to_string(),
        layer_name: j.req_str("layerName")?.to_string(),
        fill: j.req_f64("fill")?,
        mask_value: j.opt_f64("maskValue"),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data() -> Dataset {
        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64_opt(vec![
                Some(1.0),
                None,
                Some(3.0),
                Some(3.0),
                Some(f64::NAN),
                Some(10.0),
            ]),
        )])
        .unwrap();
        Dataset::from_dataframe(df, 2)
    }

    #[test]
    fn mean_impute() {
        let model = ImputeEstimator::new("x", "xi", ImputeStrategy::Mean)
            .fit(&data())
            .unwrap();
        let mut df = data().collect().unwrap();
        model.transform(&mut df).unwrap();
        let v = df.column("xi").unwrap().as_f64().unwrap();
        let mean = (1.0 + 3.0 + 3.0 + 10.0) / 4.0;
        assert_eq!(v[1], mean);
        assert_eq!(v[4], mean);
        assert_eq!(v[0], 1.0);
        assert_eq!(df.column("xi").unwrap().null_count(), 0);
    }

    #[test]
    fn median_and_mode() {
        let model = ImputeEstimator::new("x", "xm", ImputeStrategy::Median)
            .fit(&data())
            .unwrap();
        let j = model.save();
        assert_eq!(j.req_f64("fill").unwrap(), 3.0);
        let model = ImputeEstimator::new("x", "xo", ImputeStrategy::Mode)
            .fit(&data())
            .unwrap();
        assert_eq!(model.save().req_f64("fill").unwrap(), 3.0);
    }

    #[test]
    fn mask_value_sentinel() {
        let df = DataFrame::new(vec![(
            "x".into(),
            Column::from_f64(vec![-1.0, 5.0, 7.0]),
        )])
        .unwrap();
        let model = ImputeEstimator::new("x", "xi", ImputeStrategy::Mean)
            .mask_value(-1.0)
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        assert_eq!(out.column("xi").unwrap().as_f64().unwrap(), &[6.0, 5.0, 7.0]);
    }

    #[test]
    fn save_load() {
        let model = ImputeEstimator::new("x", "xi", ImputeStrategy::Mean)
            .fit(&data())
            .unwrap();
        let j = crate::pipeline::with_type(model.save(), model.type_name());
        let loaded = crate::transformers::load(&j).unwrap();
        let mut a = data().collect().unwrap();
        let mut b = a.clone();
        model.transform(&mut a).unwrap();
        loaded.transform(&mut b).unwrap();
        // compare imputed outputs only (the raw input contains NaN, and
        // NaN != NaN under PartialEq)
        assert_eq!(a.column("xi").unwrap(), b.column("xi").unwrap());
    }
}
