//! Quantile binning estimator — one of the paper's named future-work
//! items ("commonly used preprocessing steps (e.g. tokenization,
//! **quantile binning**)"), implemented here as an extension.
//!
//! Fits `numBins` equi-depth split points from a bounded reservoir sample
//! (same substitution note as the median imputer) and produces a plain
//! [`crate::transformers::BucketizeTransformer`] — so the export path and
//! the compiled graph reuse the existing `bucketize` op.

use crate::dataframe::DataFrame;
use crate::engine::{tree_aggregate, Accumulator, Dataset};
use crate::error::{KamaeError, Result};
use crate::pipeline::{Estimator, Transformer};
use crate::util::json::Json;
use crate::util::rng::Rng;

const RESERVOIR: usize = 100_000;

struct SampleAcc {
    input: String,
    sample: Vec<f64>,
    seen: u64,
    rng: Rng,
}

impl Accumulator for SampleAcc {
    fn add_partition(&mut self, df: &DataFrame) -> Result<()> {
        let col = df.column(&self.input)?;
        let v = crate::ops::cast::to_f64_vec(col)?;
        for (i, &x) in v.iter().enumerate() {
            if col.is_null(i) || x.is_nan() {
                continue;
            }
            self.seen += 1;
            if self.sample.len() < RESERVOIR {
                self.sample.push(x);
            } else {
                let j = self.rng.below(self.seen) as usize;
                if j < RESERVOIR {
                    self.sample[j] = x;
                }
            }
        }
        Ok(())
    }

    fn merge(&mut self, other: Self) -> Result<()> {
        self.seen += other.seen;
        self.sample.extend(other.sample);
        if self.sample.len() > RESERVOIR {
            self.rng.shuffle(&mut self.sample);
            self.sample.truncate(RESERVOIR);
        }
        Ok(())
    }
}

/// Unfitted quantile binner.
#[derive(Debug, Clone)]
pub struct QuantileBinEstimator {
    pub input_col: String,
    pub output_col: String,
    pub layer_name: String,
    pub num_bins: usize,
}

impl QuantileBinEstimator {
    pub fn new(input: &str, output: &str, num_bins: usize) -> Self {
        QuantileBinEstimator {
            input_col: input.to_string(),
            output_col: output.to_string(),
            layer_name: format!("{output}_layer"),
            num_bins,
        }
    }

    pub fn layer_name(mut self, name: &str) -> Self {
        self.layer_name = name.to_string();
        self
    }
}

impl Estimator for QuantileBinEstimator {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        "QuantileBinEstimator"
    }

    fn fit(&self, data: &Dataset) -> Result<Box<dyn Transformer>> {
        if self.num_bins < 2 {
            return Err(KamaeError::InvalidConfig(
                "QuantileBinEstimator: numBins must be >= 2".into(),
            ));
        }
        let mut acc = tree_aggregate(data, || SampleAcc {
            input: self.input_col.clone(),
            sample: Vec::new(),
            seen: 0,
            rng: Rng::new(0xB1A5),
        })?;
        if acc.sample.is_empty() {
            return Err(KamaeError::InvalidConfig(
                "QuantileBinEstimator: no non-missing rows to fit on".into(),
            ));
        }
        acc.sample.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = acc.sample.len();
        if acc.sample[0] == acc.sample[n - 1] {
            return Err(KamaeError::InvalidConfig(
                "QuantileBinEstimator: data has a single distinct value".into(),
            ));
        }
        let mut splits = Vec::with_capacity(self.num_bins - 1);
        for k in 1..self.num_bins {
            let q = k as f64 / self.num_bins as f64;
            let idx = ((n as f64) * q) as usize;
            let s = acc.sample[idx.min(n - 1)];
            // keep splits strictly increasing (skewed data can repeat)
            if splits.last().map_or(true, |&last| s > last) {
                splits.push(s);
            }
        }
        if splits.is_empty() {
            return Err(KamaeError::InvalidConfig(
                "QuantileBinEstimator: data has a single distinct value".into(),
            ));
        }
        Ok(Box::new(
            crate::transformers::BucketizeTransformer::new(
                &self.input_col,
                &self.output_col,
                splits,
            )
            .layer_name(&self.layer_name),
        ))
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("inputCol", self.input_col.clone());
        j.set("outputCol", self.output_col.clone());
        j.set("layerName", self.layer_name.clone());
        j.set("numBins", self.num_bins);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataframe::Column;

    #[test]
    fn equi_depth_bins() {
        let values: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let df = DataFrame::new(vec![("x".into(), Column::from_f64(values))]).unwrap();
        let model = QuantileBinEstimator::new("x", "b", 4)
            .fit(&Dataset::from_dataframe(df.clone(), 4))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        let b = out.column("b").unwrap().as_i64().unwrap();
        // roughly 250 rows per bin
        for bin in 0..4 {
            let count = b.iter().filter(|&&x| x == bin).count();
            assert!((200..=300).contains(&count), "bin {bin}: {count}");
        }
    }

    #[test]
    fn degenerate_data_errors() {
        let df = DataFrame::new(vec![("x".into(), Column::from_f64(vec![7.0; 50]))]).unwrap();
        let r = QuantileBinEstimator::new("x", "b", 4).fit(&Dataset::from_dataframe(df, 1));
        assert!(r.is_err());
    }

    #[test]
    fn skewed_data_dedups_splits() {
        let mut values = vec![0.0; 900];
        values.extend((1..=100).map(|i| i as f64));
        let df = DataFrame::new(vec![("x".into(), Column::from_f64(values))]).unwrap();
        let model = QuantileBinEstimator::new("x", "b", 10)
            .fit(&Dataset::from_dataframe(df.clone(), 2))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        // must not panic despite 90% duplicate split candidates; all bins
        // stay within range (boundary convention: first split > x)
        let b = out.column("b").unwrap().as_i64().unwrap();
        assert!(b.iter().all(|&x| (0..=10).contains(&x)));
        // zeros all land in the same (low) bin
        assert!(b[..900].iter().all(|&x| x == b[0]));
    }
}
