//! The estimator library — Kamae's fitted preprocessing stages
//! (string-/shared-/one-hot indexing, standard & min-max scaling,
//! imputation, quantile binning).
//!
//! Estimators fit via distributed tree aggregation
//! ([`crate::engine::tree_aggregate`]) and produce fitted models that
//! implement [`crate::pipeline::Transformer`], so a fitted pipeline is
//! transformers end-to-end and exports uniformly.

mod impute;
mod one_hot;
mod quantile;
mod scale;
mod string_index;

pub use impute::{ImputeEstimator, ImputeModel, ImputeStrategy};
pub use one_hot::{OneHotEncodeEstimator, OneHotModel};
pub use quantile::QuantileBinEstimator;
pub use scale::{MinMaxScaleEstimator, ScaleModel, StandardScaleEstimator};
pub use string_index::{StringIndexEstimator, StringIndexModel, StringOrder};

use crate::error::Result;
use crate::pipeline::Transformer;
use crate::util::json::Json;

// Fitted-model loaders used by the transformer registry.
pub(crate) fn string_index_model_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    string_index::model_from_json(j)
}

pub(crate) fn one_hot_model_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    one_hot::model_from_json(j)
}

pub(crate) fn standard_scale_model_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    scale::scale_model_from_json(j, "StandardScaleModel")
}

pub(crate) fn min_max_scale_model_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    scale::scale_model_from_json(j, "MinMaxScaleModel")
}

pub(crate) fn impute_model_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    impute::model_from_json(j)
}
