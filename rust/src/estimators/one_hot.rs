//! One-hot encoding estimator (Listing 1's `OneHotEncodeEstimator`).
//!
//! Fits a vocabulary like the string indexer (no mask token — one-hot
//! features are scalar categoricals), then encodes to a fixed-width 0/1
//! vector. With `dropUnseen=true` the OOV slots are dropped and unseen
//! values encode as the all-zeros vector.

use crate::dataframe::{Column, DataFrame, DType, ListColumn};
use crate::engine::Dataset;
use crate::error::{KamaeError, Result};
use crate::export::{SpecBuilder, SpecDType};
use crate::ops::hash;
use crate::pipeline::{Estimator, Transformer};
use crate::util::json::Json;
use crate::optim::names as op_names;

use super::string_index::{StringIndexEstimator, StringOrder};

/// Unfitted one-hot encoder.
#[derive(Debug, Clone)]
pub struct OneHotEncodeEstimator {
    pub input_col: String,
    pub output_col: String,
    pub layer_name: String,
    pub order: StringOrder,
    pub num_oov: usize,
    pub drop_unseen: bool,
    pub cast_to_string: bool,
}

impl OneHotEncodeEstimator {
    pub fn new(input: &str, output: &str) -> Self {
        OneHotEncodeEstimator {
            input_col: input.to_string(),
            output_col: output.to_string(),
            layer_name: format!("{output}_layer"),
            order: StringOrder::FrequencyDesc,
            num_oov: 1,
            drop_unseen: false,
            cast_to_string: false,
        }
    }

    pub fn order(mut self, order: StringOrder) -> Self {
        self.order = order;
        self
    }

    pub fn num_oov(mut self, n: usize) -> Self {
        self.num_oov = n;
        self
    }

    pub fn drop_unseen(mut self, drop: bool) -> Self {
        self.drop_unseen = drop;
        self
    }

    pub fn cast_to_string(mut self) -> Self {
        self.cast_to_string = true;
        self
    }

    pub fn layer_name(mut self, name: &str) -> Self {
        self.layer_name = name.to_string();
        self
    }
}

impl Estimator for OneHotEncodeEstimator {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        "OneHotEncodeEstimator"
    }

    fn fit(&self, data: &Dataset) -> Result<Box<dyn Transformer>> {
        let mut inner = StringIndexEstimator::new(&self.input_col, "__onehot_tmp")
            .order(self.order)
            .num_oov(self.num_oov)
            .layer_name(&self.layer_name);
        if self.cast_to_string {
            inner = inner.cast_to_string();
        }
        let fitted = inner.fit(data)?;
        let model = fitted
            .save()
            .req_array("labels")?
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| KamaeError::Serde("label".into()))
            })
            .collect::<Result<Vec<String>>>()?;
        Ok(Box::new(OneHotModel {
            input_col: self.input_col.clone(),
            output_col: self.output_col.clone(),
            layer_name: self.layer_name.clone(),
            num_oov: self.num_oov,
            drop_unseen: self.drop_unseen,
            cast_to_string: self.cast_to_string,
            lookup: model.iter().cloned().zip(0u32..).collect(),
            labels: model,
        }))
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("inputCol", self.input_col.clone());
        j.set("outputCol", self.output_col.clone());
        j.set("layerName", self.layer_name.clone());
        j.set("stringOrderType", self.order.name());
        j.set("numOOVIndices", self.num_oov);
        j.set("dropUnseen", self.drop_unseen);
        j.set("castToString", self.cast_to_string);
        j
    }
}

/// Fitted one-hot encoder.
#[derive(Debug, Clone)]
pub struct OneHotModel {
    pub input_col: String,
    pub output_col: String,
    pub layer_name: String,
    pub num_oov: usize,
    pub drop_unseen: bool,
    pub cast_to_string: bool,
    pub labels: Vec<String>,
    lookup: std::collections::HashMap<String, u32>,
}

impl OneHotModel {
    /// Output vector width.
    pub fn depth(&self) -> usize {
        if self.drop_unseen {
            self.labels.len()
        } else {
            self.num_oov + self.labels.len()
        }
    }

    /// Hot position for a token, or None for all-zeros (dropped unseen).
    fn hot(&self, s: &str) -> Option<usize> {
        match self.lookup.get(s) {
            Some(&rank) => Some(if self.drop_unseen {
                rank as usize
            } else {
                self.num_oov + rank as usize
            }),
            None => {
                if self.drop_unseen {
                    None
                } else {
                    Some(hash::bucket(hash::fnv1a64(s), 0, self.num_oov as i64) as usize)
                }
            }
        }
    }
}

impl Transformer for OneHotModel {
    fn layer_name(&self) -> &str {
        &self.layer_name
    }

    fn type_name(&self) -> &'static str {
        "OneHotModel"
    }

    fn transform(&self, df: &mut DataFrame) -> Result<()> {
        let col = df.column(&self.input_col)?;
        let col = if self.cast_to_string && !matches!(col.dtype(), DType::Str) {
            crate::ops::cast::cast(col, &DType::Str)?
        } else {
            col.clone()
        };
        let v = col.as_str()?;
        let depth = self.depth();
        let mut values = vec![0.0f64; v.len() * depth];
        for (i, s) in v.iter().enumerate() {
            if let Some(h) = self.hot(s) {
                values[i * depth + h] = 1.0;
            }
        }
        let offsets = (0..=v.len() as u32).map(|i| i * depth as u32).collect();
        df.set_column(
            self.output_col.clone(),
            Column::ListF64(ListColumn { values, offsets }),
        )
    }

    fn spec_nodes(&self, b: &mut SpecBuilder) -> Result<()> {
        let mut pairs: Vec<(i64, i64)> = self
            .labels
            .iter()
            .enumerate()
            .map(|(rank, s)| (hash::fnv1a64(s), rank as i64))
            .collect();
        pairs.sort();
        for w in pairs.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(KamaeError::Unsupported("one-hot vocabulary hash collision".into()));
            }
        }
        let (hashes, ranks): (Vec<i64>, Vec<i64>) = pairs.into_iter().unzip();
        let href = crate::transformers::indexing_hash_ref(b, &self.input_col, None)?;
        let mut attrs = Json::object();
        attrs.set("vocab_hashes", Json::Array(hashes.into_iter().map(Json::Int).collect()));
        attrs.set("vocab_ranks", Json::Array(ranks.into_iter().map(Json::Int).collect()));
        attrs.set("num_oov", self.num_oov);
        attrs.set("drop_unseen", self.drop_unseen);
        b.graph_node(
            op_names::ONE_HOT,
            &[&href],
            attrs,
            &self.output_col,
            SpecDType::F32,
            Some(self.depth()),
        )?;
        Ok(())
    }

    fn save(&self) -> Json {
        let mut j = Json::object();
        j.set("inputCol", self.input_col.clone());
        j.set("outputCol", self.output_col.clone());
        j.set("layerName", self.layer_name.clone());
        j.set("numOOVIndices", self.num_oov);
        j.set("dropUnseen", self.drop_unseen);
        j.set("castToString", self.cast_to_string);
        j.set(
            "labels",
            Json::Array(self.labels.iter().map(|s| Json::Str(s.clone())).collect()),
        );
        j
    }
}

pub(crate) fn model_from_json(j: &Json) -> Result<Box<dyn Transformer>> {
    let labels: Vec<String> = j
        .req_array("labels")?
        .iter()
        .map(|s| {
            s.as_str()
                .map(str::to_string)
                .ok_or_else(|| KamaeError::Serde("label".into()))
        })
        .collect::<Result<_>>()?;
    Ok(Box::new(OneHotModel {
        input_col: j.req_str("inputCol")?.to_string(),
        output_col: j.req_str("outputCol")?.to_string(),
        layer_name: j.req_str("layerName")?.to_string(),
        num_oov: j.req_i64("numOOVIndices")? as usize,
        drop_unseen: j.opt_bool("dropUnseen").unwrap_or(false),
        cast_to_string: j.opt_bool("castToString").unwrap_or(false),
        lookup: labels.iter().cloned().zip(0u32..).collect(),
        labels,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data() -> Dataset {
        let df = DataFrame::new(vec![(
            "occ".into(),
            Column::from_str(vec!["eng", "doc", "eng", "art"]),
        )])
        .unwrap();
        Dataset::from_dataframe(df, 2)
    }

    #[test]
    fn basic_encoding() {
        let model = OneHotEncodeEstimator::new("occ", "v").fit(&data()).unwrap();
        let mut df = DataFrame::new(vec![(
            "occ".into(),
            Column::from_str(vec!["eng", "art", "UNSEEN"]),
        )])
        .unwrap();
        model.transform(&mut df).unwrap();
        let l = df.column("v").unwrap().as_list_f64().unwrap();
        // depth = 1 oov + 3 labels = 4; eng rank0 -> slot 1
        assert_eq!(l.row(0), &[0.0, 1.0, 0.0, 0.0]);
        // art (count 1, tie alpha: art < doc) rank1 -> slot 2
        assert_eq!(l.row(1), &[0.0, 0.0, 1.0, 0.0]);
        // unseen -> oov slot 0
        assert_eq!(l.row(2), &[1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn drop_unseen_zeros() {
        let model = OneHotEncodeEstimator::new("occ", "v")
            .drop_unseen(true)
            .fit(&data())
            .unwrap();
        let mut df = DataFrame::new(vec![(
            "occ".into(),
            Column::from_str(vec!["eng", "UNSEEN"]),
        )])
        .unwrap();
        model.transform(&mut df).unwrap();
        let l = df.column("v").unwrap().as_list_f64().unwrap();
        assert_eq!(l.row(0), &[1.0, 0.0, 0.0]); // depth 3, eng hot at 0
        assert_eq!(l.row(1), &[0.0, 0.0, 0.0]); // all zeros
    }

    #[test]
    fn int_input_with_cast() {
        // Listing 1: Occupation is int32 with inputDtype="string"
        let df = DataFrame::new(vec![("occ".into(), Column::from_i32(vec![1, 2, 1]))]).unwrap();
        let model = OneHotEncodeEstimator::new("occ", "v")
            .cast_to_string()
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let mut out = df;
        model.transform(&mut out).unwrap();
        let l = out.column("v").unwrap().as_list_f64().unwrap();
        assert_eq!(l.row(0), l.row(2));
        assert_ne!(l.row(0), l.row(1));
    }

    #[test]
    fn save_load() {
        let model = OneHotEncodeEstimator::new("occ", "v").fit(&data()).unwrap();
        let j = crate::pipeline::with_type(model.save(), model.type_name());
        let loaded = crate::transformers::load(&j).unwrap();
        let mut a = data().collect().unwrap();
        let mut b = a.clone();
        model.transform(&mut a).unwrap();
        loaded.transform(&mut b).unwrap();
        assert_eq!(a, b);
    }
}
