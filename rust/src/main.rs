//! `kamae` — CLI for the Kamae-RS engine.
//!
//! Subcommands:
//!   gen-data         generate synthetic datasets (movielens | ltr)
//!   fit              fit a catalog pipeline on synthetic data, save model+spec
//!   export-examples  fit all example pipelines and write GraphSpec JSONs
//!                    into artifacts/specs/ (the Rust half of `make artifacts`)
//!   transform        run a saved PipelineModel over a JSONL file
//!   optimize         run the GraphSpec optimizer over a spec JSON and
//!                    print the per-pass node-count report
//!   serve-bench      load compiled artifacts and run the open-loop
//!                    Poisson serving benchmark (experiments C3/C5)
//!   serve            load K spec variants as ONE merged routed backend
//!                    and drive mixed per-variant traffic through the
//!                    batcher, reporting the per-variant split — or, with
//!                    --listen ADDR, serve it over HTTP/1.1 with bounded
//!                    admission control and load shedding
//!
//! Arg parsing is in-tree (offline environment — no clap).

use std::path::{Path, PathBuf};

use kamae::dataframe::{infer_jsonl_schema, read_jsonl, write_jsonl};
use kamae::engine::Dataset;
use kamae::error::{KamaeError, Result};
use kamae::pipeline::catalog;
use kamae::pipeline::PipelineModel;
use kamae::synth;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

/// Tiny `--key value` argument map, plus bare `--flag` switches and
/// positional operands (`kamae deploy <tenant> <spec.json>`).
struct Args {
    flags: std::collections::HashMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    fn parse(args: &[String]) -> Args {
        let mut flags = std::collections::HashMap::new();
        let mut positionals = Vec::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                // a following token that is itself a flag means this one
                // is a bare switch (e.g. --registry)
                match args.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        flags.insert(key.to_string(), String::new());
                        i += 1;
                    }
                }
            } else {
                positionals.push(args[i].clone());
                i += 1;
            }
        }
        Args { flags, positionals }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn pos(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }
}

fn run(raw: &[String]) -> Result<()> {
    let Some(cmd) = raw.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&raw[1..]);
    match cmd.as_str() {
        "gen-data" => gen_data(&args),
        "fit" => fit(&args),
        "export-examples" => export_examples(&args),
        "transform" => transform(&args),
        "optimize" => optimize(&args),
        "serve-bench" => serve_bench(&args),
        "serve" => serve(&args),
        "deploy" => deploy(&args),
        "rollback" => rollback(&args),
        "tenants" => tenants(&args),
        "dead-letter" => dead_letter(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(KamaeError::InvalidConfig(format!("unknown subcommand: {other}"))),
    }
}

fn print_usage() {
    println!(
        "kamae — Spark-like preprocessing engine with compiled-graph export\n\
         \n\
         USAGE: kamae <subcommand> [--key value ...]\n\
         \n\
         SUBCOMMANDS:\n\
         \x20 gen-data         --dataset movielens|ltr --rows N --out FILE.jsonl\n\
         \x20 fit              --dataset movielens|ltr|quickstart --rows N --out-dir DIR [--partitions P]\n\
         \x20 export-examples  [--out-dir artifacts/specs] [--rows N]\n\
         \x20 transform        --model model.json --input in.jsonl --output out.jsonl\n\
         \x20 optimize         --spec spec.json --out opt.json [--level none|basic|full]\n\
         \x20                  [--report-json report.json]\n\
         \x20                  or --variants a.json,b.json[,...] --out merged.json — merge\n\
         \x20                  K spec variants into one multi-variant spec (shared-prefix\n\
         \x20                  dedup) before optimizing\n\
         \x20                  or --calibrate ltr|movielens|quickstart [--fit-rows N]\n\
         \x20                  [--rows N] [--repeats R] — fit a catalog pipeline, time\n\
         \x20                  per-op interpreter evaluation on a synthetic batch, print\n\
         \x20                  measured-vs-registry cost drift and append the trajectory\n\
         \x20                  to BENCH_op_costs.json\n\
         \x20 serve-bench      --artifacts DIR --spec NAME --rps R --seconds S [--mode compiled|interpreted|mleap]\n\
         \x20 serve            --artifacts DIR --variants a,b[,...] [--rps R] [--seconds S]\n\
         \x20                  [--level none|basic|full] [--route on|off] [--workers N]\n\
         \x20                  — serve K catalog variants from ONE merged backend; requests\n\
         \x20                  target their variant (routed cone evaluation) unless\n\
         \x20                  --route off; --workers N drains the queue with an N-thread\n\
         \x20                  pool over the shared backend (reports per-worker\n\
         \x20                  utilization; requires --route on)\n\
         \x20                  or --listen ADDR [--admission M] — serve the merged backend\n\
         \x20                  over HTTP/1.1 (POST /v1/infer, GET /healthz, GET /metrics,\n\
         \x20                  POST /admin/shutdown); at most M requests are in flight at\n\
         \x20                  once, beyond that the listener sheds with 429 + Retry-After\n\
         \x20                  — add --registry [--tenants t=a+b,u=c] for multi-tenant mode:\n\
         \x20                  each tenant serves its own merged spec set, addressed as\n\
         \x20                  POST /v1/infer/<tenant>, hot-swappable at runtime via\n\
         \x20                  POST /admin/deploy / /admin/rollback (zero-downtime; without\n\
         \x20                  --tenants the --variants list becomes the 'default' tenant)\n\
         \x20                  — add --validate [--dead-letter FILE.jsonl] to gate ingress\n\
         \x20                  data quality: invalid rows are quarantined (responses carry\n\
         \x20                  per-row verdicts, the batch is served compacted) and\n\
         \x20                  appended to the dead-letter file with their errors;\n\
         \x20                  --quarantine-alert RATE flips /healthz to \"degraded\" when a\n\
         \x20                  tenant's rolling quarantine rate reaches RATE (0 < RATE <= 1)\n\
         \x20                  — add --deadline-ms N to bound queue time: requests that age\n\
         \x20                  out waiting are answered 504 deadline_exceeded instead of\n\
         \x20                  occupying a batch (clients may override per request with\n\
         \x20                  \"deadline_ms\" in the body)\n\
         \x20 deploy           <tenant> <spec.json[,spec2.json...]> --addr HOST:PORT\n\
         \x20                  [--expect-version N] [--level none|basic|full] — hot-swap a\n\
         \x20                  tenant's specs on a running --registry listener (creates the\n\
         \x20                  tenant if new; N protects against concurrent deploys, 409 on\n\
         \x20                  a lost race); --rules FILE.json attaches declarative\n\
         \x20                  data-quality rules (range | one_of | pattern) that version\n\
         \x20                  and roll back WITH the specs\n\
         \x20 rollback         <tenant> --addr HOST:PORT [--to-version N] — re-activate the\n\
         \x20                  previous (or an explicit) still-warm version, no rebuild\n\
         \x20 tenants          --addr HOST:PORT — list tenants, versions and per-version\n\
         \x20                  request counts on a running listener\n\
         \x20 dead-letter      replay FILE.jsonl --tenant T --addr HOST:PORT [--dry-run]\n\
         \x20                  — re-submit a tenant's dead-lettered rows through the live\n\
         \x20                  validation gate one row at a time, printing a per-row verdict\n\
         \x20                  (recovered | still quarantined | rejected) and a summary;\n\
         \x20                  --dry-run lists the matching rows without submitting\n"
    );
}

fn gen_dataset(name: &str, rows: usize) -> Result<kamae::dataframe::DataFrame> {
    match name {
        "movielens" => Ok(synth::gen_movielens(&synth::MovieLensConfig { rows, ..Default::default() })),
        "ltr" => Ok(synth::gen_ltr(&synth::LtrConfig { rows, ..Default::default() })),
        other => Err(KamaeError::InvalidConfig(format!("unknown dataset: {other}"))),
    }
}

fn gen_data(args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "movielens");
    let rows = args.usize_or("rows", 100_000);
    let out = PathBuf::from(args.get_or("out", &format!("{dataset}.jsonl")));
    let df = gen_dataset(&dataset, rows)?;
    write_jsonl(&df, &out)?;
    println!("wrote {rows} rows of {dataset} to {}", out.display());
    Ok(())
}

/// Fit one catalog pipeline and save model + spec.
fn fit_one(name: &str, rows: usize, partitions: usize, out_dir: &Path) -> Result<()> {
    let (pipeline, inputs, outputs, data): (_, _, Vec<&str>, _) = match name {
        "movielens" => (
            catalog::movielens_pipeline(),
            catalog::movielens_inputs(),
            catalog::MOVIELENS_OUTPUTS.to_vec(),
            gen_dataset("movielens", rows)?,
        ),
        "ltr" => (
            catalog::ltr_pipeline(),
            catalog::ltr_inputs(),
            catalog::LTR_OUTPUTS.to_vec(),
            gen_dataset("ltr", rows)?,
        ),
        "quickstart" => (
            catalog::quickstart_pipeline(),
            catalog::quickstart_inputs(),
            catalog::QUICKSTART_OUTPUTS.to_vec(),
            kamae::serving::request_pool("quickstart", rows)?,
        ),
        other => return Err(KamaeError::InvalidConfig(format!("unknown pipeline: {other}"))),
    };
    let ds = Dataset::from_dataframe(data, partitions);
    let t0 = std::time::Instant::now();
    let model = pipeline.fit(&ds)?;
    let fit_ms = t0.elapsed().as_millis();
    std::fs::create_dir_all(out_dir)?;
    let model_path = out_dir.join(format!("{name}.model.json"));
    model.save(&model_path)?;
    let spec = model.to_graph_spec(name, inputs, &outputs)?;
    let spec_path = out_dir.join(format!("{name}.json"));
    spec.save(&spec_path)?;
    println!(
        "{name}: fitted {} stages on {} rows x {} partitions in {fit_ms} ms -> {}",
        model.stages.len(),
        ds.num_rows(),
        ds.num_partitions(),
        spec_path.display()
    );
    Ok(())
}

fn fit(args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "quickstart");
    let rows = args.usize_or("rows", 50_000);
    let partitions = args.usize_or("partitions", kamae::util::pool::default_threads());
    let out_dir = PathBuf::from(args.get_or("out-dir", "artifacts/specs"));
    fit_one(&dataset, rows, partitions, &out_dir)
}

fn export_examples(args: &Args) -> Result<()> {
    let out_dir = PathBuf::from(args.get_or("out-dir", "artifacts/specs"));
    let rows = args.usize_or("rows", 50_000);
    let partitions = args.usize_or("partitions", kamae::util::pool::default_threads());
    for name in ["quickstart", "movielens", "ltr"] {
        fit_one(name, rows, partitions, &out_dir)?;
    }
    Ok(())
}

fn transform(args: &Args) -> Result<()> {
    let model_path = PathBuf::from(
        args.get("model")
            .ok_or_else(|| KamaeError::InvalidConfig("--model required".into()))?,
    );
    let input = PathBuf::from(
        args.get("input")
            .ok_or_else(|| KamaeError::InvalidConfig("--input required".into()))?,
    );
    let output = PathBuf::from(
        args.get("output")
            .ok_or_else(|| KamaeError::InvalidConfig("--output required".into()))?,
    );
    let model = PipelineModel::load(&model_path)?;
    let schema = infer_jsonl_schema(&input)?;
    let df = read_jsonl(&input, &schema)?;
    let partitions = args.usize_or("partitions", kamae::util::pool::default_threads());
    let ds = Dataset::from_dataframe(df, partitions);
    let t0 = std::time::Instant::now();
    let out = model.transform(&ds)?;
    let secs = t0.elapsed().as_secs_f64();
    let rows = out.num_rows();
    write_jsonl(&out.collect()?, &output)?;
    println!(
        "transformed {rows} rows in {secs:.3}s ({:.0} rows/s) -> {}",
        rows as f64 / secs,
        output.display()
    );
    Ok(())
}

/// Optimize a spec JSON to `--out`, printing the per-pass node-count
/// report and any registry lint findings. `--out` is mandatory (it may
/// equal `--spec`): rewriting an artifact spec in place would silently
/// break the compiled backend's positional contract with HLO files
/// lowered from the old graph, so overwriting must be an explicit
/// choice — and any rewritten spec must be re-lowered (`make
/// artifacts`) before compiled serving.
fn optimize(args: &Args) -> Result<()> {
    // --calibrate is a separate mode: no spec rewrite, no --out
    if let Some(catalog_name) = args.get("calibrate") {
        return calibrate(catalog_name, args);
    }
    let out = PathBuf::from(args.get("out").ok_or_else(|| {
        KamaeError::InvalidConfig(
            "--out required (pass the same path as --spec to overwrite in place; \
             re-run `make artifacts` afterwards if compiled serving uses this spec)"
                .into(),
        )
    })?);
    let level = kamae::optim::OptimizeLevel::parse(&args.get_or("level", "full"))?;
    let spec = match (args.get("spec"), args.get("variants")) {
        (Some(p), None) => kamae::export::GraphSpec::load(&PathBuf::from(p))?,
        (None, Some(list)) => {
            // merge K variant specs into one multi-variant spec; the
            // optimizer's CrossOutputDedup pass collapses their shared
            // preprocessing prefix
            let specs = list
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|p| kamae::export::GraphSpec::load(&PathBuf::from(p)))
                .collect::<Result<Vec<_>>>()?;
            let refs: Vec<&kamae::export::GraphSpec> = specs.iter().collect();
            let name = refs
                .iter()
                .map(|s| s.name.as_str())
                .collect::<Vec<_>>()
                .join("+");
            kamae::export::GraphSpec::merge_variants(&name, &refs)?
        }
        _ => {
            return Err(KamaeError::InvalidConfig(
                "pass exactly one of --spec spec.json or --variants a.json,b.json".into(),
            ))
        }
    };
    for finding in kamae::optim::lint_spec(&spec) {
        eprintln!("warning: {finding}");
    }
    let (spec, report) = kamae::optim::optimize(spec, level)?;
    println!("{report}");
    print_variant_costs(&spec);
    spec.save(&out)?;
    println!("wrote {}", out.display());
    // machine-readable per-pass node/cost trajectory (CI and perf tooling)
    if let Some(path) = args.get("report-json") {
        let path = PathBuf::from(path);
        std::fs::write(&path, report.to_json().to_string_pretty())?;
        println!("wrote report to {}", path.display());
    }
    Ok(())
}

/// `kamae optimize --calibrate <catalog>` — the cost-model calibration
/// harness (first step of the ROADMAP "fit the work constants from
/// measured timings" item): fit the named catalog pipeline in-process,
/// export its optimized spec, time per-op interpreter evaluation over a
/// synthetic request batch, print the measured-vs-registry drift table,
/// and append the per-op records to BENCH_op_costs.json so the
/// constants can be refitted from the accumulated trajectory.
fn calibrate(catalog_name: &str, args: &Args) -> Result<()> {
    use kamae::util::json::Json;

    let fit_rows = args.usize_or("fit-rows", 10_000);
    let rows = args.usize_or("rows", 1024);
    let repeats = args.usize_or("repeats", 20);
    let level = kamae::optim::OptimizeLevel::parse(&args.get_or("level", "full"))?;
    let (pipeline, inputs, outputs, data): (_, _, Vec<&str>, _) = match catalog_name {
        "movielens" => (
            catalog::movielens_pipeline(),
            catalog::movielens_inputs(),
            catalog::MOVIELENS_OUTPUTS.to_vec(),
            gen_dataset("movielens", fit_rows)?,
        ),
        "ltr" => (
            catalog::ltr_pipeline(),
            catalog::ltr_inputs(),
            catalog::LTR_OUTPUTS.to_vec(),
            gen_dataset("ltr", fit_rows)?,
        ),
        "quickstart" => (
            catalog::quickstart_pipeline(),
            catalog::quickstart_inputs(),
            catalog::QUICKSTART_OUTPUTS.to_vec(),
            kamae::serving::request_pool("quickstart", fit_rows)?,
        ),
        other => {
            return Err(KamaeError::InvalidConfig(format!(
                "--calibrate takes a catalog pipeline (ltr|movielens|quickstart), got {other}"
            )))
        }
    };
    let ds = Dataset::from_dataframe(data, kamae::util::pool::default_threads());
    let model = pipeline.fit(&ds)?;
    let (spec, _) = model.to_graph_spec_opt(catalog_name, inputs, &outputs, level)?;
    let batch = kamae::serving::request_pool(catalog_name, rows)?;
    let report = kamae::optim::calibrate(&spec, &batch, repeats)?;
    println!("{report}");
    let records = report.to_records();
    let n = records.len();
    let path = kamae::util::bench::append_run(
        "op_costs",
        &[
            ("spec", Json::from(catalog_name)),
            ("level", Json::from(level.name())),
            ("rows", Json::from(report.rows)),
            ("repeats", Json::from(report.repeats)),
            ("scale_ns_per_unit", Json::from(report.scale_ns_per_unit)),
        ],
        records,
    )?;
    println!("\nappended {n} per-op records to {}", path.display());
    Ok(())
}

fn serve_bench(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let spec_name = args.get_or("spec", "ltr");
    let rps = args.usize_or("rps", 200);
    let seconds = args.usize_or("seconds", 10);
    let mode = args.get_or("mode", "compiled");
    let report = kamae::serving::bench_serve(&artifacts, &spec_name, rps, seconds, &mode)?;
    println!("{report}");
    Ok(())
}

/// Per-variant cost attribution table for a merged multi-variant spec
/// (no-op on ordinary specs).
fn print_variant_costs(spec: &kamae::export::GraphSpec) {
    let costs = kamae::optim::variant_costs(spec);
    if costs.is_empty() {
        return;
    }
    println!("per-variant cost attribution (est. units/row):");
    for c in &costs {
        println!(
            "  {:<16} {:>3} outputs  exclusive {:>6}  shared share {:>6}  cone total {:>6}",
            c.variant,
            c.outputs,
            c.exclusive,
            c.shared,
            c.exclusive + c.shared
        );
    }
}

/// Serve K catalog variants from one merged routed backend: mixed
/// open-loop traffic, each request targeting its variant round-robin.
/// `--route off` degrades to all-outputs-per-request on the same
/// backend (the PR 3 behavior) for comparison; `--workers N` serves the
/// queue with an N-thread pool over the one shared backend and reports
/// per-worker utilization.
fn serve(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let variants_arg = args.get("variants").ok_or_else(|| {
        KamaeError::InvalidConfig("--variants a,b[,...] required (artifact spec names)".into())
    })?;
    let names: Vec<&str> = variants_arg.split(',').filter(|s| !s.is_empty()).collect();
    let rps = args.usize_or("rps", 200);
    let seconds = args.usize_or("seconds", 5);
    let level = kamae::optim::OptimizeLevel::parse(&args.get_or("level", "full"))?;
    if let Some(listen) = args.get("listen") {
        return serve_listen(args, &artifacts, &names, level, listen);
    }
    let route = match args.get_or("route", "on").as_str() {
        "on" | "1" | "true" => true,
        "off" | "0" | "false" => false,
        other => {
            return Err(KamaeError::InvalidConfig(format!(
                "--route takes on|off, got {other}"
            )))
        }
    };
    // workers != 1 (including the nonsense 0) takes the pool path, so
    // Server::start's BatchConfig validation rejects 0 loudly instead
    // of a silent single-worker fallback
    let workers = args.usize_or("workers", 1);
    if workers != 1 && !route {
        // the pool driver is routed-only: the route-off baseline exists
        // to isolate routing's win, mixing it with pooling would
        // measure neither cleanly
        return Err(KamaeError::InvalidConfig(
            "--workers N > 1 requires --route on (the pool serves routed traffic)".into(),
        ));
    }
    // show what the merged backend looks like before driving traffic
    let spec = kamae::serving::load_variant_spec(&artifacts, &names, level)?;
    println!(
        "merged backend {}: {} ingress + {} graph nodes, {} outputs",
        spec.name,
        spec.ingress.len(),
        spec.nodes.len(),
        spec.outputs.len()
    );
    print_variant_costs(&spec);
    let report = if workers != 1 {
        kamae::serving::bench_serve_pool(&artifacts, &names, rps, seconds, level, workers)?
    } else {
        kamae::serving::bench_serve_variants(&artifacts, &names, rps, seconds, level, route)?
    };
    println!("{report}");
    Ok(())
}

/// `kamae serve --listen ADDR`: put the HTTP/1.1 front-end in front of
/// the merged routed backend and park until `POST /admin/shutdown`
/// begins the drain. `--rps/--seconds/--route` are bench-driver knobs
/// and are ignored here — traffic comes over the wire. With
/// `--registry` the listener serves a whole [`kamae::serving::SpecRegistry`]:
/// tenants come from `--tenants t=a+b,u=c` (artifact spec names joined
/// with `+` merge into one backend per tenant) or, without it, the
/// `--variants` list seeds the `default` tenant; further tenants and
/// versions deploy at runtime with zero downtime.
fn serve_listen(
    args: &Args,
    artifacts: &Path,
    names: &[&str],
    level: kamae::optim::OptimizeLevel,
    listen: &str,
) -> Result<()> {
    use kamae::serving::{BatchConfig, NetConfig, NetServer, SpecRegistry, DEFAULT_TENANT};

    let workers = args.usize_or("workers", 1);
    let admission = args.usize_or("admission", 64);
    let validate = args.has("validate");
    let dead_letter = args.get("dead-letter").map(PathBuf::from);
    if dead_letter.is_some() && !validate {
        return Err(KamaeError::InvalidConfig(
            "--dead-letter requires --validate (nothing is quarantined without the gate)".into(),
        ));
    }
    let quarantine_alert = match args.get("quarantine-alert") {
        None => None,
        Some(v) => {
            let rate: f64 = v.parse().map_err(|_| {
                KamaeError::InvalidConfig(format!(
                    "--quarantine-alert takes a fraction in (0, 1], got {v}"
                ))
            })?;
            if !validate {
                return Err(KamaeError::InvalidConfig(
                    "--quarantine-alert requires --validate (the rate never moves \
                     without the gate)"
                        .into(),
                ));
            }
            Some(rate)
        }
    };
    let request_deadline = match args.get("deadline-ms") {
        None => None,
        Some(v) => {
            let ms: u64 = v.parse().map_err(|_| {
                KamaeError::InvalidConfig(format!(
                    "--deadline-ms takes a positive integer of milliseconds, got {v}"
                ))
            })?;
            Some(std::time::Duration::from_millis(ms))
        }
    };
    let config = NetConfig {
        batch: BatchConfig { workers, request_deadline, ..Default::default() },
        admission,
        validate,
        dead_letter: dead_letter.clone(),
        quarantine_alert,
        ..NetConfig::default()
    };
    let registry_mode = args.has("registry");
    let server = if registry_mode {
        // tenant -> spec-name list; default: the --variants list under
        // the default tenant
        let tenant_specs: Vec<(String, Vec<String>)> = match args.get("tenants") {
            Some(list) => {
                let mut out = Vec::new();
                for entry in list.split(',').filter(|s| !s.is_empty()) {
                    let (tenant, specs) = entry.split_once('=').ok_or_else(|| {
                        KamaeError::InvalidConfig(format!(
                            "--tenants entries are tenant=spec[+spec...], got '{entry}'"
                        ))
                    })?;
                    out.push((
                        tenant.to_string(),
                        specs.split('+').map(str::to_string).collect(),
                    ));
                }
                out
            }
            None => vec![(
                DEFAULT_TENANT.to_string(),
                names.iter().map(|s| s.to_string()).collect(),
            )],
        };
        let registry = std::sync::Arc::new(SpecRegistry::with_level(level));
        for (tenant, spec_names) in &tenant_specs {
            let specs = spec_names
                .iter()
                .map(|n| {
                    kamae::export::GraphSpec::load(
                        &artifacts.join("specs").join(format!("{n}.json")),
                    )
                })
                .collect::<Result<Vec<_>>>()?;
            let summary = registry.deploy_specs(tenant, &specs, None, None)?;
            println!(
                "tenant {tenant}: v{} backend {} ({})",
                summary.version,
                summary.backend,
                spec_names.join("+")
            );
        }
        NetServer::bind_registry(registry, listen, config)?
    } else {
        let spec = kamae::serving::load_variant_spec(artifacts, names, level)?;
        println!(
            "merged backend {}: {} ingress + {} graph nodes, {} outputs",
            spec.name,
            spec.ingress.len(),
            spec.nodes.len(),
            spec.outputs.len()
        );
        print_variant_costs(&spec);
        let backend: std::sync::Arc<dyn kamae::serving::Backend> =
            std::sync::Arc::from(kamae::serving::load_variant_backend(artifacts, names, level)?);
        NetServer::bind(backend, listen, config)?
    };
    println!(
        "kamae serve: listening on http://{} ({}; workers {workers}; admission {admission}{})",
        server.addr(),
        if registry_mode {
            "registry mode".to_string()
        } else {
            format!("variants: {}", names.join(", "))
        },
        match &dead_letter {
            Some(p) => format!("; validate on, dead-letter {}", p.display()),
            None if validate => "; validate on".to_string(),
            None => String::new(),
        }
    );
    if registry_mode {
        println!(
            "endpoints: POST /v1/infer[/<tenant>]  GET /healthz  GET /metrics  \
             POST /admin/deploy  POST /admin/rollback  GET /admin/tenants  POST /admin/shutdown"
        );
    } else {
        println!("endpoints: POST /v1/infer  GET /healthz  GET /metrics  POST /admin/shutdown");
    }
    server.wait();
    println!("kamae serve: drained and stopped");
    Ok(())
}

/// POST `body` to `path` on the listener at `--addr`, pretty-print the
/// JSON reply, and fail loudly on a non-2xx status (the wire error body
/// carries the typed code + message).
fn admin_call(args: &Args, method: &str, path: &str, body: &str) -> Result<()> {
    let addr = args.get("addr").ok_or_else(|| {
        KamaeError::InvalidConfig("--addr HOST:PORT required (a running `kamae serve --listen --registry`)".into())
    })?;
    let mut client = kamae::serving::NetClient::connect(addr)?;
    let resp = client.request(method, path, &[], body)?;
    let pretty = resp
        .json()
        .map(|j| j.to_string_pretty())
        .unwrap_or_else(|_| resp.body.clone());
    if resp.status >= 300 {
        return Err(KamaeError::Serving(format!(
            "{path} returned {}: {pretty}",
            resp.status
        )));
    }
    println!("{pretty}");
    Ok(())
}

/// `kamae deploy <tenant> <spec.json[,spec2.json...]> --addr HOST:PORT`
/// — hot-swap a tenant's spec set on a running registry listener. The
/// listener builds the new version off the request path and swaps
/// atomically; in-flight requests finish on the old version.
fn deploy(args: &Args) -> Result<()> {
    use kamae::util::json::Json;

    let tenant = args.pos(0).ok_or_else(|| {
        KamaeError::InvalidConfig("usage: kamae deploy <tenant> <spec.json[,spec2...]> --addr HOST:PORT".into())
    })?;
    let spec_paths = args.pos(1).ok_or_else(|| {
        KamaeError::InvalidConfig("usage: kamae deploy <tenant> <spec.json[,spec2...]> --addr HOST:PORT".into())
    })?;
    let mut specs = Vec::new();
    for p in spec_paths.split(',').filter(|s| !s.is_empty()) {
        // parse locally first: a bad file should fail here, not 400 on
        // the server
        specs.push(kamae::export::GraphSpec::load(&PathBuf::from(p))?.to_json());
    }
    let mut body = Json::object();
    body.set("tenant", tenant);
    body.set("specs", Json::Array(specs));
    if let Some(v) = args.get("expect-version") {
        let v: i64 = v.parse().map_err(|_| {
            KamaeError::InvalidConfig(format!("--expect-version takes an integer, got {v}"))
        })?;
        body.set("expect_version", v);
    }
    if let Some(level) = args.get("level") {
        kamae::optim::OptimizeLevel::parse(level)?; // fail fast locally
        body.set("level", level);
    }
    if let Some(path) = args.get("rules") {
        // data-quality rules deploy WITH the specs: one version, one
        // atomic swap, one rollback for both
        let text = std::fs::read_to_string(path)?;
        let rules = Json::parse(&text)?;
        if rules.as_array().is_none() {
            return Err(KamaeError::InvalidConfig(format!(
                "--rules {path}: expected a JSON array of rule objects"
            )));
        }
        body.set("validation", rules);
    }
    admin_call(args, "POST", "/admin/deploy", &body.to_string())
}

/// `kamae rollback <tenant> --addr HOST:PORT [--to-version N]` —
/// re-activate a previous still-warm version (no rebuild).
fn rollback(args: &Args) -> Result<()> {
    use kamae::util::json::Json;

    let tenant = args.pos(0).ok_or_else(|| {
        KamaeError::InvalidConfig("usage: kamae rollback <tenant> --addr HOST:PORT [--to-version N]".into())
    })?;
    let mut body = Json::object();
    body.set("tenant", tenant);
    if let Some(v) = args.get("to-version") {
        let v: i64 = v.parse().map_err(|_| {
            KamaeError::InvalidConfig(format!("--to-version takes an integer, got {v}"))
        })?;
        body.set("to_version", v);
    }
    admin_call(args, "POST", "/admin/rollback", &body.to_string())
}

/// `kamae tenants --addr HOST:PORT` — registry snapshot: every tenant's
/// versions with per-version request counts.
fn tenants(args: &Args) -> Result<()> {
    admin_call(args, "GET", "/admin/tenants", "")
}

/// `kamae dead-letter replay FILE.jsonl --tenant T --addr HOST:PORT
/// [--dry-run]` — re-submit a tenant's dead-lettered rows through the
/// live validation gate, one row per request so each verdict names its
/// source line. A row recovers when the current rules accept it (they
/// may have been fixed by a deploy since the quarantine); a row that is
/// quarantined again, or rejected with a wire error, stays dead.
fn dead_letter(args: &Args) -> Result<()> {
    use kamae::util::json::Json;

    const USAGE: &str =
        "usage: kamae dead-letter replay FILE.jsonl --tenant T --addr HOST:PORT [--dry-run]";
    match args.pos(0) {
        Some("replay") => {}
        Some(other) => {
            return Err(KamaeError::InvalidConfig(format!(
                "unknown dead-letter verb '{other}'\n{USAGE}"
            )))
        }
        None => return Err(KamaeError::InvalidConfig(USAGE.into())),
    }
    let path = PathBuf::from(
        args.pos(1)
            .ok_or_else(|| KamaeError::InvalidConfig(USAGE.into()))?,
    );
    let tenant = args
        .get("tenant")
        .ok_or_else(|| KamaeError::InvalidConfig(format!("--tenant required\n{USAGE}")))?;
    let dry_run = args.has("dry-run");

    // parse the JSONL sink format ({"tenant", "row", "errors"}) and
    // keep this tenant's rows, remembering source lines for the report
    let text = std::fs::read_to_string(&path)?;
    let mut rows: Vec<(usize, Json)> = Vec::new();
    let mut other_tenants = 0usize;
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let entry = Json::parse(line).map_err(|e| {
            KamaeError::Serde(format!(
                "{}:{}: not a dead-letter entry: {e}",
                path.display(),
                i + 1
            ))
        })?;
        let row_tenant = entry.get("tenant").and_then(Json::as_str).ok_or_else(|| {
            KamaeError::Serde(format!(
                "{}:{}: dead-letter entry has no 'tenant' key",
                path.display(),
                i + 1
            ))
        })?;
        if row_tenant != tenant {
            other_tenants += 1;
            continue;
        }
        let row = entry.get("row").cloned().ok_or_else(|| {
            KamaeError::Serde(format!(
                "{}:{}: dead-letter entry has no 'row' key",
                path.display(),
                i + 1
            ))
        })?;
        rows.push((i + 1, row));
    }
    if rows.is_empty() {
        println!(
            "no dead-letter rows for tenant '{tenant}' in {} ({other_tenants} other-tenant \
             entr{})",
            path.display(),
            if other_tenants == 1 { "y" } else { "ies" }
        );
        return Ok(());
    }
    if dry_run {
        println!("would replay {} row(s) for tenant '{tenant}':", rows.len());
        for (line, row) in &rows {
            println!("  line {line}: {row}");
        }
        return Ok(());
    }

    let addr = args.get("addr").ok_or_else(|| {
        KamaeError::InvalidConfig(format!(
            "--addr HOST:PORT required (a running `kamae serve --listen --validate`)\n{USAGE}"
        ))
    })?;
    let mut client = kamae::serving::NetClient::connect(addr)?;
    let infer_path = format!("/v1/infer/{tenant}");
    let (mut recovered, mut quarantined, mut rejected) = (0usize, 0usize, 0usize);
    for (line, row) in &rows {
        let mut body = Json::object();
        body.set("rows", Json::Array(vec![row.clone()]));
        let resp = client.request("POST", &infer_path, &[], &body.to_string())?;
        if resp.status >= 300 {
            // a typed wire error (validation off, unknown tenant, ...):
            // surface the code, keep going — other rows may still land
            let code = resp
                .json()
                .ok()
                .and_then(|j| j.get("error").and_then(|e| e.get("code")).and_then(Json::as_str).map(str::to_string))
                .unwrap_or_else(|| format!("http {}", resp.status));
            println!("line {line}: rejected ({code})");
            rejected += 1;
        } else {
            let reply = resp.json()?;
            // with validation on, valid_rows says whether the row passed
            // the gate; without the key the request simply served
            let valid = reply
                .get("valid_rows")
                .and_then(Json::as_i64)
                .unwrap_or(1);
            if valid >= 1 {
                println!("line {line}: recovered");
                recovered += 1;
            } else {
                // quote the first structured error so the operator sees
                // WHY it is still dead without opening the sink file
                let why = reply
                    .get("verdicts")
                    .and_then(Json::as_array)
                    .and_then(|vs| vs.first())
                    .and_then(|v| v.get("errors"))
                    .and_then(Json::as_array)
                    .and_then(|es| es.first())
                    .map(|e| {
                        format!(
                            "{}: {}",
                            e.get("rule").and_then(Json::as_str).unwrap_or("?"),
                            e.get("message").and_then(Json::as_str).unwrap_or("?")
                        )
                    })
                    .unwrap_or_else(|| "no verdict errors returned".to_string());
                println!("line {line}: still quarantined — {why}");
                quarantined += 1;
            }
        }
        if resp.closed {
            client = kamae::serving::NetClient::connect(addr)?;
        }
    }
    println!(
        "replayed {} row(s) for tenant '{tenant}': {recovered} recovered, {quarantined} still \
         quarantined, {rejected} rejected",
        rows.len()
    );
    Ok(())
}
