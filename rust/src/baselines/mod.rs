//! Baselines the paper compares against.
//!
//! [`mleap_like`] reproduces the performance-relevant shape of MLeap's
//! runtime: the fitted pipeline is interpreted **row at a time** over
//! boxed dynamically-typed values, with per-row dispatch and allocation
//! and no vectorisation or fusion — exactly the "user-defined functions"
//! execution model the paper contrasts with native transformations
//! (experiments C2 and C3).

pub mod mleap_like;

pub use mleap_like::RowPipeline;
