//! MLeap-like row-at-a-time pipeline execution.
//!
//! MLeap executes a serialised Spark pipeline one row at a time on the
//! JVM: every value is boxed, every stage dispatches dynamically, and
//! nothing is vectorised or fused across rows. We reproduce that
//! execution model by driving the fitted pipeline over **single-row
//! frames**: each row is sliced out (allocating one boxed buffer per
//! column, the `Vec` analogue of JVM boxing), pushed through every stage
//! with full dynamic dispatch, and the 1-row results concatenated.
//!
//! This preserves what makes the baseline slow — per-row allocation and
//! per-row per-stage dispatch, O(rows · stages) overhead — without a
//! JVM. We report *relative* numbers against it (the paper's −61 %
//! latency claim is also relative); see DESIGN.md §Substitutions.

use crate::dataframe::DataFrame;
use crate::error::Result;
use crate::export::GraphSpec;
use crate::pipeline::PipelineModel;
use crate::runtime::Tensor;

/// Row-at-a-time executor wrapping a fitted pipeline.
pub struct RowPipeline {
    model: PipelineModel,
    /// Output columns to materialise (the graph outputs of the paired
    /// spec, so compiled/interpreted/row-wise modes are comparable).
    outputs: Vec<String>,
}

impl RowPipeline {
    pub fn new(model: PipelineModel, outputs: Vec<String>) -> RowPipeline {
        RowPipeline { model, outputs }
    }

    /// Derive the comparable output set from a GraphSpec (maps the
    /// spec's graph outputs back to engine column names).
    pub fn from_spec(model: PipelineModel, spec: &GraphSpec) -> RowPipeline {
        let outputs = spec
            .outputs
            .iter()
            .map(|o| o.strip_suffix("__out").unwrap_or(o).to_string())
            .collect();
        RowPipeline::new(model, outputs)
    }

    pub fn outputs(&self) -> &[String] {
        &self.outputs
    }

    /// Transform row-at-a-time (the MLeap execution model).
    pub fn transform_rows(&self, df: &DataFrame) -> Result<DataFrame> {
        let mut parts = Vec::with_capacity(df.num_rows());
        for i in 0..df.num_rows() {
            let row = df.slice(i, 1);
            let out = self.model.transform_df(row)?;
            parts.push(out);
        }
        let refs: Vec<&DataFrame> = parts.iter().collect();
        DataFrame::concat(&refs)
    }

    /// Serving-comparable entry point: transform row-wise, then
    /// materialise the output columns as tensors (same contract as the
    /// compiled / interpreted backends).
    pub fn process(&self, df: &DataFrame) -> Result<Vec<Tensor>> {
        let out = self.transform_rows(df)?;
        self.outputs
            .iter()
            .map(|name| column_to_tensor(out.column(name)?))
            .collect()
    }
}

/// Engine column → serving tensor (f64→f32, ints/bools→i64), matching
/// the compiled graph's output dtypes.
pub fn column_to_tensor(col: &crate::dataframe::Column) -> Result<Tensor> {
    use crate::dataframe::Column;
    use crate::runtime::TensorData;
    let n = col.len();
    Ok(match col {
        Column::Bool(v, _) => Tensor::new(
            TensorData::I64(v.iter().map(|&b| b as i64).collect()),
            vec![n],
        )?,
        Column::I32(v, _) => Tensor::new(
            TensorData::I64(v.iter().map(|&x| x as i64).collect()),
            vec![n],
        )?,
        Column::I64(v, _) => Tensor::new(TensorData::I64(v.clone()), vec![n])?,
        Column::F32(v, _) => Tensor::new(TensorData::F32(v.clone()), vec![n])?,
        Column::F64(v, _) => Tensor::new(
            TensorData::F32(v.iter().map(|&x| x as f32).collect()),
            vec![n],
        )?,
        Column::ListI64(l) => {
            let w = l.fixed_width().ok_or_else(|| {
                crate::error::KamaeError::InvalidConfig("ragged output tensor".into())
            })?;
            Tensor::new(TensorData::I64(l.values.clone()), vec![n, w])?
        }
        Column::ListF64(l) => {
            let w = l.fixed_width().ok_or_else(|| {
                crate::error::KamaeError::InvalidConfig("ragged output tensor".into())
            })?;
            Tensor::new(
                TensorData::F32(l.values.iter().map(|&x| x as f32).collect()),
                vec![n, w],
            )?
        }
        other => {
            return Err(crate::error::KamaeError::Unsupported(format!(
                "output column dtype {} as tensor",
                other.dtype().name()
            )))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Dataset;
    use crate::pipeline::catalog;
    use crate::synth;

    #[test]
    fn row_wise_matches_columnar() {
        let df = synth::gen_movielens(&synth::MovieLensConfig { rows: 50, ..Default::default() });
        let model = catalog::movielens_pipeline()
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let columnar = model.transform_df(df.clone()).unwrap();
        let spec = model
            .to_graph_spec("m", catalog::movielens_inputs(), &catalog::MOVIELENS_OUTPUTS)
            .unwrap();
        let row_model = catalog::movielens_pipeline()
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let rp = RowPipeline::from_spec(row_model, &spec);
        let rowwise = rp.transform_rows(&df).unwrap();
        for col in catalog::MOVIELENS_OUTPUTS {
            assert_eq!(
                rowwise.column(col).unwrap(),
                columnar.column(col).unwrap(),
                "mismatch in {col}"
            );
        }
    }

    #[test]
    fn process_produces_tensors() {
        let df = synth::gen_movielens(&synth::MovieLensConfig { rows: 10, ..Default::default() });
        let model = catalog::movielens_pipeline()
            .fit(&Dataset::from_dataframe(df.clone(), 1))
            .unwrap();
        let spec = model
            .to_graph_spec("m", catalog::movielens_inputs(), &catalog::MOVIELENS_OUTPUTS)
            .unwrap();
        let rp = RowPipeline::from_spec(model, &spec);
        let tensors = rp.process(&df).unwrap();
        assert_eq!(tensors.len(), 4);
        assert_eq!(tensors[0].shape, vec![10]);
        assert_eq!(tensors[3].shape, vec![10, 6]);
    }
}
