//! In-tree utility substrates.
//!
//! The build environment is fully offline with only the `xla` dependency
//! tree vendored, so the usual ecosystem crates are re-implemented here at
//! the scale this project needs: a JSON parser/writer ([`json`]), a
//! deterministic PRNG with the distributions the synthetic generators use
//! ([`rng`]), a benchmark harness with robust statistics ([`bench`]), a
//! property-testing mini-framework ([`prop`]), a scoped thread pool
//! ([`pool`]), and shared synchronization primitives such as the counting
//! semaphore ([`sync`]).

pub mod bench;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod sync;
