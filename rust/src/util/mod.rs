//! In-tree utility substrates.
//!
//! The build environment is fully offline with only the `xla` dependency
//! tree vendored, so the usual ecosystem crates are re-implemented here at
//! the scale this project needs: a JSON parser/writer ([`json`]), a
//! deterministic PRNG with the distributions the synthetic generators use
//! ([`rng`]), a benchmark harness with robust statistics ([`bench`]), a
//! property-testing mini-framework ([`prop`]), and a scoped thread pool
//! ([`pool`]).

pub mod bench;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
