//! Benchmark harness (criterion replacement for the offline environment).
//!
//! Provides warmup + timed iterations with robust statistics (mean, p50,
//! p95, p99, min), throughput reporting, and a tiny table printer so each
//! bench binary can regenerate its experiment's rows in one run.

use std::time::{Duration, Instant};

use crate::error::{KamaeError, Result};
use crate::util::json::Json;

/// Result statistics for one benchmark case, in nanoseconds.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }

    /// Machine-readable record for the `BENCH_*.json` trajectory files
    /// (the Stats analogue of `ServeReport::to_json`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::object();
        j.set("name", self.name.clone());
        j.set("iters", self.iters);
        j.set("mean_ns", self.mean_ns);
        j.set("p50_ns", self.p50_ns);
        j.set("p95_ns", self.p95_ns);
        j.set("p99_ns", self.p99_ns);
        j.set("min_ns", self.min_ns);
        j
    }
}

/// First non-finite float found anywhere in a JSON value, as a path
/// string for the error message (`None` = all numbers finite).
fn find_non_finite(v: &Json, path: &str) -> Option<String> {
    match v {
        Json::Float(x) if !x.is_finite() => Some(format!("{path} = {x}")),
        Json::Array(items) => items
            .iter()
            .enumerate()
            .find_map(|(i, item)| find_non_finite(item, &format!("{path}[{i}]"))),
        Json::Object(map) => map
            .iter()
            .find_map(|(k, item)| find_non_finite(item, &format!("{path}.{k}"))),
        _ => None,
    }
}

/// Append one run record to `BENCH_<bench>.json` at the repo root (the
/// perf-trajectory convention started by `benches/optimizer.rs`): the
/// file holds a JSON array of runs, each `{bench, ...fields, records}`.
/// Returns the file path written.
///
/// The target directory defaults to the repo root but honours the
/// `KAMAE_BENCH_DIR` env var — tests that drive trajectory-writing
/// tooling (e.g. the `kamae optimize --calibrate` integration test)
/// point it at a temp dir so throwaway runs never pollute the real
/// trajectory files the perf tooling is fitted from.
///
/// Non-finite numbers are rejected: JSON has no NaN/Inf (our writer
/// would degrade them to `null`), so a buggy record would silently
/// poison the whole trajectory file for downstream tooling. Benches
/// must fix the record (see `ServeReport`'s zero-request guard), not
/// serialise the corruption.
pub fn append_run(
    bench: &str,
    fields: &[(&str, Json)],
    records: Vec<Json>,
) -> Result<std::path::PathBuf> {
    let dir = std::env::var_os("KAMAE_BENCH_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    let path = dir.join(format!("BENCH_{bench}.json"));
    let mut runs = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| j.as_array().cloned())
        .unwrap_or_default();
    let mut run = Json::object();
    run.set("bench", bench);
    for (key, value) in fields {
        run.set(*key, value.clone());
    }
    run.set("records", Json::Array(records));
    if let Some(what) = find_non_finite(&run, "run") {
        return Err(KamaeError::InvalidConfig(format!(
            "bench record for '{bench}' contains a non-finite number: {what}"
        )));
    }
    runs.push(run);
    std::fs::write(&path, Json::Array(runs).to_string_pretty())?;
    Ok(path)
}

/// Compute percentile from a sorted slice (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Compute [`Stats`] from raw per-iteration durations.
pub fn stats_from(name: &str, samples: &[Duration]) -> Stats {
    let mut ns: Vec<f64> = samples.iter().map(|d| d.as_nanos() as f64).collect();
    ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = ns.iter().sum::<f64>() / ns.len().max(1) as f64;
    Stats {
        name: name.to_string(),
        iters: ns.len(),
        mean_ns: mean,
        p50_ns: percentile(&ns, 50.0),
        p95_ns: percentile(&ns, 95.0),
        p99_ns: percentile(&ns, 99.0),
        min_ns: ns.first().copied().unwrap_or(f64::NAN),
    }
}

/// Benchmark runner: warm up for `warmup`, then collect timed iterations
/// until `measure` wall time has elapsed (min 10, max 10_000 iterations).
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: Duration::from_millis(300), measure: Duration::from_secs(2) }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: Duration::from_millis(50), measure: Duration::from_millis(500) }
    }

    /// Run `f` repeatedly; the closure must do one full unit of work.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while (m0.elapsed() < self.measure && samples.len() < 10_000) || samples.len() < 10 {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        stats_from(name, &samples)
    }
}

/// Prevent the optimizer from eliding a computed value (ptr::read-based
/// black_box, stable-rust friendly).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width results table, criterion-style.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:width$}", c, width = widths.get(i).copied().unwrap_or(0)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.2} s", ns / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interp() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 100.0), 4.0);
        assert_eq!(percentile(&s, 50.0), 2.5);
    }

    #[test]
    fn bench_runs() {
        let b = Bencher { warmup: Duration::from_millis(1), measure: Duration::from_millis(20) };
        let mut acc = 0u64;
        let st = b.run("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(st.iters >= 10);
        assert!(st.mean_ns >= 0.0);
        assert!(st.p99_ns >= st.p50_ns);
    }

    #[test]
    fn stats_json_roundtrip() {
        let st = stats_from("case", &[Duration::from_millis(1), Duration::from_millis(3)]);
        let j = st.to_json();
        assert_eq!(j.req_str("name").unwrap(), "case");
        assert_eq!(j.req_i64("iters").unwrap(), 2);
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn append_run_rejects_non_finite_records() {
        let mut bad = Json::object();
        bad.set("throughput_rps", f64::NAN);
        let err = append_run("reject_test", &[], vec![bad]).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        let mut bad = Json::object();
        bad.set("nested", Json::Array(vec![Json::Float(f64::INFINITY)]));
        assert!(append_run("reject_test", &[("quick", Json::Bool(true))], vec![bad]).is_err());
        // nothing was written for the rejected runs
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_reject_test.json");
        assert!(!path.exists());
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1500.0).contains("µs"));
        assert!(fmt_ns(2_500_000.0).contains("ms"));
        assert!(fmt_ns(1.5e9).contains(" s"));
    }
}
